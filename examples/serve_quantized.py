"""Serve a quantized model with batched requests over the int8 KV cache.

Demonstrates the deployment path: slot-based continuous batching, prefill +
decode against the integer cache, plus a direct comparison of the Pallas
w4a8 kernel vs the fake-quant training path on one layer.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.core.calibration import mse_weight_scale
from repro.core.qat import export_linear_int, make_ctx, qlinear
from repro.kernels.w4a8.ops import w4a8_linear
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine

ARCH = "qwen2.5-3b"

cfg = get_reduced_config(ARCH)
params = init_params(cfg, jax.random.PRNGKey(0))

# --- batched serving over the int8 cache ---------------------------------
engine = ServeEngine(cfg, params, policy="A8d-C8-W4", slots=4, cache_len=96)
rng = np.random.default_rng(0)
for uid in range(12):
    engine.submit(Request(uid=uid,
                          prompt=rng.integers(0, cfg.vocab_size, 24,
                                              ).astype(np.int32),
                          max_new_tokens=12))
t0 = time.perf_counter()
stats = engine.run_until_drained()
dt = time.perf_counter() - t0
print(f"served 12 requests in {dt:.1f}s — {stats['tokens_out']} tokens, "
      f"{stats['tokens_out'] / dt:.1f} tok/s over the int8 KV cache")

# --- deployed w4a8 kernel vs fake-quant path ------------------------------
lin = params["segments"][0]["0"]["attn"]["wq"]
lin = jax.tree.map(lambda x: x[0], lin)            # unstack the scan axis
lin = dict(lin, s_w=mse_weight_scale(lin["w"], 4))
exported = export_linear_int(lin, 4)               # packed int4 + scales
x = jax.random.normal(jax.random.PRNGKey(2), (16, cfg.d_model),
                      jnp.bfloat16)
y_kernel = w4a8_linear(x, exported)                # Pallas int4xint8 matmul
y_fake = qlinear(make_ctx("A8d-C8-W4"), x, lin)    # training-time fake quant
err = float(jnp.mean(jnp.abs(y_kernel.astype(jnp.float32)
                             - y_fake.astype(jnp.float32))))
print(f"w4a8 kernel vs fake-quant training path: mean |err| = {err:.2e} "
      f"(expected: quantization noise floor)")
