"""Live serving dashboard from ``GET /v1/metrics`` (stdlib only).

Start a server first, e.g.::

    PYTHONPATH=src python -m repro.launch.serve \\
        --arch qwen2.5-3b --kv-layout paged --http-port 8000

then point this at it (drive load with ``examples/stream_client.py`` or
the streaming benchmark to see the numbers move)::

    python examples/scrape_metrics.py --port 8000 --interval 1.0

Each tick scrapes the Prometheus endpoint and prints one dashboard
line: decode rate derived from counter deltas between scrapes (how a
real Prometheus ``rate()`` works), resident/pending/swapped occupancy
gauges, pool fill, and p95 TTFT estimated from the cumulative histogram
buckets. ``--once`` prints the raw exposition text and exits (the
"is my scrape config right?" probe).

The endpoint speaks standard exposition format, so the same URL drops
into a real Prometheus scrape job unchanged; this script exists so you
can watch an engine without standing one up. Parsing lives in
``repro.obs.metrics.parse_prometheus`` — but since examples run without
``PYTHONPATH=src``, a local fallback parser keeps this file standalone.
"""
from __future__ import annotations

import argparse
import socket
import sys
import time

try:
    from repro.obs.metrics import parse_prometheus
except ImportError:                    # standalone: minimal local parser
    def parse_prometheus(text):
        out = {}
        for line in text.splitlines():
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            name, _, value = line.rpartition(" ")
            out[name] = float(value)
        return out


def scrape(host: str, port: int, timeout: float = 5.0) -> str:
    """One GET /v1/metrics over a raw socket; returns the body text."""
    with socket.create_connection((host, port), timeout=timeout) as sock:
        sock.sendall(f"GET /v1/metrics HTTP/1.1\r\nHost: {host}\r\n"
                     f"Connection: close\r\n\r\n".encode())
        raw = b""
        while chunk := sock.recv(1 << 16):
            raw += chunk
    header, _, body = raw.partition(b"\r\n\r\n")
    status = header.split(None, 2)[1]
    if status != b"200":
        raise RuntimeError(f"HTTP {status.decode()} from /v1/metrics")
    return body.decode()


def hist_p95(m: dict, name: str) -> float:
    """p95 upper bound from cumulative ``_bucket`` samples (the same
    estimate ``Histogram.quantile`` computes server-side)."""
    total = m.get(f"{name}_count", 0)
    if not total:
        return 0.0
    buckets = sorted(
        (float(k[k.index('le="') + 4:-2]), v) for k, v in m.items()
        if k.startswith(f'{name}_bucket') and '+Inf' not in k)
    for bound, cum in buckets:
        if cum >= 0.95 * total:
            return bound
    return buckets[-1][0] if buckets else 0.0


def dash_line(m: dict, prev: dict, dt: float) -> str:
    def rate(key):
        return (m.get(key, 0) - prev.get(key, 0)) / max(dt, 1e-9)

    return (f"{rate('serve_tokens_out_total'):7.1f} tok/s | "
            f"fin {int(m.get('serve_requests_finished_total', 0)):4d} "
            f"(+{rate('serve_requests_finished_total'):.1f}/s) | "
            f"res {int(m.get('serve_resident_requests', 0)):2d} "
            f"pend {int(m.get('serve_pending_requests', 0)):2d} "
            f"swap {int(m.get('serve_swapped_requests', 0)):2d} | "
            f"pool {100 * m.get('serve_pool_occupancy', 0.0):3.0f}% | "
            f"ttft p95 <= {1e3 * hist_p95(m, 'serve_ttft_seconds'):.0f} ms")


def main() -> int:
    ap = argparse.ArgumentParser(
        description="poll /v1/metrics and print a one-line dashboard")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--interval", type=float, default=1.0,
                    help="seconds between scrapes")
    ap.add_argument("--count", type=int, default=0,
                    help="stop after N ticks (0 = until interrupted)")
    ap.add_argument("--once", action="store_true",
                    help="print the raw exposition text and exit")
    args = ap.parse_args()

    if args.once:
        print(scrape(args.host, args.port), end="")
        return 0

    prev, prev_t, tick = {}, time.perf_counter(), 0
    while True:
        try:
            m = parse_prometheus(scrape(args.host, args.port))
        except (OSError, RuntimeError) as e:
            print(f"scrape failed: {e}", file=sys.stderr)
            return 1
        now = time.perf_counter()
        print(dash_line(m, prev, now - prev_t), flush=True)
        prev, prev_t, tick = m, now, tick + 1
        if args.count and tick >= args.count:
            return 0
        time.sleep(args.interval)


if __name__ == "__main__":
    sys.exit(main())
