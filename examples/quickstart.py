"""Quickstart: quantize a model with SiLQ in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.core.precision import parse_policy
from repro.core.qat import calibrate_weight_scales, make_ctx
from repro.models import forward, init_params

# 1. a model (any of the 10 registered architectures; reduced size for CPU)
cfg = get_reduced_config("qwen2.5-3b")
params = init_params(cfg, jax.random.PRNGKey(0))

# 2. pick the paper's deployment precision: 8-bit dynamic activations,
#    8-bit KV cache, 4-bit weights
policy = parse_policy("A8d-C8-W4")

# 3. calibrate weight step sizes with the convex-MSE rule (paper Eq. 2)
params = calibrate_weight_scales(params, policy, method="mse")

# 4. run the quantized model — same forward, quantizers active
ctx = make_ctx(policy)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                      cfg.vocab_size)}
logits_q, _ = forward(cfg, params, ctx, batch)

# compare against the unquantized model
logits_fp, _ = forward(cfg, params, make_ctx("A16-C16-W16", mode="off"),
                       batch)
agree = float(jnp.mean(jnp.argmax(logits_q, -1) == jnp.argmax(logits_fp, -1)))
print(f"quantized forward: {logits_q.shape}, "
      f"top-1 agreement with fp16: {agree:.1%}")
print("next: examples/qat_train.py trains the quantizers end-to-end with "
      "knowledge distillation (the SiLQ recipe)")
