"""Minimal streaming client for the serve HTTP endpoint (stdlib only).

Start a server first, e.g.::

    PYTHONPATH=src python -m repro.launch.serve \\
        --arch qwen2.5-3b --kv-layout paged --http-port 8000

then stream a completion (prompts are token-id lists — the repo serves
models, it does not ship a tokenizer)::

    python examples/stream_client.py --port 8000 \\
        --prompt 11 42 7 99 --max-tokens 16 --stream

or fetch the same thing non-streaming (one JSON body)::

    python examples/stream_client.py --port 8000 --prompt 11 42 7 99

The SSE wire format is one ``data: {json}`` line per drained token span
(``decode_block`` granularity), a final span carrying ``finish_reason``,
then ``data: [DONE]``. See ``docs/serving_api.md`` for the full
protocol.
"""
from __future__ import annotations

import argparse
import asyncio
import json


async def stream_completion(host: str, port: int, payload: dict) -> list:
    """POST /v1/completions with ``stream: true``; print each SSE span
    as it arrives and return the collected token ids."""
    reader, writer = await asyncio.open_connection(host, port)
    body = json.dumps(dict(payload, stream=True)).encode()
    writer.write(
        f"POST /v1/completions HTTP/1.1\r\nHost: {host}\r\n"
        f"Content-Type: application/json\r\n"
        f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await writer.drain()

    status = (await reader.readline()).decode().split()
    if status[1] != "200":
        raise RuntimeError(f"HTTP {status[1]}: {await reader.read()}")
    while (await reader.readline()) not in (b"\r\n", b"\n"):
        pass                                    # skip response headers

    tokens: list = []
    async for raw in reader:
        line = raw.decode().strip()
        if not line.startswith("data: "):
            continue
        data = line[len("data: "):]
        if data == "[DONE]":
            break
        chunk = json.loads(data)
        choice = chunk["choices"][0]
        tokens.extend(choice["token_ids"])
        print(f"  span={choice['token_ids']} "
              f"finish_reason={choice['finish_reason']}")
    writer.close()
    await writer.wait_closed()
    return tokens


async def blocking_completion(host: str, port: int, payload: dict) -> dict:
    """POST /v1/completions without streaming; return the parsed JSON."""
    reader, writer = await asyncio.open_connection(host, port)
    body = json.dumps(payload).encode()
    writer.write(
        f"POST /v1/completions HTTP/1.1\r\nHost: {host}\r\n"
        f"Content-Type: application/json\r\n"
        f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await writer.drain()
    raw = await reader.read()
    writer.close()
    await writer.wait_closed()
    header, _, payload_bytes = raw.partition(b"\r\n\r\n")
    status = header.split()[1].decode()
    out = json.loads(payload_bytes)
    if status != "200":
        raise RuntimeError(f"HTTP {status}: {out}")
    return out


async def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--prompt", type=int, nargs="+", required=True,
                    help="prompt as a list of int token ids")
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="first-token SLO; the server sheds/downgrades "
                         "when it predicts a miss (engine slo_shed mode)")
    ap.add_argument("--priority", type=int, default=None,
                    help="EDF priority class (lower = more urgent)")
    ap.add_argument("--stream", action="store_true",
                    help="use SSE streaming instead of one JSON response")
    args = ap.parse_args()

    payload = {"prompt": args.prompt, "max_tokens": args.max_tokens,
               "temperature": args.temperature, "top_k": args.top_k,
               "seed": args.seed}
    if args.deadline_ms is not None:
        payload["deadline_ms"] = args.deadline_ms
    if args.priority is not None:
        payload["priority"] = args.priority

    if args.stream:
        tokens = await stream_completion(args.host, args.port, payload)
        print(f"streamed {len(tokens)} tokens: {tokens}")
    else:
        out = await blocking_completion(args.host, args.port, payload)
        choice = out["choices"][0]
        print(f"finish_reason={choice['finish_reason']} "
              f"usage={out['usage']}")
        print(f"tokens: {choice['token_ids']}")


if __name__ == "__main__":
    asyncio.run(main())
