"""End-to-end SiLQ QAT driver (paper §3.1), CPU-scale.

Trains a ~small "original" fp16 model on the synthetic mixture, then runs
the full SiLQ recipe — convex-MSE weight calibration, percentile activation
calibration, LSQ step-size learning with the 50x activation-scale LR boost,
pure-KD loss from the fp16 teacher — and reports quantized quality
before/after QAT.

    PYTHONPATH=src python examples/qat_train.py --steps 300
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core.qat import make_ctx
from repro.data import MixtureIterator
from repro.launch.train import run_qat


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--precision", default="A8d-C8-W4")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--teacher-steps", type=int, default=300)
    args = ap.parse_args()

    tcfg = TrainConfig(precision=args.precision, total_steps=args.steps,
                       ref_steps=args.steps, batch_size=8, seq_len=64)
    teacher, student, _ = run_qat(args.arch, tcfg, reduced=True,
                                  teacher_steps=args.teacher_steps)

    from benchmarks.common import eval_quality
    from repro.configs import get_reduced_config
    cfg = get_reduced_config(args.arch)
    base = eval_quality(cfg, teacher, teacher, "A16-C16-W16")
    post = eval_quality(cfg, student, teacher, args.precision)
    print(f"\nfp16 baseline : loss={base['ntp_loss']:.4f}")
    print(f"SiLQ {args.precision}: loss={post['ntp_loss']:.4f} "
          f"teacher-agreement={post['teacher_agreement']:.1%}")


if __name__ == "__main__":
    main()
