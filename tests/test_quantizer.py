"""Unit + property tests for the SiLQ quantizers (core/quantizer.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.quantizer import (dequantize_int, dynamic_fake_quant,
                                  dynamic_quantize_to_int, lsq_fake_quant,
                                  pack_int4, qbounds, quantize_to_int,
                                  round_ste, unpack_int4)


def test_qbounds():
    assert qbounds(4) == (-8, 7)
    assert qbounds(8) == (-128, 127)
    assert qbounds(16) == (-32768, 32767)


def test_round_ste_gradient_is_identity():
    x = jnp.linspace(-3, 3, 31)
    g = jax.grad(lambda x: jnp.sum(round_ste(x) * 2))(x)
    np.testing.assert_allclose(g, 2.0 * np.ones_like(x))


class TestLSQ:
    def test_idempotent(self, rng):
        """Quantizing a quantized tensor is the identity."""
        x = jax.random.normal(rng, (64, 32))
        s = jnp.float32(0.1)
        y = lsq_fake_quant(x, s, 8)
        y2 = lsq_fake_quant(y, s, 8)
        np.testing.assert_allclose(y, y2, atol=1e-6)

    def test_error_bounded_by_half_step(self, rng):
        x = jax.random.normal(rng, (128,)) * 0.5
        s = jnp.float32(0.01)
        y = lsq_fake_quant(x, s, 16)    # wide range: no clipping
        assert float(jnp.max(jnp.abs(y - x))) <= 0.005 + 1e-6

    def test_clipping(self):
        x = jnp.array([100.0, -100.0])
        s = jnp.float32(1.0)
        y = lsq_fake_quant(x, s, 4)
        np.testing.assert_allclose(y, [7.0, -8.0])

    def test_grad_zero_outside_range(self):
        x = jnp.array([100.0, 0.5, -100.0])
        g = jax.grad(lambda x: jnp.sum(lsq_fake_quant(x, jnp.float32(1.0),
                                                      4)))(x)
        np.testing.assert_allclose(g, [0.0, 1.0, 0.0])

    def test_scale_gradient_signs(self):
        """LSQ: clipped-high values push ds positive via b_u term."""
        s = jnp.float32(1.0)
        ds_hi = jax.grad(lambda s: jnp.sum(lsq_fake_quant(
            jnp.array([100.0]), s, 4)), argnums=0)(s)
        assert float(ds_hi) > 0          # growing s recovers clipped mass
        ds_lo = jax.grad(lambda s: jnp.sum(lsq_fake_quant(
            jnp.array([-100.0]), s, 4)), argnums=0)(s)
        assert float(ds_lo) < 0

    def test_per_channel(self, rng):
        x = jax.random.normal(rng, (16, 8))
        s = jnp.abs(jax.random.normal(rng, (1, 8))) * 0.1 + 0.01
        y = lsq_fake_quant(x, s, 8)
        for c in range(8):
            yc = lsq_fake_quant(x[:, c], s[0, c], 8)
            np.testing.assert_allclose(y[:, c], yc, atol=1e-6)

    @given(bits=st.sampled_from([4, 8, 16]),
           scale=st.floats(1e-4, 10.0),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_output_on_grid(self, bits, scale, seed):
        """Property: outputs are exact integer multiples of s, in range."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 3
        s = jnp.float32(scale)
        y = np.asarray(lsq_fake_quant(x, s, bits), np.float64)
        q = y / scale
        qn, qp = qbounds(bits)
        tol = 1e-3 * np.maximum(1.0, np.abs(q))   # fp32 product round-off
        assert np.all(np.abs(q - np.round(q)) < tol)
        assert q.min() >= qn - 0.1 and q.max() <= qp + 0.1


class TestDynamic:
    @given(bits=st.sampled_from([4, 8]), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_error_bound(self, bits, seed):
        """Property: per-token error <= absmax/(2^{b-1}-1)/2 per token."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (4, 32))
        y = dynamic_fake_quant(x, bits)
        _, qp = qbounds(bits)
        bound = np.asarray(jnp.max(jnp.abs(x), -1)) / qp / 2 + 1e-6
        err = np.asarray(jnp.max(jnp.abs(y - x), -1))
        assert np.all(err <= bound)

    def test_scale_no_gradient(self, rng):
        """Dynamic scale is stop-gradiented; data grad is STE-identity."""
        x = jax.random.normal(rng, (4, 16))
        g = jax.grad(lambda x: jnp.sum(dynamic_fake_quant(x, 8)))(x)
        np.testing.assert_allclose(g, np.ones_like(g))


class TestIntConversion:
    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_pack_unpack_roundtrip(self, seed):
        q = jax.random.randint(jax.random.PRNGKey(seed), (6, 16), -8, 8,
                               jnp.int8)
        assert bool(jnp.all(unpack_int4(pack_int4(q)) == q))

    def test_quant_dequant_matches_fake_quant(self, rng):
        x = jax.random.normal(rng, (32, 16))
        s = jnp.float32(0.05)
        real = dequantize_int(quantize_to_int(x, s, 8), s, jnp.float32)
        fake = lsq_fake_quant(x, s, 8)
        np.testing.assert_allclose(real, fake, atol=1e-6)

    def test_dynamic_int_roundtrip(self, rng):
        x = jax.random.normal(rng, (8, 64))
        q, s = dynamic_quantize_to_int(x, 8)
        err = jnp.abs(q.astype(jnp.float32) * s - x)
        assert float(jnp.max(err)) <= float(jnp.max(s)) / 2 + 1e-6
