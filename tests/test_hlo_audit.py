"""Unit tests for the audit walkers in ``runtime/hlo_analysis`` and the
``repro.analysis`` rule set against crafted programs/HLO.

Covers the alias-table and entry-parameter parsers, host-transfer and
float-intermediate detection, the unknown-dtype flag-and-skip path, the
trip-count-recovery fallback (unrecoverable ``while`` condition →
multiplier 1 + flagged), and each rule's seeded-violation firing over
synthetic waves — no engine construction, so this file stays fast.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (CollectiveCensusRule, DequantPlacementRule,
                            DonationRule, HostTransferRule,
                            RetraceBudgetRule, audit_waves, default_rules)
from repro.runtime.hlo_analysis import (analyze_collectives, analyze_program,
                                        collective_sites, entry_parameters,
                                        float_intermediate_sites,
                                        host_transfer_sites,
                                        input_output_aliases)


def _compile(fn, *args, **jit_kw):
    return jax.jit(fn, **jit_kw).lower(*args).compile().as_text()


F32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)  # noqa: E731
S8 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int8)      # noqa: E731


class TestAliasWalkers:
    def test_donated_arg_appears_in_alias_table(self):
        hlo = _compile(lambda x, y: (x + y, x - y), F32(64, 64), F32(64, 64),
                       donate_argnums=(0,))
        aliases = input_output_aliases(hlo)
        assert any(a["param"] == 0 for a in aliases)

    def test_undonated_program_has_no_aliases(self):
        hlo = _compile(lambda x, y: x + y, F32(64, 64), F32(64, 64))
        assert input_output_aliases(hlo) == []

    def test_donated_pytree_aliases_every_leaf(self):
        state = {"a": F32(32, 32), "b": S8(64, 64)}
        hlo = _compile(lambda s: {"a": s["a"] * 2, "b": s["b"] + 1},
                       state, donate_argnums=(0,))
        assert len(input_output_aliases(hlo)) == 2

    def test_entry_parameters_report_bytes_and_names(self):
        hlo = _compile(lambda x, y: x + y, F32(128, 64), F32(128, 64))
        params = entry_parameters(hlo)
        assert [p["num"] for p in params] == [0, 1]
        assert all(p["dtype"] == "f32" for p in params)
        assert all(p["bytes"] == 128 * 64 * 4 for p in params)
        # jax records the argument path in op_name metadata
        assert params[0]["op_name"] == "x"


class TestHostTransferWalker:
    def test_io_callback_flagged(self):
        from jax.experimental import io_callback

        def f(x):
            io_callback(lambda v: None, None, x)
            return x * 2

        sites = host_transfer_sites(_compile(f, F32(8,)))
        assert sites and any("callback" in s["reason"] for s in sites)

    def test_pure_wave_clean(self):
        hlo = _compile(lambda x: jnp.tanh(x) @ x.T, F32(32, 32))
        assert host_transfer_sites(hlo) == []


class TestFloatIntermediates:
    def test_wholesale_dequant_found(self):
        def f(pool):
            return (pool.astype(jnp.bfloat16) * 2.0).sum()

        hlo = _compile(f, S8(256, 256))
        sites = float_intermediate_sites(hlo, 256 * 256)
        assert sites and sites[0]["elems"] >= 256 * 256
        assert sites[0]["dtype"] in ("bf16", "f32")

    def test_threshold_excludes_small(self):
        hlo = _compile(lambda p: (p.astype(jnp.bfloat16) * 2.0).sum(),
                       S8(16, 16))
        assert float_intermediate_sites(hlo, 1 << 20) == []


class TestUnknownDtypes:
    """Satellite: unknown dtype tokens flag-and-skip into an explicit
    ``unknown_dtypes`` field instead of silently undercounting."""

    def _fake(self):
        hlo = _compile(lambda x, y: x + y, F32(128, 64), F32(128, 64))
        return hlo.replace("f32[128,64]", "f8e4m3[128,64]")

    def test_analyze_program_flags(self):
        rep = analyze_program(self._fake())
        assert rep["unknown_dtypes"] == ["f8e4m3"]

    def test_analyze_collectives_field_present(self):
        rep = analyze_collectives(self._fake())
        assert "unknown_dtypes" in rep

    def test_collective_sites_per_site_flag(self):
        hlo = ("ENTRY %main (p0: f8e4m3[64]) -> f8e4m3[64] {\n"
               "  %p0 = f8e4m3[64]{0} parameter(0)\n"
               "  ROOT %ag = f8e4m3[64]{0} all-gather(%p0), dimensions={0}\n"
               "}\n")
        sites = collective_sites(hlo)
        assert len(sites) == 1
        assert sites[0]["unknown_dtypes"] == ["f8e4m3"]
        assert sites[0]["bytes"] == 0


class TestTripCountFallback:
    """Satellite: unrecoverable ``while`` condition → multiplier 1 and
    ``unresolved_loops`` flagged (previously untested)."""

    # condition reads a runtime-dependent bound: no s32[] constant(N)
    # anywhere in the condition computation, so recovery must fall back
    _HLO = """\
%cond (arg: (s32[], s32[], f32[8])) -> pred[] {
  %arg = (s32[], s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] get-tuple-element(%arg), index=1
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (barg: (s32[], s32[], f32[8])) -> (s32[], s32[], f32[8]) {
  %barg = (s32[], s32[], f32[8]) parameter(0)
  %bi = s32[] get-tuple-element(%barg), index=0
  %bn = s32[] get-tuple-element(%barg), index=1
  %bx = f32[8] get-tuple-element(%barg), index=2
  %ar = f32[8] all-reduce(%bx), to_apply=%add
  ROOT %t = (s32[], s32[], f32[8]) tuple(%bi, %bn, %ar)
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p: (s32[], s32[], f32[8])) -> (s32[], s32[], f32[8]) {
  %p = (s32[], s32[], f32[8]) parameter(0)
  ROOT %w = (s32[], s32[], f32[8]) while(%p), condition=%cond, body=%body
}
"""

    def test_unresolved_flagged_and_multiplier_one(self):
        rep = analyze_collectives(self._HLO)
        assert rep["unresolved_loops"] == 1
        # multiplier fell back to 1: the all-reduce counts its bytes once
        assert rep["total_bytes"] == 8 * 4
        assert rep["per_site"][0]["mult"] == 1.0

    def test_recoverable_loop_still_multiplies(self):
        hlo = self._HLO.replace(
            "%n = s32[] get-tuple-element(%arg), index=1",
            "%n = s32[] constant(7)")
        rep = analyze_collectives(hlo)
        assert rep["unresolved_loops"] == 0
        assert rep["total_bytes"] == 7 * 8 * 4


# --------------------------------------------------------------------------
# Seeded rule violations over synthetic waves (audit_waves pure core)
# --------------------------------------------------------------------------


def _wave(fn, *args, family="decode", label=None, donate=(), donated=None):
    hlo = _compile(fn, *args, donate_argnums=donate)
    return {"family": family, "label": label or family, "hlo": hlo,
            "donated": donated or []}


class TestSeededViolations:
    def test_undonated_wave_fires_donation_rule(self):
        # the wave *claims* a donated pool leaf, but the jit never donated
        # it — no alias table entry, so the rule must fire and name it
        nbytes = 256 * 256
        wave = _wave(lambda s: {"pool": s["pool"] + 1},
                     {"pool": S8(256, 256)},
                     donated=[{"path": "['pool']", "dtype": "int8",
                               "bytes": nbytes}])
        vs = DonationRule(min_bytes=1 << 10).check(wave, {})
        assert vs and "pool" in vs[0].sites[0]
        assert str(nbytes) in vs[0].summary

    def test_donated_wave_passes_donation_rule(self):
        nbytes = 256 * 256
        wave = _wave(lambda s: {"pool": s["pool"] + 1},
                     {"pool": S8(256, 256)}, donate=(0,),
                     donated=[{"path": "['pool']", "dtype": "int8",
                               "bytes": nbytes}])
        assert DonationRule(min_bytes=1 << 10).check(wave, {}) == []

    def test_host_callback_fires_host_transfer_rule(self):
        from jax.experimental import io_callback

        def f(x):
            io_callback(lambda v: None, None, x)
            return x * 2

        vs = HostTransferRule().check(_wave(f, F32(8,)), {})
        assert vs and "host" in vs[0].summary

    def test_full_pool_dequant_fires_dequant_rule(self):
        pool_elems = 256 * 256
        wave = _wave(lambda p: (p.astype(jnp.bfloat16) * 2.0).sum(),
                     S8(256, 256))
        vs = DequantPlacementRule(frac=0.5).check(
            wave, {"pool_elems": pool_elems})
        assert vs and "dequantized outside" in vs[0].summary

    def test_windowed_dequant_passes_dequant_rule(self):
        # dequantizing a 1/16 window of the pool is the sanctioned pattern
        wave = _wave(lambda p: (p[:16].astype(jnp.bfloat16) * 2.0).sum(),
                     S8(256, 256))
        assert DequantPlacementRule(frac=0.5).check(
            wave, {"pool_elems": 256 * 256}) == []

    def test_budget_overflow_fires_and_names_signature(self):
        ctx = {"variant_counts": {"decode": 3},
               "variant_signatures": {"decode": ["(a)", "(b)", "(c)"]},
               "budgets": {"decode": 2}}
        vs = RetraceBudgetRule().check_engine(ctx)
        assert vs and "decode" in vs[0].summary
        assert any("(c)" in s for s in vs[0].sites)
        ctx["variant_counts"]["decode"] = 2
        assert RetraceBudgetRule().check_engine(ctx) == []

    def test_s8_pool_gather_fires_census_rule(self):
        hlo = ("ENTRY %main (p0: s8[524288]) -> s8[1048576] {\n"
               "  %p0 = s8[524288]{0} parameter(0)\n"
               "  ROOT %ag = s8[1048576]{0} all-gather(%p0), dimensions={0}\n"
               "}\n")
        wave = {"family": "tail", "label": "tail", "hlo": hlo, "donated": []}
        vs = CollectiveCensusRule().check(wave, {"tp": 2})
        assert vs and "regathered" in vs[0].summary

    def test_tp1_wave_with_collective_fires(self):
        hlo = ("ENTRY %main (p0: f32[8]) -> f32[8] {\n"
               "  %p0 = f32[8]{0} parameter(0)\n"
               "  ROOT %ar = f32[8]{0} all-reduce(%p0), to_apply=%add\n"
               "}\n")
        wave = {"family": "decode", "label": "decode", "hlo": hlo,
                "donated": []}
        assert CollectiveCensusRule().check(wave, {"tp": 1})

    def test_tp2_decode_without_allreduce_fires(self):
        wave = _wave(lambda x: x * 2, F32(8,), family="decode")
        vs = CollectiveCensusRule().check(wave, {"tp": 2})
        assert vs and "no all-reduce" in vs[0].summary


class TestAuditReport:
    def test_matrix_and_json_roundtrip(self):
        from jax.experimental import io_callback

        def dirty(x):
            io_callback(lambda v: None, None, x)
            return x + 1

        waves = [_wave(lambda x: x + 1, F32(8,), family="decode",
                       label="clean"),
                 _wave(dirty, F32(8,), family="tail", label="dirty")]
        report = audit_waves(waves, default_rules(), {"tp": 1})
        assert not report.ok
        assert report.cells[("host-transfer", "clean")] == "ok"
        assert report.cells[("host-transfer", "dirty")] == "FAIL"
        txt = report.render()
        assert "FAIL" in txt and "clean" in txt
        js = report.to_json()
        assert js["ok"] is False
        assert js["matrix"]["host-transfer"]["dirty"] == "FAIL"
        assert js["violations"][0]["rule"] == "host-transfer"

    def test_clean_report_ok(self):
        waves = [_wave(lambda x: x + 1, F32(8,), label="w")]
        report = audit_waves(waves, default_rules(),
                             {"tp": 1, "budgets": {}, "variant_counts": {}})
        assert report.ok
        assert "clean" in report.render()
