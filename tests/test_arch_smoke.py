"""Per-architecture smoke tests: reduced config, forward + one train step on
CPU, asserting shapes and finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.configs.base import SHAPES, TrainConfig
from repro.core.qat import make_ctx
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import decode_step, forward, init_cache, init_params, \
    prefill
from repro.optim import adamw_init


def _batch(cfg, key, B=2, S=16, labels=True):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        b["positions"] = jnp.tile(jnp.arange(S + cfg.vision_tokens),
                                  (3, B, 1))
    if cfg.is_encdec:
        b["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if labels:
        b["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        b["loss_mask"] = jnp.ones((B, S), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch, rng):
        cfg = get_reduced_config(arch)
        params = init_params(cfg, rng)
        B, S = 2, 16
        batch = _batch(cfg, rng, B, S, labels=False)
        logits, aux = forward(cfg, params, make_ctx("A8d-C8-W4"), batch)
        S_out = S + (cfg.vision_tokens if cfg.family == "vlm" else 0)
        assert logits.shape == (B, S_out, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    @pytest.mark.slow          # full QAT train step across all 10 archs
    def test_train_step(self, arch, rng):
        cfg = get_reduced_config(arch)
        tcfg = TrainConfig(total_steps=10, ref_steps=10, batch_size=2,
                           seq_len=16)
        params = init_params(cfg, rng)
        opt = adamw_init(params)
        step = make_train_step(cfg, tcfg)
        batch = _batch(cfg, rng)
        new_params, new_opt, metrics = step(params, params, opt, batch,
                                            jnp.int32(0))
        assert bool(jnp.isfinite(metrics["loss"]))
        # params actually changed
        moved = jax.tree.leaves(jax.tree.map(
            lambda a, b: bool(jnp.any(a != b)), params, new_params))
        assert any(moved)

    def test_prefill_decode(self, arch, rng):
        cfg = get_reduced_config(arch)
        params = init_params(cfg, rng)
        ctx = make_ctx("A8d-C8-W4")
        B, S = 2, 16
        batch = _batch(cfg, rng, B, S, labels=False)
        logits, cache = prefill(cfg, params, ctx, batch, cache_budget=S + 8)
        assert logits.shape == (B, 1, cfg.vocab_size)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        l1, cache = decode_step(cfg, params, ctx, tok, cache)
        l2, cache = decode_step(cfg, params, ctx, tok, cache)
        assert l2.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(l2.astype(jnp.float32))))

    def test_full_config_exact_dims(self, arch):
        """The full (non-reduced) config carries the assigned dimensions."""
        cfg = get_config(arch)
        expected = {
            "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151_936),
            "qwen2-7b": (28, 3584, 28, 4, 18944, 152_064),
            "qwen3-14b": (40, 5120, 40, 8, 17408, 151_936),
            "qwen3-32b": (64, 5120, 64, 8, 25600, 151_936),
            "whisper-large-v3": (32, 1280, 20, 20, 5120, 51_866),
            "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163_840),
            "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32_000),
            "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256_000),
            "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151_936),
            "xlstm-125m": (12, 768, 4, 4, 0, 50_304),
        }[arch]
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == expected


def test_moe_routing_active():
    """MoE models actually route through multiple experts."""
    cfg = get_reduced_config("mixtral-8x7b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                          cfg.vocab_size)}
    _, aux = forward(cfg, params, make_ctx("A8d-C8-W4"), batch)
    assert float(aux["moe_aux"]) > 0.0


def test_swa_bounds_cache():
    """Sliding-window arch allocates a window-bounded decode cache."""
    cfg = get_reduced_config("mixtral-8x7b")
    ctx = make_ctx("A8d-C8-W4")
    cache = init_cache(cfg, ctx, 2, 1000)
    k_shape = cache["segments"][0]["0"]["self"]["k_q"].shape
    assert k_shape[3] == cfg.sliding_window     # ring-bounded, not 1000


def test_long_context_support_flags():
    assert not get_config("qwen3-32b").supports_long_context
    assert get_config("mixtral-8x7b").supports_long_context
    assert get_config("recurrentgemma-2b").supports_long_context
    assert get_config("xlstm-125m").supports_long_context
