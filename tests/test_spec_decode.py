"""Speculative decoding subsystem: draft -> verify-wave -> rollback.

The load-bearing properties:

* **Token parity** — exact-mode speculative output is identical to plain
  decode (greedy AND sampled, same per-slot PRNG keys) for ANY draft,
  including an adversarial one that is rejected every wave (maximal
  rollback), across prefix-shared (COW) blocks and preempt/swap-resume.
* **Rejection-sampling correctness** — the committed-token distribution
  equals the target's (unit-tested on synthetic p/q), and a self-draft
  with coupled keys reproduces plain decode exactly.
* **Rollback hygiene** — rejected-suffix blocks return to the pool
  (`BlockAllocator.trim`), conservation invariants hold after every
  drain, and `_written` mirrors the device counters.

Note on adversarial drafts: random-init models with tied embeddings
degenerate to echo-like argmaxes and flat logits, so *any* coupled-key
draft trivially matches the target. The sabotaged draft used here gets
an untied sharp random head (scaled 40x) whose proposals genuinely
diverge — acceptance collapses to ~0 and every wave exercises the
rollback path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.qat import init_linear
from repro.models import init_params
from repro.serve.engine import _PROBE_CACHE, Request, ServeEngine
from repro.serve.sampling import sample_tokens, token_probs
from repro.serve.spec import SpecConfig, accept_rejection, make_draft


@pytest.fixture(scope="module")
def served(rng):
    cfg = get_reduced_config("qwen2.5-3b")
    return cfg, init_params(cfg, rng)


def _req(uid, prompt, **kw):
    return Request(uid=uid, prompt=np.asarray(prompt, np.int32), **kw)


def _mixed_reqs(n=5, temperature=0.0, top_k=0, seed=3):
    rng = np.random.default_rng(7)
    return [_req(i, rng.integers(0, 250, int(rng.integers(6, 30))),
                 max_new_tokens=int(rng.integers(3, 14)),
                 temperature=temperature, top_k=top_k, seed=seed)
            for i in range(n)]


def _engine(served, spec, **kw):
    cfg, params = served
    kw.setdefault("slots", 4)
    kw.setdefault("cache_len", 64)
    kw.setdefault("kv_layout", "paged")
    kw.setdefault("block_size", 8)
    kw.setdefault("num_blocks", 64)
    kw.setdefault("max_seq_len", 96)
    kw.setdefault("decode_block", 4)
    return ServeEngine(cfg, params, spec=spec, **kw)


def _sabotage(eng, cfg, scale=40.0):
    """Give the draft an untied sharp random head: proposals diverge
    from the target and acceptance collapses (maximal rollback)."""
    eng.draft_cfg = eng.draft_cfg.replace(tie_embeddings=False)
    head = init_linear(jax.random.PRNGKey(123), cfg.d_model, cfg.vocab_size)
    eng.draft_params = {**eng.draft_params,
                        "head": {**head, "w": head["w"] * scale}}


def _run(eng, reqs, max_steps=50_000):
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained(max_steps=max_steps)
    assert all(r.done for r in reqs)
    assert eng.alloc.allocated_blocks == 0
    eng.alloc.check()
    return [r.generated for r in reqs], stats


class TestTokenParity:
    def test_greedy_parity_and_full_acceptance(self, served):
        g_plain, _ = _run(_engine(served, None), _mixed_reqs())
        g_spec, st = _run(_engine(served, SpecConfig(k=3, draft_layers=1)),
                          _mixed_reqs())
        assert g_spec == g_plain
        assert st["spec_drafted"] > 0
        assert st["spec_accept_rate"] == 1.0       # echo drafts all match
        assert st["tokens_out"] == sum(len(g) for g in g_spec)

    def test_sampled_exact_mode_parity(self, served):
        kw = dict(temperature=1.5, top_k=0)
        g_plain, _ = _run(_engine(served, None), _mixed_reqs(**kw))
        g_spec, _ = _run(_engine(served, SpecConfig(k=3, draft_layers=1)),
                         _mixed_reqs(**kw))
        assert g_spec == g_plain

    def test_adversarial_draft_parity_with_maximal_rollback(self, served):
        """A draft that is wrong every wave: acceptance ~0, every wave
        rolls back its whole suffix — and the output is still exactly
        plain decode (greedy and hot-sampled)."""
        cfg, _ = served
        for kw in (dict(), dict(temperature=1.5)):
            g_plain, _ = _run(_engine(served, None), _mixed_reqs(**kw))
            eng = _engine(served, SpecConfig(k=3, draft_layers=1))
            _sabotage(eng, cfg)
            g_spec, st = _run(eng, _mixed_reqs(**kw))
            assert g_spec == g_plain
            assert st["spec_accept_rate"] == 0.0
            assert st["spec_rolled_back"] == st["spec_drafted"] > 0

    def test_parity_with_shared_prefix_and_cow_mid_wave(self, served):
        """Prefix-hit followers share the warm chain's split block; the
        spec wave's writes COW it mid-run and tokens still match the
        spec-off engine."""
        def shared(n=4):
            rng = np.random.default_rng(3)
            prefix = rng.integers(0, 250, 40).astype(np.int32)
            return [_req(i, np.concatenate(
                        [prefix, ((np.arange(5) * (i + 3) + i)
                                  % 250).astype(np.int32)]),
                        max_new_tokens=7, temperature=1.2, seed=11)
                    for i in range(n)]

        def staged(spec):
            eng = _engine(served, spec, slots=6, block_size=16,
                          num_blocks=48)
            rs = shared()
            _run(eng, rs[:1])
            g, st = _run(eng, rs[1:])
            return [rs[0].generated] + g, st

        g_plain, _ = staged(None)
        g_spec, st = staged(SpecConfig(k=3, draft_layers=1))
        assert g_spec == g_plain
        assert st["cow_copies"] >= 3 and st["prefix_hit_tokens"] > 0

    def test_sampled_preempt_swap_resume_parity(self, served):
        """Tight pool + optimistic admission: spec residents get swapped
        out mid-stream (the draft cache is rebuilt from tokens on
        restore) and still produce the uninterrupted solo stream."""
        def mk(uid, plen, mn):
            r = _req(uid, (np.arange(plen) * 7 + uid) % 250,
                     max_new_tokens=mn)
            r.temperature, r.top_k, r.seed = 0.7, 8, 5
            return r

        solo_req = mk(9, 10, 30)
        solo = _engine(served, None, slots=1, num_blocks=32)
        _run(solo, [solo_req])
        eng = _engine(served, SpecConfig(k=3, draft_layers=1), num_blocks=8,
                      admission="optimistic", prefix_cache=False)
        reqs = [mk(0, 10, 30), mk(9, 10, 30), mk(2, 10, 30)]
        _, st = _run(eng, reqs)
        assert st["preemptions"] >= 1
        assert reqs[1].generated == solo_req.generated

    def test_eos_inside_window_stops_like_plain_decode(self, served):
        """An EOS landing mid-window truncates the commit at it, exactly
        where plain decode stops."""
        base = _mixed_reqs(n=3, temperature=1.5)
        g_plain, _ = _run(_engine(served, None), base)
        eos = g_plain[0][min(2, len(g_plain[0]) - 1)]
        def with_eos():
            rs = _mixed_reqs(n=3, temperature=1.5)
            for r in rs:
                r.eos_id = int(eos)
            return rs
        ge_plain, _ = _run(_engine(served, None), with_eos())
        ge_spec, _ = _run(_engine(served, SpecConfig(k=4, draft_layers=1)),
                          with_eos())
        assert ge_spec == ge_plain
        assert any(len(a) < len(b) for a, b in zip(ge_plain, g_plain))


class TestRejectionSampling:
    def test_self_draft_rejection_reproduces_plain_decode(self, served):
        """Self-draft + coupled keys: p == q, every proposal survives the
        rejection test, and the sampled stream equals plain decode."""
        cfg, _ = served
        kw = dict(temperature=1.2, top_k=8)
        g_plain, _ = _run(_engine(served, None), _mixed_reqs(**kw))
        spec = SpecConfig(k=3, draft_layers=cfg.n_layers,
                          accept_mode="rejection")
        g_spec, st = _run(_engine(served, spec), _mixed_reqs(**kw))
        assert g_spec == g_plain
        assert st["spec_accept_mode"] == "rejection"

    def test_rejection_preserves_target_distribution(self):
        """The acceptance math itself, on synthetic p/q over a tiny
        vocab: the committed-token distribution at the first position
        matches sampling from p directly (total variation < 2%)."""
        V, N = 8, 20_000
        rng = np.random.default_rng(0)
        p_row = rng.dirichlet(np.ones(V)).astype(np.float32)
        q_row = rng.dirichlet(np.ones(V)).astype(np.float32)
        keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(N, dtype=jnp.uint32))
        n_gen = jnp.zeros((N,), jnp.int32)
        n_draft = jnp.full((N,), 1, jnp.int32)
        # draft proposes from q with the coupled step key; the target's
        # own sample (bonus path) comes from p with the same key
        from repro.serve.sampling import fold_step
        step0 = fold_step(keys, n_gen)
        draft = jax.vmap(lambda kk: jax.random.categorical(
            kk, jnp.log(q_row)))(step0).astype(jnp.int32)[:, None]
        target = jax.vmap(lambda kk: jax.random.categorical(
            kk, jnp.log(p_row)))(step0).astype(jnp.int32)[:, None]
        target = jnp.concatenate([target, target], axis=1)   # (N, k+1=2)
        q = jnp.broadcast_to(q_row, (N, 1, V))
        p = jnp.broadcast_to(p_row, (N, 2, V))
        n_acc, committed = jax.jit(accept_rejection)(
            draft, q, p, target, keys, n_gen, n_draft)
        first = np.asarray(committed[:, 0])
        emp = np.bincount(first, minlength=V) / N
        tv = 0.5 * np.abs(emp - p_row).sum()
        assert tv < 0.02, f"total variation {tv:.3f} vs target p"
        acc = float(np.mean(np.asarray(n_acc) > 0))
        expected_acc = np.minimum(p_row, q_row).sum()
        assert abs(acc - expected_acc) < 0.02


class TestRollbackAccounting:
    def test_written_and_trim_track_accepted_extent(self, served):
        """After every spec step, `_written` equals the device counters
        and the slot owns exactly the blocks covering it (the wave's
        over-allocation was trimmed)."""
        cfg, _ = served
        eng = _engine(served, SpecConfig(k=3, draft_layers=1))
        _sabotage(eng, cfg)             # rejections -> real rollback
        reqs = _mixed_reqs(n=3, temperature=1.5)
        for r in reqs:
            eng.submit(r)
        for _ in range(60):
            eng.step()
            n_gen = jax.device_get(eng.state["n_gen"])
            pos = jax.device_get(eng.state["cache"]["position"])
            for s, r in eng._slot_req.items():
                w = len(r.prompt) + int(n_gen[s]) - 1
                assert eng._written[s] == w == int(pos[s])
                assert len(eng.alloc.owned(s)) == \
                    eng.alloc.blocks_for_tokens(w)
            eng.alloc.check()
            if all(r.done for r in reqs):
                break
        assert all(r.done for r in reqs)

    def test_finished_at_admission_residents_drain_and_do_not_skew_stats(
            self, served):
        """Requests that finish at prefill (max_new == 1) never enter a
        wave: they must still be harvested (no hang when NO slot has
        draft budget), and must not count as drafted/rolled-back or
        subtract from the accepted total."""
        eng = _engine(served, SpecConfig(k=3, draft_layers=1))
        one = [_req(i, np.arange(6, dtype=np.int32) + i, max_new_tokens=1)
               for i in range(3)]
        g, st = _run(eng, one, max_steps=200)
        assert [len(x) for x in g] == [1, 1, 1]
        assert st["spec_drafted"] == st["spec_accepted"] == 0
        # mixing a max_new=1 request into a normal workload leaves the
        # accept rate of the real waves untouched
        eng2 = _engine(served, SpecConfig(k=3, draft_layers=1))
        reqs = _mixed_reqs(n=3) + [_req(9, np.arange(5, dtype=np.int32),
                                        max_new_tokens=1)]
        _, st2 = _run(eng2, reqs, max_steps=500)
        assert st2["spec_accept_rate"] == 1.0

    def test_stats_counters_consistent(self, served):
        g, st = _run(_engine(served, SpecConfig(k=3, draft_layers=1)),
                     _mixed_reqs())
        assert st["spec_drafted"] == st["spec_accepted"] \
            + st["spec_rolled_back"]
        assert st["spec_waves"] > 0
        assert st["spec_k"] == 3 and st["spec_draft_layers"] == 1
        assert st["decode_block_mode"] == "spec"
        # every committed token is counted exactly once
        assert st["tokens_out"] == sum(len(x) for x in g)


class TestDraftConstruction:
    def test_make_draft_shares_embeddings_and_slices_layers(self, served):
        cfg, params = served
        dcfg, dparams = make_draft(cfg, params, SpecConfig(draft_layers=1))
        assert dcfg.n_layers == 1
        assert dparams["embed"] is params["embed"]          # shared HBM
        assert dparams["head"] is params["head"]
        lp = jax.tree.leaves(dparams["segments"][0])
        lt = jax.tree.leaves(params["segments"][0])
        assert all(a.shape[0] == 1 for a in lp)
        assert all(np.array_equal(a, b[:1]) for a, b in zip(lp, lt))

    def test_self_draft_is_the_target_verbatim(self, served):
        cfg, params = served
        dcfg, dparams = make_draft(
            cfg, params, SpecConfig(draft_layers=cfg.n_layers))
        assert dcfg is cfg and dparams is params

    def test_spec_requires_paged_layout(self, served):
        cfg, params = served
        with pytest.raises(ValueError, match="paged"):
            ServeEngine(cfg, params, slots=2, cache_len=64,
                        spec=SpecConfig(k=2))

    def test_invalid_spec_config(self):
        with pytest.raises(ValueError, match="k must be"):
            SpecConfig(k=0)
        with pytest.raises(ValueError, match="accept_mode"):
            SpecConfig(accept_mode="maybe")


class TestProbeAndScheduling:
    def test_auto_probe_skipped_when_spec_enabled(self, served):
        """decode_block='auto' with spec on: the spec loop owns step
        granularity — no probe runs, no probe-cache entry is written,
        and stats() reports the mode."""
        before = dict(_PROBE_CACHE)
        eng = _engine(served, SpecConfig(k=5, draft_layers=1),
                      decode_block="auto")
        assert _PROBE_CACHE == before           # nothing probed/cached
        assert eng.decode_block == 6            # k + 1 per wave
        assert eng.stats()["decode_block_mode"] == "spec"

    def test_cross_wave_dedup_same_step_identical_prompts(self, served):
        """Two identical prompts admitted in the same engine step: the
        in-batch dedup keeps the second OUT of the first's cold wave, so
        it prefix-hits the freshly registered blocks (admission loop
        re-examines it the moment the first registers) and prefills only
        the uncached tail instead of recomputing the shared content."""
        rng = np.random.default_rng(5)
        prompt = rng.integers(0, 250, 40).astype(np.int32)
        solo = _engine(served, None, slots=2, block_size=16, num_blocks=32)
        ra = _req(0, prompt, max_new_tokens=6)
        _run(solo, [ra])
        eng = _engine(served, None, slots=2, block_size=16, num_blocks=32)
        r1 = _req(1, prompt, max_new_tokens=6)
        r2 = _req(2, prompt, max_new_tokens=6)
        eng.submit(r1)
        eng.submit(r2)
        st = eng.run_until_drained()
        assert r1.done and r2.done
        # r2 reused r1's chain: only r1's 40 prompt tokens plus r2's
        # 1-token uncached tail were ever prefilled (not 80)
        assert st["prefix_hit_tokens"] == 39
        assert st["prompt_tokens_prefilled"] == 41
        assert r1.generated == r2.generated == ra.generated
        assert eng.alloc.allocated_blocks == 0
        eng.alloc.check()

    def test_dedup_holds_follower_of_inflight_chunked_prefill(self, served):
        """Two identical LONG prompts: the first admits as a chunked tail
        job; the second is held while the job is in flight (instead of
        chunk-prefilling the same windows concurrently) and maps the
        registered chain once available."""
        rng = np.random.default_rng(6)
        prompt = rng.integers(0, 250, 60).astype(np.int32)
        eng = _engine(served, None, slots=3, block_size=16, num_blocks=32,
                      prefill_chunk=16, max_seq_len=96)
        r1 = _req(1, prompt, max_new_tokens=5)
        r2 = _req(2, prompt, max_new_tokens=5)
        eng.submit(r1)
        eng.submit(r2)
        eng.step()                          # r1 -> tail job; r2 held
        assert len(eng._tail_jobs) == 1
        assert eng.scheduler.pending == 1   # r2 still queued
        st = eng.run_until_drained()
        assert r1.done and r2.done
        assert st["prefix_hit_tokens"] > 0
        assert r1.generated == r2.generated
        # the shared content was computed once: well under 2x the prompt
        assert st["prompt_tokens_prefilled"] < 2 * len(prompt)

    def test_held_follower_does_not_block_strangers(self, served):
        """The dedup hold applies to the held request only: unrelated
        work behind it in FCFS order still admits the same step."""
        rng = np.random.default_rng(8)
        prompt = rng.integers(0, 250, 60).astype(np.int32)
        eng = _engine(served, None, slots=3, block_size=16, num_blocks=48,
                      prefill_chunk=16, max_seq_len=96)
        r1 = _req(1, prompt, max_new_tokens=5)
        r2 = _req(2, prompt, max_new_tokens=5)
        stranger = _req(3, rng.integers(0, 250, 12), max_new_tokens=20)
        for r in (r1, r2, stranger):
            eng.submit(r)
        eng.step()          # r1 -> tail job, r2 held, stranger admits
        assert any(r is stranger for r in eng._slot_req.values())
        assert eng.scheduler.pending == 1       # only r2 still queued
        eng.run_until_drained()
        assert r1.done and r2.done and stranger.done
        assert r1.generated == r2.generated