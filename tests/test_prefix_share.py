"""Prefix-shared paged KV cache + preemption/swap-out.

Covers the refcounted allocator's prefix index (rolling-hash chain, split
blocks, copy-on-write, LRU eviction), token parity with prefix sharing on
vs off (including COW at the split block), multi-turn reuse of decoded
blocks, optimistic admission with scheduler-driven preemption (swap-out
mid-decode resumes bit-exactly), the submit-time block-table feasibility
check, and the memoized decode_block="auto" probe.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import init_params
from repro.serve.block_alloc import BlockAllocator, PoolDry
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import Scheduler


def _req(uid, prompt, **kw):
    return Request(uid=uid, prompt=np.asarray(prompt, np.int32), **kw)


@pytest.fixture(scope="module")
def served(rng):
    cfg = get_reduced_config("qwen2.5-3b")
    return cfg, init_params(cfg, rng)


class TestPrefixIndex:
    def _alloc(self, **kw):
        kw.setdefault("num_blocks", 16)
        kw.setdefault("block_size", 4)
        kw.setdefault("slots", 4)
        kw.setdefault("table_len", 8)
        return BlockAllocator(**kw)

    def test_full_chain_lookup_caps_below_prompt_end(self):
        a = self._alloc()
        toks = np.arange(12, dtype=np.int32)
        a.register(0)
        a.ensure(0, 12)
        a.register_prefix(0, toks, 12)
        a.release(0)
        # identical prompt: the last full block is NOT taken (at least one
        # tail token must be left to recompute), the split rule can't help
        # because block 3 of the chain holds tokens the index never saw
        ids, cached, partial = a.lookup(toks)
        assert cached == 8 and len(ids) == 2 and not partial
        # longer prompt sharing the full 12: all three blocks hit
        ids, cached, partial = a.lookup(np.arange(20, dtype=np.int32))
        assert cached == 12 and len(ids) == 3 and not partial

    def test_split_block_matches_exact_divergence_point(self):
        a = self._alloc()
        a.register(0)
        a.ensure(0, 7)                     # 1 full + 3-token split block
        a.register_prefix(0, np.arange(7, dtype=np.int32), 7)
        a.release(0)
        other = np.array([0, 1, 2, 3, 4, 9, 9, 9], np.int32)  # diverges at 5
        ids, cached, partial = a.lookup(other)
        assert cached == 5 and partial     # 4 full + 1 shared split token
        miss = np.array([0, 1, 2, 3, 9, 9, 9], np.int32)      # diverges at 4
        ids, cached, partial = a.lookup(miss)
        assert cached == 4 and not partial

    def test_shared_map_refcounts_and_release_to_lru(self):
        a = self._alloc(num_blocks=4)
        toks = np.arange(8, dtype=np.int32)
        a.register(0)
        a.ensure(0, 8)
        a.register_prefix(0, toks, 8)
        assert a.release(0) == 2
        assert a.cached_blocks == 2 and a.allocated_blocks == 0
        ids, cached, _ = a.lookup(np.arange(12, dtype=np.int32))
        assert a.reserve(1, 12, shared=ids)
        assert a.allocated_blocks == 2     # resurrected from the LRU
        assert a.cached_blocks == 0
        a.check()

    def test_eviction_frees_index_entries_under_pressure(self):
        a = self._alloc(num_blocks=4)
        for i, slot in enumerate((0, 1)):
            toks = np.arange(8, dtype=np.int32) + 100 * i
            a.register(slot)
            a.ensure(slot, 8)
            a.register_prefix(slot, toks, 8)
            a.release(slot)
        assert a.cached_blocks == 4
        a.register(2)
        a.ensure(2, 12)                    # must evict 3 LRU blocks
        assert a.prefix_evictions == 3
        # the evicted (oldest) chain is gone, the newer one partially lives
        assert a.lookup(np.arange(12, dtype=np.int32))[1] == 0
        a.check()

    def test_cow_on_frozen_split_block_preserves_index_content(self):
        """A non-owner writing below a registered extent must copy: the
        index keeps addressing the original bytes."""
        a = self._alloc()
        a.register(0)
        a.ensure(0, 7)
        a.register_prefix(0, np.arange(7, dtype=np.int32), 7)
        a.release(0)
        probe = np.array([0, 1, 2, 3, 4, 9, 9], np.int32)
        ids, cached, partial = a.lookup(probe)
        assert cached == 5 and partial
        a.register(1, shared=ids)
        split = ids[-1]
        pairs = a.cow_range(1, 5, 7)       # writes offsets 1.. of the split
        assert pairs and pairs[0][0] == split
        assert a.owned(1)[-1] == pairs[0][1]
        # original stays resurrectable with its full 3-token extent
        ids2, cached2, _ = a.lookup(np.arange(7, dtype=np.int32))
        assert split in ids2 and cached2 == 6
        a.check()

    def test_slot_id_reuse_does_not_inherit_write_privilege(self):
        """Ownership dies with the filling slot: a new request admitted
        into a recycled slot id must COW a still-shared split block, not
        write into it in place (slot 1 keeps mapping it)."""
        a = self._alloc()
        a.register(2)
        a.ensure(2, 7)
        a.register_prefix(2, np.arange(7, dtype=np.int32), 7)
        probe = np.array([0, 1, 2, 3, 4, 9, 9], np.int32)
        ids, cached, partial = a.lookup(probe)
        a.register(1, shared=ids)          # sharer keeps the block alive
        split = ids[-1]
        a.release(2)                       # owner leaves, ref stays 1
        ids2, cached2, _ = a.lookup(probe)
        assert split in ids2
        a.register(2, shared=ids2)         # same slot id, new request
        pairs = a.cow_range(2, cached2, 7)
        assert [s for s, _ in pairs] == [split]
        a.check()

    def test_owner_appends_beyond_extent_without_copy(self):
        a = self._alloc()
        a.register(0)
        a.ensure(0, 6)
        a.register_prefix(0, np.arange(6, dtype=np.int32), 6)
        # the filling slot keeps writing past the registered 2-token extent
        assert a.cow_range(0, 6, 8) == []
        a.check()

    def test_reserve_accounts_for_resurrected_shared_hits(self):
        """Shared hits sitting on the evictable LRU leave the obtainable
        pool when mapped: a reservation that would rely on those same
        blocks must refuse up front, not crash later in ensure()."""
        a = self._alloc(num_blocks=4)
        toks = np.arange(8, dtype=np.int32)
        a.register(0)
        a.ensure(0, 8)
        a.register_prefix(0, toks, 8)
        a.release(0)                       # 2 registered blocks -> LRU
        assert a.reserve(1, 8)             # resident takes the other 2
        a.ensure(1, 8)
        ids, cached, partial = a.lookup(np.arange(16, dtype=np.int32))
        assert len(ids) == 2
        assert not a.reserve(2, 16, shared=ids, partial=partial)
        a.check()

    def test_harvest_extends_split_block_and_walks_past_it(self):
        """Admission registers a split block; a later pass over the
        decoded stream extends its stored content and promotes it with a
        full entry once filled, so the chain stays walkable past it."""
        a = self._alloc()
        prompt = np.arange(6, dtype=np.int32)
        a.register(0)
        a.ensure(0, 6)
        a.register_prefix(0, prompt, 6)            # split extent 2
        full = np.arange(11, dtype=np.int32)       # prompt + 5 decoded
        a.ensure(0, 11)
        a.register_prefix(0, full, 11)             # harvest-style pass
        a.release(0)
        ids, cached, partial = a.lookup(np.arange(12, dtype=np.int32))
        assert cached == 11 and partial            # 2 full + 3-token split
        # the original divergence point still matches via stored content
        probe = np.array([0, 1, 2, 3, 4, 9, 9], np.int32)
        assert a.lookup(probe)[1] == 5
        a.check()

    def test_pool_dry_raises_for_unreserved_slot(self):
        a = self._alloc(num_blocks=2)
        a.register(0)
        a.ensure(0, 8)
        a.register(1)
        with pytest.raises(PoolDry):
            a.ensure(1, 4)
        a.check()


class TestPrefixSharingEngine:
    BS = 16

    def _engine(self, served, **kw):
        cfg, params = served
        kw.setdefault("slots", 4)
        kw.setdefault("cache_len", 64)
        kw.setdefault("kv_layout", "paged")
        kw.setdefault("block_size", self.BS)
        kw.setdefault("num_blocks", 32)
        kw.setdefault("max_seq_len", 96)
        return ServeEngine(cfg, params, **kw)

    def _shared_reqs(self, n=3, prefix_len=40, tail=5, max_new=6):
        rng = np.random.default_rng(3)
        prefix = rng.integers(0, 250, prefix_len).astype(np.int32)
        return [_req(i, np.concatenate(
                    [prefix, ((np.arange(tail) * (i + 3) + i) % 250)
                     .astype(np.int32)]), max_new_tokens=max_new)
                for i in range(n)]

    def _run_staged(self, eng, reqs):
        """First request warms the prefix cache, the rest follow."""
        eng.submit(reqs[0])
        eng.run_until_drained()
        for r in reqs[1:]:
            eng.submit(r)
        return eng.run_until_drained()

    def test_token_parity_prefix_sharing_on_vs_off(self, served):
        """Greedy outputs of a shared-prefix batch are identical with
        sharing on vs off — including requests that COW the split block
        (the 40-token prefix ends 8 tokens into a block)."""
        reqs_on = self._shared_reqs()
        reqs_off = self._shared_reqs()
        on = self._run_staged(self._engine(served, prefix_cache=True),
                              reqs_on)
        off = self._run_staged(self._engine(served, prefix_cache=False),
                               reqs_off)
        assert all(r.done for r in reqs_on + reqs_off)
        assert [r.generated for r in reqs_on] == \
            [r.generated for r in reqs_off]
        # the 2 followers each found >= the 32-token full-block chain
        assert on["prefix_hit_tokens"] >= 64
        assert on["cow_copies"] >= 2          # split block cloned per fork
        assert off["prefix_hit_tokens"] == 0 and off["cow_copies"] == 0
        # the whole point: followers prefilled only their tails
        assert on["prompt_tokens_prefilled"] < \
            off["prompt_tokens_prefilled"] - 2 * self.BS

    def test_cow_protects_original_for_reissued_prompt(self, served):
        """After divergent followers wrote 'their' copies of the split
        block, re-issuing the original prompt must still reproduce the
        unshared output — the regression COW-on-frozen-extent guards."""
        reqs = self._shared_reqs(n=3)
        eng = self._engine(served, prefix_cache=True)
        self._run_staged(eng, reqs)
        reissue = _req(9, reqs[0].prompt, max_new_tokens=6)
        eng.submit(reissue)
        eng.run_until_drained()
        assert reissue.generated == reqs[0].generated

    def test_multi_turn_continuation_reuses_decoded_blocks(self, served):
        """Harvest registers prompt+completion content: a follow-up prompt
        extending the finished conversation hits blocks written by
        *decode*, and still matches the unshared engine's tokens."""
        rng = np.random.default_rng(5)
        turn1 = rng.integers(0, 250, 20).astype(np.int32)

        def run(prefix_cache):
            eng = self._engine(served, prefix_cache=prefix_cache)
            r1 = _req(0, turn1, max_new_tokens=8)
            eng.submit(r1)
            eng.run_until_drained()
            turn2 = np.concatenate(
                [turn1, np.asarray(r1.generated, np.int32),
                 rng.integers(0, 250, 4).astype(np.int32)])
            r2 = _req(1, turn2, max_new_tokens=5)
            eng.submit(r2)
            stats = eng.run_until_drained()
            return r1.generated, r2.generated, stats

        g1_on, g2_on, on = run(True)
        rng = np.random.default_rng(5)
        turn1 = rng.integers(0, 250, 20).astype(np.int32)
        g1_off, g2_off, _ = run(False)
        assert (g1_on, g2_on) == (g1_off, g2_off)
        # turn 2 reused more than turn 1's whole prompt: content written
        # by decode (the split block's extended extent) hit too
        assert on["prefix_hit_tokens"] > 20

    def test_wave_admissions_register_and_later_waves_hit(self, served):
        """Admitted requests register their prompts and same-chain
        followers prefill only tails. Cross-wave dedup (PR 5) keeps the
        second request of the FIRST pair out of the cold wave too: it
        prefix-hits the first's freshly registered blocks in the same
        engine step instead of recomputing the shared 34 tokens."""
        def reqs(uid0):
            return [_req(uid0 + i,
                         np.concatenate([np.arange(34, dtype=np.int32),
                                         np.asarray([i, i + 1], np.int32)]),
                         max_new_tokens=4) for i in range(2)]

        eng = self._engine(served, prefix_cache=True)
        for r in reqs(0):
            eng.submit(r)
        eng.run_until_drained()
        # dedup: the second request hit the first's 32 full-block tokens
        # (cold would have been 0 hits, 72 prompt tokens prefilled)
        assert eng.stats()["prefix_hit_tokens"] >= 32
        assert eng.stats()["prompt_tokens_prefilled"] <= 36 + 4
        wave2 = reqs(10)
        for r in wave2:
            eng.submit(r)
        stats = eng.run_until_drained()
        assert all(r.done for r in wave2)
        # cumulative: wave-1's dedup hit (34) + both wave-2 prompts
        # hitting their full cached extent (35 each, capped at plen - 1)
        assert stats["prefix_hit_tokens"] >= 100


class TestPreemption:
    def _mk(self, uid, plen, mn):
        return _req(uid, (np.arange(plen) * 7 + uid) % 250,
                    max_new_tokens=mn)

    def _opt_engine(self, served, **kw):
        cfg, params = served
        kw.setdefault("slots", 4)
        kw.setdefault("cache_len", 64)
        kw.setdefault("kv_layout", "paged")
        kw.setdefault("block_size", 8)
        kw.setdefault("max_seq_len", 96)
        kw.setdefault("admission", "optimistic")
        kw.setdefault("prefix_cache", False)
        kw.setdefault("decode_block", 4)
        return ServeEngine(cfg, params, **kw)

    def test_pick_victim_policies(self):
        cands = [(0, 5, 40), (1, 9, 10), (2, 2, 80)]
        assert Scheduler.pick_victim(cands, "last_admitted") == 1
        assert Scheduler.pick_victim(cands, "longest_remaining") == 2
        assert Scheduler.pick_victim([], "last_admitted") is None
        with pytest.raises(ValueError, match="preemption"):
            Scheduler.pick_victim(cands, "coin_flip")

    def test_swap_out_mid_decode_resumes_exact_tokens(self, served):
        """Over-committed optimistic pool: decode growth preempts a
        victim whose blocks swap to the host; after restore its greedy
        stream is identical to an uninterrupted run."""
        cfg, params = served
        solo_req = self._mk(9, 10, 30)
        solo = ServeEngine(cfg, params, slots=1, cache_len=64,
                           kv_layout="paged", block_size=8, num_blocks=32,
                           max_seq_len=96, decode_block=4)
        solo.submit(solo_req)
        solo.run_until_drained()
        # 8-block pool; three requests each ultimately need 5 blocks
        eng = self._opt_engine(served, num_blocks=8)
        reqs = [self._mk(0, 10, 30), self._mk(9, 10, 30),
                self._mk(2, 10, 30)]
        for r in reqs:
            eng.submit(r)
        stats = eng.run_until_drained(max_steps=50_000)
        assert all(r.done for r in reqs)
        assert [len(r.generated) for r in reqs] == [30, 30, 30]
        assert stats["preemptions"] >= 1
        assert stats["swap_out_bytes"] == stats["swap_in_bytes"] > 0
        assert reqs[1].generated == solo_req.generated
        # conservation after the churn
        assert eng.alloc.allocated_blocks == 0
        assert (eng.alloc.tables == eng.num_blocks).all()

    def test_optimistic_admits_more_residents_than_reserve(self, served):
        """The concurrency win: with prompt-footprint admission the same
        pool holds more co-residents than worst-case reservation."""
        def run(admission):
            eng = self._opt_engine(served, num_blocks=10,
                                   admission=admission)
            reqs = [self._mk(i, 8, 24) for i in range(4)]
            for r in reqs:
                eng.submit(r)
            stats = eng.run_until_drained(max_steps=50_000)
            assert all(r.done for r in reqs)
            return stats

        res = run("reserve")
        opt = run("optimistic")
        assert opt["max_residents"] > res["max_residents"]
        assert res["preemptions"] == 0

    def test_preempted_chunk_job_resumes(self, served):
        """A long prompt mid-chunked-prefill can itself be swapped out
        (no other victim) and restores from its last finished window."""
        eng = self._opt_engine(served, slots=2, num_blocks=8,
                               prefill_chunk=16, max_seq_len=96)
        long_req = self._mk(0, 60, 4)          # 8 blocks for prompt alone
        rival = self._mk(1, 8, 30)
        eng.submit(long_req)
        eng.submit(rival)
        stats = eng.run_until_drained(max_steps=50_000)
        assert long_req.done and rival.done
        assert len(long_req.generated) == 4 and len(rival.generated) == 30
        assert stats["preemptions"] >= 1

    @pytest.mark.slow
    def test_preemption_thrash_stress(self, served):
        """Sustained over-commit: a dozen decode-heavy requests on a pool
        a fraction of their aggregate need, with sharing enabled. Every
        request drains with its exact budget, blocks conserve, and the
        engine actually preempted (no silent fallback to reservation)."""
        eng = self._opt_engine(served, slots=6, num_blocks=16,
                               prefix_cache=True, max_seq_len=96)
        rng = np.random.default_rng(11)
        reqs = []
        for i in range(12):
            plen = int(rng.integers(4, 30))
            reqs.append(self._mk(i, plen, int(rng.integers(8, 28))))
        for r in reqs:
            eng.submit(r)
        stats = eng.run_until_drained(max_steps=200_000)
        assert all(r.done for r in reqs)
        assert [len(r.generated) for r in reqs] == \
            [r.max_new_tokens for r in reqs]
        assert stats["preemptions"] >= 1
        assert eng.alloc.allocated_blocks == 0
        assert eng.alloc.free_blocks == eng.num_blocks
        eng.alloc.check()


class TestFeasibilityAndProbe:
    def test_submit_rejects_block_table_overflow_with_requirement(self,
                                                                  served):
        """A request whose block count exceeds the table width is rejected
        at submit() with the computed need — not a mid-chunk crash in
        BlockAllocator.ensure."""
        cfg, params = served
        eng = ServeEngine(cfg, params, slots=2, cache_len=64,
                          kv_layout="paged", block_size=16, num_blocks=16,
                          max_seq_len=128, table_len=4)
        with pytest.raises(ValueError,
                           match=r"needs 5 block-table entries.*table_len=4"):
            eng.submit(_req(0, np.arange(60), max_new_tokens=8))  # 67 tokens
        # within the table: accepted
        eng.submit(_req(1, np.arange(50), max_new_tokens=8))      # 57 tokens

    def test_auto_probe_memoized_per_config(self, served, monkeypatch):
        """decode_block="auto" probes once per (config, policy, slots,
        layout) within the process; a second engine reuses the result."""
        from repro.serve import engine as E
        cfg, params = served
        monkeypatch.setattr(E, "_PROBE_CACHE", {})
        calls = []
        orig = ServeEngine._probe_decode_block

        def counting(self, *a, **kw):
            calls.append(1)
            return orig(self, *a, **kw)

        monkeypatch.setattr(ServeEngine, "_probe_decode_block", counting)
        e1 = ServeEngine(cfg, params, slots=2, cache_len=64,
                         decode_block="auto")
        e2 = ServeEngine(cfg, params, slots=2, cache_len=64,
                         decode_block="auto")
        assert len(calls) == 1
        assert e2.decode_block == e1.decode_block
        # a different slot count is a different compiled program: re-probe
        ServeEngine(cfg, params, slots=4, cache_len=64, decode_block="auto")
        assert len(calls) == 2
