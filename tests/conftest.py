"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override belongs exclusively to launch/dryrun.py)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
