"""Asyncio serving frontend + SLO-aware admission + HTTP endpoint.

Covers: incremental streaming at ``decode_block`` granularity,
streamed-token parity against a batch drain (greedy and sampled),
EDF-within-priority admission order, shed-load under an over-capacity
burst (reject and downgrade), deadline/stream accounting surviving
preempt/swap-resume, and the SSE HTTP round trip.
"""
import asyncio
import json

import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine
from repro.serve.frontend import AsyncFrontend
from repro.serve.http import ServeHTTP
from repro.serve.scheduler import BEST_EFFORT_PRIORITY, Scheduler


def _req(uid, plen, max_new=8, **kw):
    rng = np.random.default_rng(100 + uid)
    return Request(uid=uid, prompt=rng.integers(0, 250, plen).astype(np.int32),
                   max_new_tokens=max_new, **kw)


@pytest.fixture(scope="module")
def served(rng):
    cfg = get_reduced_config("qwen2.5-3b")
    return cfg, init_params(cfg, rng)


@pytest.fixture(scope="module")
def eng(served):
    """Shared EDF engine; tests reset() it (compiled programs survive)."""
    cfg, params = served
    return ServeEngine(cfg, params, slots=2, cache_len=64,
                       kv_layout="paged", block_size=16, num_blocks=16,
                       max_seq_len=64, decode_block=4,
                       sched_policy="edf", slo_shed="reject")


class TestSchedulerSLO:
    """Pure host-side EDF + shed semantics (deterministic clock)."""

    def _sched(self, reqs, now=0.0):
        s = Scheduler("edf")
        for r in reqs:
            s.submit(r, now=now)
        return s

    def test_edf_orders_by_priority_then_deadline_then_arrival(self):
        a = _req(0, 8, priority=5)                       # arrival 0
        b = _req(1, 8, priority=5)                       # arrival 1
        c = _req(2, 8, priority=0, deadline_ms=9000.0)
        d = _req(3, 8, priority=0, deadline_ms=1000.0)   # tightest SLO
        s = self._sched([a, b, c, d])
        assert s.select(4) == [d, c, a, b]

    def test_shed_reject_accounts_backlog_in_policy_order(self):
        """predict = 1 s per 10 prompt tokens. The urgent head (8 tokens
        ahead of nothing -> 0.8 s) meets its 1 s deadline; the same
        deadline behind it (16 tokens of backlog -> 1.6 s) is shed, and
        its work leaves the backlog so a 3 s deadline behind survives."""
        a = _req(0, 8, deadline_ms=1000.0)
        b = _req(1, 8, deadline_ms=1000.0)
        c = _req(2, 8, deadline_ms=3000.0)
        s = self._sched([a, b, c])
        shed = s.shed_overdue(lambda toks: toks / 10.0, "reject", now=0.0)
        assert shed == [b]
        assert s.shed_rejected == 1 and s.pending == 2
        assert s.select(3) == [a, c]

    def test_shed_downgrade_demotes_to_best_effort(self):
        """Downgrade keeps the request but clears its deadline and drops
        it behind on-time work; a cleared deadline never re-sheds."""
        hopeless = _req(0, 8, deadline_ms=1.0)
        ontime = _req(1, 8, deadline_ms=60000.0)
        s = self._sched([hopeless, ontime])
        assert s.shed_overdue(lambda t: 1.0, "downgrade", now=0.0) == []
        assert s.shed_downgraded == 1
        assert hopeless.deadline_ms is None
        assert hopeless.priority == BEST_EFFORT_PRIORITY
        # second pass: nothing left to shed, order is ontime-first
        assert s.shed_overdue(lambda t: 1.0, "downgrade", now=0.0) == []
        assert s.select(2) == [ontime, hopeless]


class TestEngineStreaming:
    def test_incremental_spans_at_decode_block_granularity(self, eng):
        """Tokens drain through on_tokens as decode chunks harvest —
        several spans no wider than decode_block, not one burst at
        finish; their concatenation is exactly req.generated."""
        eng.reset()
        spans = []
        r = _req(0, 12, max_new=12,
                 on_tokens=lambda _r, toks, done: spans.append(
                     (list(toks), done)))
        eng.submit(r)
        eng.run_until_drained()
        assert r.done and len(r.generated) == 12
        toks = [t for s, _ in spans for t in s]
        assert toks == r.generated
        assert sum(1 for _, done in spans if done) == 1 and spans[-1][1]
        # prefill's first token + 4-token decode chunks => >= 3 spans
        assert len([s for s, _ in spans if s]) >= 3
        assert all(len(s) <= eng.decode_block for s, _ in spans[1:])

    def test_edf_priority_order_controls_admission(self, eng):
        """4 queued requests, 2 slots: the priority-0 pair gets its
        first tokens in wave one, the priority-5 pair waits."""
        eng.reset()
        first_seen = []
        reqs = [_req(i, 8, max_new=4, priority=pri,
                     deadline_ms=60000.0 if pri == 0 else None)
                for i, pri in enumerate((5, 5, 0, 0))]
        for r in reqs:
            r.on_tokens = lambda rr, toks, done: (
                first_seen.append(rr.uid)
                if toks and rr.uid not in first_seen else None)
            eng.submit(r)
        eng.run_until_drained()
        assert all(r.done for r in reqs)
        assert set(first_seen[:2]) == {2, 3}


class TestFrontendStreaming:
    def test_stream_parity_vs_batch_drain_greedy_and_sampled(self, eng):
        """Tokens collected from each RequestStream are identical (same
        tokens, same order) to a batch drain of the same requests —
        greedy and sampled (sampling keys derive from uid + seed)."""
        eng.reset()
        specs = [dict(plen=10, temperature=0.0, top_k=0, seed=0),
                 dict(plen=13, temperature=0.7, top_k=4, seed=3),
                 dict(plen=9, temperature=0.0, top_k=0, seed=0),
                 dict(plen=17, temperature=0.7, top_k=8, seed=9)]
        prompts = [np.random.default_rng(40 + i).integers(0, 250, s["plen"])
                   .astype(np.int32) for i, s in enumerate(specs)]

        async def run():
            async with AsyncFrontend(eng) as fe:
                handles = [await fe.submit(
                    list(map(int, prompts[i])), max_new_tokens=8,
                    temperature=s["temperature"], top_k=s["top_k"],
                    seed=s["seed"]) for i, s in enumerate(specs)]
                return [(await h.tokens(), h) for h in handles]

        streamed = asyncio.run(run())
        for toks, h in streamed:
            assert h.submit_t <= h.first_token_t <= h.finish_t
            assert not h.shed and len(toks) == 8

        eng.reset()              # same uids: frontend counts from 0
        batch = [Request(uid=i, prompt=prompts[i], max_new_tokens=8,
                         temperature=s["temperature"], top_k=s["top_k"],
                         seed=s["seed"]) for i, s in enumerate(specs)]
        for r in batch:
            eng.submit(r)
        eng.run_until_drained()
        assert [t for t, _ in streamed] == [r.generated for r in batch]

    def test_overcapacity_burst_sheds_hopeless_keeps_ontime(self, eng):
        """A burst beyond capacity with unmeetable deadlines: the
        hopeless requests shed (empty closed streams, engine counters),
        the deadline-less ones all serve in full."""
        eng.reset()

        async def run():
            async with AsyncFrontend(eng) as fe:
                ontime = [await fe.submit([7 + i] * 8, max_new_tokens=6)
                          for i in range(2)]
                hopeless = [await fe.submit([40 + i] * 8, max_new_tokens=6,
                                            deadline_ms=1e-3)
                            for i in range(3)]
                o = [(await h.tokens(), h) for h in ontime]
                s = [(await h.tokens(), h) for h in hopeless]
                stats = await fe.stats()
            return o, s, stats

        ontime, hopeless, stats = asyncio.run(run())
        assert all(not h.shed and len(t) == 6 for t, h in ontime)
        assert all(h.shed and t == [] and h.request.done
                   for t, h in hopeless)
        assert stats["requests_shed"] == 3
        assert stats["requests_finished"] == 2


class TestDeadlineAcrossSwap:
    def test_deadline_and_stream_survive_preempt_resume(self, served):
        """An over-committed optimistic pool preempts residents mid-
        stream; after swap-in each request finishes its stream on the
        same handle with its deadline intact — generous SLOs are never
        shed by the preemption round trip."""
        cfg, params = served
        eng = ServeEngine(cfg, params, slots=4, cache_len=64,
                          kv_layout="paged", block_size=8, num_blocks=8,
                          max_seq_len=96, decode_block=4,
                          admission="optimistic", prefix_cache=False,
                          sched_policy="edf", slo_shed="reject")

        async def run():
            async with AsyncFrontend(eng) as fe:
                handles = [await fe.submit([30 + 7 * i] * 10,
                                           max_new_tokens=30,
                                           deadline_ms=600000.0,
                                           priority=i % 2)
                           for i in range(3)]
                toks = [await h.tokens() for h in handles]
                stats = await fe.stats()
            return handles, toks, stats

        handles, toks, stats = asyncio.run(run())
        assert stats["preemptions"] >= 1
        assert stats["swap_out_bytes"] == stats["swap_in_bytes"] > 0
        assert stats["requests_shed"] == 0
        for i, (h, t) in enumerate(zip(handles, toks)):
            assert len(t) == 30 and t == h.request.generated
            assert not h.shed and h.request.done
            # the SLO class survived the swap round trip un-downgraded
            assert h.request.deadline_ms == 600000.0
            assert h.request.priority == i % 2
            assert h.submit_t <= h.first_token_t <= h.finish_t


async def _sse_completion(port, payload):
    """Minimal SSE client: returns (spans, finish_reason)."""
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps(dict(payload, stream=True)).encode()
    writer.write(b"POST /v1/completions HTTP/1.1\r\n"
                 b"Content-Length: %d\r\n\r\n" % len(body) + body)
    await writer.drain()
    status = (await reader.readline()).split()
    assert status[1] == b"200", status
    while (await reader.readline()) not in (b"\r\n", b"\n"):
        pass
    spans, reason, done = [], None, False
    async for raw in reader:
        line = raw.decode().strip()
        if not line.startswith("data: "):
            continue
        data = line[len("data: "):]
        if data == "[DONE]":
            done = True
            break
        choice = json.loads(data)["choices"][0]
        spans.append(choice["token_ids"])
        reason = choice["finish_reason"]
    writer.close()
    await writer.wait_closed()
    assert done, "stream ended without data: [DONE]"
    return spans, reason


async def _json_request(port, method, path, payload=None):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps(payload).encode() if payload is not None else b""
    writer.write(b"%s %s HTTP/1.1\r\nContent-Length: %d\r\n\r\n"
                 % (method.encode(), path.encode(), len(body)) + body)
    await writer.drain()
    raw = await reader.read()
    writer.close()
    await writer.wait_closed()
    header, _, payload = raw.partition(b"\r\n\r\n")
    return int(header.split()[1]), json.loads(payload)


class TestHTTP:
    def test_sse_stream_blocking_and_errors(self, eng):
        """SSE streaming parity with a batch drain, the blocking JSON
        path, /health, and 400 on a malformed body — one server."""
        eng.reset()
        prompt = [11, 42, 7, 99, 3, 18]

        async def run():
            async with AsyncFrontend(eng) as fe:
                async with ServeHTTP(fe, port=0) as srv:
                    spans, reason = await _sse_completion(
                        srv.port, {"prompt": prompt, "max_tokens": 8,
                                   "temperature": 0.6, "top_k": 4,
                                   "seed": 5})
                    code, out = await _json_request(
                        srv.port, "POST", "/v1/completions",
                        {"prompt": prompt, "max_tokens": 8})
                    health = await _json_request(srv.port, "GET", "/health")
                    bad = await _json_request(
                        srv.port, "POST", "/v1/completions",
                        {"prompt": "text"})
            return spans, reason, code, out, health, bad

        spans, reason, code, out, health, bad = asyncio.run(run())
        # span *count* varies: the SSE writer coalesces harvest bursts
        # when the client reads slowly (decode_block granularity itself
        # is asserted at the engine level above)
        assert reason == "length" and sum(len(s) for s in spans) == 8
        assert code == 200
        assert len(out["choices"][0]["token_ids"]) == 8
        assert out["usage"]["total_tokens"] == len(prompt) + 8
        assert health == (200, {"status": "ok"})
        assert bad[0] == 400 and "token ids" in bad[1]["error"]["message"]

        # streamed sampled tokens == batch drain (frontend uid 0)
        eng.reset()
        ref = Request(uid=0, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=8, temperature=0.6, top_k=4, seed=5)
        eng.submit(ref)
        eng.run_until_drained()
        assert [t for s in spans for t in s] == ref.generated
