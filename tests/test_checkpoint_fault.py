"""Checkpointing (atomicity, keep-k, reshard-on-restore) and the fault-
tolerance state machine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.runtime.fault import (ElasticPlan, HeartbeatFile,
                                 HeartbeatMonitor, RestartPolicy)


@pytest.fixture
def tree(rng):
    return {"a": jax.random.normal(rng, (8, 4)),
            "nested": {"b": jnp.arange(6).reshape(2, 3),
                       "scales": (jnp.float32(0.5), jnp.int32(3))}}


class TestCheckpointer:
    def test_roundtrip(self, tmp_path, tree):
        ck = Checkpointer(str(tmp_path))
        ck.save(10, tree, {"step": 10, "data": {"step": 99}})
        restored, extra = ck.restore(tree)
        assert extra["step"] == 10 and extra["data"]["step"] == 99
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_keep_k_gc(self, tmp_path, tree):
        ck = Checkpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, tree, {})
        assert ck.list_steps() == [3, 4]

    def test_latest_and_explicit_step(self, tmp_path, tree):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, jax.tree.map(lambda x: x * 0, tree), {})
        ck.save(2, tree, {})
        r1, _ = ck.restore(tree, step=1)
        assert float(jnp.sum(jnp.abs(r1["a"]))) == 0.0
        assert ck.latest_step() == 2

    def test_async_save(self, tmp_path, tree):
        ck = Checkpointer(str(tmp_path))
        ck.save_async(5, tree, {"step": 5})
        ck.wait()
        assert ck.latest_step() == 5

    def test_torn_write_ignored(self, tmp_path, tree):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, tree, {})
        os.makedirs(tmp_path / "step_0000000002.tmp")  # crashed writer
        assert ck.latest_step() == 1

    def test_restore_with_sharding_fn(self, tmp_path, tree):
        """Elastic restore: every leaf re-placed via sharding_fn."""
        ck = Checkpointer(str(tmp_path))
        ck.save(1, tree, {})
        from jax.sharding import SingleDeviceSharding
        sh = SingleDeviceSharding(jax.devices()[0])
        calls = []

        def sharding_fn(key):
            calls.append(key)
            return sh

        restored, _ = ck.restore(tree, sharding_fn=sharding_fn)
        assert len(calls) == len(jax.tree.leaves(tree))
        assert restored["a"].sharding == sh

    def test_missing_key_raises(self, tmp_path, tree):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, {"a": tree["a"]}, {})
        with pytest.raises(KeyError):
            ck.restore(tree)


class TestFaultMachinery:
    def test_straggler_detection(self):
        mon = HeartbeatMonitor(n_workers=4)
        for w in range(4):
            mon.beat(w, step_time=1.0 if w != 2 else 5.0, now=100.0)
        assert mon.stragglers() == [2]
        assert mon.healthy_quorum(now=100.0) == [0, 1, 3]

    def test_dead_detection(self):
        mon = HeartbeatMonitor(n_workers=3, timeout_s=10.0)
        mon.beat(0, 1.0, now=0.0)
        mon.beat(1, 1.0, now=0.0)
        # worker 2 never beats; workers 0,1 beat recently at t=5
        mon.beat(0, 1.0, now=5.0)
        mon.beat(1, 1.0, now=5.0)
        assert mon.dead(now=6.0) == [2]
        assert mon.dead(now=100.0) == [0, 1, 2]

    def test_restart_policy_backoff_and_budget(self):
        rp = RestartPolicy(max_restarts=3, backoff_base_s=1.0)
        delays = [rp.next_delay() for _ in range(4)]
        assert delays[:3] == [1.0, 2.0, 4.0] and delays[3] is None
        rp2 = RestartPolicy(max_restarts=2)
        rp2.next_delay()
        rp2.record_success(steps_since_restart=500)
        assert rp2.restarts == 0        # budget resets after stability

    def test_elastic_shrink(self):
        plan = ElasticPlan(data_axis=16, model_axis=16)
        assert plan.shrink_for(512) == (16, 16)
        assert plan.shrink_for(255) == (8, 16)
        assert plan.shrink_for(100) == (4, 16)
        assert plan.shrink_for(10) is None   # can't break a TP group

    def test_heartbeat_file_roundtrip(self, tmp_path):
        hb = HeartbeatFile(str(tmp_path), worker=3)
        hb.write(step=7, step_time=1.25)
        all_hb = HeartbeatFile.read_all(str(tmp_path))
        assert all_hb[3]["step"] == 7
        assert abs(all_hb[3]["step_time"] - 1.25) < 1e-9


class TestTrainRestartIntegration:
    @pytest.mark.slow
    def test_crash_resume_continues(self, tmp_path):
        """Kill training mid-run; resume completes from the checkpoint."""
        import subprocess
        import sys
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        base = [sys.executable, "-m", "repro.launch.train",
                "--arch", "xlstm-125m", "--steps", "202",
                "--teacher-steps", "3", "--batch-size", "2",
                "--seq-len", "32", "--ckpt-dir", str(tmp_path)]
        p1 = subprocess.run(base + ["--simulate-failure-at", "150"],
                            env=env, capture_output=True, text=True,
                            timeout=560)
        assert p1.returncode == 42      # simulated crash
        p2 = subprocess.run(base + ["--resume"], env=env,
                            capture_output=True, text=True, timeout=560)
        assert p2.returncode == 0, p2.stdout + p2.stderr
        assert "resumed from step" in p2.stdout


def test_bf16_roundtrip(tmp_path):
    """npz cannot store ml_dtypes natively; the dtype-recorded uint view
    must round-trip bfloat16 exactly."""
    import jax.numpy as jnp
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(8, dtype=jnp.bfloat16) * 0.25,
            "s": jnp.float32(2.0)}
    ck.save(1, tree, {})
    r, _ = ck.restore(tree)
    assert r["w"].dtype == np.asarray(tree["w"]).dtype
    np.testing.assert_array_equal(np.asarray(r["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))
