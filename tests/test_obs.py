"""Runtime observability: tracer, Perfetto export, /v1/metrics.

Covers: ring bounding + the disabled no-op contract (spans still
measure), span nesting/ordering over a served mixed workload, the
preempt/swap-resume request timeline, Chrome trace_event validity
(b/e pairing, metadata tracks, truncation synthesis), trace-vs-scheduler
latency reconciliation, the stats()-is-JSON regression, Prometheus
rendering consistency with engine.stats(), and an HTTP end-to-end
``GET /v1/metrics`` scrape mid-serve.
"""
import asyncio
import json
import time

import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import init_params
from repro.obs.export import (chrome_trace, compile_split, render_report,
                              request_attribution, step_breakdown)
from repro.obs.metrics import (Histogram, ServeMetrics, parse_prometheus)
from repro.obs.trace import NULL_TRACER, SPAN_NAMES, Tracer
from repro.serve.engine import Request, ServeEngine
from repro.serve.frontend import AsyncFrontend
from repro.serve.http import ServeHTTP
from repro.serve.spec import SpecConfig

from test_frontend import _json_request


def _req(uid, plen, max_new=8, **kw):
    rng = np.random.default_rng(300 + uid)
    return Request(uid=uid, prompt=rng.integers(0, 250, plen)
                   .astype(np.int32), max_new_tokens=max_new, **kw)


@pytest.fixture(scope="module")
def served(rng):
    cfg = get_reduced_config("qwen2.5-3b")
    return cfg, init_params(cfg, rng)


@pytest.fixture(scope="module")
def traced_run(served):
    """One traced mixed run (paged + spec + tight pool -> preemption),
    shared by the timeline/export/report assertions."""
    cfg, params = served
    tracer = Tracer()
    eng = ServeEngine(cfg, params, slots=4, cache_len=64,
                      kv_layout="paged", block_size=8, num_blocks=8,
                      max_seq_len=96, decode_block=4,
                      admission="optimistic", prefix_cache=False,
                      spec=SpecConfig(k=3, draft_layers=1), trace=tracer)
    reqs = [_req(i, 10, max_new=24) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done for r in reqs)
    assert eng.stats()["preemptions"] >= 1, "workload must exercise swap"
    return eng, tracer, reqs


class TestTracer:
    def test_ring_bounds_memory_and_counts_evictions(self):
        tr = Tracer(capacity=8)
        for i in range(100):
            tr.event("submit", uid=i)
        assert len(tr) == 8
        assert tr.dropped == 92
        # oldest evicted, newest kept
        assert [r["uid"] for r in tr.events()] == list(range(92, 100))
        tr.clear()
        assert len(tr) == 0 and tr.dropped == 0

    def test_disabled_records_nothing_but_spans_still_measure(self):
        tr = Tracer(enabled=False)
        with tr.span("step") as sp:
            tr.event("submit", uid=0)
            tr.annotate(compiled="decode")
            time.sleep(0.002)
        assert sp.dt >= 0.002          # engine bookkeeping depends on dt
        assert len(tr) == 0 and tr.dropped == 0 and not tr._stack
        assert not NULL_TRACER.enabled and len(NULL_TRACER) == 0

    def test_nesting_depth_and_annotate_target_innermost(self):
        tr = Tracer()
        with tr.span("step"):
            with tr.span("decode", rows=2):
                tr.annotate(compiled="decode")
        spans = {r["name"]: r for r in tr.events()}
        assert spans["decode"]["depth"] == 1      # committed inside step
        assert spans["step"]["depth"] == 0
        assert spans["decode"]["args"] == {"rows": 2, "compiled": "decode"}
        assert spans["step"]["t0"] <= spans["decode"]["t0"]

    def test_capacity_must_be_positive(self):
        with pytest.raises(ValueError):
            Tracer(capacity=0)


class TestServedTrace:
    def test_span_vocabulary_nesting_and_step_ordering(self, traced_run):
        """Every span the engine emits is in the documented vocabulary,
        steps are contiguous ascending, and wave spans sit inside their
        step span's window."""
        _, tracer, _ = traced_run
        recs = tracer.events()
        assert tracer.dropped == 0
        spans = [r for r in recs if r["ph"] == "span"]
        assert {s["name"] for s in spans} <= set(SPAN_NAMES)
        # the mixed workload exercised the full machinery
        names = {s["name"] for s in spans}
        assert {"step", "prefill_wave", "spec_draft", "spec_verify",
                "swap_out", "swap_in", "harvest", "sync"} <= names
        steps = {}
        for s in spans:
            if s["name"] == "step":
                steps[s["step"]] = (s["t0"], s["t0"] + s["dur"])
        assert sorted(steps) == list(range(1, len(steps) + 1))
        eps = 1e-4                     # span exit bookkeeping slack
        for s in spans:
            if s["name"] == "step" or s["step"] not in steps:
                continue
            lo, hi = steps[s["step"]]
            assert lo - eps <= s["t0"] <= s["t0"] + s["dur"] <= hi + eps, \
                f"{s['name']} escapes its step window"
            assert s["depth"] >= 1     # committed nested under step

    def test_request_lifecycle_and_swap_timeline(self, traced_run):
        """Each request's events arrive in causal order; the preempted
        request's timeline is submit -> ... -> preempted -> swap_resumed
        -> finished with monotone timestamps."""
        _, tracer, reqs = traced_run
        by_uid = {r.uid: [] for r in reqs}
        for rec in tracer.events():
            if rec["ph"] == "event" and rec.get("uid") in by_uid:
                by_uid[rec["uid"]].append(rec)
        swapped = 0
        for uid, evs in by_uid.items():
            names = [e["name"] for e in evs]
            ts = [e["t"] for e in evs]
            assert ts == sorted(ts)
            assert names[:2] == ["submit", "queued"]
            assert names[-1] == "finished"
            for must in ("admitted", "first_token"):
                assert must in names, f"uid {uid} missing {must}"
            assert names.index("admitted") < names.index("first_token")
            if "preempted" in names:
                swapped += 1
                assert names.index("preempted") \
                    < names.index("swap_resumed") < names.index("finished")
                pre = evs[names.index("preempted")]
                res = evs[names.index("swap_resumed")]
                assert pre["args"]["bytes"] == res["args"]["bytes"] > 0
        assert swapped >= 1

    def test_chrome_export_is_valid_and_pairs_async_spans(self, traced_run):
        eng, tracer, reqs = traced_run
        trace = chrome_trace(tracer, eng.wave_variant_signatures())
        json.loads(json.dumps(trace))            # pure-JSON round trip
        ev = trace["traceEvents"]
        procs = {e["args"]["name"] for e in ev
                 if e["ph"] == "M" and e["name"] == "process_name"}
        assert procs == {"engine waves", "requests"}
        tracks = {e["args"]["name"] for e in ev
                  if e["ph"] == "M" and e["name"] == "thread_name"}
        assert "step" in tracks and "spec_verify" in tracks
        for e in ev:
            if e["ph"] == "X":
                assert e["ts"] >= 0 and e["dur"] >= 0
        # every request opens exactly once and closes exactly once, and
        # no finished request is flagged truncated
        for r in reqs:
            b = [e for e in ev if e["ph"] == "b" and e.get("id") == r.uid]
            e_ = [e for e in ev if e["ph"] == "e" and e.get("id") == r.uid]
            assert len(b) == 1 and len(e_) == 1
            assert "truncated" not in e_[0]["args"]
        assert trace["otherData"]["compile_variants"]

    def test_truncated_request_gets_synthetic_end(self):
        tr = Tracer()
        tr.event("submit", uid=7)
        tr.event("queued", uid=7)
        ends = [e for e in chrome_trace(tr)["traceEvents"]
                if e["ph"] == "e" and e.get("id") == 7]
        assert len(ends) == 1 and ends[0]["args"]["truncated"]

    def test_reconciliation_and_reports(self, traced_run):
        """Trace-side submit->finish deltas agree with the scheduler
        clock within the 5% acceptance bound, and the report functions
        cover every phase of the run."""
        eng, tracer, reqs = traced_run
        trace = chrome_trace(tracer, eng.wave_variant_signatures())
        ra = request_attribution(trace)
        assert ra["finished"] == len(reqs)
        assert ra["reconcile_max_err"] <= 0.05
        assert ra["latency"]["p95_s"] >= ra["ttft"]["p95_s"] > 0
        bd = step_breakdown(trace)
        assert bd["step"]["pct_of_step"] == pytest.approx(100.0)
        assert 0 < bd["spec_verify"]["total_s"] <= bd["step"]["total_s"]
        cs = compile_split(trace)
        # first call of each wave family is compile-tainted
        assert cs["prefill_wave"]["compile_calls"] >= 1
        assert cs["prefill_wave"]["variants"]
        report = render_report(trace)
        for needle in ("step-time breakdown", "request attribution",
                       "compile vs execute", "max rel err"):
            assert needle in report


class TestStatsAndMetrics:
    def test_stats_are_json_clean(self, traced_run):
        """Regression: stats() must serialize with the stock JSON encoder
        (numpy/jax scalars cast at the boundary), and survive a
        round trip unchanged."""
        eng, _, _ = traced_run
        stats = eng.stats()
        assert json.loads(json.dumps(stats)) == stats
        for k, v in stats.items():
            assert not isinstance(v, np.generic), f"{k} leaks {type(v)}"

    def test_histogram_buckets_and_quantiles(self):
        h = Histogram("x_seconds", "t", buckets=(0.01, 0.1, 1.0))
        for v in (0.005, 0.05, 0.05, 0.5, 5.0):
            h.observe(v)
        h.observe(None)                # absent observation is dropped
        assert h.count == 5 and h.sum == pytest.approx(5.605)
        parsed = parse_prometheus(h.render())
        assert parsed['x_seconds_bucket{le="0.01"}'] == 1
        assert parsed['x_seconds_bucket{le="1.0"}'] == 4   # cumulative
        assert parsed['x_seconds_bucket{le="+Inf"}'] == 5
        assert h.quantile(50) == 0.1
        assert h.quantile(99) == 1.0   # clamped to the last bound

    def test_parse_prometheus_rejects_malformed(self):
        with pytest.raises(ValueError):
            parse_prometheus("lonely_token\n")
        with pytest.raises(ValueError):
            parse_prometheus("name not_a_number\n")

    def test_render_matches_engine_stats(self, traced_run):
        """The scrape projection agrees with stats() — counter for
        counter — including the spec and swap families this workload
        exercised, and the per-family compile-variant gauges."""
        eng, _, _ = traced_run
        stats = eng.stats()
        parsed = parse_prometheus(eng.metrics.render(stats))
        for key, name in (("tokens_out", "serve_tokens_out_total"),
                          ("preemptions", "serve_preemptions_total"),
                          ("spec_waves", "serve_spec_waves_total"),
                          ("requests_finished",
                           "serve_requests_finished_total"),
                          ("free_blocks", "serve_free_blocks")):
            assert parsed[name] == pytest.approx(stats[key]), name
        for fam, n in stats["compile_variants"].items():
            assert parsed[f'serve_compile_variants{{family="{fam}"}}'] == n
        assert parsed["serve_request_latency_seconds_count"] == \
            stats["requests_finished"]

    def test_observe_finished_derives_tpot(self):
        m = ServeMetrics()
        m.observe_ttft(0.02)
        m.observe_finished(0.5, 0.4, 9)          # 0.4 s over 8 tokens
        snap = m.snapshot()
        assert snap["ttft"]["count"] == snap["latency"]["count"] == 1
        assert snap["tpot"]["count"] == 1
        assert m.tpot.sum == pytest.approx(0.05)
        m.observe_finished(0.5, 0.4, 1)          # single token: no TPOT
        assert m.snapshot()["tpot"]["count"] == 1
        m.reset()
        assert m.snapshot()["latency"]["count"] == 0


async def _text_request(port, path):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(b"GET %s HTTP/1.1\r\n\r\n" % path.encode())
    await writer.drain()
    raw = await reader.read()
    writer.close()
    await writer.wait_closed()
    header, _, payload = raw.partition(b"\r\n\r\n")
    lines = header.decode().split("\r\n")
    headers = dict((k.strip().lower(), v.strip()) for k, _, v in
                   (ln.partition(":") for ln in lines[1:]))
    return int(lines[0].split()[1]), headers, payload.decode()


class TestHTTPMetrics:
    def test_scrape_mid_serve_and_after_drain(self, served):
        """GET /v1/metrics parses as Prometheus text both while requests
        are in flight and after the drain, when its counters must agree
        with the frontend stats snapshot."""
        cfg, params = served
        eng = ServeEngine(cfg, params, slots=2, cache_len=64,
                          kv_layout="paged", block_size=16, num_blocks=16,
                          max_seq_len=64, decode_block=4, trace=Tracer())

        async def run():
            async with AsyncFrontend(eng) as fe:
                async with ServeHTTP(fe, port=0) as srv:
                    handles = [await fe.submit([9 + i] * 8,
                                               max_new_tokens=12)
                               for i in range(4)]
                    mid = await _text_request(srv.port, "/v1/metrics")
                    for h in handles:
                        await h.tokens()
                    done = await _text_request(srv.port, "/v1/metrics")
                    code, stats = await _json_request(srv.port, "GET",
                                                      "/v1/stats")
            return mid, done, code, stats

        mid, done, code, stats = asyncio.run(run())
        assert mid[0] == done[0] == code == 200
        assert done[1]["content-type"].startswith(
            "text/plain; version=0.0.4")
        assert parse_prometheus(mid[2])          # well-formed mid-flight
        parsed = parse_prometheus(done[2])
        assert parsed["serve_requests_finished_total"] == 4
        assert parsed["serve_tokens_out_total"] == \
            stats["tokens_out"] == 4 * 12
        assert parsed["serve_ttft_seconds_count"] == 4
        # /v1/stats carries the matching histogram digest
        assert stats["metrics"]["ttft"]["count"] == 4
        assert json.loads(json.dumps(stats)) == stats
