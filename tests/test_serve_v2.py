"""Serve engine v2: scheduler policies, batched prefill parity, on-device
EOS/slot lifecycle, sampling, and stats accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.qat import make_ctx
from repro.models import decode_step, init_params, prefill
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import make_slot_keys, sample_tokens
from repro.serve.scheduler import Scheduler


def _req(uid, plen, **kw):
    return Request(uid=uid, prompt=np.arange(plen, dtype=np.int32), **kw)


class TestScheduler:
    def test_fcfs_admits_in_arrival_order(self):
        s = Scheduler("fcfs")
        for uid, plen in enumerate([9, 3, 6]):
            s.submit(_req(uid, plen))
        assert [r.uid for r in s.select(2)] == [0, 1]
        assert [r.uid for r in s.select(2)] == [2]
        assert s.pending == 0

    def test_sjf_admits_shortest_prompt_first(self):
        s = Scheduler("sjf")
        for uid, plen in enumerate([9, 3, 6, 3]):
            s.submit(_req(uid, plen))
        # shortest first; equal lengths keep arrival order
        assert [r.uid for r in s.select(3)] == [1, 3, 2]
        assert [r.uid for r in s.select(3)] == [0]

    def test_equal_length_grouping(self):
        s = Scheduler("fcfs")
        for uid, plen in enumerate([4, 7, 4, 4]):
            s.submit(_req(uid, plen))
        batch = s.select(4, equal_length_only=True)
        assert [r.uid for r in batch] == [0, 2, 3]
        assert [r.uid for r in s.select(4, equal_length_only=True)] == [1]

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError):
            Scheduler("priority")


class TestSampling:
    def test_greedy_matches_argmax(self, rng):
        logits = jax.random.normal(rng, (4, 32))
        keys = make_slot_keys(jnp.arange(4))
        toks = sample_tokens(logits, keys, jnp.zeros(4), jnp.zeros(4, int))
        np.testing.assert_array_equal(np.asarray(toks),
                                      np.asarray(jnp.argmax(logits, -1)))

    def test_top_k_restricts_support(self, rng):
        logits = jax.random.normal(rng, (64, 16))
        top2 = np.asarray(jax.lax.top_k(logits, 2)[1])
        keys = make_slot_keys(jnp.arange(64))
        toks = np.asarray(sample_tokens(
            logits, keys, jnp.full(64, 1.5), jnp.full(64, 2, int)))
        for i in range(64):
            assert toks[i] in top2[i]

    def test_mixed_greedy_and_stochastic_rows(self, rng):
        logits = jax.random.normal(rng, (2, 64))
        keys = make_slot_keys(jnp.arange(2))
        toks = sample_tokens(logits, keys,
                             jnp.asarray([0.0, 1.0]),
                             jnp.zeros(2, int))
        assert int(toks[0]) == int(jnp.argmax(logits[0]))


class TestBatchedPrefill:
    def test_matches_per_request_prefill(self, rng):
        """Padded batched prefill must agree with per-request prefill on
        logits, cache positions, and the next decode step."""
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        ctx = make_ctx("A8d-C8-W4")
        p1 = np.arange(5, dtype=np.int32) + 3
        p2 = np.arange(9, dtype=np.int32)
        l1, c1 = prefill(cfg, params, ctx,
                         {"tokens": jnp.asarray(p1)[None]}, cache_budget=32)
        l2, c2 = prefill(cfg, params, ctx,
                         {"tokens": jnp.asarray(p2)[None]}, cache_budget=32)
        toks = np.zeros((2, 16), np.int32)
        toks[0, :5], toks[1, :9] = p1, p2
        lb, cb = prefill(cfg, params, ctx,
                         {"tokens": jnp.asarray(toks),
                          "lengths": jnp.asarray([5, 9], jnp.int32)},
                         cache_budget=32)
        np.testing.assert_allclose(np.asarray(lb[0, 0], np.float32),
                                   np.asarray(l1[0, 0], np.float32),
                                   rtol=1e-2, atol=1e-2)
        np.testing.assert_allclose(np.asarray(lb[1, 0], np.float32),
                                   np.asarray(l2[0, 0], np.float32),
                                   rtol=1e-2, atol=1e-2)
        np.testing.assert_array_equal(np.asarray(cb["position"]), [5, 9])
        nxt = jnp.asarray([[7], [11]], jnp.int32)
        db, _ = decode_step(cfg, params, ctx, nxt, cb)
        d1, _ = decode_step(cfg, params, ctx, nxt[:1], c1)
        d2, _ = decode_step(cfg, params, ctx, nxt[1:], c2)
        np.testing.assert_allclose(np.asarray(db[0], np.float32),
                                   np.asarray(d1[0], np.float32),
                                   rtol=1e-2, atol=1e-2)
        np.testing.assert_allclose(np.asarray(db[1], np.float32),
                                   np.asarray(d2[0], np.float32),
                                   rtol=1e-2, atol=1e-2)


    def test_lengths_rejected_on_recurrent_arch(self, rng):
        """Right-padded prefill is only exact for attention-only decoders;
        the model API must refuse it elsewhere, not silently corrupt the
        scan state."""
        cfg = get_reduced_config("xlstm-125m")
        params = init_params(cfg, rng)
        ctx = make_ctx("A8d-C8-W4")
        with pytest.raises(ValueError, match="attention-only"):
            prefill(cfg, params, ctx,
                    {"tokens": jnp.zeros((2, 8), jnp.int32),
                     "lengths": jnp.asarray([4, 8], jnp.int32)},
                    cache_budget=16)


class TestEngineV2:
    def test_on_device_eos_stops_one_slot_others_continue(self, rng):
        """Replay a seeded stochastic request with its EOS set to a token
        that first appears mid-stream: that slot must stop exactly there
        while the co-resident greedy slot runs to its max-token budget."""
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)

        def probe_run(eos_id):
            eng = ServeEngine(cfg, params, slots=2, cache_len=64)
            stoch = _req(0, 8, max_new_tokens=12, eos_id=eos_id)
            stoch.temperature, stoch.seed = 1.0, 11
            runner = _req(1, 6, max_new_tokens=8)
            eng.submit(stoch)
            eng.submit(runner)
            eng.run_until_drained()
            return stoch, runner

        free_run, _ = probe_run(-1)
        assert len(free_run.generated) == 12
        # latest first occurrence of any token — an EOS that fires
        # mid-stream (seeded sampling makes the stream reproducible)
        first_seen = {}
        for i, t in enumerate(free_run.generated):
            first_seen.setdefault(t, i)
        eos, stop_i = max(first_seen.items(), key=lambda kv: kv[1])
        if stop_i == 0:
            pytest.skip("degenerate stream: every token equals the first")
        stopped, runner = probe_run(eos)
        assert stopped.done and runner.done
        assert len(stopped.generated) == stop_i + 1  # stops at its EOS
        assert stopped.generated[-1] == eos
        assert len(runner.generated) == 8            # unaffected neighbor

    def test_drained_stats_match_submitted_tokens(self, rng):
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        eng = ServeEngine(cfg, params, slots=2, cache_len=64)
        budgets = [4, 7, 3, 5, 6]
        reqs = [_req(i, 4 + i, max_new_tokens=b)
                for i, b in enumerate(budgets)]
        for r in reqs:
            eng.submit(r)
        stats = eng.run_until_drained()
        assert all(r.done for r in reqs)
        assert [len(r.generated) for r in reqs] == budgets
        assert stats["tokens_out"] == sum(budgets)
        assert stats["requests_finished"] == len(reqs)
        assert stats["ttft_p95_s"] >= stats["ttft_p50_s"] >= 0.0

    def test_mixed_length_batched_admission(self, rng):
        """One admission wave with different prompt lengths (padded batched
        prefill) still produces per-request budgets."""
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        eng = ServeEngine(cfg, params, slots=4, cache_len=64)
        reqs = [_req(i, plen, max_new_tokens=5)
                for i, plen in enumerate([5, 12, 8, 3])]
        for r in reqs:
            eng.submit(r)
        stats = eng.run_until_drained()
        assert stats["prefill_calls"] == 1          # one batched prefill
        assert all(len(r.generated) == 5 for r in reqs)

    def test_recurrent_arch_exact_length_admission(self, rng):
        """Recurrent archs can't absorb padding: admission groups equal
        lengths, and everything still drains."""
        cfg = get_reduced_config("xlstm-125m")
        params = init_params(cfg, rng)
        eng = ServeEngine(cfg, params, slots=2, cache_len=32)
        assert not eng._pad_ok
        reqs = [_req(0, 4, max_new_tokens=3), _req(1, 6, max_new_tokens=3),
                _req(2, 4, max_new_tokens=3)]
        for r in reqs:
            eng.submit(r)
        stats = eng.run_until_drained()
        assert all(len(r.generated) == 3 for r in reqs)
        assert stats["tokens_out"] == 9

    def test_sjf_policy_serves_short_prompts_first(self, rng):
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        eng = ServeEngine(cfg, params, slots=1, cache_len=64,
                          sched_policy="sjf")
        long = _req(0, 16, max_new_tokens=2)
        short = _req(1, 4, max_new_tokens=2)
        eng.submit(long)
        eng.submit(short)
        eng.step()                      # admits (and may finish) one request
        assert short.done and not long.done

    def test_infeasible_requests_are_rejected_at_submit(self, rng):
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        eng = ServeEngine(cfg, params, slots=1, cache_len=64, max_new_cap=16)
        with pytest.raises(ValueError, match="max_new_cap"):
            eng.submit(_req(0, 4, max_new_tokens=17))
        # the message must report the computed requirement (60 + 8 - 1 = 67)
        # alongside the limit, not just restate the inputs
        with pytest.raises(ValueError,
                           match=r"needs 67 cache tokens.*cache_len=64"):
            eng.submit(_req(1, 60, max_new_tokens=8))   # 60 + 8 - 1 > 64

    def test_duplicate_uid_requests_do_not_break_selection(self, rng):
        """Request equality is identity (ndarray prompts break value eq):
        two queued requests with the same uid must still schedule."""
        s = Scheduler("sjf")
        a = Request(uid=0, prompt=np.arange(9, dtype=np.int32))
        b = Request(uid=0, prompt=np.arange(3, dtype=np.int32))
        s.submit(a)
        s.submit(b)
        assert s.select(1)[0] is b
        assert s.select(1)[0] is a

    def test_budget_abort_keeps_partial_output(self, rng):
        """Exhausting max_steps mid-request must surface the tokens already
        generated on device instead of dropping them."""
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        eng = ServeEngine(cfg, params, slots=1, cache_len=64, decode_block=4)
        r = _req(0, 8, max_new_tokens=32)
        eng.submit(r)
        stats = eng.run_until_drained(max_steps=8)     # 2 chunks of 4
        assert not r.done
        assert len(r.generated) == 9                   # 1 prefill + 8 decode
        assert stats["tokens_out"] == 9

    def test_auto_decode_block_probe_picks_a_candidate(self, rng):
        """decode_block="auto" runs the construction-time latency probe;
        an int stays the config override."""
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        eng = ServeEngine(cfg, params, slots=2, cache_len=64,
                          decode_block="auto")
        assert eng.decode_block in (4, 8, 16, 32)
        r = _req(0, 6, max_new_tokens=5)
        eng.submit(r)
        eng.run_until_drained()
        assert r.done and len(r.generated) == 5
        over = ServeEngine(cfg, params, slots=2, cache_len=64,
                           decode_block=4)
        assert over.decode_block == 4

    def test_temperature_sampling_is_seeded_and_in_vocab(self, rng):
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)

        def run(seed):
            eng = ServeEngine(cfg, params, slots=1, cache_len=64)
            r = _req(0, 6, max_new_tokens=6)
            r.temperature, r.top_k, r.seed = 1.0, 4, seed
            eng.submit(r)
            eng.run_until_drained()
            return r.generated

        a, b = run(7), run(7)
        assert a == b                       # deterministic per seed
        assert all(0 <= t < cfg.vocab_size for t in a)


class TestPagedEngine:
    """Paged (block-table) KV cache engine vs the dense engine."""

    def _mixed_reqs(self):
        reqs = []
        for i, (plen, temp) in enumerate(
                [(5, 0.0), (12, 0.9), (8, 0.0), (3, 1.2)]):
            r = _req(i, plen, max_new_tokens=6)
            r.temperature, r.top_k, r.seed = temp, 8 if temp else 0, i
            reqs.append(r)
        return reqs

    def test_paged_matches_dense_tokens_mixed_length_batch(self, rng):
        """Identical generated tokens on a mixed-length batch (greedy and
        seeded-stochastic rows): the paged layout only changes where cache
        bytes live, never what attention computes."""
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        dense = ServeEngine(cfg, params, slots=4, cache_len=64)
        rd = self._mixed_reqs()
        for r in rd:
            dense.submit(r)
        dense.run_until_drained()
        paged = ServeEngine(cfg, params, slots=4, cache_len=64,
                            kv_layout="paged", block_size=16,
                            max_seq_len=64)
        rp = self._mixed_reqs()
        for r in rp:
            paged.submit(r)
        paged.run_until_drained()
        assert all(r.done for r in rd + rp)
        assert [a.generated for a in rd] == [b.generated for b in rp]

    def test_chunked_prefill_admits_prompts_beyond_one_bucket(self, rng):
        """A prompt longer than ``prefill_chunk`` (and longer than any
        dense per-slot stripe would allow) is admitted as fixed-size
        chunks appending blocks incrementally — the cache_len prompt bound
        is gone in paged mode."""
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        eng = ServeEngine(cfg, params, slots=2, cache_len=32,
                          kv_layout="paged", block_size=16, num_blocks=8,
                          max_seq_len=128, prefill_chunk=16)
        long_req = _req(0, 50, max_new_tokens=5)    # dense would reject
        short = _req(1, 6, max_new_tokens=4)
        eng.submit(long_req)
        eng.submit(short)
        stats = eng.run_until_drained()
        assert long_req.done and len(long_req.generated) == 5
        assert short.done and len(short.generated) == 4
        assert stats["prefill_chunks"] == 4         # ceil(50 / 16)

    def test_chunked_prefill_interleaves_with_decode(self, rng):
        """One prefill chunk per engine step: a co-resident short request
        keeps decoding while a long prompt is still prefilling, so the
        short one finishes before the long one even produces its first
        token."""
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        eng = ServeEngine(cfg, params, slots=2, cache_len=64,
                          kv_layout="paged", block_size=16, num_blocks=16,
                          max_seq_len=128, prefill_chunk=16,
                          decode_block=2)
        short = _req(0, 4, max_new_tokens=4)
        long_req = _req(1, 64, max_new_tokens=4)    # 4 chunks of 16
        eng.submit(short)
        eng.submit(long_req)
        eng.run_until_drained()
        assert short.done and long_req.done
        assert len(short.generated) == 4 and len(long_req.generated) == 4
        # the short request drained while the long prompt was chunking
        assert short._timing.finish_t < long_req._timing.admit_t

    def test_chunked_prefill_matches_one_shot_greedy(self, rng):
        """Greedy decode after a chunked prefill agrees with the one-shot
        batched prefill of the same prompt (history is re-read quantized,
        which is exactly what decode reads too)."""
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        prompt = (np.arange(50) * 3 % 250).astype(np.int32)

        def run(chunk):
            eng = ServeEngine(cfg, params, slots=1, cache_len=64,
                              kv_layout="paged", block_size=16,
                              num_blocks=8, max_seq_len=128,
                              prefill_chunk=chunk)
            r = Request(uid=0, prompt=prompt, max_new_tokens=6)
            eng.submit(r)
            eng.run_until_drained()
            return r.generated

        assert run(64) == run(16)           # one-shot vs 4 chunks

    def test_paged_requires_full_attention_decoder(self, rng):
        cfg = get_reduced_config("xlstm-125m")
        params = init_params(cfg, rng)
        with pytest.raises(ValueError, match="full-attention"):
            ServeEngine(cfg, params, slots=2, cache_len=32,
                        kv_layout="paged")

    def test_paged_submit_reports_computed_tokens(self, rng):
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        eng = ServeEngine(cfg, params, slots=2, cache_len=32,
                          kv_layout="paged", block_size=16,
                          max_seq_len=64)
        with pytest.raises(ValueError,
                           match=r"needs 79 cache tokens.*max_seq_len=64"):
            eng.submit(_req(0, 72, max_new_tokens=8))   # 72 + 8 - 1 = 79
