"""Calibration tests: convex-MSE weight scales (Eq. 2) + percentile acts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.calibration import (act_percentile_stat, act_scale_from_stat,
                                    lsq_weight_scale, mse_objective,
                                    mse_weight_scale)
from repro.core.quantizer import lsq_fake_quant, qbounds


def _true_mse(w, s, bits):
    return float(jnp.mean((lsq_fake_quant(w, s, bits) - w) ** 2))


class TestMSECalibration:
    @given(bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**31 - 1),
           dist=st.sampled_from(["normal", "laplace", "heavy"]))
    @settings(max_examples=25, deadline=None)
    def test_beats_naive_calibrations(self, bits, seed, dist):
        """Property: Eq.2 beats absmax scaling at low precision (the regime
        the paper targets — clipping trades against resolution); at 8-bit,
        where absmax is already near-optimal, the convex approximation must
        stay within a small factor of it."""
        key = jax.random.PRNGKey(seed)
        w = jax.random.normal(key, (256, 1))
        if dist == "laplace":
            w = jax.random.laplace(key, (256, 1))
        elif dist == "heavy":
            w = jax.random.t(key, 2.5, (256, 1))
        _, qp = qbounds(bits)
        s_mse = mse_weight_scale(w, bits)
        s_max = jnp.max(jnp.abs(w), axis=0, keepdims=True) / qp
        e_mse = _true_mse(w, s_mse, bits)
        e_max = _true_mse(w, s_max, bits)
        if bits <= 4:
            assert e_mse <= e_max * 1.001
        else:
            assert e_mse <= e_max * 1.25

    def test_objective_tracks_true_mse(self, rng):
        """Eq. 2 is a close approximation of the true MSE near optimum."""
        w = jax.random.normal(rng, (4096,))
        absw = jnp.abs(w)[None, :]
        for s in (0.05, 0.1, 0.3):
            approx = float(mse_objective(absw, jnp.array([s]), 4)[0]) / w.size
            true = _true_mse(w, jnp.float32(s), 4)
            assert abs(approx - true) / true < 0.35

    def test_convexity_bracket(self, rng):
        """Optimum lies strictly inside (0, max|w|/b]."""
        w = jax.random.normal(rng, (512, 1)) * 2.0
        s = float(mse_weight_scale(w, 4)[0, 0])
        b = 2 ** 3 - 0.5
        assert 0 < s <= float(jnp.max(jnp.abs(w))) / b + 1e-6

    def test_per_channel_shapes(self, rng):
        w = jax.random.normal(rng, (3, 32, 16))     # e.g. stacked layers
        s = mse_weight_scale(w, 4)
        assert s.shape == (3, 1, 16)

    def test_scale_positive(self, rng):
        w = jnp.zeros((64, 4))                       # degenerate weights
        s = mse_weight_scale(w, 4)
        assert bool(jnp.all(s > 0))


class TestActCalibration:
    def test_percentile_ignores_outliers(self, rng):
        x = jax.random.normal(rng, (100_000,))
        x = x.at[0].set(1e6)                         # one huge outlier
        stat = act_percentile_stat(x, 8)             # p99.99
        assert float(stat) < 10.0                    # not dragged to 1e6

    def test_scale_from_stat(self):
        s = act_scale_from_stat(jnp.float32(127.0), 8)
        np.testing.assert_allclose(float(s), 1.0, rtol=1e-5)

    def test_bits_percentiles_ordered(self, rng):
        """Higher precision uses a higher percentile."""
        x = jax.random.normal(rng, (50_000,))
        assert float(act_percentile_stat(x, 4)) <= \
            float(act_percentile_stat(x, 8)) <= \
            float(act_percentile_stat(x, 16))


def test_lsq_init_reasonable(rng):
    w = jax.random.normal(rng, (128, 8))
    s = lsq_weight_scale(w, 4)
    assert s.shape == (1, 8)
    assert bool(jnp.all(s > 0))
