"""PTQ baselines (RTN, SmoothQuant) + rotation machinery + Procrustes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.analysis.rotation import (procrustes_distances,
                                          random_rotation, rotate_residual,
                                          rotation_report)
from repro.core.precision import parse_policy
from repro.core.ptq.rtn import rtn_quantize
from repro.core.ptq.smoothquant import fold_smoothing, smoothquant_quantize
from repro.core.qat import make_ctx
from repro.data import SyntheticConfig, calibration_batches
from repro.models import forward, init_params


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced_config("qwen3-14b")
    params = init_params(jax.random.PRNGKey(0), None) \
        if False else init_params(cfg, jax.random.PRNGKey(0),
                                  dtype=jnp.float32)
    dc = SyntheticConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)
    cb = calibration_batches(dc, 2)
    batch = {"tokens": jnp.asarray(cb[0]["tokens"])}
    return cfg, params, cb, batch


class TestRotation:
    def test_function_preserving(self, setup):
        cfg, params, _, batch = setup
        ctx = make_ctx("A16-C16-W16", mode="off")
        l0, _ = forward(cfg, params, ctx, batch)
        rot = rotate_residual(cfg, params, jax.random.PRNGKey(7))
        l1, _ = forward(cfg, rot, ctx, batch)
        # tolerance: the attention probability tensor is bf16 (production
        # precision), and rotated activations round differently in bf16
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                   atol=1e-2)

    def test_rotation_matrix_orthonormal(self):
        R = random_rotation(32, jax.random.PRNGKey(0))
        np.testing.assert_allclose(np.asarray(R @ R.T), np.eye(32),
                                   atol=1e-5)

    def test_procrustes_pure_rotation(self, rng):
        W = np.asarray(jax.random.normal(rng, (48, 32)))
        R = np.asarray(random_rotation(48, jax.random.PRNGKey(1)))
        d = procrustes_distances(W, R @ W)
        assert d["non_rotational"] < 1e-4
        assert d["rotational"] > 0.1

    def test_procrustes_identity(self, rng):
        W = np.asarray(jax.random.normal(rng, (32, 32)))
        d = procrustes_distances(W, W)
        assert d["total"] < 1e-6

    def test_rotation_report_separates_qat_from_rotation(self, setup, rng):
        """The paper's Fig-3 mechanism: a rotated model shows high
        rotational share; a randomly perturbed model much lower."""
        cfg, params, _, _ = setup
        rot = rotate_residual(cfg, params, jax.random.PRNGKey(3))
        rep_rot = rotation_report(cfg, params, rot)
        perturbed = jax.tree.map(
            lambda x: x + 0.05 * jnp.std(x) *
            jax.random.normal(rng, x.shape, x.dtype)
            if x.ndim >= 2 else x, params)
        rep_pert = rotation_report(cfg, params, perturbed)

        def share(rep):
            tot = sum(v["rotational"] + v["non_rotational"]
                      for v in rep.values())
            return sum(v["rotational"] for v in rep.values()) / tot

        assert share(rep_rot) > 0.8
        assert share(rep_pert) < 0.5


class TestPTQ:
    def test_rtn_improves_with_bits(self, setup):
        cfg, params, cb, batch = setup
        ctx_off = make_ctx("A16-C16-W16", mode="off")
        l0, _ = forward(cfg, params, ctx_off, batch)

        def agreement(policy_name):
            pol = parse_policy(policy_name)
            q = rtn_quantize(cfg, params, pol, cb)
            lq, _ = forward(cfg, q, make_ctx(pol), batch)
            return float(jnp.mean(jnp.argmax(lq, -1) == jnp.argmax(l0, -1)))

        a4 = agreement("A8s-C8-W4")
        a8 = agreement("A8s-C8-W8")
        assert a8 >= a4

    def test_smoothquant_finite_and_scales_folded(self, setup):
        cfg, params, cb, batch = setup
        folded = fold_smoothing(cfg, params, 0.5, cb)
        # function preserved before quantization (norm/linear fold identity)
        ctx_off = make_ctx("A16-C16-W16", mode="off")
        l0, _ = forward(cfg, params, ctx_off, batch)
        l1, _ = forward(cfg, folded, ctx_off, batch)
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                   rtol=2e-2, atol=2e-2)
        # weights actually changed
        w0 = params["segments"][0]["0"]["attn"]["wq"]["w"]
        w1 = folded["segments"][0]["0"]["attn"]["wq"]["w"]
        assert bool(jnp.any(jnp.abs(w0 - w1) > 1e-6))

    def test_smoothquant_pipeline_runs(self, setup):
        cfg, params, cb, batch = setup
        pol = parse_policy("A8s-C8-W4")
        q = smoothquant_quantize(cfg, params, pol, cb, alpha=0.4)
        lq, _ = forward(cfg, q, make_ctx(pol), batch)
        assert bool(jnp.all(jnp.isfinite(lq)))
