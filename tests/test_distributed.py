"""Distribution-layer tests: sharding rules, HLO collective analysis,
gradient compression, and a miniature multi-device dry run. Multi-device
cases run in a subprocess so the main test session keeps 1 CPU device."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.hlo_analysis import _shape_bytes, analyze_collectives

# multi-device subprocess compiles put the whole module in the slow tier
pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str, devices: int = 8, timeout: int = 560):
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          env=env, capture_output=True, text=True,
                          timeout=timeout)


class TestHLOAnalysis:
    def test_shape_bytes(self):
        assert _shape_bytes("f32[16,768]") == 16 * 768 * 4
        assert _shape_bytes("(bf16[8,4]{1,0}, s8[2,2])") == 64 + 4
        assert _shape_bytes("pred[10]") == 10

    def test_loop_multiplier(self):
        hlo = textwrap.dedent("""\
        HloModule test
        %cond (p: (s32[], f32[8])) -> pred[] {
          %p = (s32[], f32[8]) parameter(0)
          %i = s32[] get-tuple-element(%p), index=0
          %n = s32[] constant(7)
          ROOT %lt = pred[] compare(%i, %n), direction=LT
        }
        %body (p2: (s32[], f32[8])) -> (s32[], f32[8]) {
          %p2 = (s32[], f32[8]) parameter(0)
          %x = f32[8] get-tuple-element(%p2), index=1
          %ar = f32[8] all-reduce(%x), to_apply=%add
          ROOT %t = (s32[], f32[8]) tuple(%i2, %ar)
        }
        ENTRY %main (a: f32[8]) -> f32[8] {
          %a = f32[8] parameter(0)
          %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body
          %big = f32[128] all-gather(%a), dimensions={0}
          ROOT %r = f32[8] get-tuple-element(%w), index=1
        }
        """)
        r = analyze_collectives(hlo)
        assert r["by_op"]["all-reduce"] == 7 * 8 * 4   # trip count 7
        assert r["by_op"]["all-gather"] == 128 * 4

    def test_real_compiled_module(self):
        """End-to-end on an actual compiled GSPMD program: a scan of
        column->row tensor-parallel matmul pairs — the serve wave's layer
        structure in miniature. The row-parallel product forces one
        all-reduce per scan step, and the analyzer must recover the scan
        trip count as the site's loop multiplier."""
        code = """
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        mesh = jax.make_mesh((8,), ("model",))
        w1 = jax.device_put(jnp.ones((5, 16, 64)),
                            NamedSharding(mesh, P(None, None, "model")))
        w2 = jax.device_put(jnp.ones((5, 64, 16)),
                            NamedSharding(mesh, P(None, "model", None)))
        def f(x, w1, w2):
            def body(c, ws):
                a, b = ws
                h = jnp.maximum(c @ a, 0.0)
                return c + h @ b, None
            c, _ = jax.lax.scan(body, x, (w1, w2))
            return c
        with mesh:
            hlo = jax.jit(f).lower(jnp.ones((4, 16)), w1,
                                   w2).compile().as_text()
        from repro.runtime.hlo_analysis import (analyze_collectives,
                                                collective_counts,
                                                pool_allgather_sites)
        r = analyze_collectives(hlo)
        mults = {s["mult"] for s in r["per_site"]}
        assert r["by_op"].get("all-reduce"), "row-parallel all-reduce lost"
        assert 5.0 in mults, mults   # scan trip count recovered
        assert collective_counts(hlo).get("all-reduce", 0) >= 1
        assert pool_allgather_sites(hlo) == []   # f32 program: no s8 pool
        print("OK")
        """
        r = run_subprocess(code)
        assert "OK" in r.stdout, r.stdout + r.stderr


class TestShardingRules:
    def test_param_specs_divisible(self):
        """Every spec produced for every arch divides its dims on the
        production mesh axis sizes (checked symbolically, 1 device)."""
        from repro.configs import ARCH_IDS, get_config
        from repro.launch.specs import param_struct
        from repro.runtime.sharding import param_spec, _path_str

        class FakeMesh:
            shape = {"pod": 2, "data": 16, "model": 16}
            axis_names = ("pod", "data", "model")

        for arch in ARCH_IDS:
            cfg = get_config(arch)
            ps = param_struct(cfg)
            flat, _ = jax.tree_util.tree_flatten_with_path(ps)
            for path, leaf in flat:
                spec = param_spec(cfg, FakeMesh(), _path_str(path),
                                  leaf.shape)
                assert len(spec) <= len(leaf.shape), (arch, path)
                for dim, ax in zip(leaf.shape, tuple(spec)):
                    if ax is None:
                        continue
                    size = FakeMesh.shape[ax] if isinstance(ax, str) else \
                        int(np.prod([FakeMesh.shape[a] for a in ax]))
                    assert dim % size == 0, (arch, _path_str(path), spec)

    def test_moe_expert_parallel_choice(self):
        """64-expert moonshot shards experts; 8-expert mixtral uses TP."""
        from repro.configs import get_config
        from repro.runtime.sharding import param_spec

        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")

        moon = param_spec(get_config("moonshot-v1-16b-a3b"), FakeMesh(),
                          "segments/0/0/moe/wg/w", (48, 64, 2048, 1408))
        assert tuple(moon) == (None, "model", None, None)
        mix = param_spec(get_config("mixtral-8x7b"), FakeMesh(),
                         "segments/0/0/moe/wg/w", (32, 8, 4096, 14336))
        assert tuple(mix) == (None, None, None, "model")


class TestCompression:
    def test_compressed_psum_matches_mean(self):
        """int8-compressed all-reduce approximates the true mean; error
        feedback drives the *accumulated* bias to zero over steps."""
        code = """
        import jax, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.runtime.compression import compressed_psum, \\
            init_error_feedback
        mesh = jax.make_mesh((8,), ("data",))
        g_global = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        true_mean = jnp.mean(g_global, 0)

        def step(g, e):
            gs, e2 = compressed_psum({"w": g}, e, "data")
            return gs["w"], e2

        f = shard_map(step, mesh=mesh,
                      in_specs=(P("data"), {"w": P("data")}),
                      out_specs=(P("data"), {"w": P("data")}))
        err = init_error_feedback({"w": g_global})
        out, err = f(g_global, err)
        rel = float(jnp.linalg.norm(out[0] - true_mean)
                    / jnp.linalg.norm(true_mean))
        assert rel < 0.02, rel
        # error feedback: residual bounded by one quantization step
        assert float(jnp.max(jnp.abs(err["w"]))) < float(
            jnp.max(jnp.abs(g_global))) / 100.0
        print("OK", rel)
        """
        r = run_subprocess(code)
        assert "OK" in r.stdout, r.stdout + r.stderr


class TestMiniDryRun:
    @pytest.mark.slow
    def test_mini_mesh_train_compile(self):
        """A reduced arch train step lowers + compiles on a (2,2,2) pod
        mesh with the real sharding rules — the dry-run path in miniature."""
        code = """
        import jax, jax.numpy as jnp
        from repro.configs import get_reduced_config
        from repro.configs.base import ShapeConfig, TrainConfig
        from repro.launch.specs import (batch_struct, opt_struct,
                                        param_struct, sds)
        from repro.launch.steps import make_train_step
        from repro.runtime.sharding import (batch_shardings, opt_shardings,
                                            param_shardings)
        cfg = get_reduced_config("qwen2.5-3b").replace(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=256)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        shape = ShapeConfig("t", "train", 64, 8)
        ps = param_struct(cfg)
        psh = param_shardings(cfg, mesh, ps)
        bs = batch_struct(cfg, shape, with_labels=True)
        with mesh:
            fn = make_train_step(cfg, TrainConfig())
            low = jax.jit(fn, in_shardings=(
                psh, psh, opt_shardings(psh, opt_struct(ps)),
                batch_shardings(mesh, bs), None)).lower(
                ps, ps, opt_struct(ps), bs, sds((), jnp.int32))
            comp = low.compile()
        # jax 0.4.3x returns a one-element list of cost dicts
        ca = comp.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        assert ca["flops"] > 0
        print("OK", int(ca["flops"]))
        """
        r = run_subprocess(code, devices=8)
        assert "OK" in r.stdout, r.stdout + r.stderr
