"""Property test: BlockAllocator conservation under random op sequences.

Drives reserve/register/ensure/share(COW)/release/swap-like churn with
Hypothesis and checks, after every op, that block conservation holds
(every block is in exactly one of free / evictable / mapped, and refcounts
equal the number of table views), that refcounts never go negative, and
that released slots leave only sentinel table entries. ``prefix_cache``
traffic is generated from a tiny token alphabet so chains genuinely
collide and share.
"""
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve.block_alloc import BlockAllocator, PoolDry  # noqa: E402

NUM_BLOCKS, BLOCK_SIZE, SLOTS, TABLE_LEN = 8, 4, 4, 6
MAX_TOKENS = TABLE_LEN * BLOCK_SIZE


def _op():
    return st.one_of(
        st.tuples(st.just("reserve"), st.integers(0, SLOTS - 1),
                  st.integers(1, MAX_TOKENS)),
        st.tuples(st.just("register"), st.integers(0, SLOTS - 1),
                  st.integers(1, MAX_TOKENS)),
        st.tuples(st.just("ensure"), st.integers(0, SLOTS - 1),
                  st.integers(1, MAX_TOKENS)),
        st.tuples(st.just("cow"), st.integers(0, SLOTS - 1),
                  st.integers(0, MAX_TOKENS - 1)),
        st.tuples(st.just("release"), st.integers(0, SLOTS - 1),
                  st.just(0)),
        st.tuples(st.just("harvest_register"), st.integers(0, SLOTS - 1),
                  st.just(0)),
        st.tuples(st.just("trim"), st.integers(0, SLOTS - 1),
                  st.integers(0, MAX_TOKENS)),
    )


@settings(max_examples=120, deadline=None)
@given(st.lists(_op(), min_size=1, max_size=60),
       st.randoms(use_true_random=False))
def test_block_conservation_under_random_lifecycle(ops, rnd):
    a = BlockAllocator(NUM_BLOCKS, BLOCK_SIZE, SLOTS, TABLE_LEN,
                       prefix_cache=True)
    prompts = {}                       # slot -> tokens it claims to hold
    written = {}                       # slot -> tokens ensured so far

    for kind, slot, n in ops:
        active = slot in prompts
        if kind in ("reserve", "register") and not active:
            # tiny alphabet -> real chain collisions across iterations
            toks = np.asarray(rnd.choices(range(3), k=n), np.int32)
            ids, cached, partial = a.lookup(toks)
            if kind == "reserve":
                if a.reserve(slot, n, shared=ids, partial=partial):
                    prompts[slot] = toks
                    written[slot] = cached
            else:
                a.register(slot, shared=ids)
                prompts[slot] = toks
                written[slot] = cached
        elif kind == "ensure" and active:
            target = min(n, MAX_TOKENS)
            try:
                a.ensure(slot, target)
            except (PoolDry, RuntimeError):
                pass                   # dry pool / reservation exhausted
            else:
                covered = len(a.owned(slot)) * BLOCK_SIZE
                start = written[slot]
                end = min(max(target, start), covered)
                if end > start:
                    try:
                        a.cow_range(slot, start, end)
                    except (PoolDry, RuntimeError):
                        pass           # partially applied: still consistent
                    else:
                        written[slot] = end
        elif kind == "cow" and active:
            end = min(n + 1, len(a.owned(slot)) * BLOCK_SIZE)
            if end > n:
                try:
                    a.cow_range(slot, n, end)
                except (PoolDry, RuntimeError):
                    pass
        elif kind == "release" and active:
            a.release(slot)
            assert (a.tables[slot] == NUM_BLOCKS).all()
            prompts.pop(slot)
            written.pop(slot)
        elif kind == "harvest_register" and active:
            upto = min(written[slot], len(prompts[slot]))
            a.register_prefix(slot, prompts[slot], upto)
        elif kind == "trim" and active:
            # speculative rollback: drop whole blocks past n tokens
            # (refcounts of shared blocks drop, indexed blocks park on
            # the LRU, boundary index entries are repaired)
            a.trim(slot, n)
            assert len(a.owned(slot)) <= a.blocks_for_tokens(n)
            written[slot] = min(written[slot],
                                len(a.owned(slot)) * BLOCK_SIZE)
        a.check()                      # conservation after every op

    # full teardown returns every block to free/evictable
    for slot in list(prompts):
        a.release(slot)
    a.check()
    assert a.allocated_blocks == 0
    assert len(a._free) + a.cached_blocks == NUM_BLOCKS
    assert (a.tables == NUM_BLOCKS).all()
    assert all(r == 0 for r in a._ref)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(1, MAX_TOKENS), min_size=1, max_size=10))
def test_double_release_is_rejected_and_free_never_overflows(sizes):
    a = BlockAllocator(NUM_BLOCKS, BLOCK_SIZE, SLOTS, TABLE_LEN)
    for i, n in enumerate(sizes):
        slot = i % SLOTS
        if slot not in a._owned and a.reserve(slot, n):
            a.ensure(slot, n)
            a.release(slot)
            # a second release of the same slot is a no-op (idempotent by
            # design: the slot no longer owns anything)
            assert a.release(slot) == 0
            assert len(a._free) <= NUM_BLOCKS
            a.check()
