"""Data pipeline determinism/checkpointing + serving engine behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.data import (MixtureIterator, ShardedLoader, SyntheticConfig,
                        calibration_batches)
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine


class TestData:
    def test_deterministic_given_step(self):
        cfg = SyntheticConfig(vocab_size=128, seq_len=16, batch_size=2)
        a = next(MixtureIterator(cfg, start_step=5))
        b = next(MixtureIterator(cfg, start_step=5))
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_iterator_checkpoint_resume(self):
        cfg = SyntheticConfig(vocab_size=128, seq_len=16, batch_size=2)
        it = MixtureIterator(cfg)
        next(it)
        state = it.state_dict()
        b1 = next(it)
        it2 = MixtureIterator(cfg)
        it2.load_state_dict(state)
        b2 = next(it2)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = SyntheticConfig(vocab_size=128, seq_len=16, batch_size=2)
        b = next(MixtureIterator(cfg))
        assert b["tokens"].shape == b["labels"].shape == (2, 16)
        # labels[t] == tokens[t+1] within the same document
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_mixture_masking(self):
        cfg = SyntheticConfig(vocab_size=128, seq_len=32, batch_size=64,
                              dclm_ratio=0.25, seed=3)
        b = next(MixtureIterator(cfg))
        frac_masked_rows = float(np.mean(np.any(b["loss_mask"] == 0, axis=1)))
        assert 0.5 < frac_masked_rows < 0.95     # ~75% SFT rows masked

    def test_calibration_disjoint_from_training(self):
        cfg = SyntheticConfig(vocab_size=128, seq_len=16, batch_size=2)
        cb = calibration_batches(cfg, 2)
        tr = next(MixtureIterator(cfg))
        assert not np.array_equal(cb[0]["tokens"], tr["tokens"])

    def test_sharded_loader_prefetch(self):
        cfg = SyntheticConfig(vocab_size=128, seq_len=16, batch_size=2)
        loader = ShardedLoader(MixtureIterator(cfg), mesh=None, prefetch=2)
        b = next(loader)
        assert b["tokens"].shape == (2, 16)


class TestServeEngine:
    def test_serves_all_requests(self, rng):
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        eng = ServeEngine(cfg, params, slots=2, cache_len=64)
        reqs = [Request(uid=i,
                        prompt=np.arange(8, dtype=np.int32) + i,
                        max_new_tokens=4) for i in range(5)]
        for r in reqs:
            eng.submit(r)
        stats = eng.run_until_drained()
        assert all(r.done for r in reqs)
        assert all(len(r.generated) == 4 for r in reqs)
        assert stats["tokens_out"] >= 5 * 3

    def test_eos_stops_early(self, rng):
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        eng = ServeEngine(cfg, params, slots=1, cache_len=64)
        r = Request(uid=0, prompt=np.arange(8, dtype=np.int32),
                    max_new_tokens=32, eos_id=-2)  # unreachable eos
        eng.submit(r)
        eng.run_until_drained(max_steps=40)
        assert len(r.generated) == 32

    def test_slot_reuse(self, rng):
        cfg = get_reduced_config("xlstm-125m")
        params = init_params(cfg, rng)
        eng = ServeEngine(cfg, params, slots=1, cache_len=32)
        for i in range(3):
            eng.submit(Request(uid=i, prompt=np.arange(4, dtype=np.int32),
                               max_new_tokens=2))
        stats = eng.run_until_drained()
        # each request: 1 token from prefill + 1 decoded token
        assert stats["tokens_out"] == 6
