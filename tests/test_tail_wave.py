"""Batched tail prefill (the tail-wave) + its satellite bugfixes.

Covers: token parity of N simultaneous prefix-hit admissions against the
serialized single-slot path (greedy and sampled, including a COW at the
split block during the wave), concurrent long-prompt chunked prefills
sharing one wave, prefix-affinity scheduling, the exact ``_written``
accounting harvested from the device ``n_gen`` counter, the live-PRNG-key
swap record (sampled preempt/resume parity), and the FCFS head-of-line
swap-in policy under mixed record sizes.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import HOT_BYPASS_CAP, Scheduler


def _req(uid, prompt, **kw):
    return Request(uid=uid, prompt=np.asarray(prompt, np.int32), **kw)


@pytest.fixture(scope="module")
def served(rng):
    cfg = get_reduced_config("qwen2.5-3b")
    return cfg, init_params(cfg, rng)


def _shared_reqs(n=4, prefix_len=40, tail=5, max_new=6, **kw):
    """One common prefix (2 full 16-token blocks + an 8-token split
    block), n unique tails — every follower COWs the split block."""
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, 250, prefix_len).astype(np.int32)
    return [_req(i, np.concatenate(
                [prefix,
                 ((np.arange(tail) * (i + 3) + i) % 250).astype(np.int32)]),
                max_new_tokens=max_new, **kw)
            for i in range(n)]


class TestBatchedTailParity:
    BS = 16

    def _engine(self, served, tail_batch, **kw):
        cfg, params = served
        kw.setdefault("slots", 6)
        kw.setdefault("cache_len", 64)
        kw.setdefault("kv_layout", "paged")
        kw.setdefault("block_size", self.BS)
        kw.setdefault("num_blocks", 48)
        kw.setdefault("max_seq_len", 96)
        return ServeEngine(cfg, params, tail_batch=tail_batch, **kw)

    def _run(self, served, tail_batch, reqs):
        """First request warms the prefix cache; the rest arrive as one
        simultaneous burst of prefix hits."""
        eng = self._engine(served, tail_batch)
        eng.submit(reqs[0])
        eng.run_until_drained()
        for r in reqs[1:]:
            eng.submit(r)
        stats = eng.run_until_drained()
        assert all(r.done for r in reqs)
        return [r.generated for r in reqs], stats

    def test_burst_parity_greedy_with_cow_at_split_block(self, served):
        """4 simultaneous prefix-hit tails ride ONE wave and produce the
        exact tokens of the serialized one-tail-per-step path; each
        follower's first window writes into the shared split block, so the
        COW clones happen during the wave."""
        g_wave, s_wave = self._run(served, 0, _shared_reqs())
        g_ser, s_ser = self._run(served, 1, _shared_reqs())
        assert g_wave == g_ser
        # all three followers hit the cached chain and cloned the split
        assert s_wave["prefix_hit_tokens"] == s_ser["prefix_hit_tokens"] > 0
        assert s_wave["cow_copies"] >= 3 and s_ser["cow_copies"] >= 3
        # the wave collapses the followers' admissions into one call
        assert s_wave["prefill_calls"] < s_ser["prefill_calls"]

    def test_burst_parity_sampled(self, served):
        """Same burst with temperature + top-k sampling: per-request PRNG
        streams are independent of wave packing."""
        kw = dict(temperature=0.8, max_new=8)
        reqs_w = _shared_reqs(**kw)
        reqs_s = _shared_reqs(**kw)
        for r in reqs_w + reqs_s:
            r.top_k = 8
            r.seed = 11
        g_wave, _ = self._run(served, 0, reqs_w)
        g_ser, _ = self._run(served, 1, reqs_s)
        assert g_wave == g_ser

    def test_two_long_prompts_share_one_wave(self, served):
        """Chunked prefill is no longer one-prompt-at-a-time: two long
        prompts advance window-by-window in the same wave and match the
        serialized engine's tokens."""
        def reqs():
            return [_req(0, np.arange(40, dtype=np.int32) % 250,
                         max_new_tokens=4),
                    _req(1, (np.arange(36) * 3 % 250).astype(np.int32),
                         max_new_tokens=4)]

        def run(tail_batch):
            eng = self._engine(served, tail_batch, prefill_chunk=16,
                               prefix_cache=False, max_seq_len=128)
            rs = reqs()
            for r in rs:
                eng.submit(r)
            stats = eng.run_until_drained()
            assert all(r.done for r in rs)
            return [r.generated for r in rs], stats

        g_wave, s_wave = run(0)
        g_ser, s_ser = run(1)
        assert g_wave == g_ser
        # same windows computed either way, fewer engine steps batched
        assert s_wave["prefill_chunks"] == s_ser["prefill_chunks"] == 6

    def test_tail_batch_validation(self, served):
        cfg, params = served
        with pytest.raises(ValueError, match="tail_batch"):
            ServeEngine(cfg, params, slots=2, cache_len=64,
                        kv_layout="paged", tail_batch=3)


class TestPrefixAffinity:
    def test_group_key_orders_chain_sharers_back_to_back(self):
        """Requests with equal non-None keys are pulled behind the
        group's first occurrence; keyless requests keep their rank."""
        s = Scheduler("fcfs")
        reqs = [_req(i, np.arange(4) + i) for i in range(5)]
        for r in reqs:
            s.submit(r)
        key = {0: "a", 1: None, 2: "b", 3: "a", 4: "b"}.get
        ordered = s._ordered(group_key=lambda r: key(r.uid))
        assert [r.uid for r in ordered] == [0, 3, 1, 2, 4]
        # select pops in the grouped order, head-of-line stop intact
        picked = s.select(3, group_key=lambda r: key(r.uid))
        assert [r.uid for r in picked] == [0, 3, 1]

    def test_hot_bypass_is_starvation_bounded(self):
        """A steady stream of hot-chain sharers may jump the FCFS head
        only HOT_BYPASS_CAP times; then grouping pauses and the head
        orders first again (non-starvation)."""
        s = Scheduler("fcfs")
        stranger = _req(999, np.arange(4))
        s.submit(stranger)
        gk = (lambda r: "chain" if r.uid != 999 else None)
        for i in range(HOT_BYPASS_CAP + 2):
            sharer = _req(i, np.arange(4) + 100)
            s.submit(sharer)
            head = s.first(group_key=gk, hot={"chain"})
            if i < HOT_BYPASS_CAP:
                assert head is sharer          # hot jumps the stranger
                s.take(sharer)
            else:
                assert head is stranger        # bound reached: head wins
        s.take(stranger)                       # head admitted: bound resets
        assert s.first(group_key=gk, hot={"chain"}).uid != 999

    def test_engine_admits_chain_sharers_before_stranger(self, served):
        """With affinity on, a late request extending the cached chain is
        admitted in the same tail wave as an earlier sharer even though a
        chain-less request sits between them in FCFS order."""
        cfg, params = served
        eng = ServeEngine(cfg, params, slots=2, cache_len=64,
                          kv_layout="paged", block_size=16, num_blocks=32,
                          max_seq_len=96)
        warm = _shared_reqs(1)[0]
        eng.submit(warm)
        eng.run_until_drained()
        sharers = _shared_reqs(3)[1:]       # uids 1, 2: extend the chain
        stranger = _req(7, (np.arange(12) * 13 % 250).astype(np.int32),
                        max_new_tokens=4)
        eng.submit(sharers[0])
        eng.submit(stranger)                # FCFS-between the two sharers
        eng.submit(sharers[1])
        eng.run_until_drained()
        assert all(r.done for r in sharers + [stranger])
        t = {r.uid: r._timing.admit_t for r in sharers + [stranger]}
        assert max(t[1], t[2]) < t[7]       # sharers first, back-to-back


class TestWrittenAccounting:
    def test_written_tracks_device_n_gen_exactly(self, served):
        """After every engine step, the host ``_written`` mirror of each
        resident equals prompt + n_gen - 1 (the newest sampled token's KV
        is not yet committed) — the invariant a swap-out relies on to
        gather only written blocks."""
        cfg, params = served
        eng = ServeEngine(cfg, params, slots=4, cache_len=64,
                          kv_layout="paged", block_size=8, num_blocks=32,
                          max_seq_len=96, decode_block=4, prefill_chunk=16)
        reqs = [_req(0, np.arange(6, dtype=np.int32), max_new_tokens=17),
                _req(1, np.arange(30, dtype=np.int32) % 250,
                     max_new_tokens=5),              # chunked: arms mid-run
                _req(2, np.arange(9, dtype=np.int32) + 3,
                     max_new_tokens=2)]              # finishes mid-chunk
        for r in reqs:
            eng.submit(r)
        for _ in range(40):
            eng.step()
            n_gen = jax.device_get(eng.state["n_gen"])
            for s, r in eng._slot_req.items():
                assert eng._written[s] == len(r.prompt) + int(n_gen[s]) - 1
            if all(r.done for r in reqs):
                break
        assert all(r.done for r in reqs)


class TestSampledPreemptResume:
    def _mk(self, uid, plen, mn):
        r = _req(uid, (np.arange(plen) * 7 + uid) % 250, max_new_tokens=mn)
        r.temperature, r.top_k, r.seed = 0.7, 8, 5
        return r

    def test_sampled_swap_out_resumes_exact_tokens(self, served):
        """Preempt/resume with temperature>0: the swap record carries the
        live per-slot PRNG key, so the resumed stream equals an
        uninterrupted solo run token-for-token."""
        cfg, params = served
        solo_req = self._mk(9, 10, 30)
        solo = ServeEngine(cfg, params, slots=1, cache_len=64,
                           kv_layout="paged", block_size=8, num_blocks=32,
                           max_seq_len=96, decode_block=4)
        solo.submit(solo_req)
        solo.run_until_drained()
        eng = ServeEngine(cfg, params, slots=4, cache_len=64,
                          kv_layout="paged", block_size=8, num_blocks=8,
                          max_seq_len=96, decode_block=4,
                          admission="optimistic", prefix_cache=False)
        reqs = [self._mk(0, 10, 30), self._mk(9, 10, 30),
                self._mk(2, 10, 30)]
        for r in reqs:
            eng.submit(r)
        stats = eng.run_until_drained(max_steps=50_000)
        assert all(r.done for r in reqs)
        assert stats["preemptions"] >= 1
        assert reqs[1].generated == solo_req.generated
        assert eng.alloc.allocated_blocks == 0


class TestSwapInPolicy:
    def test_fcfs_head_blocks_smaller_later_record(self, served):
        """Documented head-of-line policy: when the swap-queue head's
        worst case doesn't fit, a later smaller record that WOULD fit is
        not restored ahead of it (no queue jumping), and nothing is
        restored at all."""
        cfg, params = served
        eng = ServeEngine(cfg, params, slots=3, cache_len=64,
                          kv_layout="paged", block_size=8, num_blocks=10,
                          max_seq_len=96, decode_block=4,
                          admission="optimistic", prefix_cache=False)
        big = _req(0, np.arange(10, dtype=np.int32), max_new_tokens=60)
        small = _req(1, np.arange(8, dtype=np.int32) + 50, max_new_tokens=8)
        rival = _req(2, np.arange(8, dtype=np.int32) + 90,
                     max_new_tokens=40)
        for r in (big, small, rival):
            eng.submit(r)
        eng.step()                          # all three admitted
        slots = {r.uid: s for s, r in eng._slot_req.items()}
        assert set(slots) == {0, 1, 2}
        eng._swap_out(slots[0])             # big first -> queue head
        eng._swap_out(slots[1])             # small behind it
        assert [rec["req"].uid for rec in eng._swapped] == [0, 1]
        # rival keeps enough of the pool that big's worst case (9 blocks)
        # can't fit, while small's (2 blocks) could
        need_big = eng.alloc.blocks_for_tokens(10 + 60 - 1)
        need_small = eng.alloc.blocks_for_tokens(8 + 8 - 1)
        assert need_small <= eng.alloc.free_blocks < need_big
        eng._try_swap_in()
        assert [rec["req"].uid for rec in eng._swapped] == [0, 1]  # intact
        assert len(eng._slot_req) == 1      # nothing restored
        # once the pool recovers, FCFS order restores big before small
        stats = eng.run_until_drained(max_steps=50_000)
        assert big.done and small.done and rival.done
        assert stats["swap_in_bytes"] == stats["swap_out_bytes"] > 0
