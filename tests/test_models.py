"""Model correctness: blockwise attention vs dense, decode-vs-forward
consistency, recurrent chunked-vs-sequential equivalence, collector/scan
plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.qat import make_ctx
from repro.models import decode_step, forward, init_params, prefill
from repro.models.common import blockwise_attention
from repro.models.model import segment_plan


def _dense_attn(q, k, v, causal=True, window=0):
    B, S, H, D = q.shape
    g = H // k.shape[2]
    kr = jnp.repeat(k, g, 2)
    vr = jnp.repeat(v, g, 2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / jnp.sqrt(D)
    i = jnp.arange(S)
    m = jnp.ones((S, S), bool)
    if causal:
        m &= i[:, None] >= i[None, :]
    if window:
        m &= i[:, None] - i[None, :] < window
    s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr)


@pytest.mark.parametrize("causal,window,qc,kc", [
    (True, 0, 64, 64), (True, 0, 37, 51), (False, 0, 64, 64),
    (True, 50, 64, 64), (True, 16, 32, 32)])
def test_blockwise_attention_matches_dense(causal, window, qc, kc, rng):
    B, S, H, Hkv, D = 2, 200, 8, 2, 32
    q = jax.random.normal(rng, (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D))
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              q_chunk=qc, kv_chunk=kc, p_dtype=jnp.float32)
    ref = _dense_attn(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
    # production path: bf16 probability tensor (TPU flash-kernel precision)
    out16 = blockwise_attention(q, k, v, causal=causal, window=window,
                                q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(np.asarray(out16), np.asarray(ref),
                               atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "recurrentgemma-2b",
                                  "xlstm-125m", "mixtral-8x7b"])
def test_decode_matches_teacher_forcing(arch, rng, monkeypatch):
    """Greedy decode over the quantized cache must match positions computed
    by the parallel forward (same fake-quant policy, full-precision cache
    policy C16 so cache round-trip noise can't mask a logic bug)."""
    # capacity-dropping makes MoE prefix-inconsistent by design; give the
    # dispatch unbounded capacity for this logic test
    from repro.models import blocks as _blocks
    monkeypatch.setattr(_blocks, "MOE_CAPACITY_FACTOR", 100.0)
    cfg = get_reduced_config(arch)
    params = init_params(cfg, rng, dtype=jnp.float32)
    ctx = make_ctx("A16-C16-W16", mode="off")   # logic test, not noise test
    B, S = 1, 24
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    logits_all, _ = forward(cfg, params, ctx, {"tokens": tokens})
    # prefill on the first S-4 tokens, decode the next 4 teacher-forced
    split = S - 4
    lg_p, cache = prefill(cfg, params, ctx, {"tokens": tokens[:, :split]},
                          cache_budget=S + 4)
    np.testing.assert_allclose(np.asarray(lg_p[:, 0]),
                               np.asarray(logits_all[:, split - 1]),
                               atol=2e-2, rtol=2e-2)
    for t in range(split, S):
        lg_d, cache = decode_step(cfg, params, ctx, tokens[:, t:t + 1],
                                  cache)
        np.testing.assert_allclose(np.asarray(lg_d[:, 0]),
                                   np.asarray(logits_all[:, t]),
                                   atol=2e-2, rtol=2e-2)


def test_segment_plan_remainders():
    cfg = get_reduced_config("recurrentgemma-2b")   # 3 layers, pattern RRA
    plan = segment_plan(cfg)
    assert plan == [(("rglru", "rglru", "local_attn"), 1)]
    cfg26 = cfg.replace(n_layers=26)
    plan = segment_plan(cfg26)
    assert plan[0] == (("rglru", "rglru", "local_attn"), 8)
    assert plan[1] == (("rglru", "rglru"), 1)
    assert sum(len(k) * r for k, r in plan) == 26


def test_calib_collector_structure_matches_layers(rng):
    """Stats stack along the scan axis: leading dim == segment repeat."""
    cfg = get_reduced_config("qwen3-14b").replace(n_layers=4)
    params = init_params(cfg, rng)
    ctx = make_ctx("A8s-C8-W4", mode="calib")
    batch = {"tokens": jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)}
    _, aux = forward(cfg, params, ctx, batch, collect_stats=True)
    st = aux["qstats"]["segments"][0]["0"]
    assert st["attn"]["wq"]["s_in"].shape == (4,)
    assert st["attn"]["s_q"].shape == (4,)


def test_remat_preserves_values(rng):
    cfg = get_reduced_config("qwen2.5-3b")
    params = init_params(cfg, rng, dtype=jnp.float32)
    ctx = make_ctx("A8d-C8-W4")
    batch = {"tokens": jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)}
    l0, _ = forward(cfg, params, ctx, batch, remat=False)
    l1, _ = forward(cfg, params, ctx, batch, remat=True)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=1e-5)


def test_vlm_mrope_text_equivalence(rng):
    """With all three position streams equal, M-RoPE == standard RoPE, so a
    VLM forward on pure text must match the same model without mrope."""
    cfg = get_reduced_config("qwen2-vl-2b").replace(vision_tokens=0)
    params = init_params(cfg, rng, dtype=jnp.float32)
    ctx = make_ctx("A16-C16-W16", mode="off")
    B, S = 2, 16
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    pos = jnp.tile(jnp.arange(S), (3, B, 1))
    l_mrope, _ = forward(cfg, params, ctx,
                         {"tokens": tokens, "positions": pos})
    cfg_std = cfg.replace(mrope=False)
    l_std, _ = forward(cfg_std, params, ctx, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(l_mrope), np.asarray(l_std),
                               atol=1e-4)


def test_whisper_uses_encoder(rng):
    """Decoder logits must depend on the encoder frames (cross-attention)."""
    cfg = get_reduced_config("whisper-large-v3")
    params = init_params(cfg, rng, dtype=jnp.float32)
    ctx = make_ctx("A16-C16-W16", mode="off")
    tokens = jax.random.randint(rng, (1, 8), 0, cfg.vocab_size)
    f1 = jax.random.normal(rng, (1, cfg.encoder_seq, cfg.d_model))
    # note: a constant frame offset would be annihilated by LayerNorm; use
    # independent content
    f2 = jax.random.normal(jax.random.PRNGKey(99), f1.shape)
    l1, _ = forward(cfg, params, ctx, {"tokens": tokens, "frames": f1})
    l2, _ = forward(cfg, params, ctx, {"tokens": tokens, "frames": f2})
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-3
