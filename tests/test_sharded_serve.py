"""Tensor-parallel sharded serving: rules, parity, and HLO gates.

Two tiers in one file:

* **Always-on (1 device)** — `runtime/sharding.py` rules on serve-shaped
  pytrees (w4a8 packed-nibble planes, per-channel `s_w` co-sharding, the
  non-divisible fallback-to-replication path, the serve pool spec that
  must never shard the global block-id axis), the HLO collective-count /
  pool-all-gather helpers on synthetic modules, and the mesh-factory /
  engine-knob validation errors.
* **Mesh-backed (CI `mesh` job)** — skipped unless the session was
  launched with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
  Bit-exact token-stream parity between a tp=1 engine and tp=2 / tp=4
  engines for greedy+sampled, speculative-decode, and
  preempt/swap-resume serving; ~1/tp per-device pool + packed-weight
  bytes; and a compiled decode wave whose only collectives are the
  canonical TP pair (row-parallel all-reduce, sampled-logit all-gather)
  — no KV-pool all-gather.

Everything runs under ``weights_layout="w4a8"``: the packed path's
integer gemm partials stay below 2^24, so the row-parallel all-reduce is
exact in f32 and sharded serving is *bitwise* tp=1-equivalent, not just
close.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.precision import parse_policy
from repro.core.qat import (attach_w4a8_exports, attach_w4a8_ref_planes,
                            calibrate_weight_scales)
from repro.models import init_params
from repro.runtime.hlo_analysis import (collective_counts, collective_sites,
                                        pool_allgather_sites)
from repro.runtime.sharding import (param_spec, serve_cache_spec,
                                    _path_str)
from repro.serve.engine import Request, ServeEngine
from repro.serve.spec import SpecConfig

POLICY = "A8d-C8-W4"

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(the CI mesh job sets it)")


class FakeMesh:
    axis_names = ("data", "model")

    def __init__(self, data=4, model=2):
        self.shape = {"data": data, "model": model}


def _w4a8_tree(cfg):
    params = init_params(cfg, jax.random.PRNGKey(0))
    params = calibrate_weight_scales(params, parse_policy(POLICY))
    params = attach_w4a8_exports(params, parse_policy(POLICY))
    return attach_w4a8_ref_planes(params)


# ---------------------------------------------------------------------------
# Sharding rules on serve-shaped pytrees (1 device, fast tier)
# ---------------------------------------------------------------------------

class TestW4A8ParamSpecs:
    def test_export_planes_follow_owner(self):
        """Packed planes shard like the linear they shadow: column owners
        split wq on d_out with s_w/wf on the output channel; row owners
        split wq on the packed d_in/2 axis with s_w replicated."""
        cfg = get_reduced_config("qwen2.5-3b").replace(n_kv_heads=4)
        mesh = FakeMesh(model=2)
        flat, _ = jax.tree_util.tree_flatten_with_path(_w4a8_tree(cfg))
        seen = set()
        for path, leaf in flat:
            p = _path_str(path)
            if "w4a8" not in p.split("/"):
                continue
            parts = p.split("/")
            owner = parts[parts.index("w4a8") - 1]
            key = parts[-1]
            spec = tuple(param_spec(cfg, mesh, p, leaf.shape))
            spec = spec + (None,) * (len(leaf.shape) - len(spec))
            seen.add((owner, key))
            if owner in ("wq", "wk", "wv", "wg", "wu"):    # column-parallel
                if key == "wq":
                    assert spec[-2] == "model" and spec[-1] is None, (p, spec)
                if key == "s_w":
                    assert spec[-1] == "model", (p, spec)
                if key == "wf":
                    assert spec[-1] == "model" and spec[-2] is None, (p, spec)
            elif owner in ("wo", "wd"):                     # row-parallel
                if key == "wq":    # packed d_in/2 still divides (64/2/2)
                    assert spec[-1] == "model" and spec[-2] is None, (p, spec)
                if key == "s_w":   # output channel is device-local: replicate
                    assert spec[-1] is None, (p, spec)
                if key == "wf":
                    assert spec[-2] == "model" and spec[-1] is None, (p, spec)
            elif owner == "head":  # vocab-column-parallel, co-sharded with
                if key == "wq":    # the embed rows it was exported from
                    assert spec[-2] == "model", (p, spec)
                if key == "s_w":
                    assert spec[-1] == "model", (p, spec)
        assert ("wq", "wq") in seen and ("wo", "wq") in seen, seen
        assert ("head", "wq") in seen, "tied-head export missing"

    def test_every_spec_divides(self):
        """No rule may emit an axis that does not divide its dim."""
        cfg = get_reduced_config("qwen2.5-3b").replace(n_kv_heads=4)
        mesh = FakeMesh(model=2)
        flat, _ = jax.tree_util.tree_flatten_with_path(_w4a8_tree(cfg))
        for path, leaf in flat:
            p = _path_str(path)
            spec = param_spec(cfg, mesh, p, leaf.shape)
            assert len(spec) <= len(leaf.shape), (p, spec)
            for dim, ax in zip(leaf.shape[-len(spec):] if len(spec)
                               else (), tuple(spec)):
                if ax is not None:
                    assert dim % mesh.shape[ax] == 0, (p, spec, leaf.shape)

    def test_nondivisible_falls_back_to_replication(self):
        """A mesh axis that divides nothing must replicate everything —
        never raise, never emit a non-dividing axis."""
        cfg = get_reduced_config("qwen2.5-3b")
        mesh = FakeMesh(model=3)        # 3 divides no dim in the reduced cfg
        flat, _ = jax.tree_util.tree_flatten_with_path(_w4a8_tree(cfg))
        for path, leaf in flat:
            p = _path_str(path)
            if "w4a8" not in p.split("/"):
                continue
            spec = tuple(param_spec(cfg, mesh, p, leaf.shape))
            assert all(ax is None for ax in spec), (p, spec)

    def test_odd_packed_axis_replicates(self):
        """Row-parallel wq packs adjacent d_in pairs: when the packed
        d_in/2 axis stops dividing, the leaf replicates instead of
        splitting a nibble pair across devices."""
        cfg = get_reduced_config("qwen2.5-3b")
        spec = param_spec(cfg, FakeMesh(model=2),
                          "segments/0/0/attn/wo/w4a8/wq", (2, 64, 7))
        assert tuple(spec) == (None, None, None) or \
            all(ax is None for ax in tuple(spec))


class TestServeCacheSpec:
    CFG = get_reduced_config("qwen2.5-3b").replace(n_kv_heads=4)

    def test_pool_shards_kv_heads_only(self):
        mesh = FakeMesh(model=2)
        # paged pool leaves: (rep, NB, Hkv, bs, D) / (rep, NB, Hkv, bs)
        kq = serve_cache_spec(self.CFG, mesh,
                              "segments/0/0/self/k_q", (2, 64, 4, 16, 16))
        sk = serve_cache_spec(self.CFG, mesh,
                              "segments/0/0/self/s_k", (2, 64, 4, 16))
        assert tuple(kq) == (None, None, "model", None, None)
        assert tuple(sk) == (None, None, "model", None)

    def test_block_axis_never_shards(self):
        """The leading pool axis is the host allocator's global block-id
        space: it must stay whole even when its size divides every mesh
        axis, or block-table lookups turn into cross-device gathers."""
        for mesh in (FakeMesh(model=2), FakeMesh(data=8, model=2)):
            kq = serve_cache_spec(self.CFG, mesh,
                                  "segments/0/0/self/k_q",
                                  (2, 64, 4, 16, 16))
            assert tuple(kq)[0] is None and tuple(kq)[1] is None

    def test_gqa_nondivisible_replicates(self):
        # Hkv=2 on a 4-way model axis: GQA groups cannot stay local ->
        # the pool replicates rather than erroring
        kq = serve_cache_spec(self.CFG, FakeMesh(model=4),
                              "segments/0/0/self/k_q", (2, 64, 2, 16, 16))
        assert all(ax is None for ax in tuple(kq))

    def test_tables_lengths_replicate(self):
        mesh = FakeMesh(model=2)
        for path, shape in (("block_tbl", (4, 8)), ("position", (4,)),
                            ("segments/0/0/self/length", (2, 4))):
            spec = serve_cache_spec(self.CFG, mesh, path, shape)
            assert all(ax is None for ax in tuple(spec)), (path, spec)


class TestHLOGateHelpers:
    AG_S8 = "%ag = s8[2,131072] all-gather(%pool), dimensions={0}"
    AG_F32 = "%lg = f32[4,256] all-gather(%logits), dimensions={1}"
    AR = "%ar = f32[4,64] all-reduce(%part), to_apply=%add"

    def _mod(self, *lines):
        return "HloModule m\nENTRY %main () -> f32[] {\n" + \
            "\n".join(f"  {l}" for l in lines) + "\n}\n"

    def test_counts_and_sites(self):
        hlo = self._mod(self.AG_F32, self.AR, self.AR)
        assert collective_counts(hlo) == {"all-gather": 1, "all-reduce": 2}
        assert len(collective_sites(hlo)) == 3

    def test_pool_allgather_detection(self):
        hlo = self._mod(self.AG_S8, self.AG_F32, self.AR)
        bad = pool_allgather_sites(hlo)
        assert len(bad) == 1 and bad[0]["bytes"] == 2 * 131072
        # the f32 logit gather and tiny s8 moves are legitimate
        assert pool_allgather_sites(self._mod(self.AG_F32)) == []
        tiny = "%t = s8[8,16] all-gather(%x), dimensions={0}"
        assert pool_allgather_sites(self._mod(tiny)) == []

    def test_start_done_counted_once(self):
        hlo = self._mod(
            "%s = f32[8] all-reduce-start(%x), to_apply=%add",
            "%d = f32[8] all-reduce-done(%s)")
        assert collective_counts(hlo) == {"all-reduce": 1}


class TestMeshValidation:
    def test_local_mesh_rejects_nondividing_tp(self):
        from repro.launch.mesh import make_local_mesh
        n = jax.device_count()
        with pytest.raises(ValueError) as ei:
            make_local_mesh(model_parallel=n + 3)
        assert str(n) in str(ei.value) and str(n + 3) in str(ei.value)

    def test_engine_rejects_mesh_without_model_axis(self):
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
        cfg = get_reduced_config("qwen2.5-3b")
        with pytest.raises(ValueError, match="model"):
            ServeEngine(cfg, None, mesh=mesh)


# ---------------------------------------------------------------------------
# Mesh-backed parity (CI mesh job: 8 forced host devices)
# ---------------------------------------------------------------------------

ENG_KW = dict(policy=POLICY, slots=4, cache_len=128, max_new_cap=32,
              decode_block=4, prefill_bucket=16, kv_layout="paged",
              block_size=16, weights_layout="w4a8")
PREEMPT_KW = dict(policy=POLICY, slots=4, cache_len=128, max_new_cap=32,
                  decode_block=4, prefill_bucket=16, kv_layout="paged",
                  block_size=8, num_blocks=20, admission="optimistic",
                  preempt="last_admitted", weights_layout="w4a8")


def _mixed_reqs(cfg, n=6, max_new=16):
    r = np.random.default_rng(7)
    return [Request(uid=i,
                    prompt=r.integers(1, cfg.vocab_size,
                                      int(r.integers(5, 30))).astype(np.int32),
                    max_new_tokens=max_new, eos_id=-1,
                    temperature=0.0 if i % 2 == 0 else 0.8,
                    top_k=0 if i % 3 == 0 else 8, seed=100 + i)
            for i in range(n)]


def _run(cfg, params, mesh, kw, reqs):
    eng = ServeEngine(cfg, params, mesh=mesh, **kw)
    for rq in reqs:
        eng.submit(rq)
    eng.run_until_drained()
    return [tuple(rq.generated) for rq in reqs], eng.stats(), eng


@pytest.fixture(scope="module")
def served4():
    cfg = get_reduced_config("qwen2.5-3b").replace(n_kv_heads=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, calibrate_weight_scales(params, parse_policy(POLICY))


@pytest.fixture(scope="module")
def served2():
    cfg = get_reduced_config("qwen2.5-3b")      # n_kv_heads=2: GQA groups
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, calibrate_weight_scales(params, parse_policy(POLICY))


def _mesh(tp):
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh(model_parallel=tp)


@needs_mesh
class TestStreamParity:
    @pytest.mark.parametrize("tp", [2, 4])
    def test_greedy_sampled(self, served4, tp):
        cfg, params = served4
        base, st1, _ = _run(cfg, params, None, ENG_KW, _mixed_reqs(cfg))
        got, st2, _ = _run(cfg, params, _mesh(tp), ENG_KW, _mixed_reqs(cfg))
        assert got == base
        assert st2["tp_degree"] == tp and st2["mesh_shape"]["model"] == tp
        # per-device pool + packed-weight bytes scale ~1/tp (the pool's
        # replicated length rows and the non-dividing odd leaves keep it
        # from being exactly 1/tp)
        assert st2["per_device_pool_bytes"] <= 1.2 * st1[
            "per_device_pool_bytes"] / tp
        assert st2["per_device_weight_bytes"] <= 1.2 * st1[
            "per_device_weight_bytes"] / tp

    def test_gqa_grouped_parity(self, served2):
        """n_kv_heads=2 on tp=2: one KV head (4 grouped q heads) per
        device — the grouped decode grid survives per shard."""
        cfg, params = served2
        base, _, _ = _run(cfg, params, None, ENG_KW, _mixed_reqs(cfg))
        got, st, _ = _run(cfg, params, _mesh(2), ENG_KW, _mixed_reqs(cfg))
        assert got == base
        assert st["tp_degree"] == 2

    @pytest.mark.parametrize("tp", [2, 4])
    def test_spec_decode(self, served4, tp):
        cfg, params = served4
        kw = dict(ENG_KW, spec=SpecConfig(k=3, draft_layers=1,
                                          accept_mode="exact"))
        base, st1, _ = _run(cfg, params, None, kw, _mixed_reqs(cfg))
        got, st2, _ = _run(cfg, params, _mesh(tp), kw, _mixed_reqs(cfg))
        assert got == base
        assert st2["spec_waves"] > 0 and st2["spec_accepted"] > 0
        # acceptance itself must be sharding-invariant, not just tokens
        assert st2["spec_accepted"] == st1["spec_accepted"]

    @pytest.mark.parametrize("tp", [2, 4])
    def test_preempt_swap_resume(self, served4, tp):
        cfg, params = served4
        reqs = lambda: _mixed_reqs(cfg, n=8, max_new=20)
        base, st1, _ = _run(cfg, params, None, PREEMPT_KW, reqs())
        got, st2, _ = _run(cfg, params, _mesh(tp), PREEMPT_KW, reqs())
        assert st1["preemptions"] > 0, "workload never preempted"
        assert st2["preemptions"] == st1["preemptions"]
        assert got == base


@needs_mesh
class TestShardedWaveHLO:
    def test_decode_wave_collectives(self, served4):
        """The compiled decode chunk's only collectives are the canonical
        TP set: row-parallel all-reduces (wo / w2, plus the exact
        dynamic-A8 amax reductions) and the sampled-logit all-gather.
        No s8 pool buffer is ever gathered."""
        cfg, params = served4
        mesh = _mesh(2)
        eng = ServeEngine(cfg, params, mesh=mesh, **ENG_KW)
        with mesh:
            hlo = jax.jit(eng._decode_chunk, static_argnums=(2,)).lower(
                eng.params, eng._probe_state(), False).compile().as_text()
        counts = collective_counts(hlo)
        assert counts.get("all-reduce", 0) >= 1, counts
        assert counts.get("all-gather", 0) <= 2, counts
        assert pool_allgather_sites(hlo) == [], \
            [s["line"] for s in pool_allgather_sites(hlo)]

    def test_state_shardings_survive_serving(self, served4):
        """After a full serve run the pool is still sharded on the KV-head
        dim and the token buffers replicated — no drift through the
        donated waves."""
        cfg, params = served4
        _, _, eng = _run(cfg, params, _mesh(2), ENG_KW, _mixed_reqs(cfg))
        kq = eng.state["cache"]["segments"][0]["0"]["self"]["k_q"]
        spec = tuple(kq.sharding.spec) + (None,) * 5
        assert spec[2] == "model", kq.sharding
        assert all(ax is None for ax in tuple(eng.state["out"].sharding.spec))


@needs_mesh
class TestProbeMemoKeying:
    def test_mesh_in_probe_key(self, served4):
        """A tp=2 decode_block="auto" probe result must not be replayed
        for tp=1 (different per-step cost) — the memo key carries the
        mesh shape."""
        from repro.serve.engine import _PROBE_CACHE
        cfg, params = served4
        kw = dict(ENG_KW, decode_block="auto")
        ServeEngine(cfg, params, **kw)
        ServeEngine(cfg, params, mesh=_mesh(2), **kw)
        tails = {k[-1] for k in _PROBE_CACHE if k[0] == cfg.name}
        assert None in tails
        assert any(t is not None and ("model", 2) in t for t in tails), tails
