"""Weight-quantized (w4a8) serving path.

Covers the offline export/attach machinery (int4 packing, scale re-grid,
placeholder-scale fallback, tied-head export, byte accounting), the strict
qlinear dispatch (no silent bf16 fallback), interpret-mode Pallas-vs-XLA-ref
bit parity of the packed matmul across odd shapes / exact tiles / cross-tile
boundaries / bias, and end-to-end token parity of a w4a8-Pallas engine
against the w4a8 XLA-ref engine — greedy, sampled, speculative decode, and
preempt/swap-resume.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.precision import parse_policy
from repro.core.qat import (attach_w4a8_exports, attach_w4a8_ref_planes,
                            calibrate_weight_scales, export_linear_w4,
                            init_linear, make_ctx, qlinear,
                            w4a8_weight_bytes)
from repro.core.quantizer import pack_int4, unpack_int4
from repro.kernels.w4a8.ops import w4a8_linear, w4a8_matmul
from repro.kernels.w4a8.ref import w4a8_matmul_ref
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine

POLICY = "A8d-C8-W4"


@pytest.fixture(scope="module")
def served(rng):
    """Reduced model with *calibrated* weight scales: uncalibrated
    placeholders round every weight to zero, which would make token-parity
    checks vacuous (all streams degenerate identically)."""
    cfg = get_reduced_config("qwen2.5-3b")
    params = init_params(cfg, rng)
    params = calibrate_weight_scales(params, parse_policy(POLICY))
    return cfg, params


def _rand_case(rng_np, m, k, n, bias):
    x_q = jnp.asarray(rng_np.integers(-127, 128, (m, k)), jnp.int8)
    w_q = jnp.asarray(rng_np.integers(-8, 8, (n, k)), jnp.int8)
    s_x = jnp.asarray(rng_np.random((m, 1)) * 0.1 + 1e-3, jnp.float32)
    s_w = jnp.asarray(rng_np.random((n,)) * 0.1 + 1e-3, jnp.float32)
    b = jnp.asarray(rng_np.standard_normal(n), jnp.float32) if bias else None
    return x_q, pack_int4(w_q), s_x, s_w, b


class TestW4A8MatmulParity:
    """Pallas (interpret off-TPU) vs XLA ref: bit-identical, not just close."""

    # odd everything / sub-tile / exact BM,BK,BN=(256,512,256) tile /
    # one-past-boundary — the pad-and-slice wrapper must be invisible
    @pytest.mark.parametrize("mkn", [(1, 2, 1), (3, 130, 5), (8, 256, 512),
                                     (256, 512, 256), (257, 514, 259)])
    @pytest.mark.parametrize("bias", [False, True])
    def test_pallas_matches_ref_bitwise(self, mkn, bias):
        rng_np = np.random.default_rng(sum(mkn) + bias)
        x_q, wp, s_x, s_w, b = _rand_case(rng_np, *mkn, bias)
        ref = w4a8_matmul(x_q, wp, s_x, s_w, b, use_pallas=False)
        pal = w4a8_matmul(x_q, wp, s_x, s_w, b, use_pallas=True)
        assert ref.dtype == pal.dtype == jnp.bfloat16
        if b is None:
            # integer accumulate + scale multiplies round identically
            assert bool(jnp.all(ref == pal))
        else:
            # XLA may contract the ref's ``y * s_w + b`` into an FMA the
            # (differently fused) Pallas graph doesn't use, moving isolated
            # elements by one bf16 ulp.  Bound it: everything within 1 ulp,
            # and at most a vanishing fraction differs at all.
            r32 = ref.astype(jnp.float32)
            p32 = pal.astype(jnp.float32)
            # one bf16 ulp of v is 2**(floor(log2 |v|) - 7) <= |v| * 2**-7
            ulp = 2.0 ** -7 * jnp.maximum(
                jnp.maximum(jnp.abs(r32), jnp.abs(p32)), 2.0 ** -126)
            assert bool(jnp.all(jnp.abs(r32 - p32) <= ulp))
            mismatched = int(jnp.sum(ref != pal))
            assert mismatched <= max(1, ref.size // 10_000)

    def test_ref_matches_int32_oracle(self):
        """The f32-accumulation fast path reproduces exact integer math."""
        rng_np = np.random.default_rng(0)
        x_q, wp, s_x, s_w, b = _rand_case(rng_np, 9, 258, 33, True)
        oracle = (jnp.dot(x_q.astype(jnp.int32),
                          unpack_int4(wp).T.astype(jnp.int32))
                  .astype(jnp.float32) * s_x * s_w[None, :] + b[None, :]
                  ).astype(jnp.bfloat16)
        got = w4a8_matmul_ref(x_q, wp, s_x, s_w, b)
        assert bool(jnp.all(oracle == got))

    def test_cached_plane_identical_to_unpack(self):
        """The engine's ref-backend decode cache (``wf``) changes nothing."""
        rng_np = np.random.default_rng(1)
        x_q, wp, s_x, s_w, b = _rand_case(rng_np, 4, 64, 48, True)
        plane = jnp.swapaxes(unpack_int4(wp), -1, -2)
        a = w4a8_matmul_ref(x_q, wp, s_x, s_w, b)
        c = w4a8_matmul_ref(x_q, wp, s_x, s_w, b, w_unpacked=plane)
        assert bool(jnp.all(a == c))


class TestExportAttach:
    def test_export_shapes_and_dtypes(self, rng):
        p = init_linear(rng, 64, 48, bias=True)
        exp = export_linear_w4(p)
        assert exp["wq"].shape == (48, 32) and exp["wq"].dtype == jnp.uint8
        assert exp["s_w"].shape == p["s_w"].shape
        assert exp["s_w"].dtype == jnp.float32
        assert "b" in exp

    def test_odd_d_in_rejected(self, rng):
        with pytest.raises(ValueError, match="even d_in"):
            export_linear_w4(init_linear(rng, 63, 8))

    def test_placeholder_scale_fallback(self, rng):
        """Exactly-1.0 (uncalibrated) channels re-derive absmax/7 so the
        export never quantizes real weights to all-zeros."""
        p = init_linear(rng, 32, 8)
        p["s_w"] = jnp.ones_like(p["s_w"])
        exp = export_linear_w4(p)
        got = unpack_int4(exp["wq"])
        assert int(jnp.sum(jnp.abs(got))) > 0
        # dequant error bounded by half a quantization step per element
        deq = jnp.swapaxes(got, -1, -2).astype(jnp.float32) * exp["s_w"]
        err = jnp.abs(deq - p["w"].astype(jnp.float32))
        assert float(jnp.max(err / exp["s_w"])) <= 0.5 + 1e-3

    def test_head_regrid_from_8bit_lattice(self, rng):
        p = init_linear(rng, 32, 8)
        p["s_w"] = p["s_w"] * 0.01          # calibrated (non-placeholder)
        exp8 = export_linear_w4(p, trained_bits=8)
        # 8-bit-trained scales stretch onto the int4 grid by qmax ratio
        assert jnp.allclose(exp8["s_w"], p["s_w"] * (127.0 / 7.0))

    def test_attach_covers_every_served_linear(self, served):
        cfg, params = served
        tree = attach_w4a8_exports(params, parse_policy(POLICY))
        missing = []

        def walk(t, path):
            if isinstance(t, dict):
                if "w" in t and "s_w" in t and "w4a8" not in t:
                    missing.append(path)
                for k, v in t.items():
                    if isinstance(v, (dict, list, tuple)):
                        walk(v, f"{path}/{k}")
            elif isinstance(t, (list, tuple)):
                for i, v in enumerate(t):
                    walk(v, f"{path}[{i}]")

        walk(tree, "")
        assert not missing
        # tied head: no bf16 "w" of its own, exports from the embedding
        assert "w4a8" in tree["head"] and "w" not in tree["head"]
        assert tree["head"]["w4a8"]["wq"].shape[0] == cfg.vocab_size

    def test_weight_bytes_accounting(self, served):
        _, params = served
        tree = attach_w4a8_exports(params, parse_policy(POLICY))
        by = w4a8_weight_bytes(tree)
        assert 0 < by["packed"] < by["replaced"]
        # the ref-backend decode cache is not part of the packed layout
        assert w4a8_weight_bytes(attach_w4a8_ref_planes(tree)) == by

    def test_qlinear_raises_without_export(self, rng):
        ctx = make_ctx(parse_policy(POLICY), mode="serve",
                       weights_layout="w4a8")
        p = init_linear(rng, 16, 8)
        x = jnp.ones((2, 16), jnp.bfloat16)
        with pytest.raises(ValueError, match="no packed"):
            qlinear(ctx, x, p)

    def test_deployed_linear_tracks_fake_quant(self, rng):
        """w4a8_linear approximates the calibrated fake-quant forward."""
        p = init_linear(rng, 64, 32)
        pol = parse_policy(POLICY)
        p = calibrate_weight_scales({"lin": p}, pol)["lin"]
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 64), jnp.bfloat16)
        ctx = make_ctx(pol, mode="serve")
        fake = qlinear(ctx, x, p)
        real = w4a8_linear(x, export_linear_w4(p), use_pallas=False)
        assert jnp.mean(jnp.abs(fake.astype(jnp.float32)
                                - real.astype(jnp.float32))) < 0.05


class TestW4A8ServeParity:
    """w4a8-Pallas(interpret) and w4a8-XLA-ref engines emit identical
    token streams end-to-end."""

    def _engine(self, served, backend, **kw):
        cfg, params = served
        kw.setdefault("slots", 2)
        kw.setdefault("cache_len", 64)
        kw.setdefault("kv_layout", "paged")
        kw.setdefault("block_size", 16)
        kw.setdefault("prefill_chunk", 8)
        return ServeEngine(cfg, params, policy=POLICY,
                           weights_layout="w4a8", w4a8_backend=backend, **kw)

    def _serve(self, eng, n=4, max_new=8, **req_kw):
        reqs = [Request(uid=i,
                        prompt=np.arange(20 + i, dtype=np.int32) % 60,
                        max_new_tokens=max_new, **req_kw)
                for i in range(n)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        assert all(r.done for r in reqs)
        return [list(r.generated) for r in reqs]

    def test_greedy_parity(self, served):
        ref = self._serve(self._engine(served, "ref"))
        pal = self._serve(self._engine(served, "pallas"))
        assert any(ref) and ref == pal

    def test_sampled_parity(self, served):
        kw = dict(temperature=0.8, top_k=5, seed=7)
        ref = self._serve(self._engine(served, "ref"), **kw)
        pal = self._serve(self._engine(served, "pallas"), **kw)
        assert any(ref) and ref == pal

    def test_spec_decode_parity(self, served):
        ref = self._serve(self._engine(served, "ref", spec={"k": 2}))
        pal = self._serve(self._engine(served, "pallas", spec={"k": 2}))
        assert any(ref) and ref == pal
        # exact verify/rollback: spec output equals the plain w4a8 stream
        assert ref == self._serve(self._engine(served, "ref"))

    def test_preempt_swap_resume_parity(self, served):
        """Over-committed optimistic pool: preempted-and-restored w4a8
        decode resumes bit-exactly, on both backends."""
        def run(backend):
            eng = self._engine(served, backend, slots=4, cache_len=64,
                               block_size=8, num_blocks=8, max_seq_len=96,
                               admission="optimistic", prefix_cache=False,
                               decode_block=4, prefill_chunk=None)
            reqs = [Request(uid=i,
                            prompt=(np.arange(10, dtype=np.int32) * 7 + i)
                            % 250,
                            max_new_tokens=12) for i in range(3)]
            for r in reqs:
                eng.submit(r)
            stats = eng.run_until_drained(max_steps=50_000)
            assert all(r.done for r in reqs)
            assert stats["preemptions"] >= 1
            assert stats["swap_out_bytes"] == stats["swap_in_bytes"] > 0
            return [list(r.generated) for r in reqs]

        ref = run("ref")
        # uninterrupted single-slot run: preemption must not change tokens
        solo = self._engine(served, "ref", slots=1, cache_len=64,
                            block_size=8, num_blocks=32, max_seq_len=96,
                            decode_block=4, prefill_chunk=None)
        solo_req = Request(uid=0,
                          prompt=(np.arange(10, dtype=np.int32) * 7) % 250,
                          max_new_tokens=12)
        solo.submit(solo_req)
        solo.run_until_drained()
        assert ref[0] == list(solo_req.generated)
        assert run("pallas") == ref

    def test_stats_surface(self, served):
        cfg, params = served
        w4 = self._engine(served, "ref")
        st = w4.stats()
        assert st["weights_layout"] == "w4a8"
        assert st["packed_weight_bytes"] > 0
        assert st["weight_hbm_saved_bytes"] > 0
        bf = ServeEngine(cfg, params, policy=POLICY)
        st = bf.stats()
        assert st["weights_layout"] == "bf16"
        assert st["packed_weight_bytes"] == 0
        assert st["weight_hbm_saved_bytes"] == 0

    def test_rejects_incompatible_policy(self, served):
        cfg, params = served
        # parseable policy, but W8 weights have no int4 export to serve
        with pytest.raises(ValueError, match="dynamic-A8 W4"):
            ServeEngine(cfg, params, policy="A8d-C8-W8", weights_layout="w4a8")
        # static-activation policies can't feed the dynamic-A8 kernel either
        with pytest.raises(ValueError, match="dynamic-A8 W4"):
            ServeEngine(cfg, params, policy="A8s-C8-W4", weights_layout="w4a8")
        with pytest.raises(ValueError, match="weights_layout"):
            ServeEngine(cfg, params, policy=POLICY, weights_layout="int4")


class TestServePathLint:
    def test_no_weight_einsum_outside_funnel(self):
        from pathlib import Path
        from repro.analysis.w4a8_lint import check_static
        root = Path(__file__).resolve().parents[1]
        assert check_static(root) == []

    def test_tool_shim_keeps_api(self):
        # the tools/ CLI is a shim over repro.analysis.w4a8_lint; external
        # callers (CI, scripts) rely on its module-level API surviving
        import importlib.util
        from pathlib import Path
        root = Path(__file__).resolve().parents[1]
        spec = importlib.util.spec_from_file_location(
            "check_w4a8_lint", root / "tools" / "check_w4a8_lint.py")
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        assert mod.check_static(root) == []
        assert callable(mod.main) and callable(mod.check_runtime)
