"""Integration: the serve-graph auditor over a real engine.

One module-scoped paged engine serves a short workload (so the wave
registry holds live compile-variant counts), then ``audit_engine``
compiles every wave family abstractly and checks the full rule set —
the same path ``tools/audit_serve.py`` gates in CI. Seeded violations
rebuild a real wave the wrong way (donation dropped, host callback
injected, budget zeroed) and prove the rules fire on engine-shaped
programs, not just synthetic HLO.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (DonationRule, HostTransferRule,
                            RetraceBudgetRule, audit_engine,
                            engine_audit_ctx)
from repro.configs import get_reduced_config
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine

PAGED_KW = dict(slots=4, kv_layout="paged", block_size=16, num_blocks=128,
                max_seq_len=128, prefill_bucket=16, decode_block=4,
                max_new_cap=32)


def _sds(tree):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                       sharding=getattr(a, "sharding", None)),
        tree)


@pytest.fixture(scope="module")
def eng(rng):
    cfg = get_reduced_config("qwen2.5-3b")
    params = init_params(cfg, rng)
    eng = ServeEngine(cfg, params, **PAGED_KW)
    # mixed greedy/sampled so the decode family compiles both variants
    for i in range(5):
        eng.submit(Request(uid=i,
                           prompt=np.arange(1, 10 + i, dtype=np.int32) % 60,
                           max_new_tokens=4,
                           temperature=0.8 if i % 2 else 0.0, seed=i))
    eng.run_until_drained()
    return eng


@pytest.fixture(scope="module")
def report(eng):
    return audit_engine(eng)


class TestCleanAudit:
    def test_every_wave_passes_every_rule(self, report):
        assert report.ok, report.render()

    def test_every_live_family_enumerated(self, report):
        fams = {w.split("[")[0] for w in report.waves}
        assert {"decode", "admit_paged", "tail", "swap_in",
                "cow"} <= fams

    def test_matrix_fully_populated(self, report):
        # every wave-scope rule produced a verdict for every wave
        for wave in report.waves:
            if wave == "(engine)":
                continue
            for rule in ("donation", "host-transfer", "dequant-placement",
                         "collectives"):
                assert report.cells[(rule, wave)] == "ok"

    def test_json_artifact_shape(self, report):
        js = report.to_json()
        assert js["ok"] is True
        assert set(js["matrix"]) == set(report.rules)
        assert js["meta"]["compile_variants"]["decode"] == 2


class TestLiveVariantCounts:
    """Satellite bugfix: engine.stats() surfaces live per-family compile
    counts, and the retrace rule reads the same numbers."""

    def test_stats_reports_compile_variants(self, eng):
        cv = eng.stats()["compile_variants"]
        assert cv == eng.compile_variant_counts()
        # mixed greedy/sampled workload → both decode variants compiled
        assert cv["decode"] == 2
        assert cv["admit_paged"] >= 1
        assert cv["tail"] >= 1

    def test_signatures_recorded_per_compile(self, eng):
        sigs = eng.wave_variant_signatures()
        assert len(sigs["decode"]) == 2
        # one greedy, one sampled trace — distinguished by the static
        assert {s.rsplit(", ", 1)[-1] for s in sigs["decode"]} == \
            {"True)", "False)"}

    def test_budget_zeroed_fires_with_real_signature(self, eng):
        ctx = engine_audit_ctx(eng, budgets={"decode": 0})
        vs = RetraceBudgetRule().check_engine(ctx)
        assert vs and "'decode' compiled 2 variants, budget 0" \
            in vs[0].summary
        assert any("tree#" in s for s in vs[0].sites)


class TestSeededEngineViolations:
    def test_undonated_decode_wave_leaks_the_pool(self, eng):
        # same decode program, donation dropped: the large state leaves
        # (pool planes included) vanish from the alias table
        wave = next(w for w in eng.compiled_waves()
                    if w["family"] == "decode")
        hlo = jax.jit(eng._decode_chunk, static_argnums=(2,)).lower(
            _sds(eng.params), _sds(eng.state), False).compile().as_text()
        vs = DonationRule().check({**wave, "hlo": hlo}, {})
        assert vs, "dropping donation must fire the donation rule"
        assert any("k_q" in s or "v_q" in s for s in vs[0].sites), \
            "the leaked int8 pool planes should be named"

    def test_injected_host_callback_in_wave_body(self, eng):
        from jax.experimental import io_callback
        orig = type(eng)._decode_chunk

        def poisoned(params, state, greedy_only):
            io_callback(lambda v: None, None, state["tokens"])
            return orig(eng, params, state, greedy_only)

        eng._decode_chunk = poisoned       # instance attr shadows method
        try:
            wave = next(w for w in eng.compiled_waves()
                        if w["family"] == "decode")
            hlo = wave["lower"]().compile().as_text()
        finally:
            del eng._decode_chunk
        vs = HostTransferRule().check({**wave, "hlo": hlo}, {})
        assert vs and "host" in vs[0].summary


class TestCli:
    @pytest.mark.slow
    def test_cli_clean_run_writes_artifact(self, tmp_path):
        import importlib.util
        from pathlib import Path
        root = Path(__file__).resolve().parents[1]
        spec = importlib.util.spec_from_file_location(
            "audit_serve", root / "tools" / "audit_serve.py")
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        out = tmp_path / "audit.json"
        # pool sized so legit tail-wave activations stay under the
        # dequant threshold (smaller pools would false-positive)
        rc = mod.main(["--slots", "2", "--num-blocks", "128",
                       "--max-seq-len", "64", "--no-workload",
                       "--out", str(out)])
        assert rc == 0
        import json
        js = json.loads(out.read_text())
        assert js["ok"] is True and js["violations"] == []
