"""KD loss + QAT state-management tests (the paper's training machinery)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.distill import kd_loss, next_token_loss, silq_loss
from repro.core.precision import PAPER_POLICIES, parse_policy
from repro.core.qat import (ACT_SCALE_KEYS, act_scale_mask,
                            calibrate_weight_scales, export_linear_int,
                            init_linear, make_ctx, merge_act_scales, qlinear,
                            scale_mask)
from repro.models import forward, init_params


class TestLosses:
    def test_kd_zero_when_matching(self, rng):
        logits = jax.random.normal(rng, (2, 8, 32))
        # KD of identical distributions == entropy; KL part is zero, so the
        # gradient wrt student at the optimum vanishes
        g = jax.grad(lambda s: kd_loss(s, logits))(logits)
        np.testing.assert_allclose(np.asarray(g), 0.0, atol=1e-6)

    def test_kd_decreases_toward_teacher(self, rng):
        t = jax.random.normal(rng, (2, 8, 32))
        s = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
        l_far = kd_loss(s, t)
        l_near = kd_loss(0.9 * t + 0.1 * s, t)
        assert float(l_near) < float(l_far)

    def test_next_token_loss_perfect_prediction(self):
        labels = jnp.array([[1, 2, 3]])
        logits = jax.nn.one_hot(labels, 8) * 100.0
        assert float(next_token_loss(logits, labels)) < 1e-3

    def test_masking(self, rng):
        logits = jax.random.normal(rng, (1, 4, 16))
        labels = jnp.zeros((1, 4), jnp.int32)
        m1 = jnp.array([[1.0, 1.0, 0.0, 0.0]])
        m2 = jnp.array([[1.0, 1.0, 1.0, 1.0]])
        l1 = next_token_loss(logits, labels, m1)
        l2 = next_token_loss(logits[:, :2], labels[:, :2],
                             jnp.ones((1, 2)))
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)

    def test_silq_ratio_interpolates(self, rng):
        s = jax.random.normal(rng, (2, 4, 16))
        t = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16))
        labels = jnp.zeros((2, 4), jnp.int32)
        lk = silq_loss(s, t, labels, kd_ratio=1.0)
        ln = silq_loss(s, t, labels, kd_ratio=0.0)
        lm = silq_loss(s, t, labels, kd_ratio=0.5)
        np.testing.assert_allclose(float(lm),
                                   0.5 * float(lk) + 0.5 * float(ln),
                                   rtol=1e-5)

    def test_temperature_scaling_bounded_gradient(self, rng):
        """T^2 factor keeps gradient magnitude T-invariant (Hinton)."""
        s = jax.random.normal(rng, (2, 4, 64))
        t = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 64))
        g1 = jax.grad(lambda s: kd_loss(s, t, 1.0))(s)
        g2 = jax.grad(lambda s: kd_loss(s, t, 2.0))(s)
        r = float(jnp.linalg.norm(g2) / jnp.linalg.norm(g1))
        assert 0.3 < r < 3.0


class TestPolicies:
    @pytest.mark.parametrize("name", PAPER_POLICIES)
    def test_parse(self, name):
        p = parse_policy(name)
        assert p.name == name

    def test_parse_fields(self):
        p = parse_policy("A8d-C4-W4")
        assert (p.act_bits, p.act_dynamic, p.cache_bits, p.weight_bits) == \
            (8, True, 4, 4)
        p = parse_policy("A8s-C8-W4")
        assert not p.act_dynamic
        assert parse_policy("A16-C16-W16").enabled is False

    def test_bad_name(self):
        with pytest.raises(ValueError):
            parse_policy("W4-only")


class TestQATState:
    def test_scale_masks(self, rng):
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        smask = scale_mask(params)
        amask = act_scale_mask(params)
        flat_s = jax.tree_util.tree_flatten_with_path(smask)[0]
        flat_a = jax.tree_util.tree_flatten_with_path(amask)[0]
        n_scales = sum(bool(v) for _, v in flat_s)
        n_act = sum(bool(v) for _, v in flat_a)
        assert n_scales > n_act > 0     # weight scales not in the boost set
        for path, v in flat_a:
            if v:
                key = str(path[-1].key)
                assert key in ACT_SCALE_KEYS

    def test_weight_calibration_touches_all_s_w(self, rng):
        cfg = get_reduced_config("mixtral-8x7b")
        params = init_params(cfg, rng)
        cal = calibrate_weight_scales(params, parse_policy("A8d-C8-W4"))
        changed = unchanged = 0
        flat0 = jax.tree_util.tree_flatten_with_path(params)[0]
        flat1 = jax.tree_util.tree_flatten_with_path(cal)[0]
        for (p0, l0), (p1, l1) in zip(flat0, flat1):
            key = str(p0[-1].key) if hasattr(p0[-1], "key") else ""
            if key == "s_w":
                if bool(jnp.all(l0 == l1)):
                    unchanged += 1
                else:
                    changed += 1
        assert changed > 0 and unchanged == 0

    def test_calibration_collect_and_merge(self, rng):
        cfg = get_reduced_config("qwen3-14b")
        params = init_params(cfg, rng)
        policy = parse_policy("A8s-C8-W4")
        ctx = make_ctx(policy, mode="calib")
        batch = {"tokens": jax.random.randint(rng, (2, 16), 0,
                                              cfg.vocab_size)}
        _, aux = forward(cfg, params, ctx, batch, collect_stats=True)
        merged = merge_act_scales(params, [aux["qstats"]], policy)
        s0 = params["segments"][0]["0"]["attn"]["wq"]["s_in"]
        s1 = merged["segments"][0]["0"]["attn"]["wq"]["s_in"]
        assert bool(jnp.any(s0 != s1))
        assert bool(jnp.all(s1 > 0))

    def test_export_linear_int4_packing(self, rng):
        p = init_linear(rng, 32, 16)
        exp = export_linear_int(p, 4)
        assert exp["wq"].shape == (16, 16)      # (d_out, d_in/2) packed
        assert exp["wq"].dtype == jnp.uint8
        assert exp["packed"]

    def test_qlinear_baseline_policy_is_exact(self, rng):
        p = init_linear(rng, 16, 8)
        x = jax.random.normal(rng, (4, 16))
        y_off = qlinear(make_ctx("A16-C16-W16", mode="off"), x, p)
        np.testing.assert_allclose(np.asarray(y_off),
                                   np.asarray(x @ p["w"]), rtol=1e-5)

    def test_quantization_error_shrinks_with_bits(self, rng):
        p = init_linear(rng, 64, 32)
        from repro.core.calibration import mse_weight_scale
        x = jax.random.normal(rng, (8, 64))
        y_ref = x @ p["w"]
        errs = []
        for bits in (2, 4, 8):
            p2 = dict(p)
            p2["s_w"] = mse_weight_scale(p["w"], bits)
            ctx = make_ctx(f"A16-C16-W{bits}".replace("A16", "A8d")
                           .replace("C16", "C8"))
            y = qlinear(ctx, x, p2, weight_bits=bits, act_bits=16)
            errs.append(float(jnp.mean((y - y_ref) ** 2)))
        assert errs[0] > errs[1] > errs[2]
