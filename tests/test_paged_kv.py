"""Paged quantized KV cache: block-allocator accounting, engine lifecycle
(exhaustion queues instead of crashing, blocks return on harvest,
fragmentation stress), and block-table kvq_attn kernel parity vs the XLA
reference on CPU (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.kernels.kvq_attn.ops import copy_pool_blocks, kvq_paged_decode_attn
from repro.kernels.kvq_attn.ref import (copy_pool_blocks_ref, gather_paged_kv,
                                        kvq_paged_decode_attn_ref)
from repro.models import init_params
from repro.serve.block_alloc import BlockAllocator
from repro.serve.engine import Request, ServeEngine


def _req(uid, plen, **kw):
    return Request(uid=uid, prompt=np.arange(plen, dtype=np.int32), **kw)


class TestBlockAllocator:
    def test_reserve_then_exhaustion_refuses(self):
        a = BlockAllocator(num_blocks=4, block_size=8, slots=4, table_len=4)
        assert a.reserve(0, 20)            # 3 blocks
        assert not a.reserve(1, 16)        # 2 blocks > 1 unreserved
        assert a.reserve(1, 8)             # exactly the last block
        assert a.free_blocks == 0

    def test_lazy_allocation_and_peak(self):
        a = BlockAllocator(num_blocks=8, block_size=8, slots=2, table_len=8)
        assert a.reserve(0, 32)            # 4 blocks reserved
        assert a.allocated_blocks == 0     # nothing physical yet
        a.ensure(0, 8)
        assert a.allocated_blocks == 1
        a.ensure(0, 9)                     # crosses a block boundary
        assert a.allocated_blocks == 2
        a.ensure(0, 9)                     # idempotent
        assert a.allocated_blocks == 2
        assert a.peak_blocks == 2

    def test_release_returns_blocks_and_reservation(self):
        a = BlockAllocator(num_blocks=4, block_size=8, slots=2, table_len=4)
        assert a.reserve(0, 32)            # whole pool
        a.ensure(0, 17)                    # 3 blocks physical
        assert not a.reserve(1, 8)
        assert a.release(0) == 3
        assert a.free_blocks == 4
        assert a.reserve(1, 32)

    def test_table_rows_use_sentinel_for_unallocated(self):
        a = BlockAllocator(num_blocks=4, block_size=8, slots=2, table_len=4)
        assert (a.tables == 4).all()
        a.reserve(0, 24)
        a.ensure(0, 10)                    # 2 blocks
        assert (a.tables[0, :2] < 4).all() and (a.tables[0, 2:] == 4).all()
        a.release(0)
        assert (a.tables == 4).all()

    def test_ensure_beyond_reservation_is_an_accounting_bug(self):
        a = BlockAllocator(num_blocks=4, block_size=8, slots=1, table_len=4)
        a.reserve(0, 8)                    # 1 block
        with pytest.raises(RuntimeError, match="reservation"):
            a.ensure(0, 16)


class TestPagedEngineLifecycle:
    def _engine(self, params, cfg, **kw):
        kw.setdefault("slots", 4)
        kw.setdefault("cache_len", 64)
        kw.setdefault("kv_layout", "paged")
        kw.setdefault("block_size", 16)
        return ServeEngine(cfg, params, **kw)

    def test_pool_exhaustion_queues_requests(self, rng):
        """More submitted work than the pool holds at once: later requests
        wait for freed blocks instead of crashing, and everything drains."""
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        # pool of 2 blocks = 32 tokens; each request needs 2 blocks
        eng = self._engine(params, cfg, num_blocks=2, max_seq_len=32)
        reqs = [_req(i, 12, max_new_tokens=6) for i in range(4)]
        for r in reqs:
            eng.submit(r)
        stats = eng.run_until_drained()
        assert all(r.done for r in reqs)
        assert all(len(r.generated) == 6 for r in reqs)
        assert stats["max_residents"] == 1      # pool admits one at a time
        assert stats["requests_finished"] == 4

    def test_blocks_freed_on_harvest(self, rng):
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        eng = self._engine(params, cfg)
        for i in range(6):
            eng.submit(_req(i, 8 + i, max_new_tokens=4))
        eng.run_until_drained()
        assert eng.alloc.allocated_blocks == 0
        assert eng.alloc.free_blocks == eng.num_blocks
        assert (eng.alloc.tables == eng.num_blocks).all()

    def test_lazy_decode_allocation_tracks_residency(self, rng):
        """A request that EOSes early never touches its tail blocks: peak
        pool usage stays below the worst-case reservation."""
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        eng = self._engine(params, cfg, block_size=4, num_blocks=32)
        r = _req(0, 5, max_new_tokens=40)       # reserves ceil(44/4) = 11
        eng.submit(r)
        eng.step()                              # prefill + first chunk
        assert eng.alloc.allocated_blocks < 11  # only residency so far
        eng.run_until_drained()
        assert len(r.generated) == 40

    def test_eos_mid_chunk_then_block_reuse_matches_dense(self, rng):
        """The paged-only hazard path: a slot that EOSes mid-chunk keeps
        committing through its still-live table until harvest, and its
        freed blocks are then reused by a queued request. If post-EOS
        commits ever leaked into reallocated blocks, the follow-up
        request's tokens would diverge from the dense engine's."""
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)

        def run(paged, eos_id):
            kw = dict(kv_layout="paged", block_size=16,
                      max_seq_len=64) if paged else {}
            eng = ServeEngine(cfg, params, slots=2, cache_len=64,
                              decode_block=4, **kw)
            stoch = _req(0, 8, max_new_tokens=12, eos_id=eos_id)
            stoch.temperature, stoch.seed = 1.0, 11
            runner = _req(1, 6, max_new_tokens=8)
            follow = _req(2, 10, max_new_tokens=6)   # reuses freed blocks
            for r in (stoch, runner, follow):
                eng.submit(r)
            eng.run_until_drained()
            return [stoch.generated, runner.generated, follow.generated]

        free_run = run(True, -1)[0]
        assert len(free_run) == 12
        first_seen = {}
        for i, t in enumerate(free_run):
            first_seen.setdefault(t, i)
        # latest first occurrence that is strictly mid-stream, so the slot
        # stops with decode steps still left in its chunk
        mid = [(t, i) for t, i in first_seen.items()
               if 0 < i < len(free_run) - 1]
        if not mid:
            pytest.skip("degenerate stream: no mid-stream token to use")
        eos, stop_i = max(mid, key=lambda kv: kv[1])
        dense, paged = run(False, eos), run(True, eos)
        assert paged[0][-1] == eos and len(paged[0]) == stop_i + 1
        assert dense == paged

    def test_submit_rejects_never_admittable_with_block_count(self, rng):
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        eng = self._engine(params, cfg, num_blocks=2, max_seq_len=256)
        with pytest.raises(ValueError, match=r"needs 4 cache blocks"):
            eng.submit(_req(0, 50, max_new_tokens=8))   # 57 tokens, 4 blocks
        with pytest.raises(ValueError, match=r"needs 263 cache tokens"):
            eng.submit(_req(1, 200, max_new_tokens=64, ))

    @pytest.mark.slow
    def test_fragmentation_stress_interleaved_lengths(self, rng):
        """Interleaved short/long requests churning an over-subscribed pool:
        blocks recycle across waves with no leak and every request gets its
        exact token budget."""
        cfg = get_reduced_config("qwen2.5-3b")
        params = init_params(cfg, rng)
        eng = self._engine(params, cfg, slots=6, block_size=8,
                           num_blocks=24, max_seq_len=96, prefill_chunk=32)
        rr = np.random.default_rng(7)
        reqs = []
        for i in range(24):
            plen = int(rr.integers(3, 40)) if i % 2 else int(
                rr.integers(40, 80))
            budget = int(rr.integers(2, 12))
            reqs.append(_req(i, min(plen, 96 - budget), max_new_tokens=budget))
        for r in reqs:
            eng.submit(r)
        stats = eng.run_until_drained(max_steps=50_000)
        assert all(r.done for r in reqs)
        assert [len(r.generated) for r in reqs] == \
            [r.max_new_tokens for r in reqs]
        assert eng.alloc.allocated_blocks == 0
        assert eng.alloc.free_blocks == eng.num_blocks
        assert stats["requests_finished"] == len(reqs)
        # fragmentation win: more than one wave was resident at peak
        assert stats["max_residents"] > 1


class TestPagedKernelParity:
    def _rand_pool(self, rng, NB, Hkv, bs, D):
        ks = jax.random.split(rng, 4)
        k = jax.random.randint(ks[0], (NB, Hkv, bs, D), -127, 128, jnp.int32)
        v = jax.random.randint(ks[1], (NB, Hkv, bs, D), -127, 128, jnp.int32)
        sk = jax.random.uniform(ks[2], (NB, Hkv, bs), jnp.float32, 0.01, 0.2)
        sv = jax.random.uniform(ks[3], (NB, Hkv, bs), jnp.float32, 0.01, 0.2)
        return k.astype(jnp.int8), v.astype(jnp.int8), sk, sv

    def test_block_table_kernel_matches_ref(self, rng):
        B, H, Hkv, D, bs, NB, T = 3, 4, 2, 16, 8, 10, 4
        kp, vp, sk, sv = self._rand_pool(rng, NB, Hkv, bs, D)
        q = jax.random.normal(jax.random.fold_in(rng, 1), (B, H, D),
                              jnp.float32)
        # distinct non-contiguous blocks per row; row 2 has sentinel tails
        tbl = jnp.asarray([[7, 2, 9, 0], [1, 4, 6, 8], [3, 5, NB, NB]],
                          jnp.int32)
        lengths = jnp.asarray([4 * bs, 3 * bs - 3, bs + 2], jnp.int32)
        out = kvq_paged_decode_attn(q, kp, vp, sk, sv, tbl, lengths)
        ref = kvq_paged_decode_attn_ref(q, kp, vp, sk, sv, tbl, lengths)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-5, atol=2e-5)

    def test_spec_verify_kernel_matches_ref(self, rng):
        """The verify-wave's multi-query kernel: one table walk serving
        C queries per slot agrees with C per-position decode calls,
        including per-query lengths and sentinel table tails."""
        from repro.kernels.kvq_attn.ops import kvq_spec_verify_attn
        from repro.kernels.kvq_attn.ref import kvq_spec_verify_attn_ref
        B, C, H, Hkv, D, bs, NB, T = 3, 4, 4, 2, 16, 8, 10, 4
        kp, vp, sk, sv = self._rand_pool(rng, NB, Hkv, bs, D)
        q = jax.random.normal(jax.random.fold_in(rng, 5), (B, C, H, D),
                              jnp.float32)
        tbl = jnp.asarray([[7, 2, 9, 0], [1, 4, 6, 8], [3, 5, NB, NB]],
                          jnp.int32)
        base = jnp.asarray([2 * bs + 3, bs, 2], jnp.int32)
        lengths = base[:, None] + 1 + jnp.arange(C)[None]   # (B, C)
        out = kvq_spec_verify_attn(q, kp, vp, sk, sv, tbl, lengths,
                                   use_pallas=True)
        ref = kvq_spec_verify_attn_ref(q, kp, vp, sk, sv, tbl, lengths)
        assert out.shape == (B, C, H, D)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-5, atol=2e-5)
        # each query row also matches the single-query paged kernel
        for j in range(C):
            one = kvq_paged_decode_attn(q[:, j], kp, vp, sk, sv, tbl,
                                        lengths[:, j])
            np.testing.assert_allclose(np.asarray(out[:, j], np.float32),
                                       np.asarray(one, np.float32),
                                       rtol=2e-5, atol=2e-5)

    def test_gather_matches_manual_indexing(self, rng):
        NB, Hkv, bs, D = 6, 2, 4, 8
        kp, _, sk, _ = self._rand_pool(rng, NB, Hkv, bs, D)
        tbl = jnp.asarray([[5, 1, 3]], jnp.int32)
        g = gather_paged_kv(kp, tbl)
        assert g.shape == (1, Hkv, 3 * bs, D)
        np.testing.assert_array_equal(np.asarray(g[0, :, :bs]),
                                      np.asarray(kp[5]))
        np.testing.assert_array_equal(np.asarray(g[0, :, bs:2 * bs]),
                                      np.asarray(kp[1]))
        gs = gather_paged_kv(sk, tbl)
        assert gs.shape == (1, Hkv, 3 * bs)
        np.testing.assert_array_equal(np.asarray(gs[0, :, 2 * bs:]),
                                      np.asarray(sk[3]))

    def test_pool_block_copy_pallas_matches_ref(self, rng):
        """The COW clone primitive: Pallas (interpret) and the XLA
        scatter reference agree bitwise, pad pairs (dst >= NB) are
        dropped, and untouched blocks are preserved."""
        rep, NB, Hkv, bs, D = 2, 6, 2, 4, 8
        kp = jax.random.randint(rng, (rep, NB, Hkv, bs, D), -127, 128,
                                jnp.int32).astype(jnp.int8)
        sk = jax.random.uniform(jax.random.fold_in(rng, 3),
                                (rep, NB, Hkv, bs), jnp.float32)
        src = jnp.asarray([4, 0, 0], jnp.int32)
        dst = jnp.asarray([1, 5, NB], jnp.int32)      # last pair = padding
        for pool in (kp, sk):
            out_k = copy_pool_blocks(pool, src, dst, use_pallas=True)
            out_r = copy_pool_blocks_ref(pool, src, dst)
            np.testing.assert_array_equal(np.asarray(out_k),
                                          np.asarray(out_r))
            exp = np.array(pool)
            exp[:, 1] = exp[:, 4]
            exp[:, 5] = exp[:, 0]
            np.testing.assert_array_equal(np.asarray(out_k), exp)

    def test_sentinel_blocks_do_not_leak_into_output(self, rng):
        """Positions past ``lengths`` (sentinel or stale blocks) must not
        change the result: scribbling on every block the slot does NOT own
        leaves its output bit-identical."""
        B, H, Hkv, D, bs, NB = 1, 2, 1, 8, 4, 6
        kp, vp, sk, sv = self._rand_pool(rng, NB, Hkv, bs, D)
        tbl = jnp.asarray([[2, 4, NB, NB]], jnp.int32)
        lengths = jnp.asarray([bs + 1], jnp.int32)
        q = jax.random.normal(jax.random.fold_in(rng, 2), (B, H, D),
                              jnp.float32)
        out = kvq_paged_decode_attn(q, kp, vp, sk, sv, tbl, lengths)
        owned = {2, 4}
        scrib = jnp.asarray(
            np.where(np.isin(np.arange(NB), list(owned))[:, None, None,
                                                         None],
                     np.asarray(kp), 77).astype(np.int8))
        out2 = kvq_paged_decode_attn(q, scrib, vp, sk, sv, tbl, lengths)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
