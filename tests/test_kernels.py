"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizer import lsq_fake_quant, pack_int4
from repro.kernels.kvq_attn.ops import kvq_decode_attn
from repro.kernels.kvq_attn.ref import kvq_decode_attn_ref
from repro.kernels.quant.ops import pallas_lsq_fake_quant
from repro.kernels.w4a8.ops import w4a8_linear, w4a8_matmul
from repro.kernels.w4a8.ref import w4a8_matmul_ref


class TestQuantKernel:
    @pytest.mark.parametrize("shape,per_channel", [
        ((256, 512), False), ((256, 512), True),
        ((300, 700), False), ((300, 700), True),      # non-tile-aligned
        ((7, 96), True), ((4, 64, 48), False),        # small + 3-D
    ])
    @pytest.mark.parametrize("bits", [4, 8, 16])
    def test_matches_oracle(self, shape, per_channel, bits, rng):
        x = jax.random.normal(rng, shape) * 3
        if per_channel:
            s = jnp.abs(jax.random.normal(rng, (shape[-1],))) * 0.1 + 0.02
            s_ref = s.reshape((1,) * (len(shape) - 1) + (-1,))
        else:
            s = jnp.float32(0.07)
            s_ref = s
        yk = pallas_lsq_fake_quant(x, s, bits)
        yr = lsq_fake_quant(x, s_ref, bits)
        np.testing.assert_allclose(yk, yr, atol=1e-6)

    @pytest.mark.parametrize("bits", [4, 8])
    def test_gradients_match_oracle(self, bits, rng):
        x = jax.random.normal(rng, (300, 260)) * 2
        s = jnp.abs(jax.random.normal(rng, (260,))) * 0.05 + 0.01

        def loss_k(x, s):
            return jnp.sum(jnp.sin(pallas_lsq_fake_quant(x, s, bits)))

        def loss_r(x, s):
            return jnp.sum(jnp.sin(lsq_fake_quant(x, s.reshape(1, -1),
                                                  bits)))

        gk = jax.grad(loss_k, argnums=(0, 1))(x, s)
        gr = jax.grad(loss_r, argnums=(0, 1))(x, s)
        np.testing.assert_allclose(gk[0], gr[0], atol=1e-5)
        np.testing.assert_allclose(gk[1], gr[1].reshape(-1), atol=1e-4,
                                   rtol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype, rng):
        x = (jax.random.normal(rng, (64, 128)) * 2).astype(dtype)
        y = pallas_lsq_fake_quant(x, jnp.float32(0.1), 8)
        assert y.dtype == dtype
        yr = lsq_fake_quant(x, jnp.float32(0.1), 8)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yr, np.float32), atol=1e-6)


class TestW4A8Kernel:
    @pytest.mark.parametrize("mkn", [(64, 128, 96), (256, 512, 256),
                                     (300, 1024, 257), (7, 512, 512),
                                     (1, 128, 64)])
    def test_matches_oracle(self, mkn, rng):
        M, K, N = mkn
        ks = jax.random.split(rng, 4)
        x_q = jax.random.randint(ks[0], (M, K), -128, 128, jnp.int8)
        w_q = jax.random.randint(ks[1], (N, K), -8, 8, jnp.int8)
        wp = pack_int4(w_q)
        s_x = jnp.abs(jax.random.normal(ks[2], (M, 1))) * 0.01 + 1e-3
        s_w = jnp.abs(jax.random.normal(ks[3], (N,))) * 0.01 + 1e-3
        b = jax.random.normal(ks[3], (N,))
        out = w4a8_matmul(x_q, wp, s_x, s_w, b)
        ref = w4a8_matmul_ref(x_q, wp, s_x, s_w, b)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-2, atol=2e-2)

    def test_deployed_linear_matches_fake_quant(self, rng):
        """End-to-end: exported int4 path ~= fake-quant training path."""
        from repro.core.calibration import mse_weight_scale
        from repro.core.qat import export_linear_int, init_linear, make_ctx, \
            qlinear
        p = init_linear(rng, 256, 128, bias=True)
        p["s_w"] = mse_weight_scale(p["w"], 4)
        exp = export_linear_int(p, 4)
        x = jax.random.normal(rng, (5, 256), jnp.bfloat16)
        y_deploy = w4a8_linear(x, exp)
        y_fake = qlinear(make_ctx("A8d-C8-W4"), x, p)
        err = float(jnp.mean(jnp.abs(y_deploy.astype(jnp.float32)
                                     - y_fake.astype(jnp.float32))))
        scale = float(jnp.mean(jnp.abs(y_fake.astype(jnp.float32)))) + 1e-9
        assert err / scale < 0.02


class TestKVQAttnKernel:
    @pytest.mark.parametrize("dims", [
        (2, 8, 2, 1024, 128),    # GQA
        (1, 4, 4, 700, 128),     # MHA, ragged S
        (3, 6, 2, 512, 256),     # wide head
        (2, 4, 1, 513, 128),     # MQA, S % BS != 0
    ])
    def test_matches_oracle(self, dims, rng):
        B, H, Hkv, S, D = dims
        ks = jax.random.split(rng, 6)
        q = jax.random.normal(ks[0], (B, H, D))
        k_q = jax.random.randint(ks[1], (B, Hkv, S, D), -128, 128, jnp.int8)
        v_q = jax.random.randint(ks[2], (B, Hkv, S, D), -128, 128, jnp.int8)
        s_k = jnp.abs(jax.random.normal(ks[3], (B, Hkv, S))) * 0.01 + 1e-3
        s_v = jnp.abs(jax.random.normal(ks[4], (B, Hkv, S))) * 0.01 + 1e-3
        lengths = jax.random.randint(ks[5], (B,), 1, S + 1, jnp.int32)
        out = kvq_decode_attn(q, k_q, v_q, s_k, s_v, lengths)
        ref = kvq_decode_attn_ref(q, k_q, v_q, s_k, s_v, lengths)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=2e-5, rtol=2e-4)

    def test_length_one(self, rng):
        """Minimal valid prefix: attends to exactly one token."""
        B, H, Hkv, S, D = 1, 2, 1, 512, 128
        ks = jax.random.split(rng, 4)
        q = jax.random.normal(ks[0], (B, H, D))
        k_q = jax.random.randint(ks[1], (B, Hkv, S, D), -128, 128, jnp.int8)
        v_q = jax.random.randint(ks[2], (B, Hkv, S, D), -128, 128, jnp.int8)
        s = jnp.full((B, Hkv, S), 0.01)
        out = kvq_decode_attn(q, k_q, v_q, s, s, jnp.array([1]))
        expect = (v_q[:, :, 0].astype(jnp.float32) * 0.01)
        expect = jnp.repeat(expect, H // Hkv, axis=1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=1e-5)


class TestFlashAttnKernel:
    @pytest.mark.parametrize("dims", [
        (1, 512, 4, 2, 128, True, 0),     # GQA causal
        (2, 300, 8, 2, 128, True, 0),     # ragged S
        (1, 700, 4, 4, 128, False, 0),    # MHA bidirectional (encoder)
        (1, 600, 4, 1, 128, True, 128),   # MQA sliding window
        (2, 256, 2, 2, 256, True, 0),     # wide head
    ])
    def test_matches_oracle(self, dims, rng):
        from repro.kernels.flash_attn.ops import flash_attention
        from repro.kernels.flash_attn.ref import flash_attn_ref
        B, S, H, Hkv, D, causal, window = dims
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, Hkv, D))
        v = jax.random.normal(ks[2], (B, S, Hkv, D))
        out = flash_attention(q, k, v, causal=causal, window=window)
        ref = flash_attn_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-2, rtol=2e-2)


class TestSLSTMScanKernel:
    @pytest.mark.parametrize("dims", [(8, 256, 128), (3, 100, 128),
                                      (8, 128, 256)])
    def test_matches_oracle(self, dims, rng):
        from repro.kernels.slstm_scan.ops import slstm_scan
        from repro.kernels.slstm_scan.ref import slstm_scan_ref
        B, T, d = dims
        ks = jax.random.split(rng, 4)
        gx = jax.random.normal(ks[0], (B, T, 4 * d)) * 0.5
        r_h = jax.random.normal(ks[1], (d, 4 * d)) * (d ** -0.5)
        h0 = jax.random.normal(ks[2], (B, d)) * 0.1
        c0 = jax.random.normal(ks[3], (B, d)) * 0.1
        hs, hT, cT = slstm_scan(gx, r_h, h0, c0)
        hs_r, hT_r, cT_r = slstm_scan_ref(gx, r_h, h0, c0)
        np.testing.assert_allclose(np.asarray(hs), np.asarray(hs_r),
                                   atol=3e-5)
        np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_r),
                                   atol=3e-5)
        np.testing.assert_allclose(np.asarray(cT), np.asarray(cT_r),
                                   atol=3e-5)
