"""Serving throughput benchmark: seed (v1) engine vs. continuous-batching v2.

Measures tok/s, TTFT p50/p95, and decode-step latency on the reduced config
and writes ``BENCH_serve.json`` so the perf trajectory has serving numbers.

The baseline is a faithful reimplementation of the seed ``ServeEngine``
(per-request compiled prefill, per-token ``int(jnp.argmax(...))`` host sync)
driven by the *same* model functions, so the delta isolates the engine
architecture: batched prefill + on-device decode chunks.

Both engines get one untimed warmup pass over the identical workload so
compile time is excluded from the comparison.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.core.precision import parse_policy
from repro.core.qat import calibrate_weight_scales, make_ctx
from repro.models import decode_step, init_cache, init_params, prefill
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import percentile


class BaselineEngine:
    """Seed (v1) serve loop: slot batching, but per-request prefill and a
    host sync on every decode step — the architecture v2 replaces."""

    def __init__(self, cfg, params, *, policy: str = "A8d-C8-W4",
                 slots: int = 8, cache_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.ctx = make_ctx(policy)
        self.slots = slots
        self.cache_len = cache_len
        self._decode = jax.jit(
            lambda p, t, c: decode_step(cfg, p, self.ctx, t, c))
        self._prefill = jax.jit(
            lambda p, b: prefill(cfg, p, self.ctx, b,
                                 cache_budget=cache_len))
        self.reset()

    def reset(self):
        self.cache = init_cache(self.cfg, self.ctx, self.slots,
                                self.cache_len)
        self.active: Dict[int, Request] = {}
        self.queue: List[Request] = []
        self.last_tokens = jnp.zeros((self.slots, 1), jnp.int32)
        self.submit_t: Dict[int, float] = {}
        self.ttfts: List[float] = []
        self.stats = {"tokens_out": 0, "decode_steps": 0, "decode_s": 0.0}

    def submit(self, req: Request):
        self.queue.append(req)
        self.submit_t[req.uid] = time.perf_counter()

    def _write_slot(self, slot: int, cache1):
        def cp(dst, src):
            if dst.ndim == 1:
                return dst.at[slot].set(src[0])
            return dst.at[:, slot].set(src[:, 0])
        self.cache = jax.tree.map(cp, self.cache, cache1)

    def _admit(self):
        for slot in [s for s in range(self.slots) if s not in self.active]:
            if not self.queue:
                break
            req = self.queue.pop(0)
            batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
            logits, cache1 = self._prefill(self.params, batch)
            first = int(jnp.argmax(logits[0, -1]))          # host sync
            req.generated.append(first)
            self.stats["tokens_out"] += 1
            self.ttfts.append(time.perf_counter() - self.submit_t[req.uid])
            self._write_slot(slot, cache1)
            self.last_tokens = self.last_tokens.at[slot, 0].set(first)
            self.active[slot] = req

    def step(self):
        self._admit()
        if not self.active:
            return
        t0 = time.perf_counter()
        logits, self.cache = self._decode(self.params, self.last_tokens,
                                          self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))  # host sync
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["decode_steps"] += 1
        for slot, req in list(self.active.items()):
            tok = int(nxt[slot])
            req.generated.append(tok)
            self.stats["tokens_out"] += 1
            if tok == req.eos_id or len(req.generated) >= req.max_new_tokens:
                req.done = True
                del self.active[slot]
            else:
                self.last_tokens = self.last_tokens.at[slot, 0].set(tok)

    def run_until_drained(self, max_steps: int = 10_000) -> Dict:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        out = dict(self.stats)
        out["ttft_p50_s"] = percentile(self.ttfts, 50)
        out["ttft_p95_s"] = percentile(self.ttfts, 95)
        out["decode_step_s"] = (out["decode_s"]
                                / max(out["decode_steps"], 1))
        return out


def make_requests(args, cfg) -> List[Request]:
    rng = np.random.default_rng(0)
    return [Request(uid=uid,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for uid in range(args.requests)]


# --------------------------------------------------------------------------
# Paged vs dense KV cache at equal HBM budget
# --------------------------------------------------------------------------

def make_mixed_requests(n: int, cfg, lens,
                        max_new: int = 8) -> List[Request]:
    """Mixed-length workload: mostly short prompts plus a long tail that
    forces the dense engine's per-slot stripe to the worst case."""
    rng = np.random.default_rng(1)
    return [Request(uid=uid,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        lens[uid % len(lens)]).astype(
                                            np.int32),
                    max_new_tokens=max_new)
            for uid in range(n)]


MIXED_LENS = (4, 8, 12, 56)
MIXED_MAX_NEW = 8


def paged_vs_dense(args, cfg, params) -> Dict:
    """Same mixed-length workload, same total cache HBM: a dense engine
    reserving ``cache_len`` per slot vs a paged engine whose pool holds the
    identical token budget in ``block_size``-token blocks shared across 4x
    the slots. Records tok/s, peak cache bytes, and the max-concurrent-
    residents ratio (the fragmentation win)."""
    slots_d, cache_len, bs = args.slots, args.cache_len, 16
    budget_tokens = slots_d * cache_len           # dense total reservation
    n_req = args.requests

    def dense_engine():
        return ServeEngine(cfg, params, policy=args.policy, slots=slots_d,
                           cache_len=cache_len,
                           decode_block=args.decode_block,
                           max_new_cap=max(32, args.max_new))

    def paged_engine():
        return ServeEngine(cfg, params, policy=args.policy,
                           slots=slots_d * 4, cache_len=cache_len,
                           kv_layout="paged", block_size=bs,
                           num_blocks=budget_tokens // bs,
                           max_seq_len=cache_len,
                           decode_block=args.decode_block,
                           max_new_cap=max(32, args.max_new))

    out: Dict = {"workload": {"requests": n_req,
                              "prompt_lens": list(MIXED_LENS),
                              "max_new": MIXED_MAX_NEW,
                              "budget_tokens": budget_tokens,
                              "block_size": bs}}
    for name, factory in (("dense", dense_engine), ("paged", paged_engine)):
        engine = factory()
        run_engine(engine, make_mixed_requests(n_req, cfg, MIXED_LENS,
                                               MIXED_MAX_NEW))
        engine.reset()                                        # ^ warmup
        stats = run_engine(engine, make_mixed_requests(n_req, cfg,
                                                       MIXED_LENS,
                                                       MIXED_MAX_NEW))
        out[name] = {k: stats[k] for k in
                     ("tok_s", "wall_s", "tokens_out", "max_residents",
                      "cache_tokens_capacity", "peak_cache_tokens",
                      "cache_bytes", "peak_cache_bytes", "ttft_p50_s",
                      "ttft_p95_s")}
        print(f"{name:5s} kv: {stats['tok_s']:8.1f} tok/s, "
              f"{stats['max_residents']:3d} max residents, peak cache "
              f"{stats['peak_cache_tokens']} tokens "
              f"({stats['peak_cache_bytes'] / 1024:.0f} KiB)")
    out["resident_ratio"] = (out["paged"]["max_residents"]
                             / max(out["dense"]["max_residents"], 1))
    print(f"paged admits {out['resident_ratio']:.2f}x the concurrent "
          f"residents at the same cache HBM")
    return out


# --------------------------------------------------------------------------
# Shared-prefix workload: prefix cache + optimistic admission
# --------------------------------------------------------------------------

SP_PREFIX_LEN = 52          # 3 full 16-token blocks + a 4-token split block
SP_TAIL = 6
SP_MAX_NEW = 24             # several decode chunks: co-residency builds up
SP_REQUESTS = 12
SP_SLOTS = 8
SP_BLOCKS = 12              # tight pool: optimistic admission must preempt


def make_shared_prefix_requests(n, cfg, uid0: int = 0) -> List[Request]:
    """System-prompt style workload: one long common prefix, short unique
    tails. Every call rebuilds the identical request list."""
    rng = np.random.default_rng(2)
    prefix = rng.integers(0, cfg.vocab_size, SP_PREFIX_LEN).astype(np.int32)
    return [Request(uid=uid0 + i,
                    prompt=np.concatenate(
                        [prefix, rng.integers(0, cfg.vocab_size,
                                              SP_TAIL).astype(np.int32)]),
                    max_new_tokens=SP_MAX_NEW)
            for i in range(n)]


def shared_prefix_bench(args, cfg, params) -> Dict:
    """Same shared-prefix workload on three paged engines: cold (no
    prefix cache), warm (prefix sharing, reservation admission), and warm
    + optimistic admission (prompt-footprint admission with preemption /
    swap-out). The first request warms the cache, the rest follow —
    recording prefill-token savings, TTFT, COW/preemption/swap costs, and
    the concurrency gain of optimistic admission."""
    def engine(prefix_cache, admission):
        return ServeEngine(cfg, params, policy=args.policy, slots=SP_SLOTS,
                           cache_len=args.cache_len, kv_layout="paged",
                           block_size=16, num_blocks=SP_BLOCKS,
                           max_seq_len=args.cache_len,
                           decode_block=4,      # short chunks: residents
                           max_new_cap=max(32, SP_MAX_NEW),  # overlap
                           prefix_cache=prefix_cache, admission=admission)

    def staged_run(eng):
        reqs = make_shared_prefix_requests(SP_REQUESTS, cfg)
        # the wall clock covers the cache-warming solo request too (every
        # variant pays it identically), so tok/s and the TTFT percentiles
        # describe exactly the tokens they count
        t0 = time.perf_counter()
        eng.submit(reqs[0])
        eng.run_until_drained()          # warms the prefix cache
        for r in reqs[1:]:
            eng.submit(r)
        stats = eng.run_until_drained(max_steps=100_000)
        stats["wall_s"] = time.perf_counter() - t0
        stats["tok_s"] = stats["tokens_out"] / max(stats["wall_s"], 1e-9)
        assert all(r.done for r in reqs), "shared-prefix workload stalled"
        return stats

    keys = ("tok_s", "ttft_p50_s", "ttft_p95_s", "max_residents",
            "prompt_tokens_prefilled", "prefix_hit_tokens", "cow_copies",
            "preemptions", "swap_out_bytes", "swap_in_bytes", "swap_s")
    out: Dict = {"workload": {
        "requests": SP_REQUESTS, "prefix_len": SP_PREFIX_LEN,
        "tail_len": SP_TAIL, "max_new": SP_MAX_NEW, "slots": SP_SLOTS,
        "num_blocks": SP_BLOCKS, "block_size": 16}}
    for name, (pc, adm) in (("cold", (False, "reserve")),
                            ("warm", (True, "reserve")),
                            ("warm_optimistic", (True, "optimistic"))):
        eng = engine(pc, adm)
        staged_run(eng)                                       # warmup
        eng.reset()
        stats = staged_run(eng)
        out[name] = {k: stats[k] for k in keys}
        print(f"{name:15s}: {stats['tok_s']:8.1f} tok/s, TTFT p50 "
              f"{stats['ttft_p50_s'] * 1e3:5.1f} ms, prefilled "
              f"{stats['prompt_tokens_prefilled']:4d} tok (hit "
              f"{stats['prefix_hit_tokens']}), {stats['max_residents']} "
              f"residents, {stats['preemptions']} preemptions "
              f"({stats['swap_out_bytes'] + stats['swap_in_bytes']} swap "
              f"bytes)")
    warm, cold = out["warm"], out["cold"]
    out["prefill_token_savings"] = (cold["prompt_tokens_prefilled"]
                                    / max(warm["prompt_tokens_prefilled"],
                                          1))
    hit = warm["prefix_hit_tokens"]
    out["prefix_hit_rate"] = hit / max(
        hit + warm["prompt_tokens_prefilled"], 1)
    out["optimistic_resident_gain"] = (
        out["warm_optimistic"]["max_residents"]
        / max(warm["max_residents"], 1))
    print(f"prefix sharing saves {out['prefill_token_savings']:.2f}x "
          f"prefill tokens (hit rate {out['prefix_hit_rate']:.2f}); "
          f"optimistic admission holds "
          f"{out['optimistic_resident_gain']:.2f}x the residents")
    return out


WB_BURST = 6                # simultaneous prefix-hit arrivals (>= 4)
WB_BLOCKS = 48              # roomy pool: isolates tail batching from
                            # preemption noise


def warm_burst_bench(args, cfg, params) -> Dict:
    """Warm-path TTFT under burst arrivals: one request warms the prefix
    cache, then ``WB_BURST`` prefix-hit requests are submitted at once.
    The batched tail-wave engine (``tail_batch=slots``) advances every
    tail in one compiled call per step; the serialized legacy path
    (``tail_batch=1``) admits one tail per engine step, so the last
    arrival's first token waits behind every earlier tail. TTFT p50/p95
    over just the burst, same workload, both engines warmed up."""
    def run(tail_batch):
        eng = ServeEngine(cfg, params, policy=args.policy, slots=SP_SLOTS,
                          cache_len=args.cache_len, kv_layout="paged",
                          block_size=16, num_blocks=WB_BLOCKS,
                          max_seq_len=args.cache_len, decode_block=4,
                          max_new_cap=max(32, SP_MAX_NEW),
                          prefix_cache=True, tail_batch=tail_batch)

        def once():
            eng.submit(make_shared_prefix_requests(1, cfg)[0])
            eng.run_until_drained()          # warms the prefix cache
            burst = make_shared_prefix_requests(WB_BURST, cfg, uid0=100)
            for r in burst:
                eng.submit(r)
            eng.run_until_drained(max_steps=100_000)
            assert all(r.done for r in burst), "warm burst stalled"
            return [r._timing.ttft for r in burst]

        once()                               # warmup: compiles
        eng.reset()
        tt = once()
        return {"ttft_p50_s": percentile(tt, 50),
                "ttft_p95_s": percentile(tt, 95)}

    out: Dict = {"workload": {
        "burst": WB_BURST, "prefix_len": SP_PREFIX_LEN,
        "tail_len": SP_TAIL, "max_new": SP_MAX_NEW, "slots": SP_SLOTS,
        "num_blocks": WB_BLOCKS, "block_size": 16}}
    out["batched"] = run(0)                  # tail_batch=0 -> every slot
    out["serialized"] = run(1)
    out["warm_ttft_batched_p95_s"] = out["batched"]["ttft_p95_s"]
    out["warm_ttft_serialized_p95_s"] = out["serialized"]["ttft_p95_s"]
    out["warm_ttft_p95_speedup"] = (
        out["warm_ttft_serialized_p95_s"]
        / max(out["warm_ttft_batched_p95_s"], 1e-9))
    for name in ("batched", "serialized"):
        print(f"warm burst {name:10s}: TTFT p50 "
              f"{out[name]['ttft_p50_s'] * 1e3:6.1f} ms, p95 "
              f"{out[name]['ttft_p95_s'] * 1e3:6.1f} ms")
    print(f"batched tail prefill cuts warm TTFT p95 by "
          f"{out['warm_ttft_p95_speedup']:.2f}x")
    return out


# --------------------------------------------------------------------------
# Speculative decoding: low-bit draft -> verify-wave vs plain decode
# --------------------------------------------------------------------------

SD_K = 15                   # drafts per wave: the wave commits up to 16
SD_SLOTS = 4
SD_PROMPT = 24
SD_MAX_NEW = 64             # decode-dominated: where spec pays off
SD_REQUESTS = 8
SD_LAYERS = 6               # target depth: speculative decoding's premise
SD_DRAFT_LAYERS = 1         # is a draft MUCH shallower than the target


def make_spec_requests(n, cfg) -> List[Request]:
    rng = np.random.default_rng(4)
    return [Request(uid=uid,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        SD_PROMPT).astype(np.int32),
                    max_new_tokens=SD_MAX_NEW)
            for uid in range(n)]


def spec_decode_bench(args, cfg, params) -> Dict:
    """Greedy self-draft speculative decoding vs plain decode at equal
    residents: the draft is the target's truncated-layer prefix (shared
    embeddings), ``SD_K`` proposals per slot are verified per compiled
    wave, and exact-match acceptance keeps the output bit-identical to
    plain decode — so tok/s is the only thing that may differ. The
    target is deepened to ``SD_LAYERS`` (speculative decoding's premise
    is a draft MUCH cheaper than the target; the 2-layer smoke model
    can't express that gap). Records accept rate and drafted/accepted/
    rolled-back token counters."""
    from repro.serve.spec import SpecConfig

    if cfg.n_layers < SD_LAYERS:
        cfg = cfg.replace(name=f"{cfg.name}-deep{SD_LAYERS}",
                          n_layers=SD_LAYERS)
        params = init_params(cfg, jax.random.PRNGKey(0))

    def engine(spec):
        return ServeEngine(cfg, params, policy=args.policy, slots=SD_SLOTS,
                           cache_len=max(args.cache_len, 128),
                           kv_layout="paged", block_size=16,
                           num_blocks=64,
                           max_seq_len=max(args.cache_len, 128) + 32,
                           decode_block=args.decode_block,
                           max_new_cap=max(64, SD_MAX_NEW), spec=spec)

    n_req = SD_REQUESTS if not args.smoke else 6
    spec_cfg = SpecConfig(k=SD_K, draft_layers=SD_DRAFT_LAYERS)
    out: Dict = {"workload": {"requests": n_req, "prompt_len": SD_PROMPT,
                              "max_new": SD_MAX_NEW, "slots": SD_SLOTS,
                              "k": SD_K, "target_layers": cfg.n_layers,
                              "draft_layers": spec_cfg.resolved_layers(cfg),
                              "accept_mode": spec_cfg.accept_mode}}
    tokens = {}
    for name, sc in (("plain", None), ("spec", spec_cfg)):
        eng = engine(sc)
        run_engine(eng, make_spec_requests(n_req, cfg))       # warmup
        # best-of-3: the gate compares wall-clock tok/s, so shed host
        # scheduler noise the way the decode_block probe does
        stats = None
        for _ in range(3):
            eng.reset()
            reqs = make_spec_requests(n_req, cfg)
            s = run_engine(eng, reqs)
            assert all(r.done for r in reqs), "spec bench stalled"
            if stats is None or s["tok_s"] > stats["tok_s"]:
                stats = s
        tokens[name] = [tuple(r.generated) for r in reqs]
        keys = ["tok_s", "wall_s", "tokens_out", "decode_steps",
                "ttft_p50_s", "ttft_p95_s"]
        if sc is not None:
            keys += ["spec_waves", "spec_drafted", "spec_accepted",
                     "spec_rolled_back", "spec_accept_rate"]
        out[name] = {k: stats[k] for k in keys}
        extra = (f", accept rate {stats['spec_accept_rate']:.2f} "
                 f"({stats['spec_accepted']}/{stats['spec_drafted']} "
                 f"drafts, {stats['spec_rolled_back']} rolled back)"
                 if sc is not None else "")
        print(f"{name:5s} decode: {stats['tok_s']:8.1f} tok/s, "
              f"{stats['decode_steps']:4d} engine waves{extra}")
    # exact-match acceptance: the speculative stream IS plain decode's
    assert tokens["spec"] == tokens["plain"], \
        "speculative output diverged from plain decode"
    out["spec_speedup"] = out["spec"]["tok_s"] / max(
        out["plain"]["tok_s"], 1e-9)
    out["accept_rate"] = out["spec"]["spec_accept_rate"]
    print(f"speculative decode: {out['spec_speedup']:.2f}x tok/s at "
          f"accept rate {out['accept_rate']:.2f}")
    return out


# --------------------------------------------------------------------------
# Open-loop streaming: Poisson arrivals through the asyncio frontend
# --------------------------------------------------------------------------

ST_MAX_NEW = 16
ST_REQUESTS = 24            # arrivals per rate (smoke shrinks via args)
ST_RATE_FACTORS = (0.4, 3.0)   # x measured closed-loop capacity
ST_MIN_DEADLINE_S = 0.3
ST_LEN_LO = 4


def weights_bench(args, cfg, params) -> Dict:
    """Packed-int4 weight serving (``weights_layout="w4a8"``) vs bf16 at an
    identical paged workload.

    The win being claimed is weight-HBM streaming: the packed layout reads
    ~0.28x the weight bytes per forward (int4 nibbles + f32 per-channel
    scales vs bf16), which on real accelerators is the dominant memory term
    of low-batch decode. On CPU smoke hardware the engines are
    dispatch-bound, so the CI gate is *parity* (w4a8 >= 0.95x bf16 tok/s:
    the packed path must not cost throughput where its byte win can't
    show), plus the byte accounting itself from the engine's stats. Both
    engines serve greedy, and the w4a8 stream is checked identical between
    its Pallas and XLA-ref backends elsewhere (tests); here bf16 vs w4a8
    streams legitimately differ (different arithmetic)."""
    def engine(layout):
        # decode_block="auto": each layout gets its probed optimum (the
        # probe memo is keyed on weights_layout), so the parity gate
        # compares production configurations instead of a block size tuned
        # for neither
        return ServeEngine(cfg, params, policy=args.policy,
                           slots=args.slots, cache_len=args.cache_len,
                           kv_layout="paged", block_size=16,
                           decode_block="auto",
                           max_new_cap=max(32, args.max_new),
                           weights_layout=layout)

    # the parity gate rides wall-clock tok/s, so the workload must be long
    # enough that host-scheduler noise stays well under the gate margin —
    # stretch the smoke token count (~50 tokens -> ~400) rather than trust
    # a 15 ms measurement
    wargs = argparse.Namespace(**vars(args))
    wargs.requests = max(args.requests, 8)
    wargs.max_new = max(args.max_new, 32)

    out: Dict = {}
    keys = ["tok_s", "wall_s", "tokens_out", "decode_steps",
            "decode_step_s", "weights_layout", "packed_weight_bytes",
            "weight_hbm_saved_bytes"]
    engines = {layout: engine(layout) for layout in ("bf16", "w4a8")}
    best: Dict = {layout: None for layout in engines}
    for eng in engines.values():
        run_engine(eng, make_requests(wargs, cfg))           # warmup
    # best-of-4 with the layouts interleaved per round: a slow window on a
    # shared CI host then penalizes both engines instead of whichever one
    # happened to be measured during it — the tok_s ratio is the gated
    # quantity, so noise that cancels is noise removed
    for _ in range(4):
        for layout, eng in engines.items():
            eng.reset()
            reqs = make_requests(wargs, cfg)
            s = run_engine(eng, reqs)
            assert all(r.done for r in reqs), "weights bench stalled"
            if best[layout] is None or s["tok_s"] > best[layout]["tok_s"]:
                best[layout] = s
    for layout, stats in best.items():
        out[layout] = {k: stats[k] for k in keys}
        print(f"{layout:5s} weights: {stats['tok_s']:8.1f} tok/s, "
              f"{stats['packed_weight_bytes'] / 1e3:.0f} KB packed, "
              f"{stats['weight_hbm_saved_bytes'] / 1e3:.0f} KB saved")
    out["tok_s_ratio"] = out["w4a8"]["tok_s"] / max(out["bf16"]["tok_s"],
                                                    1e-9)
    saved = out["w4a8"]["weight_hbm_saved_bytes"]
    packed = out["w4a8"]["packed_weight_bytes"]
    out["weight_bytes_ratio"] = packed / max(packed + saved, 1)
    print(f"w4a8 weights: {out['tok_s_ratio']:.2f}x tok/s at "
          f"{out['weight_bytes_ratio']:.2f}x the weight HBM bytes")
    return out


# --------------------------------------------------------------------------
# Tensor-parallel sharded serving: tp=1 vs tp=N on a host-device mesh
# --------------------------------------------------------------------------

SH_TP = 2                   # TP degree for the sharded comparison
SH_MAX_NEW = 24


def make_sharded_requests(n, cfg, max_new: int) -> List[Request]:
    """Alternating greedy / sampled rows: parity must hold for both
    decode variants, and the sampled rows prove the logit all-gather
    keeps the PRNG stream layout-invariant."""
    rng = np.random.default_rng(8)
    return [Request(uid=uid,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(5, 30))).astype(
                                            np.int32),
                    max_new_tokens=max_new,
                    temperature=0.0 if uid % 2 == 0 else 0.8,
                    top_k=0 if uid % 3 == 0 else 8, seed=100 + uid)
            for uid in range(n)]


def sharded_bench(args, cfg, params) -> Dict:
    """Tensor-parallel serving (``mesh=``) vs the identical single-device
    engine, both under ``weights_layout="w4a8"`` paged serving.

    The claims being gated: (1) the token streams are *bit-identical* —
    the packed path's integer partials make the row-parallel all-reduce
    exact, and the sampler's logit all-gather keeps the PRNG
    layout-invariant; (2) per-device KV-pool and packed-weight bytes
    drop to ~1/tp — the HBM headroom TP buys; (3) the compiled decode
    wave's only collectives are the canonical TP set (no s8 pool
    all-gather). On the CPU smoke host tp=``SH_TP`` "devices" are
    threads of one machine, so tok/s is gated only against collapse
    (``tok_s_ratio``), not expected to win."""
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.hlo_analysis import (collective_counts,
                                            pool_allgather_sites)

    ndev = jax.device_count()
    if ndev < SH_TP or ndev % SH_TP:
        print(f"skipping sharded serving: {ndev} device(s), need a "
              f"multiple of tp={SH_TP} (force with "
              f"XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return None
    params = calibrate_weight_scales(params, parse_policy(args.policy))
    max_new = max(SH_MAX_NEW, args.max_new)
    n_req = max(args.requests, 8)

    def engine(mesh):
        return ServeEngine(cfg, params, policy=args.policy,
                           slots=args.slots, cache_len=args.cache_len,
                           kv_layout="paged", block_size=16,
                           decode_block=4, max_new_cap=max(32, max_new),
                           weights_layout="w4a8", mesh=mesh)

    out: Dict = {"workload": {"requests": n_req, "max_new": max_new,
                              "slots": args.slots, "tp": SH_TP,
                              "devices": ndev}}
    streams = {}
    engines = {"tp1": engine(None),
               f"tp{SH_TP}": engine(make_local_mesh(model_parallel=SH_TP))}
    keys = ["tok_s", "wall_s", "tokens_out", "decode_steps", "ttft_p50_s",
            "ttft_p95_s", "tp_degree", "mesh_shape",
            "per_device_pool_bytes", "per_device_weight_bytes"]
    best: Dict = {name: None for name in engines}
    for eng in engines.values():
        run_engine(eng, make_sharded_requests(n_req, cfg, max_new))
    # interleave rounds like weights_bench: the gated quantity is the
    # tok_s ratio, so shared-host noise that hits both engines cancels
    for _ in range(3):
        for name, eng in engines.items():
            eng.reset()
            reqs = make_sharded_requests(n_req, cfg, max_new)
            s = run_engine(eng, reqs)
            assert all(r.done for r in reqs), "sharded bench stalled"
            streams[name] = [tuple(r.generated) for r in reqs]
            if best[name] is None or s["tok_s"] > best[name]["tok_s"]:
                best[name] = s
    for name, stats in best.items():
        out[name] = {k: stats[k] for k in keys}
        print(f"{name:4s} serve: {stats['tok_s']:8.1f} tok/s, per device "
              f"{stats['per_device_pool_bytes'] / 1e3:.0f} KB pool + "
              f"{stats['per_device_weight_bytes'] / 1e3:.0f} KB weights")
    tpk = f"tp{SH_TP}"
    out["stream_parity"] = bool(streams[tpk] == streams["tp1"])
    out["tok_s_ratio"] = out[tpk]["tok_s"] / max(out["tp1"]["tok_s"], 1e-9)
    out["pool_bytes_ratio"] = (out[tpk]["per_device_pool_bytes"]
                               / max(out["tp1"]["per_device_pool_bytes"], 1))
    out["weight_bytes_ratio"] = (
        out[tpk]["per_device_weight_bytes"]
        / max(out["tp1"]["per_device_weight_bytes"], 1))
    # decode-wave collective census on the tp engine (the CI gate)
    eng = engines[tpk]
    with eng.mesh:
        hlo = jax.jit(eng._decode_chunk, static_argnums=(2,)).lower(
            eng.params, eng._probe_state(), False).compile().as_text()
    out["decode_collectives"] = collective_counts(hlo)
    out["pool_allgather_sites"] = len(pool_allgather_sites(hlo))
    print(f"tp={SH_TP}: parity {'OK' if out['stream_parity'] else 'FAILED'}"
          f", {out['tok_s_ratio']:.2f}x tok/s, "
          f"{out['pool_bytes_ratio']:.2f}x pool bytes/device, "
          f"{out['weight_bytes_ratio']:.2f}x weight bytes/device, decode "
          f"collectives {out['decode_collectives']} "
          f"({out['pool_allgather_sites']} pool all-gathers)")
    return out


def heavy_tail_lens(rng, n: int, lo: int, hi: int) -> np.ndarray:
    """Lognormal prompt lengths clipped to [lo, hi]: mostly short with a
    long tail — the open-loop workload's length distribution."""
    lens = rng.lognormal(mean=np.log(12.0), sigma=0.7, size=n)
    return np.clip(lens.astype(np.int64), lo, hi)


def make_stream_specs(n, cfg, hi: int):
    """(prompt, submit-kwargs) pairs: heavy-tail lengths, alternating
    greedy / sampled rows (both decode variants stay exercised). The
    fixed seed makes every call return the identical workload — warmup
    compiles exactly the admission shapes the timed runs use."""
    rng = np.random.default_rng(5)
    lens = heavy_tail_lens(rng, n, ST_LEN_LO, hi)
    return [(rng.integers(0, cfg.vocab_size, lens[i]).astype(np.int32),
             {"max_new_tokens": ST_MAX_NEW,
              "temperature": 0.5 if i % 2 else 0.0,
              "top_k": 4 if i % 2 else 0, "seed": i})
            for i in range(n)]


def streaming_bench(args, cfg, params) -> Dict:
    """Open-loop serving through the asyncio frontend.

    Three phases on one EDF + shed-load engine:

    1. **parity** — the workload submitted all-at-once through
       ``AsyncFrontend``, streamed tokens collected per request, then the
       identical requests batch-drained on the reset engine: the streams
       must be bit-identical (greedy and sampled rows). The phase also
       calibrates closed-loop capacity (requests/s) and the TTFT
       deadline for phase 2.
    2. **rates** — Poisson arrivals (exponential inter-arrival gaps) at
       ``ST_RATE_FACTORS`` x capacity, heavy-tail prompt lengths, every
       request carrying the calibrated first-token deadline; the engine
       sheds (rejects) requests predicted to miss. Reports goodput
       (SLO-met requests/s), SLO-attainment %, and client-side
       TTFT/TPOT percentiles per rate.

    CI gates (ci.yml): ``token_parity`` true, and ``slo_attainment`` at
    the lower rate >= 0.9.
    """
    import asyncio

    from repro.serve.frontend import AsyncFrontend

    n_req = 10 if args.smoke else ST_REQUESTS
    slots = args.slots
    cache_len = args.cache_len
    hi = min(48, cache_len - ST_MAX_NEW - 1)
    blocks_per = -(-cache_len // 16)
    eng = ServeEngine(cfg, params, policy=args.policy, slots=slots,
                      cache_len=cache_len, kv_layout="paged",
                      block_size=16, num_blocks=slots * blocks_per + 4,
                      max_seq_len=cache_len, decode_block=4,
                      max_new_cap=max(32, ST_MAX_NEW),
                      sched_policy="edf", slo_shed="reject")
    specs = make_stream_specs(n_req, cfg, hi)

    def reqs_of(specs):
        return [Request(uid=i, prompt=p,
                        max_new_tokens=kw["max_new_tokens"],
                        temperature=kw["temperature"], top_k=kw["top_k"],
                        seed=kw["seed"])
                for i, (p, kw) in enumerate(specs)]

    async def closed_loop_stream():
        """All-at-once submission through the frontend; returns per-
        request streamed tokens, wall seconds, engine ttft_p95."""
        t0 = time.perf_counter()
        async with AsyncFrontend(eng) as fe:
            handles = [await fe.submit(p, **kw) for p, kw in specs]
            outs = [await h.tokens() for h in handles]
            stats = await fe.stats()
        return outs, time.perf_counter() - t0, stats["ttft_p95_s"]

    async def open_loop(rate_rps: float, deadline_ms: float, seed: int):
        rng = np.random.default_rng(seed)
        t0 = time.perf_counter()
        async with AsyncFrontend(eng) as fe:
            handles = []
            for p, kw in specs:
                await asyncio.sleep(rng.exponential(1.0 / rate_rps))
                handles.append(await fe.submit(
                    p, deadline_ms=deadline_ms, **kw))
            token_counts = [len(await h.tokens()) for h in handles]
            stats = await fe.stats()
        wall = time.perf_counter() - t0
        dl_s = deadline_ms / 1e3
        ttfts = [h.first_token_t - h.submit_t for h in handles
                 if not h.shed and h.first_token_t is not None]
        met = sum(1 for h in handles
                  if not h.shed and h.first_token_t is not None
                  and h.first_token_t - h.submit_t <= dl_s)
        met_toks = sum(nt for h, nt in zip(handles, token_counts)
                       if not h.shed and h.first_token_t is not None
                       and h.first_token_t - h.submit_t <= dl_s)
        tpots = [(h.finish_t - h.first_token_t) / (nt - 1)
                 for h, nt in zip(handles, token_counts)
                 if not h.shed and h.first_token_t is not None and nt > 1]
        return {"arrival_rate_rps": rate_rps, "requests": len(handles),
                "shed": sum(1 for h in handles if h.shed),
                "slo_attainment": met / max(len(handles), 1),
                "goodput_rps": met / max(wall, 1e-9),
                "goodput_tok_s": met_toks / max(wall, 1e-9),
                "ttft_p50_s": percentile(ttfts, 50),
                "ttft_p95_s": percentile(ttfts, 95),
                "tpot_p50_s": percentile(tpots, 50),
                "tpot_p95_s": percentile(tpots, 95),
                "wall_s": wall,
                "requests_shed": stats["requests_shed"]}

    async def bench():
        # warmup: closed-loop batch drain compiles the full-wave admission
        # shapes + both decode variants; the trickle pass then drains one
        # request per distinct length bucket alone, compiling the
        # single-admission (pad-1) shapes that Poisson arrivals hit but
        # all-at-once submission never does
        run_engine(eng, reqs_of(specs))
        # (bucket, greedy?) keys the compiled admit/decode variants: a
        # solo greedy admission runs the greedy-only kernels, a solo
        # sampled one the sampling kernels — compile both per bucket
        seen = set()
        for i, (p, kw) in enumerate(specs):
            key = (-(-len(p) // 16), kw["temperature"] <= 0.0)
            if key in seen:
                continue
            seen.add(key)
            run_engine(eng, reqs_of([(p, kw)]))
        eng.reset()
        # phase 1: streaming parity + capacity/deadline calibration
        outs, wall, ttft_p95 = await closed_loop_stream()
        eng.reset()
        reqs = reqs_of(specs)
        run_engine(eng, reqs)
        parity = all(o == r.generated for o, r in zip(outs, reqs))
        capacity_rps = n_req / max(wall, 1e-9)
        deadline_ms = max(4.0 * ttft_p95, ST_MIN_DEADLINE_S) * 1e3
        out: Dict = {
            "workload": {"requests_per_rate": n_req, "slots": slots,
                         "max_new": ST_MAX_NEW,
                         "prompt_len_range": [ST_LEN_LO, int(hi)],
                         "sched_policy": "edf", "slo_shed": "reject"},
            "token_parity": bool(parity),
            "capacity_rps": capacity_rps,
            "deadline_ms": deadline_ms,
            "rates": [],
        }
        print(f"streaming parity: {'OK' if parity else 'FAILED'} "
              f"({n_req} requests); capacity {capacity_rps:.2f} req/s, "
              f"deadline {deadline_ms:.0f} ms")
        # phase 2: open-loop Poisson arrivals at each rate factor. One
        # untimed pass per rate first: open-loop admission hits wave
        # shapes (arrival-dependent pairings) that no closed-loop warmup
        # can fully enumerate, and a mid-run XLA compile would bill
        # seconds of stall to whichever request hit it
        for i, factor in enumerate(ST_RATE_FACTORS):
            eng.reset()
            await open_loop(factor * capacity_rps, deadline_ms,
                            seed=6 + i)
            eng.reset()
            r = await open_loop(factor * capacity_rps, deadline_ms,
                                seed=6 + i)
            r["rate_factor"] = factor
            out["rates"].append(r)
            print(f"open loop {factor:3.1f}x capacity "
                  f"({r['arrival_rate_rps']:.2f} req/s): attainment "
                  f"{r['slo_attainment'] * 100:5.1f}%, goodput "
                  f"{r['goodput_rps']:.2f} req/s, TTFT p95 "
                  f"{r['ttft_p95_s'] * 1e3:6.1f} ms, TPOT p50 "
                  f"{r['tpot_p50_s'] * 1e3:5.1f} ms, {r['shed']} shed")
        return out

    return asyncio.run(bench())


# --------------------------------------------------------------------------
# Observability: disabled-tracer overhead + trace/clock reconciliation
# --------------------------------------------------------------------------

OBS_SLOTS = 4
OBS_BLOCKS = 8              # tight pool: optimistic admission preempts
OBS_PROMPT = 24
OBS_SHARED = 16             # one full shared block: prefix hits + COW
OBS_MAX_NEW = 16
OBS_K = 3                   # draft tokens per spec wave
OBS_REPEATS = 3


def make_obs_requests(n, cfg) -> List[Request]:
    """Mixed workload for the obs bench: half the prompts extend one
    shared block-aligned prefix (prefix hits + COW), half are unique."""
    rng = np.random.default_rng(5)
    shared = rng.integers(0, cfg.vocab_size, OBS_SHARED).astype(np.int32)
    reqs = []
    for uid in range(n):
        tail = rng.integers(0, cfg.vocab_size,
                            OBS_PROMPT - OBS_SHARED).astype(np.int32)
        prompt = (np.concatenate([shared, tail]) if uid % 2 == 0
                  else rng.integers(0, cfg.vocab_size,
                                    OBS_PROMPT).astype(np.int32))
        reqs.append(Request(uid=uid, prompt=prompt,
                            max_new_tokens=OBS_MAX_NEW))
    return reqs


def observability_bench(args, cfg, params) -> Dict:
    """Cost and fidelity of the runtime tracing layer on a mixed paged +
    speculative + preemption/swap workload.

    Three measurements on ONE engine (identical compiled waves), swapped
    between runs by replacing ``engine.trace``: the constructor-default
    ``NULL_TRACER`` (baseline), an explicitly constructed disabled
    ``Tracer`` (``trace_off`` — the "tracing available but off"
    production setting; CI gates ``trace_off_tok_s / baseline_tok_s >=
    0.98``), and an enabled tracer (``trace_on``, reported for context).
    Modes run round-robin, best-of-``OBS_REPEATS``, so host scheduler
    drift hits all three alike. The enabled run's trace is exported and
    its per-request event-delta latency reconciled against the scheduler
    clock (``reconcile_max_err``, gated <= 5%)."""
    from repro.obs.export import chrome_trace, request_attribution
    from repro.obs.trace import NULL_TRACER, Tracer
    from repro.serve.spec import SpecConfig

    n_req = args.requests if args.smoke else 8
    eng = ServeEngine(cfg, params, policy=args.policy, slots=OBS_SLOTS,
                      cache_len=64, kv_layout="paged", block_size=16,
                      num_blocks=OBS_BLOCKS, max_seq_len=64,
                      admission="optimistic",
                      max_new_cap=max(32, OBS_MAX_NEW),
                      spec=SpecConfig(k=OBS_K, draft_layers=1))
    run_engine(eng, make_obs_requests(n_req, cfg))            # warmup
    modes = {"baseline": NULL_TRACER, "trace_off": Tracer(enabled=False),
             "trace_on": Tracer()}
    best: Dict[str, Dict] = {}
    for _ in range(OBS_REPEATS):
        for name, tracer in modes.items():
            eng.trace = tracer
            eng.reset()                  # re-syncs the scheduler's sink
            reqs = make_obs_requests(n_req, cfg)
            s = run_engine(eng, reqs)
            assert all(r.done for r in reqs), "obs workload stalled"
            if name not in best or s["tok_s"] > best[name]["tok_s"]:
                best[name] = s
    trace = chrome_trace(modes["trace_on"],
                         eng.wave_variant_signatures())
    attr = request_attribution(trace)
    last = best["trace_on"]              # same workload every repeat
    out: Dict = {"workload": {
        "requests": n_req, "prompt_len": OBS_PROMPT,
        "shared_prefix": OBS_SHARED, "max_new": OBS_MAX_NEW,
        "slots": OBS_SLOTS, "num_blocks": OBS_BLOCKS, "block_size": 16,
        "spec_k": OBS_K, "repeats": OBS_REPEATS}}
    for name, s in best.items():
        out[f"{name}_tok_s"] = s["tok_s"]
    out["trace_off_ratio"] = (out["trace_off_tok_s"]
                              / max(out["baseline_tok_s"], 1e-9))
    out["trace_on_ratio"] = (out["trace_on_tok_s"]
                             / max(out["baseline_tok_s"], 1e-9))
    out["trace_records"] = len(modes["trace_on"])
    out["trace_dropped"] = modes["trace_on"].dropped
    out["reconcile_max_err"] = attr["reconcile_max_err"]
    # prove the trace covered the mixed machinery, not a trivial drain
    out["preemptions"] = last["preemptions"]
    out["spec_waves"] = last["spec_waves"]
    out["prefix_hit_tokens"] = last["prefix_hit_tokens"]
    print(f"observability: baseline {out['baseline_tok_s']:.1f} tok/s, "
          f"tracer off {out['trace_off_tok_s']:.1f} "
          f"({out['trace_off_ratio']:.3f}x), on "
          f"{out['trace_on_tok_s']:.1f} ({out['trace_on_ratio']:.3f}x); "
          f"{out['trace_records']} records, reconcile err "
          f"{out['reconcile_max_err'] * 100:.2f}% over "
          f"{attr['finished']} requests ({out['preemptions']} "
          f"preemptions, {out['spec_waves']} spec waves)")
    return out


def run_engine(engine, reqs) -> Dict:
    for r in reqs:
        engine.submit(r)
    t0 = time.perf_counter()
    stats = engine.run_until_drained()
    wall = time.perf_counter() - t0
    stats["wall_s"] = wall
    stats["tok_s"] = stats["tokens_out"] / max(wall, 1e-9)
    return stats


def timed(engine_factory, args, cfg) -> Dict:
    engine = engine_factory()
    run_engine(engine, make_requests(args, cfg))     # warmup: compiles
    engine.reset()
    return run_engine(engine, make_requests(args, cfg))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--policy", default="A8d-C8-W4")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--decode-block", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI workload (fewer/shorter requests)")
    ap.add_argument("--skip-baseline", action="store_true")
    ap.add_argument("--skip-paged", action="store_true",
                    help="skip the paged-vs-dense cache comparison")
    ap.add_argument("--skip-shared-prefix", action="store_true",
                    help="skip the shared-prefix / preemption workload")
    ap.add_argument("--skip-spec", action="store_true",
                    help="skip the speculative-decoding workload")
    ap.add_argument("--skip-streaming", action="store_true",
                    help="skip the open-loop streaming workload")
    ap.add_argument("--skip-weights", action="store_true",
                    help="skip the w4a8-vs-bf16 weight-layout comparison")
    ap.add_argument("--skip-sharded", action="store_true",
                    help="skip the tensor-parallel sharded-serving "
                         "comparison (auto-skips on a 1-device host)")
    ap.add_argument("--skip-obs", action="store_true",
                    help="skip the tracing-overhead / trace-fidelity "
                         "measurement")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.slots = 6, 2
        args.prompt_len, args.max_new, args.cache_len = 16, 8, 64

    cfg = get_reduced_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))

    result = {"args": vars(args)}
    if not args.skip_baseline:
        base = timed(lambda: BaselineEngine(
            cfg, params, policy=args.policy, slots=args.slots,
            cache_len=args.cache_len), args, cfg)
        result["seed"] = base
        print(f"seed v1: {base['tok_s']:.1f} tok/s, "
              f"{base['decode_step_s'] * 1e3:.1f} ms/decode-step, "
              f"TTFT p50 {base['ttft_p50_s'] * 1e3:.0f} ms")
    v2 = timed(lambda: ServeEngine(
        cfg, params, policy=args.policy, slots=args.slots,
        cache_len=args.cache_len, decode_block=args.decode_block,
        max_new_cap=max(32, args.max_new)), args, cfg)
    result["v2"] = v2
    print(f"v2:      {v2['tok_s']:.1f} tok/s, "
          f"{v2['decode_step_s'] * 1e3:.1f} ms/decode-step, "
          f"TTFT p50 {v2['ttft_p50_s'] * 1e3:.0f} ms")
    if "seed" in result:
        result["speedup_tok_s"] = v2["tok_s"] / max(result["seed"]["tok_s"],
                                                    1e-9)
        print(f"speedup: {result['speedup_tok_s']:.2f}x")
    paged_ok = not (any(k != "attn" for k in cfg.block_pattern)
                    or cfg.is_encdec or cfg.sliding_window)
    if not args.skip_paged:
        if not paged_ok:
            print(f"skipping paged comparison: {cfg.name} is not a "
                  f"full-attention decoder")
        else:
            # smoke already shrank the workload via args; the comparison
            # reuses slots/cache_len so the HBM budget follows it
            pv_req = args.requests if args.smoke else 24
            args_pv = argparse.Namespace(**{**vars(args),
                                            "requests": max(pv_req, 12)})
            result["paged_vs_dense"] = paged_vs_dense(args_pv, cfg, params)
    if not args.skip_shared_prefix and paged_ok:
        sp_args = argparse.Namespace(**{**vars(args), "cache_len":
                                        max(args.cache_len, 128)})
        result["shared_prefix"] = shared_prefix_bench(sp_args, cfg, params)
        result["warm_burst"] = warm_burst_bench(sp_args, cfg, params)
    if not args.skip_spec and paged_ok:
        result["spec_decode"] = spec_decode_bench(args, cfg, params)
    if not args.skip_streaming and paged_ok:
        result["streaming"] = streaming_bench(args, cfg, params)
    if not args.skip_weights and paged_ok:
        result["weights_w4a8"] = weights_bench(args, cfg, params)
    if not args.skip_sharded and paged_ok:
        sharded = sharded_bench(args, cfg, params)
        if sharded is not None:
            result["sharded"] = sharded
    if not args.skip_obs and paged_ok:
        result["observability"] = observability_bench(args, cfg, params)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
