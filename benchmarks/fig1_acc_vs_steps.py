"""Figure 1 (mechanism reproduction): QAT quality improves with training
duration, crossing the (fixed) PTQ lines. LR follows the paper's sqrt rule
as duration changes."""
from __future__ import annotations

from repro.configs.base import TrainConfig

from benchmarks.common import (Row, eval_quality, get_teacher, ptq_baselines,
                               run_silq)

POLICY = "A8d-C8-W4"
DURATIONS = (25, 75, 200, 400)
REF_STEPS = 200


def main(row: Row | None = None):
    row = row or Row()
    cfg, teacher = get_teacher()
    ptq = {name: eval_quality(cfg, q, teacher, POLICY)["teacher_agreement"]
           for name, q in ptq_baselines(cfg, teacher, POLICY).items()}
    print(f"# fig1 PTQ lines: " +
          " ".join(f"{k}={v:.4f}" for k, v in ptq.items()))
    curve = []
    for steps in DURATIONS:
        tcfg = TrainConfig(precision=POLICY, total_steps=steps,
                           ref_steps=REF_STEPS, batch_size=8, seq_len=64)
        student, _, dt = run_silq(cfg, teacher, tcfg)
        agree = eval_quality(cfg, student, teacher,
                             POLICY)["teacher_agreement"]
        curve.append((steps, agree))
        print(f"# fig1 steps={steps:5d} agree={agree:.4f} "
              f"(lr={tcfg.scaled_lr():.2e})")
        row.add(f"fig1/steps={steps}", dt, f"agree={agree:.4f}")
    # monotone-ish improvement: last point beats first
    assert curve[-1][1] >= curve[0][1] - 0.01
    # longest run beats RTN PTQ
    assert curve[-1][1] >= ptq["RTN"] - 0.02
    return {"curve": curve, "ptq": ptq}


if __name__ == "__main__":
    main()
