"""Serve-kernel roofline: extend the training byte/FLOP model
(``benchmarks/roofline.py``) to the serving hot-path kernels.

For each kernel the bench records three byte counts at fixed smoke shapes:

* ``ideal_bytes`` — the roofline floor: every *resident* operand byte read
  once, every output byte written once (a perfect kernel walks only the
  blocks ``lengths`` make visible)
* ``kernel_bytes`` — the modeled HBM traffic of the current implementation,
  term-by-term from its grid/tiling (documented inline against the kernel
  source); padding, full-table walks, and per-head refetch all show up here
* ``naive_bytes`` — the traffic of the implementation each kernel replaced
  (per-query-head paged grid; two-pass gather + dequant), kept as the
  regression yardstick

and gates their ratios in CI: ``roofline_frac = ideal / kernel`` (how close
the implementation sits to the floor) and ``win_vs_naive = naive / kernel``.
These are pure arithmetic plus the *real* ``nbytes`` of freshly exported
arrays (the w4a8 packed layout is measured from an actual
``export_linear_w4`` result, so a packing regression — unpacked nibbles,
blown-up scale dtype — moves the gated number), which makes the gate
deterministic on any backend: kernel-traffic regressions are caught on the
CPU CI runner, no TPU required. Wall-clock timings of the ops entry points
ride along informationally (ref backend off-TPU — the path the CPU engine
actually serves).

Usage::

    python benchmarks/serve_kernels.py [--out serve_kernels.json]
                                       [--merge BENCH_serve.json]

``--merge`` inserts the section into an existing serve-bench artifact under
``"serve_kernels"`` (the CI gates read it from there).
"""
from __future__ import annotations

import argparse
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qat import export_linear_w4, init_linear
from repro.kernels.kvq_attn import ops as kvq
from repro.kernels.w4a8.ops import w4a8_linear

# Smoke shapes: one decode/verify wave of a small GQA model over a paged
# int8 pool. Small enough to run in interpret mode, large enough that every
# modeled term is nonzero.
B, HKV, GROUP, D = 4, 2, 2, 64       # slots, kv heads, GQA group, head dim
H = HKV * GROUP
BS, T = 32, 8                        # pool block size, table length
LENS = (200, 256, 120, 64)           # resident tokens per slot
C = 5                                # spec verify window (k + 1)
CP = -(-C // 8) * 8                  # sublane-padded window (ops.py)
GP = -(-GROUP // 8) * 8              # sublane-padded GQA group (ops.py)
M, KF, N = 8, 256, 512               # w4a8 matmul: tokens x d_in -> d_out

BF16, INT8, F32 = 2, 1, 4

# one pool block's HBM payload: int8 K + V tiles and their f32 scale rows
BLOCK_BYTES = 2 * BS * D * INT8 + 2 * BS * F32
RESIDENT_BLOCKS = sum(-(-ln // BS) for ln in LENS)


def _timed(fn, *args, reps: int = 5):
    out = jax.block_until_ready(fn(*args))          # compile + warm
    best = math.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return out, best


def _pool(rng):
    nb = B * T
    k_pool = jnp.asarray(rng.integers(-127, 127, (nb, HKV, BS, D)), jnp.int8)
    v_pool = jnp.asarray(rng.integers(-127, 127, (nb, HKV, BS, D)), jnp.int8)
    s_k = jnp.asarray(rng.random((nb, HKV, BS)) * 0.02, jnp.float32)
    s_v = jnp.asarray(rng.random((nb, HKV, BS)) * 0.02, jnp.float32)
    tbl = jnp.asarray(rng.permutation(nb).reshape(B, T), jnp.int32)
    lens = jnp.asarray(LENS, jnp.int32)
    return k_pool, v_pool, s_k, s_v, tbl, lens


def paged_decode_section(rng):
    """Grouped-grid paged flash-decode (kernel.py ``_paged_kernel``)."""
    k_pool, v_pool, s_k, s_v, tbl, lens = _pool(rng)
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.bfloat16)
    # floor: q/out once, each *resident* block read once per KV head (the
    # pool holds BLOCK_BYTES per head per block)
    ideal = 2 * B * H * D * BF16 + RESIDENT_BLOCKS * HKV * BLOCK_BYTES
    # current kernel: grid (B, Hkv, T) — every table entry walked once per
    # KV head (sentinels clamp, masked later), q/out tiles padded to Gp
    # sublanes and held in VMEM across the T steps
    kernel = (B * HKV * T * BLOCK_BYTES            # pool + scale tiles
              + 2 * B * HKV * GP * D * BF16)       # padded q + out
    # pre-rework kernel: grid (B, H, T) refetched every block per *query*
    # head (GROUPx the pool traffic), 1-row q/out tiles
    naive = B * H * T * BLOCK_BYTES + 2 * B * H * D * BF16
    fn = jax.jit(lambda *a: kvq.kvq_paged_decode_attn(*a, use_pallas=False))
    _, wall = _timed(fn, q, k_pool, v_pool, s_k, s_v, tbl, lens)
    return {"ideal_bytes": ideal, "kernel_bytes": kernel,
            "naive_bytes": naive,
            "roofline_frac": ideal / kernel,
            "win_vs_naive": naive / kernel,
            "ref_wall_s": wall, "ref_gbps": kernel / wall / 1e9}


def spec_verify_section(rng):
    """Multi-query verify-wave kernel with C -> Cp sublane padding."""
    k_pool, v_pool, s_k, s_v, tbl, lens0 = _pool(rng)
    q = jnp.asarray(rng.standard_normal((B, C, H, D)), jnp.bfloat16)
    lens = jnp.minimum(lens0[:, None] + jnp.arange(C)[None, :],
                       T * BS).astype(jnp.int32)
    ideal = 2 * B * C * H * D * BF16 + RESIDENT_BLOCKS * HKV * BLOCK_BYTES
    # grid (B, H, T): blocks refetched per query head (the remaining known
    # overhead — folding the GQA group in as the decode kernel now does is
    # the next step); q/out padded C -> Cp
    kernel = B * H * T * BLOCK_BYTES + 2 * B * CP * H * D * BF16
    naive = kernel  # the rework changed sublane tiling, not byte counts
    fn = jax.jit(lambda *a: kvq.kvq_spec_verify_attn(*a, use_pallas=False))
    _, wall = _timed(fn, q, k_pool, v_pool, s_k, s_v, tbl, lens)
    return {"ideal_bytes": ideal, "kernel_bytes": kernel,
            "pad_overhead": CP / C,
            "roofline_frac": ideal / kernel,
            "ref_wall_s": wall, "ref_gbps": kernel / wall / 1e9}


def history_gather_section(rng):
    """Fused tail-wave gather-dequant vs the two-pass XLA gather."""
    k_pool, _, s_k, _, tbl, _ = _pool(rng)
    per_block_read = BS * D * INT8 + BS * F32
    per_block_write = BS * D * F32
    # fused kernel: one int8+scale read, one f32 write per gathered block
    fused = B * HKV * T * (per_block_read + per_block_write)
    # two-pass XLA path: gather materializes an int8 copy (+ scale copy) in
    # HBM, then the dequant pass re-reads both and writes the f32 result
    naive = fused + B * HKV * T * (2 * BS * D * INT8 + 2 * BS * F32)
    fn = jax.jit(lambda *a: kvq.gather_dequant_paged_kv(*a,
                                                        use_pallas=False))
    _, wall = _timed(fn, k_pool, s_k, tbl)
    return {"fused_bytes": fused, "naive_bytes": naive,
            "win_vs_naive": naive / fused,
            "ref_wall_s": wall, "ref_gbps": fused / wall / 1e9}


def w4a8_section(rng):
    """Packed-int4-weight matmul: weight traffic measured from a real
    export, not a formula — a layout regression changes the gated ratio."""
    key = jax.random.PRNGKey(3)
    lin = init_linear(key, KF, N, bias=True)
    exp = export_linear_w4(lin, trained_bits=4)
    packed = sum(int(v.size) * v.dtype.itemsize for v in exp.values())
    bf16_w = KF * N * BF16 + N * BF16                 # w + b
    x = jnp.asarray(rng.standard_normal((M, KF)), jnp.bfloat16)
    ideal = (M * KF * INT8 + M * F32                  # int8 acts + scales
             + packed + M * N * BF16)                 # weights + output
    fn = jax.jit(lambda xx: w4a8_linear(xx, exp, use_pallas=False))
    _, wall = _timed(fn, x)
    return {"packed_weight_bytes": packed, "bf16_weight_bytes": bf16_w,
            "weight_traffic_ratio": packed / bf16_w,
            "ideal_bytes": ideal,
            "ref_wall_s": wall, "ref_gbps": ideal / wall / 1e9}


def run() -> dict:
    rng = np.random.default_rng(0)
    out = {
        "shapes": {"slots": B, "kv_heads": HKV, "gqa_group": GROUP,
                   "head_dim": D, "block_size": BS, "table_len": T,
                   "lengths": list(LENS), "verify_window": C,
                   "w4a8_mkn": [M, KF, N]},
        "paged_decode": paged_decode_section(rng),
        "spec_verify": spec_verify_section(rng),
        "history_gather": history_gather_section(rng),
        "w4a8_matmul": w4a8_section(rng),
        # int8 cache + per-token scales vs a bf16 cache, per block
        "kv_cache_traffic_ratio": BLOCK_BYTES / (2 * BS * D * BF16),
    }
    for name in ("paged_decode", "spec_verify", "history_gather",
                 "w4a8_matmul"):
        s = out[name]
        frac = s.get("roofline_frac")
        win = s.get("win_vs_naive")
        bits = [f"{name}:"]
        if frac is not None:
            bits.append(f"roofline frac {frac:.2f}")
        if win is not None:
            bits.append(f"{win:.2f}x vs naive")
        bits.append(f"ref {s['ref_wall_s'] * 1e3:.2f} ms "
                    f"({s['ref_gbps']:.2f} GB/s modeled)")
        print("  ".join(bits))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="serve_kernels.json",
                    help="standalone artifact path ('' to skip)")
    ap.add_argument("--merge", default="",
                    help="existing BENCH_serve.json to insert the "
                         "'serve_kernels' section into")
    args = ap.parse_args()
    section = run()
    if args.out:
        with open(args.out, "w") as f:
            json.dump(section, f, indent=2)
        print(f"wrote {args.out}")
    if args.merge:
        with open(args.merge) as f:
            bench = json.load(f)
        bench["serve_kernels"] = section
        with open(args.merge, "w") as f:
            json.dump(bench, f, indent=2)
        print(f"merged serve_kernels into {args.merge}")


if __name__ == "__main__":
    main()
