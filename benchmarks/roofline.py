"""Roofline aggregation: reads artifacts/dryrun/*.json into the §Roofline
table (per arch x shape x mesh: three terms, bottleneck, useful-FLOPs
ratio). Also emits the markdown table pasted into EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
ART_BASELINE = ART + "_baseline"


def load_cells(mesh: str | None = None, policy: str | None = None,
               art_dir: str | None = None) -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(art_dir or ART, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r["mesh"] != mesh:
            continue
        if policy and r["policy"] != policy:
            continue
        cells.append(r)
    return cells


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.3g}s"
    if x >= 1e-3:
        return f"{x * 1e3:.3g}ms"
    return f"{x * 1e6:.3g}us"


def markdown_table(cells: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | t_compute | t_memory | t_collective | "
           "bottleneck | roofline frac | useful FLOPs |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in sorted(cells, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        rl = r["roofline"]
        tc, tm, tl = (rl["t_compute_s"], rl["t_memory_s"],
                      rl["t_collective_s"])
        dom = max(tc, tm, tl)
        frac = tc / dom if dom > 0 else 0.0
        ratio = rl.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {fmt_s(tc)} | "
            f"{fmt_s(tm)} | {fmt_s(tl)} | {rl['bottleneck']} | "
            f"{frac * 100:.1f}% | "
            f"{(ratio or 0) * 100:.0f}% |")
    return "\n".join(lines)


def main(row=None):
    art = ART_BASELINE if "--baseline" in sys.argv else None
    cells = load_cells(mesh="singlepod", art_dir=art)
    if not cells:
        print("# roofline: no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first")
        return {}
    print(markdown_table(cells))
    if row is not None:
        for r in cells:
            rl = r["roofline"]
            dom = max(rl["t_compute_s"], rl["t_memory_s"],
                      rl["t_collective_s"])
            row.add(f"roofline/{r['arch']}/{r['shape']}", dom,
                    f"bottleneck={rl['bottleneck']},"
                    f"frac={rl['t_compute_s'] / dom if dom else 0:.3f}")
    return {(
        r["arch"], r["shape"], r["mesh"]): r["roofline"] for r in cells}


if __name__ == "__main__":
    main()
