"""Table 2 (mechanism reproduction): SiLQ on open data vs an LLM-QAT-style
pipeline that self-generates its training set from the model. The paper's
point: sampling data from the model costs wall-clock and does not help —
SiLQ with a real dataset reaches better quality in less time."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.core.qat import make_ctx
from repro.data import MixtureIterator
from repro.launch.steps import make_train_step
from repro.launch.train import calibrate
from repro.models import decode_step, init_cache, prefill
from repro.optim import adamw_init

from benchmarks.common import (Row, data_cfg, eval_quality, get_teacher,
                               run_silq)

QAT_STEPS = 150
GEN_SAMPLES = 32          # self-generated corpus size (LLM-QAT style)
GEN_LEN = 64


def selfgen_corpus(cfg, teacher, n: int, length: int):
    """Sample documents from the model itself (the LLM-QAT data recipe)."""
    ctx = make_ctx("A16-C16-W16", mode="off")
    outs = []
    t0 = time.perf_counter()
    B = 8
    for start in range(0, n, B):
        tok = jnp.ones((B, 1), jnp.int32)
        logits, cache = prefill(cfg, teacher, ctx, {"tokens": tok},
                                cache_budget=length + 2)
        seq = [tok]
        nxt = jnp.argmax(logits[:, -1], -1)[:, None]
        key = jax.random.PRNGKey(start)
        for t in range(length - 1):
            seq.append(nxt)
            logits, cache = decode_step(cfg, teacher, ctx, nxt, cache)
            key, k2 = jax.random.split(key)
            nxt = jax.random.categorical(k2, logits[:, -1] / 0.9)[:, None]
        outs.append(jnp.concatenate(seq, 1))
    gen_s = time.perf_counter() - t0
    return jnp.concatenate(outs, 0)[:n], gen_s


def main(row: Row | None = None):
    row = row or Row()
    cfg, teacher = get_teacher()

    # --- SiLQ on the open synthetic mixture -------------------------------
    tcfg = TrainConfig(precision="A8d-C8-W4", total_steps=QAT_STEPS,
                       ref_steps=QAT_STEPS, batch_size=8, seq_len=64)
    student, _, silq_s = run_silq(cfg, teacher, tcfg)
    e_silq = eval_quality(cfg, student, teacher, tcfg.precision)

    # --- LLM-QAT-style: self-generate, then QAT on generated data ---------
    corpus, gen_s = selfgen_corpus(cfg, teacher, GEN_SAMPLES, GEN_LEN)
    dc = data_cfg(cfg)
    studentg = jax.tree.map(jnp.copy, teacher)
    studentg = calibrate(cfg, studentg, tcfg, dc)
    opt = adamw_init(studentg)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 2))
    t0 = time.perf_counter()
    rng = np.random.default_rng(0)
    for step in range(QAT_STEPS):
        idx = rng.integers(0, corpus.shape[0], 8)
        toks = corpus[idx]
        b = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "loss_mask": jnp.ones((8, toks.shape[1] - 1), jnp.float32)}
        studentg, opt, m = step_fn(studentg, teacher, opt, b,
                                   jnp.int32(step))
    qat_g_s = time.perf_counter() - t0
    e_gen = eval_quality(cfg, studentg, teacher, tcfg.precision)

    print(f"# {'method':24s} {'gen_s':>7s} {'train_s':>8s} {'agree%':>7s}")
    print(f"# {'SiLQ(open data)':24s} {0.0:7.1f} {silq_s:8.1f} "
          f"{e_silq['teacher_agreement'] * 100:7.2f}")
    print(f"# {'LLM-QAT(selfgen)':24s} {gen_s:7.1f} {qat_g_s:8.1f} "
          f"{e_gen['teacher_agreement'] * 100:7.2f}")
    row.add("table2/SiLQ_open_data", silq_s,
            f"agree={e_silq['teacher_agreement']:.4f},gen_s=0")
    row.add("table2/LLMQAT_selfgen", gen_s + qat_g_s,
            f"agree={e_gen['teacher_agreement']:.4f},gen_s={gen_s:.1f}")
    return {"silq": (silq_s, e_silq), "selfgen": (gen_s + qat_g_s, e_gen)}


if __name__ == "__main__":
    main()
