"""Table 3 (mechanism reproduction): QAT with the model's original mixture
vs a different open dataset. The paper's finding: a good substitute dataset
matches or beats the original — QAT is not tied to the original data."""
from __future__ import annotations

from repro.configs.base import TrainConfig

from benchmarks.common import Row, eval_quality, get_teacher, run_silq

QAT_STEPS = 150


def main(row: Row | None = None):
    row = row or Row()
    cfg, teacher = get_teacher()
    tcfg = TrainConfig(precision="A8d-C8-W4", total_steps=QAT_STEPS,
                       ref_steps=QAT_STEPS, batch_size=8, seq_len=64)
    results = {}
    for name, seed in (("original-mixture", 0), ("substitute-dataset", 42)):
        student, _, dt = run_silq(cfg, teacher, tcfg, seed_data=seed)
        e = eval_quality(cfg, student, teacher, tcfg.precision)
        results[name] = e
        print(f"# table3 {name:22s} agree={e['teacher_agreement']:.4f} "
              f"loss={e['ntp_loss']:.4f}")
        row.add(f"table3/{name}", dt, f"agree={e['teacher_agreement']:.4f}")
    gap = abs(results["original-mixture"]["teacher_agreement"]
              - results["substitute-dataset"]["teacher_agreement"])
    assert gap < 0.08, f"dataset swap should be roughly neutral, gap={gap}"
    return results


if __name__ == "__main__":
    main()
