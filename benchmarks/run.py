"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (prefixed '#' lines are
human-readable table reproductions). Budget-bounded for CPU: each QAT run
uses a reduced model and a few hundred steps.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table1 fig3
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks.common import Row


def main() -> None:
    from benchmarks import (fig1_acc_vs_steps, fig3_rotation, roofline,
                            table1_ptq_vs_qat, table2_time_to_quality,
                            table3_dataset_swap, table4_ablations)
    suites = {
        "table1": table1_ptq_vs_qat.main,
        "table2": table2_time_to_quality.main,
        "table3": table3_dataset_swap.main,
        "table4": table4_ablations.main,
        "fig1": fig1_acc_vs_steps.main,
        "fig3": fig3_rotation.main,
        "roofline": roofline.main,
    }
    want = sys.argv[1:] or list(suites)
    row = Row()
    print("name,us_per_call,derived")
    failures = []
    for name in want:
        t0 = time.perf_counter()
        try:
            suites[name](row)
            print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
                  flush=True)
        except Exception as e:
            failures.append(name)
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    row.emit()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
