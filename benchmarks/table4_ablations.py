"""Table 4 (mechanism reproduction): ablations around the SiLQ recipe.
Paper's two critical factors: pure-KD loss and quantile activation
calibration. Each row is one short QAT run differing in one knob."""
from __future__ import annotations

from repro.configs.base import TrainConfig

from benchmarks.common import Row, eval_quality, get_teacher, run_silq

QAT_STEPS = 150
BASE = dict(precision="A8s-C8-W4", total_steps=QAT_STEPS,
            ref_steps=QAT_STEPS, batch_size=8, seq_len=64)

ABLATIONS = [
    ("baseline", {}),
    ("kd_ratio=0.0(pure-NTP)", {"kd_ratio": 0.0}),
    ("kd_ratio=0.5(mixed)", {"kd_ratio": 0.5}),
    ("kd_temp=0.5", {"kd_temperature": 0.5}),
    ("kd_temp=2.0", {"kd_temperature": 2.0}),
    ("dclm_ratio=0.0", {"dclm_ratio": 0.0}),
    ("dclm_ratio=0.5", {"dclm_ratio": 0.5}),
    ("act_lrx=1(no boost)", {"act_scale_lr_mult": 1.0}),
    ("act_calib=max", {"act_calib_method": "max"}),
    ("wgt_calib=lsq", {"wgt_calib_method": "lsq"}),
]


def main(row: Row | None = None):
    row = row or Row()
    cfg, teacher = get_teacher()
    results = {}
    print(f"# {'ablation':26s} {'agree%':>7s} {'d_base':>7s} {'KL':>9s}")
    base_agree = None
    for name, overrides in ABLATIONS:
        tcfg = TrainConfig(**{**BASE, **overrides})
        student, _, dt = run_silq(cfg, teacher, tcfg)
        e = eval_quality(cfg, student, teacher, tcfg.precision)
        results[name] = e
        if base_agree is None:
            base_agree = e["teacher_agreement"]
        delta = e["teacher_agreement"] - base_agree
        print(f"# {name:26s} {e['teacher_agreement'] * 100:7.2f} "
              f"{delta * 100:+7.2f} {e.get('teacher_kl', 0):9.5f}")
        row.add(f"table4/{name}", dt,
                f"agree={e['teacher_agreement']:.4f};"
                f"kl={e.get('teacher_kl', 0):.5f}")
    # the paper's two headline ablation effects
    assert results["baseline"]["teacher_agreement"] >= \
        results["kd_ratio=0.0(pure-NTP)"]["teacher_agreement"] - 1e-6, \
        "pure KD should beat pure next-token prediction"
    return results


if __name__ == "__main__":
    main()
