"""Shared benchmark harness.

The container is offline (no HF checkpoints / lm-eval), so paper tables are
reproduced in *mechanism* on scaled-down models trained in-container:

* a reduced-config fp16 "original model" (the teacher) is pretrained on the
  synthetic mixture until it has real structure to lose under quantization,
* quality is measured on held-out data as (a) next-token loss and (b) top-1
  agreement with the fp16 teacher (the stand-in for benchmark accuracy
  deltas: a quantized model that matches the original's predictions scores
  identically on any downstream task).

Teachers are cached under artifacts/bench/ so every table reuses the same
"original model" (as the paper does).
"""
from __future__ import annotations

import os
import time
from functools import partial
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_reduced_config
from repro.configs.base import TrainConfig
from repro.core.qat import make_ctx
from repro.data import MixtureIterator, SyntheticConfig, calibration_batches
from repro.launch.steps import make_train_step
from repro.launch.train import calibrate, make_teacher_pretrain_step
from repro.models import forward, init_params
from repro.optim import adamw_init

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

BENCH_ARCH = "qwen2.5-3b"
SEQ_LEN = 64
BATCH = 8
TEACHER_STEPS = 400
EVAL_BATCHES = 8


def data_cfg(cfg, seed: int = 0, dclm_ratio: float = 0.25) -> SyntheticConfig:
    return SyntheticConfig(vocab_size=cfg.vocab_size, seq_len=SEQ_LEN,
                           batch_size=BATCH, dclm_ratio=dclm_ratio,
                           seed=seed)


def get_teacher(arch: str = BENCH_ARCH, steps: int = TEACHER_STEPS):
    """Pretrained fp16 'original model' (cached)."""
    cfg = get_reduced_config(arch)
    ck = Checkpointer(os.path.join(ART, f"teacher_{arch}_{steps}"))
    params0 = init_params(cfg, jax.random.PRNGKey(0))
    if ck.latest_step() is not None:
        params, _ = ck.restore(params0)
        return cfg, params
    dc = data_cfg(cfg)
    it = MixtureIterator(dc)
    opt = adamw_init(params0)
    step_fn = make_teacher_pretrain_step(cfg)
    params = params0
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, loss = step_fn(params, opt, b)
    ck.save(steps, params, {})
    return cfg, params


def eval_quality(cfg, params, teacher, policy: str,
                 n_batches: int = EVAL_BATCHES) -> Dict[str, float]:
    """Held-out next-token loss + top-1 agreement with the fp16 teacher."""
    from repro.core.distill import next_token_loss
    ctx = make_ctx(policy) if policy != "A16-C16-W16" else \
        make_ctx(policy, mode="off")
    tctx = make_ctx("A16-C16-W16", mode="off")
    dc = data_cfg(cfg, seed=777)          # held-out stream
    it = MixtureIterator(dc, start_step=50_000_000)
    losses, agrees, kls = [], [], []
    fwd = jax.jit(lambda p, b: forward(cfg, p, ctx, b)[0])
    tfwd = jax.jit(lambda p, b: forward(cfg, p, tctx, b)[0])
    for _ in range(n_batches):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        lg = fwd(params, b)
        tl = tfwd(teacher, b)
        losses.append(float(next_token_loss(lg, b["labels"],
                                            b["loss_mask"])))
        m = b["loss_mask"] > 0
        agrees.append(float(jnp.sum((jnp.argmax(lg, -1) ==
                                     jnp.argmax(tl, -1)) * m) /
                            jnp.sum(m)))
        # KL(teacher || student): the KD objective on held-out data —
        # far more sensitive than top-1 agreement at small scale
        lp_s = jax.nn.log_softmax(lg.astype(jnp.float32), -1)
        lp_t = jax.nn.log_softmax(tl.astype(jnp.float32), -1)
        p_t = jnp.exp(lp_t)
        kl = jnp.sum(p_t * (lp_t - lp_s), -1)
        kls.append(float(jnp.sum(kl * m) / jnp.sum(m)))
    return {"ntp_loss": float(np.mean(losses)),
            "teacher_agreement": float(np.mean(agrees)),
            "teacher_kl": float(np.mean(kls))}


def run_silq(cfg, teacher, tcfg: TrainConfig, *, seed_data: int = 0,
             eval_every: int = 0) -> Tuple[Dict, list, float]:
    """Calibrate + QAT per the paper recipe. Returns (student, curve, s)."""
    dc = data_cfg(cfg, seed=seed_data, dclm_ratio=tcfg.dclm_ratio)
    student = jax.tree.map(jnp.copy, teacher)
    student = calibrate(cfg, student, tcfg, dc)
    opt = adamw_init(student)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 2))
    it = MixtureIterator(dc, start_step=1)
    t0 = time.perf_counter()
    curve = []
    for step in range(tcfg.total_steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        student, opt, m = step_fn(student, teacher, opt, b, jnp.int32(step))
        if eval_every and (step + 1) % eval_every == 0:
            q = eval_quality(cfg, student, teacher, tcfg.precision,
                             n_batches=4)
            curve.append((step + 1, q["teacher_agreement"]))
    return student, curve, time.perf_counter() - t0


def ptq_baselines(cfg, teacher, policy_name: str) -> Dict[str, Dict]:
    from repro.core.precision import parse_policy
    from repro.core.ptq.rtn import rtn_quantize
    from repro.core.ptq.smoothquant import smoothquant_quantize
    pol = parse_policy(policy_name)
    dc = data_cfg(cfg)
    cb = calibration_batches(dc, 5)
    out = {}
    out["RTN"] = rtn_quantize(cfg, teacher, pol, cb)
    out["SmoothQuant"] = smoothquant_quantize(cfg, teacher, pol, cb,
                                              alpha=0.4)
    return out


class Row:
    """CSV row helper for benchmarks/run.py (name,us_per_call,derived)."""

    def __init__(self):
        self.rows = []

    def add(self, name: str, seconds: float, derived: str):
        self.rows.append(f"{name},{seconds * 1e6:.0f},{derived}")

    def emit(self):
        for r in self.rows:
            print(r, flush=True)
