"""Figure 3 (mechanism reproduction): factor weight changes into rotational
vs non-rotational parts (orthogonal Procrustes). Expectation, as in the
paper: rotation-based PTQ weight changes are predominantly rotational;
SiLQ's QAT changes are substantially non-rotational — a solution space
rotations cannot reach."""
from __future__ import annotations

import jax

from repro.configs.base import TrainConfig
from repro.core.analysis.rotation import rotate_residual, rotation_report
from repro.core.precision import parse_policy
from repro.core.ptq.rtn import rtn_quantize
from repro.data import calibration_batches

from benchmarks.common import Row, data_cfg, get_teacher, run_silq

QAT_STEPS = 200
POLICY = "A8d-C8-W4"


def _share(report):
    tot = sum(v["rotational"] + v["non_rotational"] for v in report.values())
    return sum(v["rotational"] for v in report.values()) / max(tot, 1e-12)


def main(row: Row | None = None):
    row = row or Row()
    cfg, teacher = get_teacher()
    pol = parse_policy(POLICY)
    cb = calibration_batches(data_cfg(cfg), 3)

    # rotation-PTQ path: residual rotation + RTN (SpinQuant-style)
    rotated = rotate_residual(cfg, teacher, jax.random.PRNGKey(11))
    rotated_q = rtn_quantize(cfg, rotated, pol, cb)
    rep_rot = rotation_report(cfg, teacher, rotated_q)

    # SiLQ path: QAT from the same teacher
    tcfg = TrainConfig(precision=POLICY, total_steps=QAT_STEPS,
                       ref_steps=QAT_STEPS, batch_size=8, seq_len=64)
    student, _, dt = run_silq(cfg, teacher, tcfg)
    rep_qat = rotation_report(cfg, teacher, student)

    s_rot, s_qat = _share(rep_rot), _share(rep_qat)
    print(f"# fig3 rotational share: rotation-PTQ={s_rot:.3f} "
          f"SiLQ-QAT={s_qat:.3f}")
    for name, rep in (("rotationPTQ", rep_rot), ("SiLQ", rep_qat)):
        for lt, d in rep.items():
            print(f"#   {name:12s} {lt:4s} rot={d['rotational']:.4f} "
                  f"nonrot={d['non_rotational']:.4f}")
    row.add("fig3/rotation_ptq_share", 0.0, f"rot_share={s_rot:.4f}")
    row.add("fig3/silq_share", dt, f"rot_share={s_qat:.4f}")
    assert s_rot > s_qat + 0.15, \
        "rotation PTQ must be more rotational than QAT"
    return {"rotation_ptq": s_rot, "silq": s_qat}


if __name__ == "__main__":
    main()
