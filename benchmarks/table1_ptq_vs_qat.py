"""Table 1 (mechanism reproduction): SiLQ vs PTQ baselines across precision
configs. Expected ordering, as in the paper: SiLQ > SmoothQuant/RTN at every
A-C-W config, approaching the fp16 baseline."""
from __future__ import annotations

import time

from repro.configs.base import TrainConfig

from benchmarks.common import (Row, eval_quality, get_teacher, ptq_baselines,
                               run_silq)

POLICIES = ("A8d-C8-W4", "A8s-C8-W4", "A8d-C4-W4")
QAT_STEPS = 300


def main(row: Row | None = None, qat_steps: int = QAT_STEPS):
    row = row or Row()
    cfg, teacher = get_teacher()
    base = eval_quality(cfg, teacher, teacher, "A16-C16-W16")
    print(f"# Table1 baseline fp16: loss={base['ntp_loss']:.4f} "
          f"agree={base['teacher_agreement']:.3f}")
    results = {"Baseline-16-16-16": (0.0, base)}
    for pol in POLICIES:
        t0 = time.perf_counter()
        for name, q in ptq_baselines(cfg, teacher, pol).items():
            dt = time.perf_counter() - t0
            e = eval_quality(cfg, q, teacher, pol)
            results[f"{name}-{pol}"] = (dt, e)
        tcfg = TrainConfig(precision=pol, total_steps=qat_steps,
                           ref_steps=qat_steps, batch_size=8, seq_len=64)
        t0 = time.perf_counter()
        student, _, train_s = run_silq(cfg, teacher, tcfg)
        e = eval_quality(cfg, student, teacher, pol)
        results[f"SiLQ-{pol}"] = (train_s, e)
    print(f"# {'method':28s} {'ntp_loss':>9s} {'agree%':>7s} "
          f"{'KL(T||S)':>9s} {'time_s':>7s}")
    for name, (dt, e) in results.items():
        print(f"# {name:28s} {e['ntp_loss']:9.4f} "
              f"{e['teacher_agreement'] * 100:7.2f} "
              f"{e.get('teacher_kl', 0):9.5f} {dt:7.1f}")
        row.add(f"table1/{name}", dt,
                f"agree={e['teacher_agreement']:.4f};kl={e.get('teacher_kl', 0):.5f}")
    # the paper's headline claim, as an assertion
    for pol in POLICIES:
        assert results[f"SiLQ-{pol}"][1]["teacher_agreement"] >= \
            results[f"SmoothQuant-{pol}"][1]["teacher_agreement"] - 0.02, pol
    return results


if __name__ == "__main__":
    main()
