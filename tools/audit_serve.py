#!/usr/bin/env python
"""Audit every compiled serve wave against the compiled-graph invariants.

Builds a reduced-config ServeEngine (optionally w4a8, optionally on a
tp>1 mesh), runs a small workload so the wave registry holds *live*
compile-variant counts, then audits every wave family's compiled HLO
with the ``repro.analysis`` rule set: donation, host-transfer, dequant
placement, retrace budget, collective census, w4a8 funnel. Renders the
rule x wave matrix, optionally writes the JSON artifact, and exits
nonzero on any violation — the CI gate for PR-introduced serving
regressions that tests which only check tokens would miss.

Usage::

    python tools/audit_serve.py                         # bf16 engine
    python tools/audit_serve.py --weights-layout w4a8 --spec
    python tools/audit_serve.py --tp 2 --out audit_tp2.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def build_engine(args):
    import jax
    import numpy as np
    from repro.configs import get_reduced_config
    from repro.core.precision import parse_policy
    from repro.core.qat import calibrate_weight_scales
    from repro.models import init_params
    from repro.serve.engine import Request, ServeEngine

    mesh = None
    if args.tp > 1:
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(model_parallel=args.tp)

    cfg = get_reduced_config(args.config)
    params = init_params(cfg, jax.random.PRNGKey(0))
    if args.weights_layout == "w4a8":
        # uncalibrated placeholder scales round every weight to zero;
        # calibrate so the audited programs match real serving numerics
        params = calibrate_weight_scales(params, parse_policy(args.policy))
    eng = ServeEngine(
        cfg, params, policy=args.policy, slots=args.slots,
        kv_layout="paged", block_size=args.block_size,
        num_blocks=args.num_blocks, max_seq_len=args.max_seq_len,
        prefill_bucket=16, decode_block=4, max_new_cap=32,
        weights_layout=args.weights_layout, mesh=mesh,
        spec={"k": 2} if args.spec else None)

    if not args.no_workload:
        # a short drain populates the wave registry with live variant
        # counts (the retrace-budget rule audits reality, not estimates)
        for i in range(args.slots + 1):
            eng.submit(Request(
                uid=i, prompt=np.arange(1, 10 + i, dtype=np.int32) % 60,
                max_new_tokens=4, temperature=0.8 if i % 2 else 0.0,
                seed=i))
        eng.run_until_drained()
    return eng


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--config", default="qwen2.5-3b",
                    help="reduced config name (default: qwen2.5-3b)")
    ap.add_argument("--policy", default="A8d-C8-W4")
    ap.add_argument("--weights-layout", default="bf16",
                    choices=("bf16", "w4a8"))
    ap.add_argument("--tp", type=int, default=1,
                    help="model-parallel degree (needs that many devices)")
    ap.add_argument("--spec", action="store_true",
                    help="enable speculative decoding (audits the draft "
                         "and verify waves too)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=256)
    ap.add_argument("--max-seq-len", type=int, default=128)
    ap.add_argument("--buckets", type=int, default=1,
                    help="admission length buckets to enumerate")
    ap.add_argument("--no-workload", action="store_true",
                    help="skip the warm-up workload (variant counts stay "
                         "at zero; retrace budget audits nothing)")
    ap.add_argument("--out", default=None,
                    help="write the JSON report artifact here")
    args = ap.parse_args(argv)

    from repro.analysis import audit_engine

    eng = build_engine(args)
    report = audit_engine(eng, buckets=args.buckets)
    report.meta["title"] = (
        f"serve-graph audit: {args.config} {args.weights_layout} "
        f"tp={args.tp}" + (" spec" if args.spec else ""))
    print(report.render())
    if args.out:
        Path(args.out).write_text(json.dumps(report.to_json(), indent=2))
        print(f"\nreport written to {args.out}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
