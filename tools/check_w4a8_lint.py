#!/usr/bin/env python
"""Thin shim over ``repro.analysis.w4a8_lint`` (moved there so the
serve-graph auditor can run the static check as a rule). Same CLI as
before::

    python tools/check_w4a8_lint.py [repo_root]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.analysis.w4a8_lint import (ALLOWED_FUNCS,  # noqa: E402,F401
                                      SERVE_PATH_GLOBS, check_runtime,
                                      check_static, main)

if __name__ == "__main__":
    sys.exit(main(sys.argv))
