#!/usr/bin/env python
"""Link-check the repo's markdown: every relative link/image target in
``docs/*.md`` and ``README.md`` must exist on disk.

External links (http/https/mailto) and pure in-page anchors are
skipped — this guards against the docs drifting from the tree (renamed
files, moved guides), which is exactly the failure mode a docs layer
invites. Exits non-zero listing every dead link.

Usage::

    python tools/check_links.py [repo_root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# inline links/images: [text](target) / ![alt](target); reference-style
# definitions: [label]: target
_INLINE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.MULTILINE)
_SKIP = ("http://", "https://", "mailto:", "#")


def _strip_code(text: str) -> str:
    """Drop fenced code blocks and inline code spans — paths inside
    them are examples, not links."""
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    return re.sub(r"`[^`]*`", "", text)


def check_file(md: Path, root: Path) -> list:
    """Return [(target, resolved_path), ...] for every dead relative
    link in ``md``."""
    text = _strip_code(md.read_text())
    targets = _INLINE.findall(text) + _REFDEF.findall(text)
    dead = []
    for t in targets:
        if t.startswith(_SKIP):
            continue
        path = t.split("#", 1)[0]           # drop in-page anchors
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        # GitHub-relative CI badge paths like ../../actions/... point
        # above the repo — only check targets that stay inside it
        try:
            resolved.relative_to(root.resolve())
        except ValueError:
            continue
        if not resolved.exists():
            dead.append((t, resolved))
    return dead


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(".")
    files = sorted((root / "docs").glob("*.md")) + [root / "README.md"]
    files = [f for f in files if f.exists()]
    if not files:
        print(f"no markdown found under {root}", file=sys.stderr)
        return 2
    bad = 0
    for md in files:
        for target, resolved in check_file(md, root):
            print(f"{md}: dead link '{target}' -> {resolved}")
            bad += 1
    print(f"checked {len(files)} files: "
          f"{'all links OK' if not bad else f'{bad} dead link(s)'}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
