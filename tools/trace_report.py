#!/usr/bin/env python
"""Summarise a serve trace exported by ``launch/serve.py --trace``.

Reads the Chrome/Perfetto ``trace_event`` JSON the serve launcher (or
``repro.obs.export.write_trace``) wrote and prints the three views the
observability layer exists for:

* step-time breakdown by wave family (where each engine step's
  wall-clock went: admit vs tail vs decode vs swap vs host scheduling),
* per-request latency attribution percentiles (queue delay / TTFT /
  decode / TPOT), with the trace-vs-scheduler-clock reconciliation,
* compile-vs-execute split per wave family, naming each recompile's
  argument signature from the compile-variant registry.

Usage::

    python tools/trace_report.py trace.json
    python tools/trace_report.py trace.json --json   # machine-readable
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.obs.export import (compile_split, load_trace, render_report,
                              request_attribution, step_breakdown)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Perfetto JSON from --trace")
    ap.add_argument("--json", action="store_true",
                    help="emit the report sections as one JSON object")
    args = ap.parse_args(argv)

    trace = load_trace(args.trace)
    if args.json:
        print(json.dumps({
            "step_breakdown": step_breakdown(trace),
            "request_attribution": request_attribution(trace),
            "compile_split": compile_split(trace),
            "otherData": trace.get("otherData", {}),
        }, indent=2, sort_keys=True))
    else:
        print(render_report(trace))
    return 0


if __name__ == "__main__":
    try:
        raise SystemExit(main())
    except BrokenPipeError:             # `trace_report ... | head` is fine
        import os
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        raise SystemExit(0)
