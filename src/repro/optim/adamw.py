"""AdamW with SiLQ parameter groups (paper Appendix B).

beta1=0.9, beta2=0.95, eps=1e-10; weight decay 0.1 on matrix weights only —
never on quantizer step sizes, norms, or biases; activation-quantizer scales
(``s_in``/``s_q``/``s_k``/``s_v``/``s_state``) get a 50x learning-rate boost
(paper §3.1 / Table 4 ``Act Lrx``). Moments kept in fp32 regardless of param
dtype (bf16-safe).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.qat import act_scale_mask, scale_mask


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def clip_by_global_norm(grads, max_norm: float):
    """Scale the gradient tree so its global L2 norm is <= max_norm."""
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def _decay_mask(params):
    """True where weight decay applies: >=2-D tensors that are not scales."""
    scales = scale_mask(params)
    return jax.tree.map(lambda p, is_s: (p.ndim >= 2) and not is_s,
                        params, scales)


def adamw_update(params, grads, state: AdamWState, *, lr,
                 beta1: float = 0.9, beta2: float = 0.95,
                 eps: float = 1e-10, weight_decay: float = 0.1,
                 act_scale_lr_mult: float = 50.0):
    """One AdamW step; ``lr`` may be a traced scalar (schedule)."""
    step = state.step + 1
    b1c = 1.0 - beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - beta2 ** step.astype(jnp.float32)
    decay = _decay_mask(params)
    boost = act_scale_mask(params)

    def upd(p, g, m, v, dec, bst):
        gf = g.astype(jnp.float32)
        m = beta1 * m + (1 - beta1) * gf
        v = beta2 * v + (1 - beta2) * gf * gf
        mhat = m / b1c
        vhat = v / b2c
        g_lr = lr * (act_scale_lr_mult if bst else 1.0)
        upd = mhat / (jnp.sqrt(vhat) + eps)
        if dec:
            upd = upd + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - g_lr * upd).astype(p.dtype), m, v

    flat = jax.tree.map(upd, params, grads, state.m, state.v, decay, boost)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
