from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedules import cosine_schedule, sqrt_rescaled_lr

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
           "sqrt_rescaled_lr"]
