"""LR schedules: cosine-to-floor (paper: min = 10% of base, no warmup) and
the power-scheduler square-root rescaling rule for changed run lengths
(Shen et al., 2024 — paper Appendix B)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, base_lr: float, total_steps: int,
                    warmup_steps: int = 0, min_lr_ratio: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = jnp.where(warmup_steps > 0,
                     jnp.minimum(s / jnp.maximum(warmup_steps, 1), 1.0), 1.0)
    prog = jnp.clip((s - warmup_steps) /
                    jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    floor = min_lr_ratio
    return base_lr * warm * (floor + (1.0 - floor) * cos)


def sqrt_rescaled_lr(base_lr: float, ref_steps: int, total_steps: int) -> float:
    """lr(T) = lr(T_ref) * sqrt(T_ref / T): 4x longer run -> half the LR."""
    return base_lr * (ref_steps / total_steps) ** 0.5
