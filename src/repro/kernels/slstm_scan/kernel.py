"""Pallas TPU kernel: sLSTM linear scan with VMEM-resident recurrent weights.

§Roofline's worst cell (xlstm × train_4k, 0.1% of roofline) is bound by the
sLSTM time scan re-reading ``r_h`` (d x 4d, ~4.7 MB at d=768) from HBM for
every token: traffic = T * |r_h|. This kernel pins ``r_h`` in VMEM for the
whole sequence: per-chip traffic drops to |gx| + |hs| (the unavoidable
input/output streams) — a ~T/(bt)-independent ~50-100x reduction for the
assigned config.

Grid (B/bb, T/bt), time-blocks innermost; (h, c) carried in VMEM scratch
across time blocks; the per-step (bb, d) @ (d, 4d) matvec batch feeds the
MXU. Time steps inside a block run in a fori_loop over the VMEM tile.
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp

BB, BT = 8, 128


def _kernel(gx_ref, rh_ref, h0_ref, c0_ref, hs_ref, hT_ref, cT_ref,
            h_scr, c_scr, *, nt: int, d: int, t_true: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)
        c_scr[...] = c0_ref[...].astype(jnp.float32)

    rh = rh_ref[...].astype(jnp.float32)            # (d, 4d) VMEM-resident
    gx = gx_ref[...]                                # (BB, BT, 4d)

    def step(tau, carry):
        h, c = carry
        g = gx[:, tau].astype(jnp.float32) + \
            jax.lax.dot_general(h, rh, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        i = jax.nn.sigmoid(g[:, :d])
        f = jax.nn.sigmoid(g[:, d:2 * d])
        z = jnp.tanh(g[:, 2 * d:3 * d])
        o = jax.nn.sigmoid(g[:, 3 * d:])
        c_new = f * c + i * z
        h_new = o * jnp.tanh(c_new)
        hs_ref[:, pl.dslice(tau, 1), :] = h_new[:, None].astype(hs_ref.dtype)
        # time padding must not evolve the state (final h/c are outputs)
        valid = (t * gx.shape[1] + tau) < t_true
        c = jnp.where(valid, c_new, c)
        h = jnp.where(valid, h_new, h)
        return h, c

    h, c = jax.lax.fori_loop(0, gx.shape[1], step,
                             (h_scr[...], c_scr[...]))
    h_scr[...] = h
    c_scr[...] = c

    @pl.when(t == nt - 1)
    def _final():
        hT_ref[...] = h
        cT_ref[...] = c


def slstm_scan(gx, r_h, h0, c0, t_true: int = 0, interpret: bool = True):
    """gx (B,T,4d) tile-padded; r_h (d,4d); h0/c0 (B,d) fp32."""
    B, T, d4 = gx.shape
    d = d4 // 4
    nb, nt = B // BB, T // BT
    return pl.pallas_call(
        functools.partial(_kernel, nt=nt, d=d, t_true=t_true or T),
        grid=(nb, nt),
        in_specs=[
            pl.BlockSpec((BB, BT, d4), lambda b, t: (b, t, 0)),
            pl.BlockSpec((d, d4), lambda b, t: (0, 0)),
            pl.BlockSpec((BB, d), lambda b, t: (b, 0)),
            pl.BlockSpec((BB, d), lambda b, t: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BB, BT, d), lambda b, t: (b, t, 0)),
            pl.BlockSpec((BB, d), lambda b, t: (b, 0)),
            pl.BlockSpec((BB, d), lambda b, t: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, d), gx.dtype),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((BB, d), jnp.float32),
                        pltpu.VMEM((BB, d), jnp.float32)],
        interpret=interpret,
    )(gx, r_h, h0, c0)
