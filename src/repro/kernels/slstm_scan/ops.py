"""jit'd wrapper for the sLSTM linear-scan kernel (pads B/T to tiles)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.slstm_scan import kernel as K
from repro.kernels.slstm_scan.ref import slstm_scan_ref

_INTERPRET = jax.default_backend() != "tpu"


def slstm_scan(gx, r_h, h0, c0, use_pallas: bool = True):
    """gx (B,T,4d); r_h (d,4d); h0/c0 (B,d). Returns (hs, hT, cT)."""
    if not use_pallas:
        return slstm_scan_ref(gx, r_h, h0, c0)
    B, T, d4 = gx.shape
    pb, pt = (-B) % K.BB, (-T) % K.BT
    if pb or pt:
        gx = jnp.pad(gx, ((0, pb), (0, pt), (0, 0)))
        h0 = jnp.pad(h0, ((0, pb), (0, 0)))
        c0 = jnp.pad(c0, ((0, pb), (0, 0)))
    hs, hT, cT = K.slstm_scan(gx, r_h, h0.astype(jnp.float32),
                              c0.astype(jnp.float32), t_true=T,
                              interpret=_INTERPRET)
    return hs[:B, :T], hT[:B], cT[:B]
