"""Oracle for the sLSTM linear-scan kernel: sequential recurrence in fp32.

h_t = sigma(o) * tanh(c_t);  c_t = sigma(f) * c_{t-1} + sigma(i) * tanh(z)
with gates (i, f, z, o) = gx_t + h_{t-1} @ r_h.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def slstm_scan_ref(gx, r_h, h0, c0):
    """gx (B,T,4d); r_h (d,4d); h0/c0 (B,d) -> (hs (B,T,d), hT, cT)."""
    d = h0.shape[-1]

    def step(carry, gx_t):
        h, c = carry
        g = gx_t.astype(jnp.float32) + h @ r_h.astype(jnp.float32)
        i, f, z, o = jnp.split(g, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(z)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    (hT, cT), hs = jax.lax.scan(
        step, (h0.astype(jnp.float32), c0.astype(jnp.float32)),
        jnp.moveaxis(gx, 1, 0))
    return jnp.moveaxis(hs, 0, 1).astype(gx.dtype), hT, cT
