"""Pure-jnp oracle for the w4a8 integer matmul kernel.

y = (x_q int8 @ w_q int4^T) * s_x * s_w (+ b)

``w_packed``: (N, K/2) uint8, two int4 per byte along K (see
``repro.core.quantizer.pack_int4``). ``s_x``: (M, 1) per-token fp32.
``s_w``: (N,) per-output-channel fp32.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quantizer import unpack_int4


def w4a8_matmul_ref(x_q: jnp.ndarray, w_packed: jnp.ndarray,
                    s_x: jnp.ndarray, s_w: jnp.ndarray,
                    bias: jnp.ndarray | None = None,
                    out_dtype=jnp.bfloat16) -> jnp.ndarray:
    w_q = unpack_int4(w_packed)                       # (N, K) int8 in [-8, 7]
    acc = jnp.dot(x_q.astype(jnp.int32), w_q.T.astype(jnp.int32))  # (M, N)
    y = acc.astype(jnp.float32) * s_x.astype(jnp.float32) \
        * s_w.astype(jnp.float32)[None, :]
    if bias is not None:
        y = y + bias.astype(jnp.float32)[None, :]
    return y.astype(out_dtype)
