"""Pure-jnp oracle for the w4a8 integer matmul kernel.

y = (x_q int8 @ w_q int4^T) * s_x * s_w (+ b)

``w_packed``: (N, K/2) uint8, two int4 per byte along K (see
``repro.core.quantizer.pack_int4``). ``s_x``: (M, 1) per-token fp32.
``s_w``: (N,) per-output-channel fp32.

This is also the off-TPU *serve* path (``w4a8_backend="ref"`` / "auto" on
CPU), so it is written for XLA:CPU speed inside the engine's decode
``while_loop``, not just clarity:

* The whole weight chain — unpack, transpose to gemm-friendly (K, N),
  convert to f32 — depends only on loop-invariant params, so XLA hoists it
  out of the decode loop; per step only the small activation quantize, one
  gemm, and the two scale multiplies remain. (A split-nibble two-gemm
  formulation avoids materializing the unpacked matrix but costs an extra
  gemm + slices *per decode step*, which at serve batch sizes is dispatch-
  bound and measurably slower.)
* **Exact f32 accumulation.** Every int8 x int4 partial product and its
  running sum stays under 2^24 for K < 16512 (any real d_in), so the f32
  gemm produces the same integers as an int32 dot while lowering to BLAS
  instead of XLA:CPU's scalar integer dot. Scales multiply the *completed*
  integer accumulator, in the same order as the Pallas kernel — results
  stay bit-identical (the bias add is the one spot XLA may contract into
  an FMA the Pallas graph doesn't, moving isolated elements by one bf16
  ulp; greedy/sampled token streams are unaffected). The int32 path is
  kept for the (never hit in practice) huge-K case.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quantizer import unpack_int4


def w4a8_matmul_ref(x_q: jnp.ndarray, w_packed: jnp.ndarray,
                    s_x: jnp.ndarray, s_w: jnp.ndarray,
                    bias: jnp.ndarray | None = None,
                    out_dtype=jnp.bfloat16,
                    w_unpacked: jnp.ndarray | None = None) -> jnp.ndarray:
    K = x_q.shape[1]
    # serve engines pass the cached (K, N) int8 plane (see
    # qat.attach_w4a8_ref_planes) so decode steps skip the unpack entirely
    w_i8 = w_unpacked if w_unpacked is not None else unpack_int4(w_packed).T
    if K * 127 * 8 < 2 ** 24:
        acc = jnp.einsum("mk,kn->mn", x_q.astype(jnp.float32),
                         w_i8.astype(jnp.float32))
    else:
        acc = jnp.dot(x_q.astype(jnp.int32), w_i8.astype(jnp.int32))
    y = acc.astype(jnp.float32) * s_x.astype(jnp.float32) \
        * s_w.astype(jnp.float32)[None, :]
    if bias is not None:
        y = y + bias.astype(jnp.float32)[None, :]
    return y.astype(out_dtype)
