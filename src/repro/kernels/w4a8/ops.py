"""jit'd wrapper for the w4a8 matmul kernel: the deployed quantized linear.

``w4a8_linear(x, exported)`` takes bf16 activations, quantizes them per-token
to int8 on the fly (token-dynamic A8d deployment), and runs the packed-int4
matmul. ``exported`` is the dict from ``repro.core.qat.export_linear_int``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantizer import dynamic_quantize_to_int
from repro.kernels.w4a8 import kernel as K
from repro.kernels.w4a8.ref import w4a8_matmul_ref

_INTERPRET = jax.default_backend() != "tpu"


def _pad_to(a, mults):
    pads = [(0, (-d) % m) for d, m in zip(a.shape, mults)]
    return jnp.pad(a, pads) if any(p for _, p in pads) else a


def w4a8_matmul(x_q, w_packed, s_x, s_w, bias=None, out_dtype=jnp.bfloat16,
                use_pallas: bool = True, w_unpacked=None):
    """Tile-padding wrapper. x_q (M,K) int8, w_packed (N,K/2) uint8,
    s_x (M,1), s_w (N,). ``w_unpacked`` is the optional pre-unpacked
    (K, N) int8 plane for the ref backend (see
    ``qat.attach_w4a8_ref_planes``); the Pallas path ignores it."""
    M, Kdim = x_q.shape
    N = w_packed.shape[0]
    if not use_pallas:
        return w4a8_matmul_ref(x_q, w_packed, s_x, s_w, bias, out_dtype,
                               w_unpacked=w_unpacked)
    xp = _pad_to(x_q, (K.BM, K.BK))
    wp = _pad_to(w_packed, (K.BN, K.BK // 2))
    sxp = _pad_to(s_x.reshape(M, 1).astype(jnp.float32), (K.BM, 1))
    swp = _pad_to(s_w.reshape(1, N).astype(jnp.float32), (1, K.BN))
    bp = None
    if bias is not None:
        bp = _pad_to(bias.reshape(1, N).astype(jnp.float32), (1, K.BN))
    out = K.w4a8_matmul(xp, wp, sxp, swp, bp, out_dtype=out_dtype,
                        interpret=_INTERPRET)
    return out[:M, :N]


def w4a8_linear(x: jnp.ndarray, exported: dict,
                out_dtype=jnp.bfloat16, use_pallas: bool = True) -> jnp.ndarray:
    """Deployed quantized linear over arbitrary leading dims."""
    assert exported.get("packed", True), "w4a8_linear needs packed int4 weights"
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x_q, s_x = dynamic_quantize_to_int(x2, 8, axis=-1)
    y = w4a8_matmul(x_q, exported["wq"], s_x, exported["s_w"].reshape(-1),
                    exported.get("b"), out_dtype, use_pallas,
                    w_unpacked=exported.get("wf"))
    return y.reshape(*lead, -1)
