"""Pallas TPU kernel: packed-int4-weight x int8-activation matmul.

The deployment (serving) hot path. TPU-native design:
* weights stored HBM-packed (two int4 per byte) -> 2x less HBM traffic than
  int8, 4x less than bf16; nibbles are unpacked in VMEM registers,
* the MXU consumes int8 x int8 -> int32 accumulation
  (``preferred_element_type=int32``),
* per-token activation scale (M, 1) and per-output-channel weight scale (N,)
  are applied once per output tile in the epilogue (VREG broadcasts),
  fused with the optional bias add.

Grid (M/bm, N/bn, K/bk), K innermost for accumulation in VMEM scratch.
Tiles: bm=256, bn=256, bk=512 -> x tile 128 KiB int8, packed w tile 64 KiB,
acc 256 KiB int32; MXU dims all multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp

BM, BN, BK = 256, 256, 512


def _unpack_nibbles(p: jnp.ndarray) -> jnp.ndarray:
    """(n, k/2) uint8 -> (n, k) int8 in [-8, 7]; interleaved layout."""
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1)                # (n, k/2, 2)
    return out.reshape(p.shape[0], p.shape[1] * 2)


def _kernel(x_ref, wp_ref, sx_ref, sw_ref, b_ref, o_ref, acc_ref, *,
            nk: int, has_bias: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _unpack_nibbles(wp_ref[...])                  # (BN, BK) int8
    x = x_ref[...]                                    # (BM, BK) int8
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),               # contract K with K
        preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _epilogue():
        y = acc_ref[...].astype(jnp.float32)
        y = y * sx_ref[...].astype(jnp.float32)       # (BM, 1)
        y = y * sw_ref[...].astype(jnp.float32)       # (1, BN)
        if has_bias:
            y = y + b_ref[...].astype(jnp.float32)
        o_ref[...] = y.astype(o_ref.dtype)


def w4a8_matmul(x_q: jnp.ndarray, w_packed: jnp.ndarray, s_x: jnp.ndarray,
                s_w: jnp.ndarray, bias: jnp.ndarray | None = None,
                out_dtype=jnp.bfloat16, interpret: bool = True) -> jnp.ndarray:
    """x_q: (M, K) int8; w_packed: (N, K/2) uint8; s_x: (M, 1); s_w: (1, N).

    All dims must be tile multiples (ops.py pads).
    """
    M, K = x_q.shape
    N = w_packed.shape[0]
    nk = K // BK
    has_bias = bias is not None
    if bias is None:
        bias = jnp.zeros((1, N), jnp.float32)
    grid = (M // BM, N // BN, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, has_bias=has_bias),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BN, BK // 2), lambda i, j, k: (j, k)),
            pl.BlockSpec((BM, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, BN), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, BN), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.int32)],
        interpret=interpret,
    )(x_q, w_packed, s_x, s_w, bias)
