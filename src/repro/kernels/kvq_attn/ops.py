"""jit'd wrapper for the quantized-KV flash-decode kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.kvq_attn import kernel as K
from repro.kernels.kvq_attn.ref import (chunk_commit_ids, copy_pool_blocks_ref,
                                        gather_paged_kv,
                                        kvq_decode_attn_ref,
                                        kvq_paged_decode_attn_ref,
                                        kvq_spec_verify_attn_ref,
                                        scatter_chunk_kv)

_INTERPRET = jax.default_backend() != "tpu"


def commit_chunk_kv(cache: dict, k_q, v_q, s_k, s_v, block_tbl,
                    offset, chunk_len) -> dict:
    """Commit a batch of prefill windows into one layer's block pool, with
    per-row write offsets.

    cache: layer dict holding pool leaves k_q/v_q (NB, Hkv, bs, D) and
    s_k/s_v (NB, Hkv, bs). k_q/v_q values (n, Hkv, C, D) int, s_k/s_v
    (n, Hkv, C) fp32: the quantized window K/V of ``n`` slots, each
    starting at absolute token position ``offset[i]`` with ``chunk_len[i]``
    real tokens. block_tbl (n, T): each row's (truncated) block table.
    Destinations are resolved once (`chunk_commit_ids`) and shared by the
    four leaf scatters; pad rows/positions land on the sentinel and drop.
    XLA's batched scatter is already memory-bound-optimal here, so the
    same path serves every backend (a Pallas variant would only re-tile
    the identical HBM traffic).
    """
    bs = cache["k_q"].shape[2]
    nb = cache["k_q"].shape[0]
    blk, off = chunk_commit_ids(block_tbl, offset, chunk_len, k_q.shape[2],
                                bs, nb)
    new = dict(cache)
    new["k_q"] = scatter_chunk_kv(cache["k_q"], jnp.swapaxes(k_q, 1, 2),
                                  blk, off)
    new["v_q"] = scatter_chunk_kv(cache["v_q"], jnp.swapaxes(v_q, 1, 2),
                                  blk, off)
    new["s_k"] = scatter_chunk_kv(cache["s_k"], jnp.swapaxes(s_k, 1, 2),
                                  blk, off)
    new["s_v"] = scatter_chunk_kv(cache["s_v"], jnp.swapaxes(s_v, 1, 2),
                                  blk, off)
    return new


def copy_pool_blocks(pool, src, dst,
                     use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Device-side copy-on-write block clone over a layer-stacked pool leaf.

    pool (rep, NB, ...) int8 payload or fp32 scales; src/dst (n,) int32
    block-id pairs. ``dst`` entries >= NB are padding (the engine buckets
    the pair count to a power of two to bound compile variants) and are
    dropped. On TPU the Pallas kernel rewrites only the ``dst`` blocks via
    an aliased in-place pallas_call; elsewhere the XLA scatter reference
    runs (bitwise-identical result).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return copy_pool_blocks_ref(pool, src, dst)
    nb = pool.shape[1]
    pad = dst >= nb
    # padding convention for the kernel: src == dst is a self-copy no-op.
    # Pads self-copy the first *source* block — a src is never a dst in
    # the same call, so no pad step can race a real pair's output DMA
    # (self-copying a dst block could prefetch its stale payload and
    # write it back after the real copy landed).
    srcp = jnp.where(pad, src[0], src).astype(jnp.int32)
    dstp = jnp.where(pad, src[0], dst).astype(jnp.int32)
    flat = pool.reshape(pool.shape[0], nb, -1)
    out = K.pool_block_copy(flat, srcp, dstp, interpret=_INTERPRET)
    return out.reshape(pool.shape)


def gather_dequant_paged_kv(pool, s_pool, block_tbl,
                            use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Dequantized history gather for the batched tail/chunk prefill wave.

    pool (NB, Hkv, bs, D) int8; s_pool (NB, Hkv, bs) fp32; block_tbl (n, T)
    int32 (sentinels clamped here). Returns (n, Hkv, T*bs, D) f32. On TPU
    the fused Pallas kernel dequantizes each gathered tile VMEM-locally (no
    int8 intermediate in HBM); elsewhere the two-gather XLA reference runs
    — bitwise-identical values either way.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return (gather_paged_kv(pool, block_tbl).astype(jnp.float32)
                * gather_paged_kv(s_pool, block_tbl)[..., None])
    nb = pool.shape[0]
    tbl = jnp.minimum(block_tbl.astype(jnp.int32), nb - 1)
    return K.gather_dequant_paged_kv(pool, s_pool.astype(jnp.float32), tbl,
                                     interpret=_INTERPRET)


def kvq_decode_attn(q, k_q, v_q, s_k, s_v, lengths,
                    use_pallas: bool = True) -> jnp.ndarray:
    """Decode attention over an integer cache; pads S to tile multiples.

    q (B,H,D); k_q/v_q (B,Hkv,S,D) int8; s_k/s_v (B,Hkv,S) fp32;
    lengths (B,) int32.
    """
    if not use_pallas:
        return kvq_decode_attn_ref(q, k_q, v_q, s_k, s_v, lengths)
    S = k_q.shape[2]
    pad = (-S) % K.BS
    if pad:
        padkv = ((0, 0), (0, 0), (0, pad), (0, 0))
        k_q = jnp.pad(k_q, padkv)
        v_q = jnp.pad(v_q, padkv)
        pads = ((0, 0), (0, 0), (0, pad))
        s_k = jnp.pad(s_k, pads)
        s_v = jnp.pad(s_v, pads)
    return K.kvq_decode_attn(q, k_q, v_q, s_k.astype(jnp.float32),
                             s_v.astype(jnp.float32),
                             lengths.astype(jnp.int32), interpret=_INTERPRET)


def kvq_spec_verify_attn(q, k_pool, v_pool, s_k, s_v, block_tbl, lengths,
                         use_pallas: bool = True) -> jnp.ndarray:
    """Multi-query block-table attention for the speculative verify-wave.

    q (B, C, H, D): the wave's C window queries per slot (their K/V are
    already committed to the pool); block_tbl (B, T) int32 (sentinels
    clamped here); lengths (B, C) per-query valid extents. On TPU the
    widened Pallas kernel serves all C queries in one table walk;
    elsewhere the gather + per-position decode oracle runs (bitwise
    identical to C sequential decode steps).
    """
    if not use_pallas:
        return kvq_spec_verify_attn_ref(q, k_pool, v_pool, s_k, s_v,
                                        block_tbl, lengths)
    nb = k_pool.shape[0]
    tbl = jnp.minimum(block_tbl.astype(jnp.int32), nb - 1)
    # pad the query-window axis to a full f32 sublane tile: C = k + 1 is
    # small (2-16), and an unpadded C leaves the (C, bs) score tile and the
    # (C, D) accumulator scratch on partial sublanes. Padded rows have q = 0
    # and length 0, so every position masks out and they reduce to exact
    # zeros (no NaN: the final divide clamps the denominator).
    C = q.shape[1]
    Cp = -(-C // 8) * 8
    if Cp != C:
        q = jnp.pad(q, ((0, 0), (0, Cp - C), (0, 0), (0, 0)))
        lengths = jnp.pad(lengths, ((0, 0), (0, Cp - C)))
    out = K.kvq_spec_verify_attn(q, k_pool, v_pool,
                                 s_k.astype(jnp.float32),
                                 s_v.astype(jnp.float32), tbl,
                                 lengths.astype(jnp.int32),
                                 interpret=_INTERPRET)
    return out[:, :C] if Cp != C else out


def kvq_paged_decode_attn(q, k_pool, v_pool, s_k, s_v, block_tbl, lengths,
                          use_pallas: bool = True) -> jnp.ndarray:
    """Block-table decode attention over a paged integer cache pool.

    q (B,H,D); k_pool/v_pool (NB,Hkv,bs,D) int8; s_k/s_v (NB,Hkv,bs) fp32;
    block_tbl (B,T) int32 (entries >= NB are unallocated sentinels, clamped
    here); lengths (B,) int32 tokens resident per slot.

    The kernel grid runs per *KV* head with the GQA group stacked on the
    q sublane axis (see kernel.py): q is regrouped (B, Hkv, group, D) and
    the group padded to a multiple of 8 sublanes here. Real hardware also
    needs the int8 (bs, D) K/V tiles to cover >= 32 sublanes, so bs < 32
    falls back to the XLA reference off the interpreter (bitwise-identical
    result; interpret mode still exercises the kernel at any bs so the
    parity tests run everywhere).
    """
    if use_pallas and not _INTERPRET and k_pool.shape[2] < 32:
        use_pallas = False
    if not use_pallas:
        return kvq_paged_decode_attn_ref(q, k_pool, v_pool, s_k, s_v,
                                         block_tbl, lengths)
    nb, Hkv = k_pool.shape[0], k_pool.shape[1]
    B, H, D = q.shape
    group = H // Hkv
    Gp = -(-group // 8) * 8
    qg = q.reshape(B, Hkv, group, D)   # head h -> (h // group, h % group)
    if Gp != group:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Gp - group), (0, 0)))
    tbl = jnp.minimum(block_tbl.astype(jnp.int32), nb - 1)
    out = K.kvq_paged_decode_attn(qg, k_pool, v_pool,
                                  s_k.astype(jnp.float32),
                                  s_v.astype(jnp.float32), tbl,
                                  lengths.astype(jnp.int32),
                                  interpret=_INTERPRET)
    return out[:, :, :group].reshape(B, H, D)
