"""Pure-jnp oracle for quantized-KV-cache decode attention.

One new query token per sequence attends over an integer-quantized cache.

Shapes:
    q:   (B, H, D)      bf16/fp32 (already int16-fake-quantized upstream)
    k_q: (B, Hkv, S, D) int8 (int4 values also stored int8, range [-8, 7])
    v_q: (B, Hkv, S, D) int8
    s_k, s_v: (B, Hkv, S) fp32 per-token cache scales
    lengths: (B,) int32 valid prefix of the cache
Returns (B, H, D) in q.dtype.
"""
from __future__ import annotations

import jax.numpy as jnp


def kvq_decode_attn_ref(q, k_q, v_q, s_k, s_v, lengths):
    B, H, D = q.shape
    Hkv, S = k_q.shape[1], k_q.shape[2]
    group = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, group, D)
    k = k_q.astype(jnp.float32) * s_k[..., None].astype(jnp.float32)
    v = v_q.astype(jnp.float32) * s_v[..., None].astype(jnp.float32)
    scores = jnp.einsum("bngd,bnsd->bngs", qf, k) / jnp.sqrt(jnp.float32(D))
    mask = (jnp.arange(S)[None, :] < lengths[:, None])[:, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p * mask
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-20)
    out = jnp.einsum("bngs,bnsd->bngd", p, v)
    return out.reshape(B, H, D).astype(q.dtype)


# --------------------------------------------------------------------------
# Paged (block-table) variant
# --------------------------------------------------------------------------

def gather_paged_kv(pool: jnp.ndarray, block_tbl: jnp.ndarray) -> jnp.ndarray:
    """Gather a slot-contiguous view out of a global block pool.

    pool: (NB, Hkv, bs, ...) — K/V values (trailing D) or scales (no D).
    block_tbl: (B, T) int32 — entries >= NB are sentinels; they are clamped
    here and masked by ``lengths`` downstream (table entry i covers absolute
    token positions [i*bs, (i+1)*bs)).
    Returns (B, Hkv, T*bs, ...).
    """
    nb = pool.shape[0]
    g = pool[jnp.minimum(block_tbl, nb - 1)]         # (B, T, Hkv, bs, ...)
    g = jnp.moveaxis(g, 2, 1)                        # (B, Hkv, T, bs, ...)
    return g.reshape(g.shape[:2] + (g.shape[2] * g.shape[3],) + g.shape[4:])


def copy_pool_blocks_ref(pool: jnp.ndarray, src: jnp.ndarray,
                         dst: jnp.ndarray) -> jnp.ndarray:
    """XLA oracle for the copy-on-write block clone.

    pool: (rep, NB, ...) layer-stacked pool leaf (K/V payload or scales).
    src/dst: (n,) int32 block-id pairs; ``dst`` entries >= NB are padding
    and dropped (``src`` is clamped so the padded gather stays in range).
    Returns the pool with ``pool[:, dst[i]] = pool[:, src[i]]`` applied.
    """
    nb = pool.shape[1]
    return pool.at[:, dst].set(pool[:, jnp.minimum(src, nb - 1)],
                               mode="drop")


def chunk_commit_ids(block_tbl: jnp.ndarray, offset: jnp.ndarray,
                     chunk_len: jnp.ndarray, window: int, page_size: int,
                     num_blocks: int):
    """Per-row (pool block, in-block offset) destinations for a batched
    tail-prefill commit with per-row write offsets.

    block_tbl (n, T) int32: each row's block table (already truncated to
    the walked prefix); offset (n,) int32: absolute token position of each
    row's first window token; chunk_len (n,) int32: real tokens in the
    ``window``-wide window (the rest is padding). Returns (blk (n, window),
    off (n, window)): window position j of row i lands at
    ``pool[blk[i, j], :, off[i, j]]``; positions at or beyond ``chunk_len``
    (and whole padding rows, whose ``chunk_len`` is 0) point at the
    ``num_blocks`` sentinel so the scatter drops them.
    """
    T = block_tbl.shape[1]
    abs_pos = offset[:, None] + jnp.arange(window)[None]        # (n, C)
    blk = jnp.take_along_axis(
        block_tbl, jnp.minimum(abs_pos // page_size, T - 1), axis=1)
    blk = jnp.where(jnp.arange(window)[None] < chunk_len[:, None],
                    blk, num_blocks)
    return blk, abs_pos % page_size


def scatter_chunk_kv(pool: jnp.ndarray, vals: jnp.ndarray,
                     blk: jnp.ndarray, off: jnp.ndarray) -> jnp.ndarray:
    """Batched scatter commit of a prefill window into the block pool.

    pool (NB, Hkv, bs, ...): one layer's K/V payload or scales.
    vals (n, C, Hkv, ...): window tokens, sequence-major. blk/off (n, C)
    from :func:`chunk_commit_ids`; sentinel blocks drop their write. The
    two advanced indices bracket the head slice, so result batch dims
    (n, C) lead and ``vals`` lines up without a transpose.
    """
    return pool.at[blk, :, off].set(vals, mode="drop")


def kvq_spec_verify_attn_ref(q, k_pool, v_pool, s_k, s_v, block_tbl,
                             lengths):
    """Multi-query decode attention for the speculative verify-wave.

    q (B, C, H, D): C window queries per slot, all of whose K/V are
    already *committed to the pool* (quantized) before this runs;
    lengths (B, C): query j of slot b attends to cache positions
    ``< lengths[b, j]`` (= history + window prefix through itself).
    Gathers each slot's blocks once and runs the decode oracle's exact
    formula with one extra query axis — per (b, c) row the masked
    softmax/reduce over S is the row-independent computation a
    sequential ``decode_step`` performs, so the committed stream is
    bitwise identical to plain decode (while the batched einsums keep
    the op count C-independent). Returns (B, C, H, D).
    """
    B, C, H, D = q.shape
    k = gather_paged_kv(k_pool, block_tbl)
    v = gather_paged_kv(v_pool, block_tbl)
    sk = gather_paged_kv(s_k, block_tbl)
    sv = gather_paged_kv(s_v, block_tbl)
    Hkv, S = k.shape[1], k.shape[2]
    group = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, C, Hkv, group, D)
    kf = k.astype(jnp.float32) * sk[..., None].astype(jnp.float32)
    vf = v.astype(jnp.float32) * sv[..., None].astype(jnp.float32)
    scores = jnp.einsum("bcngd,bnsd->bcngs", qf, kf) \
        / jnp.sqrt(jnp.float32(D))
    mask = (jnp.arange(S)[None, None]
            < lengths[:, :, None])[:, :, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p * mask
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-20)
    out = jnp.einsum("bcngs,bnsd->bcngd", p, vf)
    return out.reshape(B, C, H, D).astype(q.dtype)


def kvq_paged_decode_attn_ref(q, k_pool, v_pool, s_k, s_v, block_tbl,
                              lengths):
    """Block-table decode attention oracle: gather, then dense ref.

    q (B,H,D); k_pool/v_pool (NB,Hkv,bs,D) int8; s_k/s_v (NB,Hkv,bs) fp32;
    block_tbl (B,T) int32; lengths (B,) int32 tokens resident per slot.
    """
    return kvq_decode_attn_ref(
        q,
        gather_paged_kv(k_pool, block_tbl),
        gather_paged_kv(v_pool, block_tbl),
        gather_paged_kv(s_k, block_tbl),
        gather_paged_kv(s_v, block_tbl),
        lengths)
