"""Pallas TPU kernel: flash-decode attention over an int8/int4 KV cache.

TPU adaptation of the paper's "quantization fused into the attention kernel"
policy (the CUDA flash kernel encapsulates the softmax; our Pallas kernel
encapsulates cache *dequantization*): K/V tiles are dequantized VMEM-locally
(int8 load -> VREG multiply by per-token scale), so HBM traffic is 2-4x lower
than a bf16 cache and no dequantized copy ever exists in HBM.

Grid (B, H, S/BS) with online-softmax state (m, l, acc) in VMEM scratch,
carried across the S tiles (innermost grid dim). GQA maps query head h to
cache head h // (H // Hkv) in the BlockSpec index maps.

BS = 512 cache tokens per tile: k/v tiles are (512, D) int8 = 64 KiB each at
D=128, scales 2 KiB — small enough to double-buffer, big enough to feed the
VPU. D is the lane dim (multiple of 128); the (1, BS) score row is VREG-wide.
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp

BS = 512  # cache tokens per tile

_NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, sk_ref, sv_ref, o_ref,
            m_ref, l_ref, acc_ref, *, ns: int, scale: float):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                      # (1, D)
    k = k_ref[0, 0].astype(jnp.float32) * sk_ref[0, 0][..., None]  # (BS, D)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale       # (1, BS)

    pos = s * BS + jax.lax.broadcasted_iota(jnp.int32, (1, BS), 1)
    valid = pos < len_ref[0]
    scores = jnp.where(valid, scores, _NEG)

    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(scores))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new) * valid.astype(jnp.float32)  # (1, BS)
    l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p)
    v = v_ref[0, 0].astype(jnp.float32) * sv_ref[0, 0][..., None]  # (BS, D)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (1, D)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[0, 0] = m_new

    @pl.when(s == ns - 1)
    def _final():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[0, 0], 1e-20)).astype(o_ref.dtype)


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, sk_ref, sv_ref,
                  o_ref, m_ref, l_ref, acc_ref, *, bs: int, nt: int,
                  scale: float):
    b = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                   # (Gp, D)
    k = k_ref[0, 0].astype(jnp.float32) * sk_ref[0, 0][..., None]  # (bs, D)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale       # (Gp, bs)

    # table entry t of this slot covers absolute positions [t*bs, (t+1)*bs);
    # sentinel entries gather a clamped block whose tokens all land here
    pos = t * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = pos < len_ref[b]                              # (1, bs) -> bcast
    scores = jnp.where(valid, scores, _NEG)

    m_prev = m_ref[...]                                   # (Gp, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new) * valid.astype(jnp.float32)  # (Gp, bs)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32) * sv_ref[0, 0][..., None]  # (bs, D)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Gp, D)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(t == nt - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


def kvq_paged_decode_attn(q, k_pool, v_pool, s_k, s_v, block_tbl, lengths,
                          interpret: bool = True):
    """Block-table flash-decode over a paged int8/int4 KV pool.

    Same online-softmax walk as the dense kernel, but the grid's innermost
    dim walks the slot's *block table* instead of a contiguous cache stripe:
    the table rides in as a scalar-prefetch operand so the K/V BlockSpec
    index maps can turn (slot, table index) into a pool block id before the
    tile DMA is issued. Sentinel entries must be clamped to NB-1 by the
    caller (ops.py); their scores are masked by ``lengths``.

    TPU tiling: the grid is (B, Hkv, T) — one step per *KV* head — and the
    q operand arrives pre-grouped as (B, Hkv, Gp, D), all of a KV head's
    query heads stacked on the sublane axis (ops.py pads the GQA group to
    Gp, a multiple of 8 f32 sublanes). Each int8 (bs, D) K/V tile is
    therefore fetched once per KV head instead of once per *query* head
    (``group``x less pool HBM traffic), score/accumulator tiles are
    (Gp, bs)/(Gp, D) full-sublane VREGs rather than 1-row slivers, and the
    (1, bs) f32 scale tiles amortize the same way (lane-width at bs=128;
    ops.py requires bs >= 32 on real hardware so every tile meets the int8
    32-sublane minimum).

    q (B,Hkv,Gp,D) pre-grouped; pools (NB,Hkv,bs,D) int8; scales
    (NB,Hkv,bs) fp32; block_tbl (B,T) int32 (clamped); lengths (B,) int32.
    Returns (B,Hkv,Gp,D); rows past the real group size are garbage and
    sliced off by the wrapper.
    """
    B, Hkv, Gp, D = q.shape
    bs = k_pool.shape[2]
    T = block_tbl.shape[1]
    scale = 1.0 / (D ** 0.5)
    kv_ix = lambda b, h, t, tbl, lens: (tbl[b, t], h, 0, 0)
    sc_ix = lambda b, h, t, tbl, lens: (tbl[b, t], h, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                       # block_tbl, lengths
        grid=(B, Hkv, T),
        in_specs=[
            pl.BlockSpec((1, 1, Gp, D),
                         lambda b, h, t, tbl, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), kv_ix),      # k pool
            pl.BlockSpec((1, 1, bs, D), kv_ix),      # v pool
            pl.BlockSpec((1, 1, bs), sc_ix),         # s_k pool
            pl.BlockSpec((1, 1, bs), sc_ix),         # s_v pool
        ],
        out_specs=pl.BlockSpec((1, 1, Gp, D), lambda b, h, t, tbl, lens:
                               (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Gp, 1), jnp.float32),  # running max
            pltpu.VMEM((Gp, 1), jnp.float32),  # running denom
            pltpu.VMEM((Gp, D), jnp.float32),  # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, bs=bs, nt=T, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, Gp, D), q.dtype),
        interpret=interpret,
    )(block_tbl, lengths, q, k_pool, v_pool, s_k, s_v)


def _gather_dequant_kernel(tbl_ref, kq_ref, sk_ref, o_ref):
    o_ref[0, 0, 0] = (kq_ref[0, 0].astype(jnp.float32)
                      * sk_ref[0, 0][..., None])


def gather_dequant_paged_kv(pool, s_pool, block_tbl, interpret: bool = True):
    """Fused gather + dequant of each row's block-table extent.

    The tail-wave history read: the XLA path gathers the int8 pool and the
    scale pool separately, materializing an int8 copy of every history
    block in HBM before a second dequantize pass re-reads it. Here one
    grid step per (row, head, table entry) DMAs the (bs, D) int8 tile and
    its (bs,) scale straight into VMEM and writes only the dequantized f32
    tile back — the int8 intermediate never exists in HBM. Sentinel table
    entries must be clamped by the caller (ops.py); callers mask their
    positions exactly as they do for the XLA gather.

    pool (NB, Hkv, bs, D) int8; s_pool (NB, Hkv, bs) f32; block_tbl (n, T)
    int32 (clamped). Returns (n, Hkv, T*bs, D) f32 — identical layout and
    bitwise-identical values to ``gather_paged_kv(pool).astype(f32) *
    gather_paged_kv(s_pool)[..., None]``.
    """
    NB, Hkv, bs, D = pool.shape
    n, T = block_tbl.shape
    kv_ix = lambda r, h, t, tbl: (tbl[r, t], h, 0, 0)
    sc_ix = lambda r, h, t, tbl: (tbl[r, t], h, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                       # block_tbl
        grid=(n, Hkv, T),
        in_specs=[
            pl.BlockSpec((1, 1, bs, D), kv_ix),
            pl.BlockSpec((1, 1, bs), sc_ix),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, bs, D),
                               lambda r, h, t, tbl: (r, h, t, 0, 0)),
    )
    out = pl.pallas_call(
        _gather_dequant_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, Hkv, T, bs, D), jnp.float32),
        interpret=interpret,
    )(block_tbl, pool, s_pool)
    return out.reshape(n, Hkv, T * bs, D)


def _spec_verify_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, sk_ref,
                        sv_ref, o_ref, m_ref, l_ref, acc_ref, *, bs: int,
                        nt: int, scale: float):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0].astype(jnp.float32)                # (C, D)
    k = k_ref[0, 0].astype(jnp.float32) * sk_ref[0, 0][..., None]  # (bs, D)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale       # (C, bs)

    # query row c of this slot sees cache positions < len[b, c] — the
    # shared history plus the window prefix through itself, all already
    # committed to the pool by the wave's scatter
    pos = t * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = pos < len_ref[0][:, None]                     # (C, bs)
    scores = jnp.where(valid, scores, _NEG)

    m_prev = m_ref[...]                                   # (C, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new) * valid.astype(jnp.float32)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32) * sv_ref[0, 0][..., None]  # (bs, D)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (C, D)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(t == nt - 1)
    def _final():
        o_ref[0, :, 0] = (acc_ref[...] /
                          jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


def kvq_spec_verify_attn(q, k_pool, v_pool, s_k, s_v, block_tbl, lengths,
                         interpret: bool = True):
    """Block-table flash attention for C verify queries per slot.

    The speculative verify-wave's attention: the paged flash-decode walk
    (grid (B, H, T), table as a scalar-prefetch operand) widened to a
    (C, bs) score tile so ONE pass over each slot's block table serves
    all ``C = k + 1`` window positions — instead of C separate decode
    calls re-streaming the same int8 blocks from HBM. Per-query masking
    comes from ``lengths`` (B, C) riding along as a VMEM operand.

    q (B, C, H, D); pools (NB, Hkv, bs, D) int8; scales (NB, Hkv, bs)
    fp32; block_tbl (B, T) int32 (sentinels clamped by the caller);
    lengths (B, C) int32. Returns (B, C, H, D) in q.dtype.
    """
    B, C, H, D = q.shape
    Hkv, bs = k_pool.shape[1], k_pool.shape[2]
    T = block_tbl.shape[1]
    group = H // Hkv
    scale = 1.0 / (D ** 0.5)
    kv_ix = lambda b, h, t, tbl: (tbl[b, t], h // group, 0, 0)
    sc_ix = lambda b, h, t, tbl: (tbl[b, t], h // group, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                           # block_tbl
        grid=(B, H, T),
        in_specs=[
            pl.BlockSpec((1, C), lambda b, h, t, tbl: (b, 0)),   # lengths
            pl.BlockSpec((1, C, 1, D), lambda b, h, t, tbl: (b, 0, h, 0)),
            pl.BlockSpec((1, 1, bs, D), kv_ix),          # k pool
            pl.BlockSpec((1, 1, bs, D), kv_ix),          # v pool
            pl.BlockSpec((1, 1, bs), sc_ix),             # s_k pool
            pl.BlockSpec((1, 1, bs), sc_ix),             # s_v pool
        ],
        out_specs=pl.BlockSpec((1, C, 1, D),
                               lambda b, h, t, tbl: (b, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, 1), jnp.float32),   # running max
            pltpu.VMEM((C, 1), jnp.float32),   # running denom
            pltpu.VMEM((C, D), jnp.float32),   # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_spec_verify_kernel, bs=bs, nt=T, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C, H, D), q.dtype),
        interpret=interpret,
    )(block_tbl, lengths, q, k_pool, v_pool, s_k, s_v)


def _copy_kernel(src_ref, dst_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]


def pool_block_copy(x, src, dst, interpret: bool = True):
    """In-place pool-block copy: ``x[:, dst[i]] <- x[:, src[i]]``.

    The copy-on-write primitive of the prefix-shared paged cache: when a
    slot must write into a block another slot still maps, the engine clones
    the int8 payload (+ scales) device-side and repoints the writer's table
    entry at the clone. ``x`` (rep, NB, X) is the layer-stacked pool with
    the per-block payload flattened to the lane dim; the pool is aliased
    into the output so only the ``dst`` blocks are rewritten — one block
    DMA per (layer, pair) grid step, no full-pool traffic. Pairs with
    ``src == dst`` are self-copy no-ops (the padding convention ops.py uses
    to bound compile variants).
    """
    rep, _nb, X = x.shape
    n = src.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                       # src ids, dst ids
        grid=(rep, n),
        in_specs=[pl.BlockSpec((1, 1, X), lambda r, i, s, d: (r, s[i], 0))],
        out_specs=pl.BlockSpec((1, 1, X), lambda r, i, s, d: (r, d[i], 0)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        input_output_aliases={2: 0},                 # pool is updated in place
        interpret=interpret,
    )(src, dst, x)


def kvq_decode_attn(q, k_q, v_q, s_k, s_v, lengths,
                    interpret: bool = True):
    """See ref.py for shapes; S must be a multiple of BS (ops.py pads)."""
    B, H, D = q.shape
    Hkv, S = k_q.shape[1], k_q.shape[2]
    group = H // Hkv
    ns = S // BS
    scale = 1.0 / (D ** 0.5)
    kv_ix = lambda b, h, s: (b, h // group, s, 0)
    sc_ix = lambda b, h, s: (b, h // group, s)
    return pl.pallas_call(
        functools.partial(_kernel, ns=ns, scale=scale),
        grid=(B, H, ns),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (b,)),           # lengths
            pl.BlockSpec((1, 1, D), lambda b, h, s: (b, h, 0)),  # q
            pl.BlockSpec((1, 1, BS, D), kv_ix),                  # k
            pl.BlockSpec((1, 1, BS, D), kv_ix),                  # v
            pl.BlockSpec((1, 1, BS), sc_ix),                     # s_k
            pl.BlockSpec((1, 1, BS), sc_ix),                     # s_v
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, s: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),   # running max
            pltpu.VMEM((1, 1), jnp.float32),   # running denom
            pltpu.VMEM((1, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(lengths, q, k_q, v_q, s_k, s_v)
