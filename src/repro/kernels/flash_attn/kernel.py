"""Pallas TPU kernel: flash-attention forward (training / prefill).

The §Roofline analysis shows the memory term of every train/prefill cell is
dominated by S^2 attention-score traffic at XLA fusion boundaries. This
kernel keeps the score/probability tile in VMEM: HBM traffic drops from
O(S^2) to O(S * D) per head — the structural fix identified in
EXPERIMENTS.md §Perf.

Grid (B, H, nq, nk), nk innermost with online-softmax state in VMEM
scratch. Tiles: TQ=256 q rows x TK=512 cache tokens x full head_dim (lane
dim, multiple of 128). Causal tiles above the diagonal are masked (the
kernel still visits them — Mosaic grid pruning is a follow-up; masked tiles
cost compute but no extra HBM).

GQA maps query head h to kv head h // (H // Hkv) in the index maps.
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp

TQ, TK = 256, 512
_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            nk: int, scale: float, causal: bool, window: int,
            s_q: int, s_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale      # (TQ, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # (TK, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (TQ, TK)
    qpos = qi * TQ + jax.lax.broadcasted_iota(jnp.int32, (TQ, TK), 0)
    kpos = ki * TK + jax.lax.broadcasted_iota(jnp.int32, (TQ, TK), 1)
    mask = (qpos < s_q) & (kpos < s_kv)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, _NEG)
    m_prev = m_ref[...]                                    # (TQ, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)                         # (TQ, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(p.astype(jnp.bfloat16),
                             v_ref[0, :, 0, :],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _final():
        o_ref[0, :, 0, :] = (acc_ref[...] /
                             jnp.maximum(l_ref[...], 1e-20)
                             ).astype(o_ref.dtype)


def flash_attn_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                   s_q: int = 0, s_kv: int = 0, interpret: bool = True):
    """q (B,Sq,H,D); k/v (B,Skv,Hkv,D), dims tile-padded by ops.py.

    ``s_q``/``s_kv``: true (unpadded) lengths for masking."""
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    nq, nk = Sq // TQ, Skv // TK
    kv_ix = lambda b, h, qi, ki: (b, ki, h // group, 0)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, scale=D ** -0.5, causal=causal,
                          window=window, s_q=s_q or Sq, s_kv=s_kv or Skv),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, TQ, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, TK, 1, D), kv_ix),
            pl.BlockSpec((1, TK, 1, D), kv_ix),
        ],
        out_specs=pl.BlockSpec((1, TQ, 1, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((TQ, 1), jnp.float32),
            pltpu.VMEM((TQ, 1), jnp.float32),
            pltpu.VMEM((TQ, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
