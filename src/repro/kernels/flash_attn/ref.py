"""Oracle for the flash-attention forward kernel: dense attention with
GQA, causal and sliding-window masking (fp32 softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attn_ref(q, k, v, *, causal: bool = True,
                   window: int = 0) -> jnp.ndarray:
    """q (B,S,H,D); k/v (B,Skv,Hkv,D) -> (B,S,H,D) in q.dtype."""
    B, S, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    kr = jnp.repeat(k, g, 2).astype(jnp.float32)
    vr = jnp.repeat(v, g, 2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kr) / \
        jnp.sqrt(jnp.float32(D))
    iq = jnp.arange(S)[:, None]
    ik = jnp.arange(Skv)[None, :]
    m = jnp.ones((S, Skv), bool)
    if causal:
        m &= iq >= ik
    if window:
        m &= iq - ik < window
    s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(m[None, None], p, 0.0)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr).astype(q.dtype)
