"""jit'd wrapper for the flash-attention forward kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn import kernel as K
from repro.kernels.flash_attn.ref import flash_attn_ref

_INTERPRET = jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    use_pallas: bool = True) -> jnp.ndarray:
    """Drop-in (B,S,H,D)x(B,Skv,Hkv,D) attention; pads to tile multiples."""
    if not use_pallas:
        return flash_attn_ref(q, k, v, causal=causal, window=window)
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    pq, pk = (-Sq) % K.TQ, (-Skv) % K.TK
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    out = K.flash_attn_fwd(qp, kp, vp, causal=causal, window=window,
                           s_q=Sq, s_kv=Skv, interpret=_INTERPRET)
    return out[:, :Sq]
