"""Pallas TPU kernel: fused LSQ fake-quantization, forward + backward.

The QAT hot-spot: every linear quantizes its input (per-tensor scale) and its
weight (per-output-channel scale) each step. The fused kernel performs
scale / clip / round / rescale in one VMEM pass (vs 4+ HLO ops and 2 extra
HBM round-trips when unfused), and the backward kernel fuses the STE data
gradient with the per-tile partial reduction of the LSQ step-size gradient.

Layout: 2-D (rows, cols) view, tiles (TR, TC) = (256, 512) fp32 -> 512 KiB
per operand buffer, lane dim a multiple of 128 for VREG alignment. Inputs
are padded to tile multiples by ops.py (g padded with zeros so padding
contributes nothing to the ds reduction).
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp

from repro.core.quantizer import qbounds

TILE_R = 256
TILE_C = 512

_EPS = 1e-9


def _fwd_kernel(x_ref, s_ref, o_ref, *, qn, qp, per_channel):
    x = x_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)          # (1, TC) or (1, 1)
    s = jnp.maximum(s, _EPS)
    q = jnp.round(jnp.clip(x / s, qn, qp))
    o_ref[...] = (q * s).astype(o_ref.dtype)


def _bwd_kernel(x_ref, s_ref, g_ref, dx_ref, dsp_ref, *, qn, qp, per_channel):
    x = x_ref[...].astype(jnp.float32)
    s = jnp.maximum(s_ref[...].astype(jnp.float32), _EPS)
    g = g_ref[...].astype(jnp.float32)
    v = x / s
    within = (v >= qn) & (v <= qp)
    dx_ref[...] = jnp.where(within, g, 0.0).astype(dx_ref.dtype)
    dq_ds = jnp.where(within, jnp.round(v) - v, jnp.clip(v, qn, qp))
    contrib = g * dq_ds
    if per_channel:
        # partial per-channel sums: one row per row-tile
        dsp_ref[...] = jnp.sum(contrib, axis=0, keepdims=True)
    else:
        dsp_ref[0, 0] = jnp.sum(contrib)


def fake_quant_fwd(x: jnp.ndarray, s: jnp.ndarray, bits: int,
                   interpret: bool = True) -> jnp.ndarray:
    """x: (R, C) tile-padded; s: (1, 1) or (1, C)."""
    qn, qp = qbounds(bits)
    R, C = x.shape
    per_channel = s.shape[-1] == C
    grid = (R // TILE_R, C // TILE_C)
    s_spec = (pl.BlockSpec((1, TILE_C), lambda i, j: (0, j)) if per_channel
              else pl.BlockSpec((1, 1), lambda i, j: (0, 0)))
    return pl.pallas_call(
        functools.partial(_fwd_kernel, qn=qn, qp=qp, per_channel=per_channel),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_R, TILE_C), lambda i, j: (i, j)), s_spec],
        out_specs=pl.BlockSpec((TILE_R, TILE_C), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, s)


def fake_quant_bwd(x: jnp.ndarray, s: jnp.ndarray, g: jnp.ndarray, bits: int,
                   interpret: bool = True):
    """Returns (dx, ds_partials). ds_partials: (R/TR, C) per-channel or
    (R/TR, C/TC) per-tensor; caller reduces + applies the LSQ grad scale."""
    qn, qp = qbounds(bits)
    R, C = x.shape
    per_channel = s.shape[-1] == C
    nr, nc = R // TILE_R, C // TILE_C
    s_spec = (pl.BlockSpec((1, TILE_C), lambda i, j: (0, j)) if per_channel
              else pl.BlockSpec((1, 1), lambda i, j: (0, 0)))
    dsp_shape = (nr, C) if per_channel else (nr, nc)
    dsp_spec = (pl.BlockSpec((1, TILE_C), lambda i, j: (i, j)) if per_channel
                else pl.BlockSpec((1, 1), lambda i, j: (i, j)))
    dx, dsp = pl.pallas_call(
        functools.partial(_bwd_kernel, qn=qn, qp=qp, per_channel=per_channel),
        grid=(nr, nc),
        in_specs=[pl.BlockSpec((TILE_R, TILE_C), lambda i, j: (i, j)), s_spec,
                  pl.BlockSpec((TILE_R, TILE_C), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((TILE_R, TILE_C), lambda i, j: (i, j)),
                   dsp_spec],
        out_shape=[jax.ShapeDtypeStruct((R, C), x.dtype),
                   jax.ShapeDtypeStruct(dsp_shape, jnp.float32)],
        interpret=interpret,
    )(x, s, g)
    return dx, dsp
