"""Pure-jnp oracle for the fused LSQ fake-quant kernel.

Operates on 2-D (rows, cols) views; scale is either scalar-like (1, 1)
(per-tensor) or (1, cols) (per-output-channel). Matches
``repro.core.quantizer`` semantics exactly (fp32 internal math).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core.quantizer import qbounds

_EPS = 1e-9


def fake_quant_fwd_ref(x: jnp.ndarray, s: jnp.ndarray,
                       bits: int) -> jnp.ndarray:
    qn, qp = qbounds(bits)
    xf = x.astype(jnp.float32)
    sf = jnp.maximum(s.astype(jnp.float32), _EPS)
    q = jnp.round(jnp.clip(xf / sf, qn, qp))
    return (q * sf).astype(x.dtype)


def fake_quant_bwd_ref(x: jnp.ndarray, s: jnp.ndarray, g: jnp.ndarray,
                       bits: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (dx, ds) where ds is reduced to s.shape, WITHOUT the LSQ
    1/sqrt(N*Qp) gradient scale (applied by the wrapper)."""
    qn, qp = qbounds(bits)
    xf = x.astype(jnp.float32)
    sf = jnp.maximum(s.astype(jnp.float32), _EPS)
    gf = g.astype(jnp.float32)
    v = xf / sf
    within = (v >= qn) & (v <= qp)
    dx = jnp.where(within, gf, 0.0).astype(x.dtype)
    dq_ds = jnp.where(within, jnp.round(v) - v, jnp.clip(v, qn, qp))
    contrib = gf * dq_ds
    if s.size == 1:
        ds = jnp.sum(contrib).reshape(s.shape)
    else:  # per-channel over the last axis
        ds = jnp.sum(contrib, axis=0, keepdims=True).reshape(s.shape)
    return dx, ds.astype(jnp.float32)
