"""jit'd wrapper for the fused LSQ fake-quant Pallas kernel.

``pallas_lsq_fake_quant(x, s, bits)`` is a drop-in replacement for
``repro.core.quantizer.lsq_fake_quant`` (same custom_vjp contract, same LSQ
gradient-scale). Arbitrary-rank inputs are viewed as 2-D (rows, cols) with
the channel axis last; inputs are padded to tile multiples (g zero-padded so
padding cannot contribute to the step-size reduction).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quant import kernel as K

_INTERPRET = jax.default_backend() != "tpu"


def _pad2(a, tr, tc):
    r, c = a.shape
    pr, pc = (-r) % tr, (-c) % tc
    if pr or pc:
        a = jnp.pad(a, ((0, pr), (0, pc)))
    return a


def _as2d(x: jnp.ndarray):
    """(..., C) -> (R, C)."""
    c = x.shape[-1] if x.ndim else 1
    return x.reshape(-1, c) if x.ndim >= 1 else x.reshape(1, 1)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def pallas_lsq_fake_quant(x: jnp.ndarray, s: jnp.ndarray, bits: int):
    out, _ = _fq_fwd(x, s, bits)
    return out


def _fq_fwd(x, s, bits):
    x2 = _as2d(x)
    per_channel = s.size == x.shape[-1] and s.size > 1
    s2 = s.reshape(1, -1) if per_channel else s.reshape(1, 1)
    R, C = x2.shape
    xp = _pad2(x2, K.TILE_R, K.TILE_C)
    sp = _pad2(s2, 1, K.TILE_C) if per_channel else s2
    # padded scale entries must stay positive (kernel clamps at eps anyway)
    out = K.fake_quant_fwd(xp, sp, bits, interpret=_INTERPRET)
    out = out[:R, :C].reshape(x.shape)
    return out, (x, s)


def _fq_bwd(bits, res, g):
    x, s = res
    x2, g2 = _as2d(x), _as2d(g)
    per_channel = s.size == x.shape[-1] and s.size > 1
    s2 = s.reshape(1, -1) if per_channel else s.reshape(1, 1)
    R, C = x2.shape
    xp = _pad2(x2, K.TILE_R, K.TILE_C)
    gp = _pad2(g2, K.TILE_R, K.TILE_C)   # zero pad -> no ds contribution
    sp = _pad2(s2, 1, K.TILE_C) if per_channel else s2
    dx, dsp = K.fake_quant_bwd(xp, sp, gp, bits, interpret=_INTERPRET)
    dx = dx[:R, :C].reshape(x.shape)
    from repro.core.quantizer import qbounds
    _, qp = qbounds(bits)
    n_per_scale = max(x.size // max(s.size, 1), 1)
    gscale = 1.0 / jnp.sqrt(jnp.float32(n_per_scale * qp))
    if per_channel:
        ds = jnp.sum(dsp, axis=0)[:C].reshape(s.shape) * gscale
    else:
        ds = (jnp.sum(dsp) * gscale).reshape(s.shape)
    return dx, ds.astype(s.dtype)


pallas_lsq_fake_quant.defvjp(_fq_fwd, _fq_bwd)
