"""Fault-tolerant checkpointing.

Design goals (1000+-node deployments):
* **atomic**: write to ``step_XXXX.tmp/`` then ``os.replace`` — a crashed
  writer never corrupts the latest checkpoint; restore scans for the newest
  *complete* step directory.
* **mesh-elastic**: tensors are saved as host numpy (gathered), so a restart
  may use a different mesh/device count — ``restore(..., sharding_fn)``
  re-places each leaf under the *new* sharding (re-shard on load).
* **complete state**: params, optimizer moments, quantizer scales (they live
  inside params), RNG, data-iterator state, and the step counter.
* **async**: ``save_async`` hands the (already host-transferred) arrays to a
  writer thread so the train loop never blocks on disk.
* **bounded**: keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---- write ----------------------------------------------------------
    def save(self, step: int, tree: Dict, extra: Optional[Dict] = None):
        arrays = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._write(step, arrays, extra or {})

    def save_async(self, step: int, tree: Dict,
                   extra: Optional[Dict] = None):
        self.wait()
        arrays = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, arrays, extra or {}), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, arrays: Dict, extra: Dict):
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(arrays)
        # npz cannot represent ml_dtypes (bfloat16 etc.): store such leaves
        # as same-width uint views and record the true dtype in the manifest
        to_save, dtypes = {}, {}
        for k, v in flat:
            v = np.asarray(v)
            if v.dtype.kind not in "biufc":       # custom dtype (bf16, ...)
                dtypes[k] = str(v.dtype)
                v = v.view(np.dtype(f"u{v.dtype.itemsize}"))
            to_save[k] = v
        np.savez(os.path.join(tmp, "tensors.npz"), **to_save)
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump({"step": step, "keys": [k for k, _ in flat],
                       "dtypes": dtypes, "extra": extra}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---- read -----------------------------------------------------------
    def list_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, _MANIFEST)):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, template: Dict, step: Optional[int] = None,
                sharding_fn: Optional[Callable[[str], Any]] = None
                ) -> Tuple[Dict, Dict]:
        """Restore into the structure of ``template``.

        ``sharding_fn(key) -> Sharding|None`` re-places each leaf for the
        *current* mesh (elastic restart across different topologies).
        Returns (tree, extra).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, _MANIFEST)) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "tensors.npz"))
        dtypes = manifest.get("dtypes", {})
        keys = [k for k, _ in _flatten(template)]
        missing = [k for k in keys if k not in data]
        if missing:
            raise KeyError(f"checkpoint missing keys: {missing[:5]}...")
        leaves = []
        for k in keys:
            arr = data[k]
            if k in dtypes:
                import ml_dtypes  # noqa: F401  (registers bf16 et al.)
                arr = arr.view(np.dtype(dtypes[k]))
            if sharding_fn is not None and (sh := sharding_fn(k)) is not None:
                arr = jax.device_put(arr, sh)
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(template)
        return jax.tree_util.tree_unflatten(treedef, leaves), \
            manifest["extra"]
