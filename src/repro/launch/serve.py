"""Batched quantized serving driver.

Loads (or initializes) a model, deploys it at the given precision, and runs
a batch of synthetic requests through the slot-based ServeEngine
(prefill -> continuous decode over the int8 cache).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--policy", default="A8d-C8-W4")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = (get_config if args.full else get_reduced_config)(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, policy=args.policy, slots=args.slots,
                         cache_len=args.cache_len)
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    stats = engine.run_until_drained()
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests in {dt:.2f}s: "
          f"{stats['tokens_out']} tokens, "
          f"{stats['tokens_out'] / max(dt, 1e-9):.1f} tok/s, "
          f"{stats['decode_steps']} decode steps")


if __name__ == "__main__":
    main()
