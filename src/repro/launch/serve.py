"""Batched quantized serving driver (continuous-batching engine v2).

Loads (or initializes) a model, deploys it at the given precision, and
drives the slot-based ServeEngine three ways:

* default — closed-loop batch: submit every synthetic request up front,
  drain, report throughput/TTFT.
* ``--arrival-rate R`` — open-loop: Poisson arrivals at R req/s through
  the asyncio frontend, optionally with a first-token SLO
  (``--deadline-ms`` + ``--shed``), reporting SLO attainment and
  goodput alongside the engine stats.
* ``--http-port P`` — serve: start the OpenAI-style HTTP endpoint
  (``/v1/completions`` with SSE streaming; see docs/serving_api.md) and
  run until interrupted.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine


def build_requests(args, cfg) -> list:
    rng = np.random.default_rng(0)
    reqs = []
    for uid in range(args.requests):
        plen = args.prompt_len
        if args.vary_prompts:
            plen = int(rng.integers(max(4, plen // 2), plen + 1))
        reqs.append(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=args.max_new,
            temperature=args.temperature,
            top_k=args.top_k,
            seed=uid))
    return reqs


def run_open_loop(args, engine, cfg):
    """Poisson arrivals at ``--arrival-rate`` req/s through the asyncio
    frontend; returns (engine stats + SLO metrics, wall seconds).

    Runs the workload twice: an untimed warmup pass (open-loop arrivals
    hit XLA compile variants — small admission waves — that a batch
    drain never triggers; a cold pass would blame multi-second compile
    stalls on the SLO) and then the identical timed pass."""
    import asyncio

    from repro.serve.frontend import AsyncFrontend

    deadline_ms = args.deadline_ms or None

    async def one_pass():
        rng = np.random.default_rng(1)
        t0 = time.perf_counter()
        async with AsyncFrontend(engine,
                                 default_deadline_ms=deadline_ms) as fe:
            handles = []
            for req in build_requests(args, cfg):
                await asyncio.sleep(rng.exponential(1.0 / args.arrival_rate))
                handles.append(await fe.submit(
                    req.prompt, max_new_tokens=req.max_new_tokens,
                    temperature=req.temperature, top_k=req.top_k,
                    seed=req.seed))
            for h in handles:
                await h.tokens()
            stats = await fe.stats()
        return handles, stats, time.perf_counter() - t0

    async def go():
        print("warmup pass (compiling open-loop admission variants)...")
        await one_pass()
        engine.reset()
        handles, stats, wall = await one_pass()
        shed = sum(1 for h in handles if h.shed)
        ttfts = sorted(h.first_token_t - h.submit_t for h in handles
                       if not h.shed and h.first_token_t is not None)
        stats["arrival_rate_rps"] = args.arrival_rate
        if deadline_ms is not None:
            met = sum(1 for t in ttfts if t <= deadline_ms / 1e3)
            stats["slo_attainment"] = met / max(len(handles), 1)
            stats["goodput_rps"] = met / max(wall, 1e-9)
            print(f"open loop @ {args.arrival_rate:.1f} req/s: "
                  f"{met}/{len(handles)} met the {deadline_ms:.0f} ms "
                  f"first-token SLO ({shed} shed), goodput "
                  f"{stats['goodput_rps']:.2f} req/s")
        else:
            print(f"open loop @ {args.arrival_rate:.1f} req/s: "
                  f"{len(handles)} served, {shed} shed")
        return stats, wall

    return asyncio.run(go())


def run_http(args, engine):
    """Serve the OpenAI-style HTTP endpoint until interrupted."""
    import asyncio

    from repro.serve.frontend import AsyncFrontend
    from repro.serve.http import ServeHTTP

    async def go():
        async with AsyncFrontend(
                engine, default_deadline_ms=args.deadline_ms or None) as fe:
            async with ServeHTTP(fe, host=args.http_host,
                                 port=args.http_port) as srv:
                print(f"serving on http://{args.http_host}:{srv.port} "
                      f"(POST /v1/completions, GET /v1/stats, "
                      f"/v1/metrics, /health; Ctrl-C to stop)")
                await srv.serve_forever()

    try:
        asyncio.run(go())
    except KeyboardInterrupt:
        print("\nshutting down")


def write_obs(args, engine, stats=None):
    """``--trace`` / ``--metrics`` epilogue shared by all three drive
    modes (closed-loop drain, open-loop arrivals, HTTP serve)."""
    if args.trace:
        from repro.obs.export import write_trace
        write_trace(args.trace, engine.trace,
                    compile_variants=engine.wave_variant_signatures())
        n_spans = sum(1 for e in engine.trace.events()
                      if e["ph"] == "span")
        print(f"wrote {args.trace}: {len(engine.trace)} trace records "
              f"({n_spans} spans, {engine.trace.dropped} dropped) — load "
              f"at ui.perfetto.dev or run: python tools/trace_report.py "
              f"{args.trace}")
    if args.metrics:
        print(engine.metrics.render(stats if stats is not None
                                    else engine.stats()), end="")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--policy", default="A8d-C8-W4")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--vary-prompts", action="store_true",
                    help="draw prompt lengths in [prompt_len/2, prompt_len]")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--decode-block", default="8",
                    help="decode steps per compiled on-device chunk; "
                         "'auto' probes decode-step latency at startup. "
                         "With speculative decoding active (the paged "
                         "default — see --no-spec) the draft+verify wave "
                         "owns step granularity instead: this knob is "
                         "overridden to spec-k+1 and the 'auto' probe is "
                         "skipped, so pass --no-spec to make it (or the "
                         "probe) take effect")
    ap.add_argument("--kv-layout", default="dense",
                    choices=("dense", "paged"),
                    help="paged = block-table KV cache with free-block "
                         "admission and chunked prefill")
    ap.add_argument("--block-size", type=int, default=64,
                    help="tokens per cache block (paged layout)")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="pool size in blocks (0 = match the dense "
                         "slots*cache_len budget)")
    ap.add_argument("--max-seq-len", type=int, default=0,
                    help="per-request token cap / block-table width "
                         "(paged; 0 = match the dense cache_len)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable prefix sharing (paged; on by default: "
                         "prompts extending a cached prefix map the same "
                         "pool blocks and prefill only their tail)")
    ap.add_argument("--admission", default="reserve",
                    choices=("reserve", "optimistic"),
                    help="paged admission: reserve worst-case blocks up "
                         "front, or admit on prompt footprint and preempt "
                         "(swap out) a resident when the pool runs dry")
    ap.add_argument("--tail-batch", type=int, default=0,
                    help="max tail/chunked prefills advanced per batched "
                         "wave (0 = every slot, 1 = serialized legacy "
                         "path)")
    ap.add_argument("--no-prefix-affinity", action="store_true",
                    help="disable chain-grouped scheduling of prefix-hit "
                         "requests")
    ap.add_argument("--preempt", default="last_admitted",
                    choices=("last_admitted", "longest_remaining"),
                    help="victim policy for optimistic-admission "
                         "preemption")
    ap.add_argument("--no-spec", action="store_true",
                    help="disable speculative decoding (paged layout "
                         "enables it by default: a truncated-layer draft "
                         "proposes k tokens per slot and the target "
                         "verifies every resident's drafts in one "
                         "compiled wave, rolling rejected suffixes back)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per slot per verify-wave")
    ap.add_argument("--spec-draft", type=int, default=0,
                    help="draft depth in layers (0 = half the target's "
                         "layers; equal to n_layers = self-draft)")
    ap.add_argument("--spec-accept", default="exact",
                    choices=("exact", "rejection"),
                    help="acceptance rule: 'exact' commits the target's "
                         "own samples (output identical to plain decode); "
                         "'rejection' runs speculative rejection sampling "
                         "for temperature/top-k requests")
    ap.add_argument("--sched", default="fcfs",
                    choices=("fcfs", "sjf", "edf"),
                    help="admission order: arrival, shortest-prompt, or "
                         "earliest-deadline-first within priority class "
                         "(pair edf with --deadline-ms / --shed)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="open-loop mode: Poisson arrivals at this many "
                         "requests/s through the asyncio frontend "
                         "(0 = closed-loop batch, the default)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request first-token SLO in ms (open-loop / "
                         "HTTP modes; 0 = no deadline). With --shed the "
                         "engine rejects or downgrades requests predicted "
                         "to miss it")
    ap.add_argument("--shed", default="none",
                    choices=("none", "reject", "downgrade"),
                    help="SLO admission control when a queued request's "
                         "predicted TTFT exceeds its deadline: drop it "
                         "(reject) or clear its deadline and demote it "
                         "behind on-time work (downgrade)")
    ap.add_argument("--http-port", type=int, default=0,
                    help="serve mode: bind the OpenAI-style HTTP endpoint "
                         "(/v1/completions with SSE streaming) on this "
                         "port and run until interrupted (0 = off)")
    ap.add_argument("--http-host", default="127.0.0.1")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: builds a local "
                         "('data', 'model') mesh over the visible devices "
                         "and serves every wave sharded across it")
    ap.add_argument("--weights", default="bf16", choices=("bf16", "w4a8"),
                    help="serve weight layout: bf16 fake-quant einsums, or "
                         "w4a8 packed-int4 weights x dynamic-int8 "
                         "activations through the deployment matmul "
                         "(Pallas on TPU, XLA ref elsewhere)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--trace", default="",
                    help="record a runtime trace and write it here as "
                         "Chrome/Perfetto trace_event JSON (open at "
                         "ui.perfetto.dev; summarize with "
                         "tools/trace_report.py). Open-loop runs trace "
                         "the timed pass only (the warmup's records are "
                         "cleared by the engine reset)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the Prometheus text /v1/metrics serves "
                         "at the end of the run")
    ap.add_argument("--bench-out", default="",
                    help="write the run's stats to this JSON file")
    args = ap.parse_args()

    cfg = (get_config if args.full else get_reduced_config)(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    decode_block = (args.decode_block if args.decode_block == "auto"
                    else int(args.decode_block))
    kw = {}
    if args.kv_layout == "paged":
        kw = {"kv_layout": "paged", "block_size": args.block_size,
              "num_blocks": args.num_blocks or None,
              "max_seq_len": args.max_seq_len or None,
              "prefix_cache": not args.no_prefix_cache,
              "admission": args.admission, "preempt": args.preempt,
              "tail_batch": args.tail_batch,
              "prefix_affinity": not args.no_prefix_affinity}
        if not args.no_spec:
            from repro.serve.spec import SpecConfig
            kw["spec"] = SpecConfig(k=args.spec_k,
                                    draft_layers=args.spec_draft or None,
                                    accept_mode=args.spec_accept)
    mesh = None
    if args.tp > 1:
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(model_parallel=args.tp)
    tracer = None
    if args.trace:
        from repro.obs.trace import Tracer
        tracer = Tracer()
    engine = ServeEngine(cfg, params, policy=args.policy, slots=args.slots,
                         cache_len=args.cache_len,
                         decode_block=decode_block,
                         sched_policy=args.sched, slo_shed=args.shed,
                         max_new_cap=max(32, args.max_new),
                         weights_layout=args.weights, trace=tracer,
                         mesh=mesh, **kw)
    if mesh is not None:
        st0 = engine.stats()
        print(f"mesh: {dict(mesh.shape)} over {mesh.devices.size} devices, "
              f"tp={engine.tp}; per device: "
              f"{st0['per_device_pool_bytes'] / 1e6:.2f} MB KV pool, "
              f"{st0['per_device_weight_bytes'] / 1e6:.2f} MB weights")
    if args.http_port:
        run_http(args, engine)
        write_obs(args, engine)
        return
    if args.arrival_rate > 0:
        stats, dt = run_open_loop(args, engine, cfg)
    else:
        for req in build_requests(args, cfg):
            engine.submit(req)
        t0 = time.perf_counter()
        stats = engine.run_until_drained()
        dt = time.perf_counter() - t0
    stats["wall_s"] = dt
    stats["tok_s"] = stats["tokens_out"] / max(dt, 1e-9)
    print(f"served {args.requests} requests in {dt:.2f}s: "
          f"{stats['tokens_out']} tokens, {stats['tok_s']:.1f} tok/s, "
          f"{stats['decode_steps']} decode steps "
          f"({stats['decode_step_s'] * 1e3:.1f} ms/step), "
          f"TTFT p50 {stats['ttft_p50_s'] * 1e3:.0f} ms "
          f"p95 {stats['ttft_p95_s'] * 1e3:.0f} ms")
    if stats["weights_layout"] == "w4a8":
        print(f"weights: w4a8 packed, "
              f"{stats['packed_weight_bytes'] / 1e6:.2f} MB streamed per "
              f"forward ({stats['weight_hbm_saved_bytes'] / 1e6:.2f} MB "
              f"bf16 HBM traffic saved)")
    if args.kv_layout == "paged":
        print(f"prefix cache: {stats['prefix_hit_tokens']} hit tokens / "
              f"{stats['prompt_tokens_prefilled']} prefilled, "
              f"{stats['cow_copies']} COW copies; preemption: "
              f"{stats['preemptions']} swaps, "
              f"{stats['swap_out_bytes'] + stats['swap_in_bytes']} bytes "
              f"moved in {stats['swap_s'] * 1e3:.0f} ms")
        if "spec_waves" in stats:
            print(f"speculative: {stats['spec_waves']} waves, "
                  f"{stats['spec_drafted']} drafted / "
                  f"{stats['spec_accepted']} accepted / "
                  f"{stats['spec_rolled_back']} rolled back "
                  f"(accept rate {stats['spec_accept_rate']:.2f}, "
                  f"k={stats['spec_k']}, "
                  f"draft {stats['spec_draft_layers']} layers)")
    write_obs(args, engine, stats)
    if args.bench_out:
        with open(args.bench_out, "w") as f:
            json.dump({"args": vars(args), "stats": stats}, f, indent=2)
        print(f"wrote {args.bench_out}")


if __name__ == "__main__":
    main()
