"""Batched quantized serving driver (continuous-batching engine v2).

Loads (or initializes) a model, deploys it at the given precision, and runs
a batch of synthetic requests through the slot-based ServeEngine: batched
length-bucketed prefill, fully on-device decode chunks, pluggable scheduler.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine


def build_requests(args, cfg) -> list:
    rng = np.random.default_rng(0)
    reqs = []
    for uid in range(args.requests):
        plen = args.prompt_len
        if args.vary_prompts:
            plen = int(rng.integers(max(4, plen // 2), plen + 1))
        reqs.append(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=args.max_new,
            temperature=args.temperature,
            top_k=args.top_k,
            seed=uid))
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--policy", default="A8d-C8-W4")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--vary-prompts", action="store_true",
                    help="draw prompt lengths in [prompt_len/2, prompt_len]")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--decode-block", default="8",
                    help="decode steps per compiled on-device chunk; "
                         "'auto' probes decode-step latency at startup")
    ap.add_argument("--kv-layout", default="dense",
                    choices=("dense", "paged"),
                    help="paged = block-table KV cache with free-block "
                         "admission and chunked prefill")
    ap.add_argument("--block-size", type=int, default=64,
                    help="tokens per cache block (paged layout)")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="pool size in blocks (0 = match the dense "
                         "slots*cache_len budget)")
    ap.add_argument("--max-seq-len", type=int, default=0,
                    help="per-request token cap / block-table width "
                         "(paged; 0 = match the dense cache_len)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable prefix sharing (paged; on by default: "
                         "prompts extending a cached prefix map the same "
                         "pool blocks and prefill only their tail)")
    ap.add_argument("--admission", default="reserve",
                    choices=("reserve", "optimistic"),
                    help="paged admission: reserve worst-case blocks up "
                         "front, or admit on prompt footprint and preempt "
                         "(swap out) a resident when the pool runs dry")
    ap.add_argument("--tail-batch", type=int, default=0,
                    help="max tail/chunked prefills advanced per batched "
                         "wave (0 = every slot, 1 = serialized legacy "
                         "path)")
    ap.add_argument("--no-prefix-affinity", action="store_true",
                    help="disable chain-grouped scheduling of prefix-hit "
                         "requests")
    ap.add_argument("--preempt", default="last_admitted",
                    choices=("last_admitted", "longest_remaining"),
                    help="victim policy for optimistic-admission "
                         "preemption")
    ap.add_argument("--no-spec", action="store_true",
                    help="disable speculative decoding (paged layout "
                         "enables it by default: a truncated-layer draft "
                         "proposes k tokens per slot and the target "
                         "verifies every resident's drafts in one "
                         "compiled wave, rolling rejected suffixes back)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per slot per verify-wave")
    ap.add_argument("--spec-draft", type=int, default=0,
                    help="draft depth in layers (0 = half the target's "
                         "layers; equal to n_layers = self-draft)")
    ap.add_argument("--spec-accept", default="exact",
                    choices=("exact", "rejection"),
                    help="acceptance rule: 'exact' commits the target's "
                         "own samples (output identical to plain decode); "
                         "'rejection' runs speculative rejection sampling "
                         "for temperature/top-k requests")
    ap.add_argument("--sched", default="fcfs", choices=("fcfs", "sjf"))
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--bench-out", default="",
                    help="write the run's stats to this JSON file")
    args = ap.parse_args()

    cfg = (get_config if args.full else get_reduced_config)(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    decode_block = (args.decode_block if args.decode_block == "auto"
                    else int(args.decode_block))
    kw = {}
    if args.kv_layout == "paged":
        kw = {"kv_layout": "paged", "block_size": args.block_size,
              "num_blocks": args.num_blocks or None,
              "max_seq_len": args.max_seq_len or None,
              "prefix_cache": not args.no_prefix_cache,
              "admission": args.admission, "preempt": args.preempt,
              "tail_batch": args.tail_batch,
              "prefix_affinity": not args.no_prefix_affinity}
        if not args.no_spec:
            from repro.serve.spec import SpecConfig
            kw["spec"] = SpecConfig(k=args.spec_k,
                                    draft_layers=args.spec_draft or None,
                                    accept_mode=args.spec_accept)
    engine = ServeEngine(cfg, params, policy=args.policy, slots=args.slots,
                         cache_len=args.cache_len,
                         decode_block=decode_block,
                         sched_policy=args.sched,
                         max_new_cap=max(32, args.max_new), **kw)
    for req in build_requests(args, cfg):
        engine.submit(req)
    t0 = time.perf_counter()
    stats = engine.run_until_drained()
    dt = time.perf_counter() - t0
    stats["wall_s"] = dt
    stats["tok_s"] = stats["tokens_out"] / max(dt, 1e-9)
    print(f"served {args.requests} requests in {dt:.2f}s: "
          f"{stats['tokens_out']} tokens, {stats['tok_s']:.1f} tok/s, "
          f"{stats['decode_steps']} decode steps "
          f"({stats['decode_step_s'] * 1e3:.1f} ms/step), "
          f"TTFT p50 {stats['ttft_p50_s'] * 1e3:.0f} ms "
          f"p95 {stats['ttft_p95_s'] * 1e3:.0f} ms")
    if args.kv_layout == "paged":
        print(f"prefix cache: {stats['prefix_hit_tokens']} hit tokens / "
              f"{stats['prompt_tokens_prefilled']} prefilled, "
              f"{stats['cow_copies']} COW copies; preemption: "
              f"{stats['preemptions']} swaps, "
              f"{stats['swap_out_bytes'] + stats['swap_in_bytes']} bytes "
              f"moved in {stats['swap_s'] * 1e3:.0f} ms")
        if "spec_waves" in stats:
            print(f"speculative: {stats['spec_waves']} waves, "
                  f"{stats['spec_drafted']} drafted / "
                  f"{stats['spec_accepted']} accepted / "
                  f"{stats['spec_rolled_back']} rolled back "
                  f"(accept rate {stats['spec_accept_rate']:.2f}, "
                  f"k={stats['spec_k']}, "
                  f"draft {stats['spec_draft_layers']} layers)")
    if args.bench_out:
        with open(args.bench_out, "w") as f:
            json.dump({"args": vars(args), "stats": stats}, f, indent=2)
        print(f"wrote {args.bench_out}")


if __name__ == "__main__":
    main()
