import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real step function (train_step for train
shapes, prefill_step / serve_step for inference shapes) against
ShapeDtypeStruct inputs on the production mesh, compiles it (SPMD
partitioning for 256 or 512 devices), and records:

* memory_analysis()    — per-device bytes (proves the cell fits HBM)
* cost_analysis()      — FLOPs / bytes for the roofline terms
* collective traffic   — loop-aware HLO parse (repro.runtime.hlo_analysis)
* MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) bookkeeping

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json and feed
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import numpy as np

from repro.configs import SHAPES, arch_shape_cells, get_config, get_shape
from repro.configs.base import TrainConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.launch.steps import (make_prefill_step, make_serve_step,
                                make_train_step)
from repro.runtime.hlo_analysis import analyze_program
from repro.runtime.sharding import (batch_shardings, cache_shardings,
                                    opt_shardings, param_shardings)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link


def cell_name(arch: str, shape: str, mesh: str, policy: str) -> str:
    return f"{arch}__{shape}__{mesh}__{policy}"


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               policy: str = "A8d-C8-W4", tcfg: TrainConfig | None = None,
               remat: str = "none"):
    """Build shardings + lower + compile one cell. Returns (compiled, meta)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind, args = input_specs(arch, shape_name, policy)
    tcfg = tcfg or TrainConfig(precision=policy, batch_size=shape.global_batch,
                               seq_len=shape.seq_len, remat=remat)

    from repro.launch.steps import attn_shard_mode_for
    from repro.runtime.sharding import batch_axes as mesh_batch_axes
    asm = attn_shard_mode_for(cfg, mesh.shape["model"])
    baxes = mesh_batch_axes(mesh)
    with mesh:
        if kind == "train":
            params_s, teacher_s, opt_s, batch_s, step_s = args
            psh = param_shardings(cfg, mesh, params_s)
            in_sh = (psh, psh, opt_shardings(psh, opt_s),
                     batch_shardings(mesh, batch_s), None)
            fn = make_train_step(cfg, tcfg, attn_shard_mode=asm,
                                 batch_axes=baxes)
        elif kind == "prefill":
            params_s, batch_s = args
            psh = param_shardings(cfg, mesh, params_s)
            in_sh = (psh, batch_shardings(mesh, batch_s))
            fn = make_prefill_step(cfg, policy, cache_budget=shape.seq_len,
                                   attn_shard_mode=asm, batch_axes=baxes)
        else:  # decode
            params_s, tok_s, cache_s = args
            psh = param_shardings(cfg, mesh, params_s)
            csh = cache_shardings(cfg, mesh, cache_s)
            in_sh = (psh, batch_shardings(mesh, {"tokens": tok_s})["tokens"],
                     csh)
            fn = make_serve_step(cfg, policy, attn_shard_mode=asm,
                                 batch_axes=baxes)
        jitted = jax.jit(fn, in_shardings=in_sh)
        t0 = time.time()
        lowered = jitted.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    meta = {"kind": kind, "lower_s": t1 - t0, "compile_s": t2 - t1,
            "devices": int(np.prod(list(mesh.shape.values())))}
    return compiled, meta, cfg, shape


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             policy: str = "A8d-C8-W4", save: bool = True,
             remat: str = "none") -> dict:
    mesh_name = "multipod" if multi_pod else "singlepod"
    compiled, meta, cfg, shape = lower_cell(arch, shape_name,
                                            multi_pod=multi_pod,
                                            policy=policy, remat=remat)
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    prog = analyze_program(hlo)                    # loop-aware HLO analysis
    coll = prog["collectives"]
    chips = meta["devices"]

    # cost_analysis() counts while bodies ONCE (loop-unaware), so FLOPs and
    # bytes come from the loop-aware HLO parse; cost_analysis kept for ref.
    flops = prog["flops"]                          # per-device program FLOPs
    bytes_acc = prog["hbm_bytes"]
    coll_bytes = coll["total_bytes"]               # per-device program bytes

    # roofline terms (seconds; per-chip program -> already per-chip)
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll_bytes / ICI_BW

    pc = cfg.param_counts()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    if shape.kind == "train":
        # student fwd+bwd (6ND) + teacher fwd (2ND), per chip
        model_flops = (6 * pc["active"] + 2 * pc["active"]) * tokens / chips
    else:
        model_flops = 2 * pc["active"] * tokens / chips

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "policy": policy, "kind": meta["kind"], "chips": chips,
        "lower_s": round(meta["lower_s"], 2),
        "compile_s": round(meta["compile_s"], 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {"flops": flops, "bytes_accessed": bytes_acc,
                 "xla_cost_flops": float(cost.get("flops", 0.0)),
                 "xla_cost_bytes": float(cost.get("bytes accessed", 0.0))},
        "collectives": {"total_bytes": coll_bytes, "by_op": coll["by_op"],
                        "unresolved_loops": prog["unresolved_loops"],
                        "top_sites": coll["per_site"][:8]},
        "roofline": {
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "bottleneck": max(
                [("compute", t_compute), ("memory", t_memory),
                 ("collective", t_coll)], key=lambda kv: kv[1])[0],
            "model_flops_per_chip": model_flops,
            "useful_flops_ratio": (model_flops / flops) if flops else None,
        },
    }
    if save:
        os.makedirs(ART_DIR, exist_ok=True)
        out = os.path.join(ART_DIR,
                           cell_name(arch, shape_name, mesh_name, policy)
                           + ".json")
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--policy", default="A8d-C8-W4")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for a, s in arch_shape_cells():
            print(f"{a} {s}")
        return

    cells = arch_shape_cells() if args.all else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} x {shape} x {'multi' if mp else 'single'}"
            try:
                r = run_cell(arch, shape, multi_pod=mp, policy=args.policy)
                rl = r["roofline"]
                print(f"OK   {tag}: compile={r['compile_s']}s "
                      f"flops/chip={r['cost']['flops']:.3g} "
                      f"bottleneck={rl['bottleneck']} "
                      f"t=({rl['t_compute_s']:.4g},{rl['t_memory_s']:.4g},"
                      f"{rl['t_collective_s']:.4g})s", flush=True)
            except Exception as e:
                failures.append(tag)
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:\n" + "\n".join(failures))
        raise SystemExit(1)
    print("\nall cells compiled")


if __name__ == "__main__":
    main()
