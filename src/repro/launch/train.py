"""End-to-end QAT training driver (SiLQ §3.1 flow).

Flow: (1) obtain/pretrain the fp16 teacher, (2) clone it as the student,
(3) calibrate weight step sizes (convex-MSE, Eq. 2) and — for static
activation policies — activation step sizes (percentile over 5 batches),
(4) train end-to-end with pure-KD loss, LSQ scale learning (50x LR on
activation scales), cosine LR, AdamW, (5) checkpoint/restore with heartbeats
(fault tolerance is exercised by --simulate-failure).

CPU-runnable with --reduced; the full configs drive the same code path on
real hardware.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config, get_reduced_config
from repro.configs.base import TrainConfig
from repro.core.distill import next_token_loss
from repro.core.precision import parse_policy
from repro.core.qat import calibrate_weight_scales, make_ctx, merge_act_scales
from repro.data import MixtureIterator, SyntheticConfig, calibration_batches
from repro.launch.steps import make_train_step, _text_logits
from repro.models import forward, init_params
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.optim.adamw import clip_by_global_norm
from repro.runtime.fault import HeartbeatFile


def make_teacher_pretrain_step(cfg, lr: float = 1e-3):
    ctx = make_ctx("A16-C16-W16", mode="off")

    def step_fn(params, opt_state, batch):
        def loss_fn(p):
            logits, _ = forward(cfg, p, ctx, batch)
            return next_token_loss(_text_logits(cfg, logits),
                                   batch["labels"], batch.get("loss_mask"))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, _ = clip_by_global_norm(grads, 1.0)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr,
                                         weight_decay=0.0)
        return params, opt_state, loss

    return jax.jit(step_fn)


def pretrain_teacher(cfg, data_cfg: SyntheticConfig, steps: int, key):
    """Give the synthetic-data teacher something to teach."""
    params = init_params(cfg, key)
    opt = adamw_init(params)
    step_fn = make_teacher_pretrain_step(cfg)
    it = MixtureIterator(data_cfg)
    loss = float("nan")
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, loss = step_fn(params, opt, batch)
        if i % 50 == 0:
            print(f"  teacher step {i}: ntp-loss {float(loss):.4f}",
                  flush=True)
    print(f"  teacher final ntp-loss {float(loss):.4f}", flush=True)
    return params


def calibrate(cfg, params, tcfg: TrainConfig, data_cfg: SyntheticConfig):
    """Paper §3.1: weight scales via convex-MSE; activation scales via
    percentile over calibration batches (static policies only)."""
    policy = parse_policy(tcfg.precision)
    params = calibrate_weight_scales(params, policy, tcfg.wgt_calib_method)
    if policy.enabled and policy.acts_static:
        ctx = make_ctx(policy, mode="calib",
                       act_calib_method=tcfg.act_calib_method)
        stats = []
        fwd = jax.jit(lambda p, b: forward(cfg, p, ctx, b,
                                           collect_stats=True)[1]["qstats"])
        for batch in calibration_batches(data_cfg, tcfg.calib_batches):
            stats.append(fwd(params, {"tokens": jnp.asarray(batch["tokens"])}))
        params = merge_act_scales(params, stats, policy)
    return params


def run_qat(arch: str, tcfg: TrainConfig, *, reduced: bool = True,
            teacher_steps: int = 200, ckpt_dir: str | None = None,
            resume: bool = False, log_every: int = 20,
            heartbeat_dir: str | None = None, worker: int = 0,
            simulate_failure_at: int = -1, eval_every: int = 0,
            eval_fn=None):
    cfg = get_reduced_config(arch) if reduced else get_config(arch)
    key = jax.random.PRNGKey(tcfg.seed)
    data_cfg = SyntheticConfig(vocab_size=cfg.vocab_size,
                               seq_len=tcfg.seq_len,
                               batch_size=tcfg.batch_size,
                               dclm_ratio=tcfg.dclm_ratio, seed=tcfg.seed)

    print(f"[qat] teacher pretrain ({teacher_steps} steps)", flush=True)
    teacher = pretrain_teacher(cfg, data_cfg, teacher_steps, key)
    student = jax.tree.map(jnp.copy, teacher)
    print("[qat] calibrating step sizes", flush=True)
    student = calibrate(cfg, student, tcfg, data_cfg)
    opt = adamw_init(student)
    it = MixtureIterator(data_cfg, start_step=1)
    start_step = 0

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    if ckpt and resume and ckpt.latest_step() is not None:
        (student, opt), extra = ckpt.restore((student, opt))
        it.load_state_dict(extra["data"])
        start_step = extra["step"]
        print(f"[qat] resumed from step {start_step}", flush=True)

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 2))
    hb = HeartbeatFile(heartbeat_dir, worker) if heartbeat_dir else None
    history = []
    for step in range(start_step, tcfg.total_steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        student, opt, metrics = step_fn(student, teacher, opt, batch,
                                        jnp.int32(step))
        dt = time.perf_counter() - t0
        if hb:
            hb.write(step, dt)
        if step == simulate_failure_at:
            print(f"[qat] SIMULATED FAILURE at step {step}", flush=True)
            raise SystemExit(42)
        if step % log_every == 0 or step == tcfg.total_steps - 1:
            print(f"  step {step}: kd-loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.2f}s)", flush=True)
        if eval_every and eval_fn and (step + 1) % eval_every == 0:
            history.append((step + 1, eval_fn(student)))
        if ckpt and (step + 1) % 100 == 0:
            ckpt.save_async(step + 1, (student, opt),
                            {"step": step + 1, "data": it.state_dict()})
    if ckpt:
        ckpt.wait()
    return teacher, student, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--precision", default="A8d-C8-W4")
    ap.add_argument("--teacher-steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (needs real hardware)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-failure-at", type=int, default=-1)
    args = ap.parse_args()
    tcfg = TrainConfig(precision=args.precision, total_steps=args.steps,
                       ref_steps=args.steps, batch_size=args.batch_size,
                       seq_len=args.seq_len)
    run_qat(args.arch, tcfg, reduced=not args.full,
            teacher_steps=args.teacher_steps, ckpt_dir=args.ckpt_dir,
            resume=args.resume,
            simulate_failure_at=args.simulate_failure_at)


if __name__ == "__main__":
    main()
