"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(arch, shape_name, policy)`` returns the exact pytrees the
step function for that (arch x shape) cell is lowered with:

* train_4k     -> (params, teacher_params, opt_state, batch, step)
* prefill_32k  -> (params, batch)
* decode_32k / long_500k -> (params, tokens1, cache)
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_shape
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.qat import make_ctx
from repro.models import init_cache, init_params
from repro.optim import adamw_init

DECODE_MARGIN = 128   # extra cache capacity beyond the prefilled context


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def param_struct(cfg: ModelConfig) -> Any:
    return jax.eval_shape(partial(init_params, cfg), jax.random.PRNGKey(0))


def opt_struct(params_struct: Any) -> Any:
    return jax.eval_shape(adamw_init, params_struct)


def cache_struct(cfg: ModelConfig, policy: str, batch: int,
                 cache_len: int) -> Any:
    ctx = make_ctx(policy)
    return jax.eval_shape(partial(init_cache, cfg, ctx, batch, cache_len))


def batch_struct(cfg: ModelConfig, shape: ShapeConfig,
                 with_labels: bool) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    s_text = S
    if cfg.family == "vlm":
        s_text = S - cfg.vision_tokens
        out["patches"] = sds((B, cfg.vision_tokens, cfg.d_model),
                             jnp.bfloat16)
        out["positions"] = sds((3, B, S), jnp.int32)
    if cfg.is_encdec:
        out["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    out["tokens"] = sds((B, s_text), jnp.int32)
    if with_labels:
        out["labels"] = sds((B, s_text), jnp.int32)
        out["loss_mask"] = sds((B, s_text), jnp.float32)
    return out


def input_specs(arch: str, shape_name: str,
                policy: str = "A8d-C8-W4") -> Tuple[str, Tuple]:
    """Returns (step_kind, args_structs) for the cell."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    params = param_struct(cfg)
    if shape.kind == "train":
        return "train", (params, params, opt_struct(params),
                         batch_struct(cfg, shape, with_labels=True),
                         sds((), jnp.int32))
    if shape.kind == "prefill":
        return "prefill", (params, batch_struct(cfg, shape,
                                                with_labels=False))
    # decode: one new token against a prefilled cache of seq_len
    B = shape.global_batch
    cache = cache_struct(cfg, policy, B, shape.seq_len + DECODE_MARGIN)
    return "decode", (params, sds((B, 1), jnp.int32), cache)
