"""Step functions: QAT train (teacher fwd + student fwd/bwd + AdamW + LSQ),
prefill, and single-token decode. These are the functions the dry-run
lowers and the examples execute.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.distill import silq_loss
from repro.core.precision import parse_policy
from repro.core.qat import make_ctx
from repro.models import decode_step as model_decode
from repro.models import forward, prefill
from repro.optim import adamw_update, cosine_schedule

MOE_AUX_COEF = 0.01


def _text_logits(cfg: ModelConfig, logits: jnp.ndarray) -> jnp.ndarray:
    """Drop the vision-prefix positions for loss computation (VLM)."""
    if cfg.family == "vlm" and cfg.vision_tokens:
        return logits[:, cfg.vision_tokens:]
    return logits


def attn_shard_mode_for(cfg: ModelConfig, model_axis: int) -> str:
    """Pick the attention sharding strategy for this arch on this mesh.

    kv-heads divide the TP axis -> plain head sharding is collective-free.
    Else q-heads divide -> replicate K/V, shard q heads ("kv_rep").
    Else -> sequence-parallel attention ("seq").
    """
    if model_axis <= 1 or cfg.n_kv_heads % model_axis == 0:
        return ""
    if cfg.n_heads % model_axis == 0:
        return "kv_rep"
    return "seq"


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    attn_shard_mode: str = "",
                    batch_axes: tuple = ()) -> Callable:
    """QAT train step, paper-faithful: teacher forward (unquantized, no
    grad), student forward with fake-quant, pure-KD loss (default), AdamW
    with LSQ scale updates (50x LR on activation scales)."""
    policy = parse_policy(tcfg.precision)
    ctx = make_ctx(policy, act_calib_method=tcfg.act_calib_method,
                   attn_shard_mode=attn_shard_mode, batch_axes=batch_axes)
    tctx = make_ctx("A16-C16-W16", mode="off",
                    attn_shard_mode=attn_shard_mode, batch_axes=batch_axes)
    base_lr = tcfg.scaled_lr()
    remat = tcfg.remat != "none"

    def train_step(params, teacher_params, opt_state, batch, step):
        t_logits, _ = forward(cfg, teacher_params, tctx, batch)
        t_logits = jax.lax.stop_gradient(_text_logits(cfg, t_logits))

        def loss_fn(p):
            logits, aux = forward(cfg, p, ctx, batch, remat=remat)
            loss = silq_loss(_text_logits(cfg, logits), t_logits,
                             batch["labels"], kd_ratio=tcfg.kd_ratio,
                             kd_temperature=tcfg.kd_temperature,
                             mask=batch.get("loss_mask"))
            if cfg.is_moe:
                loss = loss + MOE_AUX_COEF * aux["moe_aux"]
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        if tcfg.grad_clip:
            from repro.optim.adamw import clip_by_global_norm
            grads, _ = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = cosine_schedule(step, base_lr=base_lr,
                             total_steps=tcfg.total_steps,
                             warmup_steps=tcfg.warmup_steps,
                             min_lr_ratio=tcfg.min_lr_ratio)
        new_params, new_opt = adamw_update(
            params, grads, opt_state, lr=lr, beta1=tcfg.beta1,
            beta2=tcfg.beta2, eps=tcfg.eps, weight_decay=tcfg.weight_decay,
            act_scale_lr_mult=tcfg.act_scale_lr_mult)
        return new_params, new_opt, {"loss": loss, "lr": lr}

    return train_step


def make_eval_loss(cfg: ModelConfig, precision: str) -> Callable:
    """Next-token loss of the (fake-)quantized model — benchmark metric."""
    ctx = make_ctx(precision if precision else "A16-C16-W16",
                   mode="train" if precision != "A16-C16-W16" else "off")

    def eval_loss(params, batch):
        from repro.core.distill import next_token_loss
        logits, _ = forward(cfg, params, ctx, batch)
        return next_token_loss(_text_logits(cfg, logits), batch["labels"],
                               batch.get("loss_mask"))

    return eval_loss


def make_prefill_step(cfg: ModelConfig, policy: str,
                      cache_budget: int = 0, attn_shard_mode: str = "",
                      batch_axes: tuple = ()) -> Callable:
    ctx = make_ctx(policy, attn_shard_mode=attn_shard_mode,
                   batch_axes=batch_axes)

    def prefill_step(params, batch):
        return prefill(cfg, params, ctx, batch, cache_budget=cache_budget)

    return prefill_step


def make_serve_step(cfg: ModelConfig, policy: str, attn_shard_mode: str = "",
                    batch_axes: tuple = ()) -> Callable:
    """One decode token for every sequence in the batch (greedy head)."""
    ctx = make_ctx(policy, attn_shard_mode=attn_shard_mode,
                   batch_axes=batch_axes)

    def serve_step(params, tokens1, cache):
        logits, new_cache = model_decode(cfg, params, ctx, tokens1, cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return logits, next_tok[:, None], new_cache

    return serve_step
