"""Production mesh factory.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the pod
axis is an outer data-parallel axis crossing the (scarce) inter-pod links.

A function, not a module constant: importing this module must never touch
jax device state (the dry-run pins the device count before first jax use).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} — "
            "run under dryrun.py (it sets xla_force_host_platform_device_count)")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    if model_parallel < 1 or n % model_parallel != 0:
        raise ValueError(
            f"model_parallel={model_parallel} must divide the device "
            f"count ({n} available) — force more host devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N or pick "
            "a TP degree that divides the machine")
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
