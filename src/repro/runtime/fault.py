"""Launcher-side fault tolerance: heartbeats, stragglers, restarts, elasticity.

On a 1000+-node fleet the failure model is: slow chips (thermal / HBM ECC
retries), dead hosts, and whole-pod network partitions. The framework's
policy, implemented here and driven by ``launch/train.py``:

* **heartbeats** — every worker appends (step, wall_time) after each step;
  the coordinator flags a worker *straggling* when its step time exceeds
  ``straggler_factor`` x the fleet median over a sliding window, and *dead*
  after ``timeout_s`` without a beat.
* **straggler mitigation** — flagged worker is (a) excluded from the
  synchronous quorum if spares exist, or (b) the whole job checkpoints and
  restarts on the surviving topology (elastic re-mesh) — checkpoints are
  mesh-shape-agnostic (see repro.checkpoint).
* **bounded restarts** — ``RestartPolicy`` implements capped exponential
  backoff so a crash-looping job fails fast instead of burning the fleet.

Everything is pure-logic + files (testable without a cluster); the same
state machine drives the simulated multi-process launcher in
``launch/train.py --simulate-failures``.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class HeartbeatMonitor:
    n_workers: int
    timeout_s: float = 300.0
    straggler_factor: float = 2.0
    window: int = 16
    _beats: Dict[int, List[float]] = field(default_factory=dict)
    _last: Dict[int, float] = field(default_factory=dict)

    def beat(self, worker: int, step_time: float,
             now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        self._beats.setdefault(worker, []).append(step_time)
        self._beats[worker] = self._beats[worker][-self.window:]
        self._last[worker] = now

    def median_step_time(self) -> Optional[float]:
        times = [b[-1] for b in self._beats.values() if b]
        if not times:
            return None
        times.sort()
        return times[len(times) // 2]

    def stragglers(self) -> List[int]:
        med = self.median_step_time()
        if med is None or med == 0:
            return []
        return sorted(w for w, b in self._beats.items()
                      if b and b[-1] > self.straggler_factor * med)

    def dead(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        known = set(self._last)
        missing = set(range(self.n_workers)) - known
        timed_out = {w for w, t in self._last.items()
                     if now - t > self.timeout_s}
        return sorted(missing | timed_out) if self._last else sorted(missing)

    def healthy_quorum(self, now: Optional[float] = None) -> List[int]:
        bad = set(self.dead(now)) | set(self.stragglers())
        return [w for w in range(self.n_workers) if w not in bad]


@dataclass
class RestartPolicy:
    max_restarts: int = 10
    backoff_base_s: float = 5.0
    backoff_cap_s: float = 600.0
    restarts: int = 0

    def next_delay(self) -> Optional[float]:
        """None when the budget is exhausted (job should fail)."""
        if self.restarts >= self.max_restarts:
            return None
        d = min(self.backoff_base_s * (2 ** self.restarts),
                self.backoff_cap_s)
        self.restarts += 1
        return d

    def record_success(self, steps_since_restart: int,
                       stable_after: int = 100) -> None:
        if steps_since_restart >= stable_after:
            self.restarts = 0    # stable again -> reset the budget


@dataclass
class ElasticPlan:
    """Decide the new mesh when workers are lost (power-of-two shrink)."""
    data_axis: int
    model_axis: int

    def shrink_for(self, healthy: int) -> Optional[tuple]:
        """Largest (data', model) mesh fitting the healthy worker count.

        Model-parallel groups are indivisible (a TP shard loss kills the
        whole replica), so only the data axis shrinks.
        """
        if healthy < self.model_axis:
            return None
        data = self.data_axis
        while data * self.model_axis > healthy:
            data //= 2
        return (data, self.model_axis) if data >= 1 else None


class HeartbeatFile:
    """File-backed heartbeat transport (shared-fs coordination pattern)."""

    def __init__(self, directory: str, worker: int):
        self.path = os.path.join(directory, f"hb_{worker:05d}.json")
        os.makedirs(directory, exist_ok=True)

    def write(self, step: int, step_time: float) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "step_time": step_time,
                       "time": time.time()}, f)
        os.replace(tmp, self.path)

    @staticmethod
    def read_all(directory: str) -> Dict[int, Dict]:
        out = {}
        if not os.path.isdir(directory):
            return out
        for name in os.listdir(directory):
            if name.startswith("hb_") and name.endswith(".json"):
                try:
                    with open(os.path.join(directory, name)) as f:
                        out[int(name[3:8])] = json.load(f)
                except (json.JSONDecodeError, ValueError):
                    continue   # torn write: ignore this round
        return out
