"""Gradient compression for cross-replica all-reduce (beyond-paper trick).

Reuses the paper's own quantizer machinery on the *communication* path:
gradients are int8-quantized per tensor (shared scale via a scalar ``pmax``)
and exchanged as **int8 payloads** (``all_gather``), then summed and
dequantized locally, with fp32 error feedback so the quantization bias is
re-injected on the next step (EF-SGD convergence guarantee).

Bytes on the synced axis per tensor of N elements, R replicas:
    fp32 ring all-reduce:   ~2 * 4N
    int8 all-gather:        (R-1) * N
For the cross-pod axis (R=2, the scarce link in the production mesh) this is
an ~8x reduction; it remains a win for R <= 8. Designed for the pod axis of
the 2x16x16 mesh — the per-pod DP/TP axes keep XLA's native reductions.

Usage (inside a shard_map'd step over the compressed axis)::

    g_sync, new_err = compressed_psum(grads, err, axis_name="pod")
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads: Any, err: Any, axis_name: str) -> Tuple[Any, Any]:
    """int8-payload mean-all-reduce with error feedback.

    Returns (mean_grads, new_err). Must run inside shard_map/pmap with
    ``axis_name`` bound.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        # shared scale across replicas (scalar collective, negligible bytes)
        amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        scale = jnp.maximum(amax / 127.0, 1e-12)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_e = gf - q.astype(jnp.float32) * scale     # error feedback
        allq = jax.lax.all_gather(q, axis_name)        # int8 on the wire
        g_sync = jnp.sum(allq.astype(jnp.float32), axis=0) * scale / n
        return g_sync.astype(g.dtype), new_e

    flat = jax.tree.map(one, grads, err)
    g_sync = jax.tree.map(lambda t: t[0], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda t: isinstance(t, tuple))
    return g_sync, new_err
