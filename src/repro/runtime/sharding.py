"""Per-architecture sharding rules (DP / TP / EP / SP on the named mesh).

Mesh axes: ``("data", "model")`` single-pod 16x16, ``("pod", "data",
"model")`` multi-pod 2x16x16. The pod axis is an outer data-parallel axis
(batch shards over ("pod", "data")).

Parameter rules (path-keyed, divisibility-checked — a rule that does not
divide falls back to replication, never to a compile error):

* column-parallel (output over "model"): wq/wk/wv, wg/wu, w1, w_in, w_gate,
  w_ig, w_rg, w_up, w_x, r_h, w_q/w_k/w_v (mLSTM)
* row-parallel (input over "model"): wo, wd, w2, w_out, w_down
* embeddings: vocab over "model" when divisible, else d_model
* MoE: expert-parallel (experts over "model") when n_experts divides the
  axis — the moonshot-64e case; tensor-parallel inside experts otherwise
  (mixtral-8e on a 16-way axis); router replicated
* per-channel quantizer scales follow their weight's output sharding;
  per-tensor scales, norms and the recurrence diagonal replicate
* w4a8 export planes (``<linear>/w4a8/{wq,s_w,b,wf}``) shard like the
  linear they shadow: column-parallel owners split ``wq`` on d_out and
  ``s_w``/``b``/``wf`` on the output channel; row-parallel owners split
  ``wq`` on the packed d_in/2 axis (nibble pairs pack adjacent input
  channels, so a divisible packed axis cuts between pairs) and ``wf`` on
  d_in, with ``s_w``/``b`` replicated
* anything under ``segments/`` gets a leading None for the scan axis

Serving rules (``serve_cache_spec`` / ``serve_state_shardings``): only the
quantized KV payload shards — over "model" on the KV-head dim, so GQA
groups stay device-local and the grouped decode grid survives unchanged
per shard. Block tables, positions, lengths, sampling state and token
buffers replicate: the host ``BlockAllocator`` keeps dealing in global
block ids with zero API change.

Batch rules: global batch over ("pod","data"); sequence over "data" when the
batch dim cannot shard (long_500k, batch=1 -> sequence parallelism for the
cache).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

COL_PARALLEL = {"wq", "wk", "wv", "wg", "wu", "w1", "w_in", "w_gate",
                "w_ig", "w_rg", "w_up", "w_x", "r_h", "w_q", "w_k", "w_v"}
ROW_PARALLEL = {"wo", "wd", "w2", "w_out", "w_down"}
MOE_KEYS = {"wg", "wu", "wd"}


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _divides(n: int, by: int) -> bool:
    return by > 0 and n % by == 0


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _maybe(spec_dim: Optional[str], size: int, mesh: Mesh):
    """Use the axis only if it divides the dim."""
    if spec_dim is None:
        return None
    ax = mesh.shape[spec_dim] if isinstance(spec_dim, str) else \
        int(np.prod([mesh.shape[a] for a in spec_dim]))
    return spec_dim if _divides(size, ax) else None


def param_spec(cfg: ModelConfig, mesh: Mesh, path: str,
               shape: Tuple[int, ...]) -> P:
    """PartitionSpec for one parameter leaf."""
    parts = path.split("/")
    key = parts[-1]
    parent = parts[-2] if len(parts) >= 2 else ""
    in_scan = "segments" in parts
    is_moe = len(parts) >= 3 and "moe" in parts
    m = mesh.shape["model"]

    def lead(spec: P) -> P:
        # scan-stacked params carry a leading layer axis (replicated)
        if in_scan and len(spec) < len(shape):
            return P(*((None,) * (len(shape) - len(spec)) + tuple(spec)))
        return spec

    # ---- w4a8 export planes (serve-time packed weights) -------------------
    # Must precede the head branch: head/w4a8/wq has parts[-2] == "w4a8".
    if "w4a8" in parts:
        owner = parts[parts.index("w4a8") - 1] if parts.index("w4a8") else ""
        col = owner in COL_PARALLEL or owner == "head"
        row = owner in ROW_PARALLEL
        if key == "wq":                 # packed uint8 (d_out, d_in/2)
            if col:
                return lead(P(_maybe("model", shape[-2], mesh), None))
            if row:
                return lead(P(None, _maybe("model", shape[-1], mesh)))
            return lead(P(None, None))
        if key == "wf":                 # int8 ref plane (d_in, d_out)
            if col:
                return lead(P(None, _maybe("model", shape[-1], mesh)))
            if row:
                return lead(P(_maybe("model", shape[-2], mesh), None))
            return lead(P(None, None))
        if key == "s_w":                # (1, d_out): follows output sharding
            if col:
                return lead(P(None, _maybe("model", shape[-1], mesh)))
            return lead(P(None, None))
        if key == "b":
            return lead(P(_maybe("model", shape[-1], mesh)) if col
                        else P(None))
        return lead(P())

    # ---- embeddings / head ------------------------------------------------
    if path.endswith("embed/w"):        # (V, d) or (maxpos, d)
        if parts[-2] == "embed" and _divides(shape[0], m) \
                and "pos_embed" not in path:
            return P("model", None)
        return P(None, _maybe("model", shape[-1], mesh))
    if parts[0] == "head" or (len(parts) >= 2 and parts[-2] == "head"):
        if key == "w":                  # (d, V)
            return P(None, _maybe("model", shape[-1], mesh))
        if key == "s_w":                # (1, V)
            return P(None, _maybe("model", shape[-1], mesh))
        return P()

    # ---- MoE expert tensors ------------------------------------------------
    if is_moe and parent in MOE_KEYS and key in ("w", "s_w"):
        e = shape[1] if in_scan else shape[0]
        base = len(shape) - 3           # dims before (E, din, dout)
        if _divides(e, m):              # expert parallelism
            return P(*((None,) * base + ("model", None, None)))
        if parent in ("wg", "wu"):      # TP inside experts, column
            return P(*((None,) * base + (None, None, "model"))) \
                if key == "w" else \
                P(*((None,) * base + (None, None, "model")))
        return P(*((None,) * base + (None, "model", None))) \
            if key == "w" else P(*((None,) * base + (None, None, None)))

    # ---- quantizer scales ----------------------------------------------------
    if key == "s_w":                    # (1, dout) [+ scan lead]
        if parent in COL_PARALLEL and _divides(shape[-1], m):
            return lead(P(None, "model"))
        return lead(P(None, None))
    if key.startswith("s_"):            # per-tensor scalars
        return lead(P())

    # ---- linears ----------------------------------------------------------------
    if key == "w" and parent in COL_PARALLEL:
        return lead(P(None, _maybe("model", shape[-1], mesh)))
    if key == "w" and parent in ROW_PARALLEL:
        return lead(P(_maybe("model", shape[-2], mesh), None))
    if key == "b":
        if parent in COL_PARALLEL:
            return lead(P(_maybe("model", shape[-1], mesh)))
        return lead(P(None))

    # ---- recurrent diagonals / conv ----------------------------------------------
    if key in ("lam", "conv_b"):
        return lead(P(_maybe("model", shape[-1], mesh)))
    if key == "conv_w":
        return lead(P(None, _maybe("model", shape[-1], mesh)))

    # ---- norms, router, gates, everything else: replicated ----------------------
    return lead(P(*([None] * 0)))


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shapes) -> Any:
    """NamedSharding tree matching a params (shape) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    out = []
    for path, leaf in flat:
        spec = param_spec(cfg, mesh, _path_str(path), leaf.shape)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------------------
# Batch / cache shardings
# --------------------------------------------------------------------------

def batch_spec(mesh: Mesh, shape: Tuple[int, ...], name: str) -> P:
    dp = batch_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    if name == "positions":             # (3, B, S)
        if len(shape) >= 2 and _divides(shape[1], dp_size):
            return P(None, dp)
        return P()
    if not shape:
        return P()
    if _divides(shape[0], dp_size):
        return P(*((dp,) + (None,) * (len(shape) - 1)))
    # batch unshardable (e.g. long_500k B=1): sequence parallelism over data
    if len(shape) >= 2 and _divides(shape[1], mesh.shape["data"]):
        return P(None, "data")
    return P()


def batch_shardings(mesh: Mesh, batch_shapes: Dict[str, Any]) -> Dict:
    return {k: NamedSharding(mesh, batch_spec(mesh, v.shape, k))
            for k, v in batch_shapes.items()}


def cache_spec(cfg: ModelConfig, mesh: Mesh, path: str,
               shape: Tuple[int, ...]) -> P:
    """Serving-cache leaf sharding.

    Attention caches (rep, B, Hkv, S, D): batch over DP when divisible,
    else sequence over "data" (long-context SP); kv-heads over "model" when
    divisible, else head_dim. Recurrent states: width/heads over "model".
    """
    key = path.split("/")[-1]
    dp = batch_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    m = mesh.shape["model"]
    if key in ("length", "position"):
        return P()
    has_rep = "segments" in path
    base = 1 if has_rep else 0          # leading scan axis replicated
    dims: list = [None] * len(shape)
    bdim = base
    if len(shape) > bdim and _divides(shape[bdim], dp_size):
        dims[bdim] = dp
        seq_sharded = False
    else:
        seq_sharded = True
    if key in ("k_q", "v_q"):           # (..., B, Hkv, S, D)
        hkv, S, D = shape[-3], shape[-2], shape[-1]
        if _divides(hkv, m):
            dims[-3] = "model"
        elif _divides(S, m):
            # context parallelism: shard the cache sequence over "model"
            # (head_dim sharding would all-reduce every decode score tile)
            dims[-2] = "model"
        elif _divides(D, m):
            dims[-1] = "model"
        if seq_sharded and dims[-2] is None \
                and _divides(S, mesh.shape["data"]):
            dims[-2] = "data"
    elif key in ("s_k", "s_v"):         # (..., B, Hkv, S)
        hkv, S = shape[-2], shape[-1]
        if _divides(hkv, m):
            dims[-2] = "model"
        elif _divides(S, m):
            dims[-1] = "model"
        elif seq_sharded and _divides(S, mesh.shape["data"]):
            dims[-1] = "data"
    elif key in ("state_q", "conv_buf", "c"):
        if _divides(shape[-1], m):
            dims[-1] = "model"
        elif len(shape) >= 3 and _divides(shape[-3], m):
            dims[-3] = "model"
    elif key == "s_state":
        pass                             # small scales: replicated
    return P(*dims)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_shapes) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = []
    for path, leaf in flat:
        spec = cache_spec(cfg, mesh, _path_str(path), leaf.shape)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------------------
# Serving-engine shardings (paged pool + full device state pytree)
# --------------------------------------------------------------------------

def serve_cache_spec(cfg: ModelConfig, mesh: Mesh, path: str,
                     shape: Tuple[int, ...]) -> P:
    """Serve-cache leaf sharding (paged block pool or dense per-slot).

    Unlike the training ``cache_spec``, the leading pool axis is NEVER
    sharded: for a paged pool that axis is the global block-id space the
    host allocator indexes into, and splitting it over "data" would turn
    every block-table lookup into a cross-device gather. Only the KV-head
    dim shards (over "model", when divisible) so attention stays
    head-local per device; everything else — lengths, positions, block
    tables, recurrent state — replicates.
    """
    key = path.split("/")[-1]
    m = mesh.shape["model"]
    dims: list = [None] * len(shape)
    if key in ("k_q", "v_q") and len(shape) >= 4:   # (..., NB|B, Hkv, S, D)
        if _divides(shape[-3], m):
            dims[-3] = "model"
    elif key in ("s_k", "s_v") and len(shape) >= 3:  # (..., NB|B, Hkv, S)
        if _divides(shape[-2], m):
            dims[-2] = "model"
    return P(*dims)


def serve_cache_shardings(cfg: ModelConfig, mesh: Mesh, cache) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in flat:
        spec = serve_cache_spec(cfg, mesh, _path_str(path), leaf.shape)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def serve_state_shardings(cfg: ModelConfig, mesh: Mesh, state) -> Any:
    """Shardings for the engine's full device state pytree.

    The cache subtree follows ``serve_cache_spec``; sampling state, token
    buffers, RNG keys and per-slot bookkeeping replicate (they are tiny
    and the sampler all-gathers the sharded logits anyway).
    """
    rep = NamedSharding(mesh, P())
    return {k: (serve_cache_shardings(cfg, mesh, v) if k == "cache"
                else jax.tree.map(lambda _: rep, v))
            for k, v in state.items()}


def opt_shardings(param_sh: Any, opt_state_shapes) -> Any:
    """Optimizer moments shard exactly like their parameters."""
    from repro.optim.adamw import AdamWState
    return AdamWState(
        step=NamedSharding(list(jax.tree.leaves(param_sh))[0].mesh, P()),
        m=param_sh, v=jax.tree.map(lambda s: s, param_sh))
