"""Post-SPMD HLO analysis: collective bytes, loop-aware.

``cost_analysis()`` does not expose collective traffic, so we parse the
compiled HLO text: every ``all-gather`` / ``all-reduce`` / ``reduce-scatter``
/ ``all-to-all`` / ``collective-permute`` op contributes its result-shape
bytes. Collectives inside ``while`` bodies (layer scans, MoE chunk scans,
attention chunk maps) execute trip-count times, so we

1. build the computation call graph (body=/condition=/to_apply=/calls=),
2. recover each while's static trip count from the ``constant(N)`` in its
   condition computation (XLA emits ``compare(iter, N), direction=LT`` for
   scan-generated loops),
3. multiply each collective's bytes by the product of enclosing trip counts.

Heuristic but validated against known scan structures in tests; falls back
to multiplier 1 (and flags it) when a trip count cannot be recovered.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0,
}

# float dtypes a dequantized int8 buffer could materialize as
FLOAT_DTYPES = ("f16", "bf16", "f32", "f64")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\([^)]*\)\s*->", re.M)
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")

_CALL_KW = re.compile(
    r"(to_apply|body|condition|calls)=%?([\w\.\-]+)")
_CALL_LIST = re.compile(
    r"(?:branch_computations|called_computations|calls)=\{([^}]*)\}")


def _callees(line: str):
    """[(name, is_while_body), ...] referenced from one HLO op line."""
    out = []
    for kw, name in _CALL_KW.findall(line):
        out.append((name, kw == "body"))
    for grp in _CALL_LIST.findall(line):
        for name in re.split(r"[,\s%]+", grp):
            if name:
                out.append((name, False))
    return out


def _shape_bytes(text: str, unknown: Optional[set] = None) -> int:
    """Sum bytes over every dtype[dims] group in a result type string.

    Dtype tokens missing from ``_DTYPE_BYTES`` (e.g. ``s4``, ``f8e4m3``)
    contribute 0 bytes and are recorded into ``unknown`` when a set is
    passed — flag-and-skip, never a KeyError, so a new XLA dtype degrades
    an analysis into an explicit ``unknown_dtypes`` report field instead
    of crashing it (or silently undercounting traffic).
    """
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            if unknown is not None:
                unknown.add(dtype)
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its body lines (robust to tuple-typed params)."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        ls = line.strip()
        if cur is None or ls.endswith("{"):
            if ls.endswith("{") and "->" in ls:
                m = _HDR_RE.match(ls)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    continue
        if cur is not None:
            comps[cur].append(line)
            if ls == "}":
                cur = None
    return comps


def _find_entry(hlo: str, comps: Dict[str, List[str]]) -> Optional[str]:
    """Name of the ENTRY computation (fallback: the largest one).

    Matches on the bare ``ENTRY %name (`` prefix: the old signature-shaped
    regex choked on tuple-typed parameters (nested parens) and silently
    fell back, mis-rooting the call-graph walk."""
    entry = None
    for line in hlo.splitlines():
        ls = line.strip()
        if ls.startswith("ENTRY"):
            m = _HDR_RE.match(ls)
            if m:
                entry = m.group(1)
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    return entry


# ops that alias / relabel buffers: no HBM traffic of their own
_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "iota", "broadcast", "reshape", "transpose", "copy-start", "copy-done",
}

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],\{\}]+))\s+"
    r"([\w\-]+)\(")
_DIMS_RE = re.compile(r"\w+\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _first_dims(type_str: str):
    m = _DIMS_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(1).split(",")] if m.group(1) else []


def _entry_and_mult(hlo: str, comps):
    """(entry, trip, mult, exec_comps): loop multipliers + the set of
    computations that execute as program code (not fusion/reducer bodies)."""
    entry = _find_entry(hlo, comps)

    trip: Dict[str, int] = {}
    unresolved = 0
    for cname, lines in comps.items():
        for line in lines:
            if " while(" not in line:
                continue
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            if not mb:
                continue
            count = None
            if mc and mc.group(1) in comps:
                consts = [int(x) for x in
                          _CONST_RE.findall("\n".join(comps[mc.group(1)]))]
                consts = [c for c in consts if c > 0]
                if consts:
                    count = max(consts)
            if count is None:
                unresolved += 1
                count = 1
            trip[mb.group(1)] = count

    mult: Dict[str, float] = {}
    exec_comps = set()

    def visit(comp: str, m: float, seen: frozenset, is_exec: bool):
        if comp not in comps or comp in seen:
            return
        if is_exec:
            exec_comps.add(comp)
        if m <= mult.get(comp, 0.0):
            return
        mult[comp] = m
        seen = seen | {comp}
        for line in comps[comp]:
            for callee, kw in _callees_kw(line):
                if callee not in comps:
                    continue
                child_m = m * trip.get(callee, 1) if kw == "body" else m
                # only while bodies/conditions execute as program regions;
                # fusion bodies / reducers are accounted at their call site
                visit(callee, child_m, seen,
                      is_exec and kw in ("body", "condition"))

    if entry:
        visit(entry, 1.0, frozenset(), True)
    return entry, trip, mult, exec_comps, unresolved


def _callees_kw(line: str):
    out = []
    for kw, name in _CALL_KW.findall(line):
        out.append((name, kw))
    for grp in _CALL_LIST.findall(line):
        for name in re.split(r"[,\s%]+", grp):
            if name:
                out.append((name, "calls"))
    return out


def analyze_program(hlo: str) -> Dict:
    """Loop-aware program analysis of post-SPMD compiled HLO.

    Returns per-device-program totals:
      flops        — 2*prod(result)*contraction for every dot, x loop trips
      hbm_bytes    — operand+result bytes of fusion-boundary ops, x trips
                     (dynamic-(update-)slice counted at slice size: in-place)
      collectives  — {"total_bytes", "by_op", "per_site"}
      unresolved_loops
      unknown_dtypes — dtype tokens skipped by the byte model (flagged,
                       never a crash; their buffers contribute 0 bytes)
    """
    comps = _split_computations(hlo)
    entry, trip, mult, exec_comps, unresolved = _entry_and_mult(hlo, comps)
    unknown: set = set()

    flops = 0.0
    hbm = 0.0
    by_op: Dict[str, float] = defaultdict(float)
    per_site = []
    hbm_sites = []
    for cname in exec_comps:
        m = mult.get(cname, 1.0) or 1.0
        shapes: Dict[str, str] = {}
        parsed = []
        for line in comps[cname]:
            om = _OP_RE.match(line)
            if not om:
                continue
            name, type_str, op = om.group(1), om.group(2), om.group(3)
            shapes[name] = type_str
            parsed.append((name, type_str, op, line))
        for name, type_str, op, line in parsed:
            if op in _NO_TRAFFIC:
                continue
            out_b = _shape_bytes(type_str, unknown)
            # ---- collectives ----
            base = next((c for c in COLLECTIVE_OPS
                         if op in (c, c + "-start", c + "-done")), None)
            if base is not None:
                if op.endswith("-done"):
                    continue
                b = out_b * m
                by_op[base] += b
                per_site.append({"op": base, "computation": cname,
                                 "bytes": b, "mult": m,
                                 "line": line.strip()[:160]})
                hbm += out_b * m        # collectives also touch HBM
                continue
            # ---- dot flops ----
            if op == "dot":
                ops_names = _OPERAND_RE.findall(
                    line.split("(", 1)[1].split(")", 1)[0])
                lhs_dims = _first_dims(shapes.get(ops_names[0], "")) \
                    if ops_names else []
                cm = _LHS_CONTRACT_RE.search(line)
                contract = 1
                if cm and lhs_dims:
                    for i in (int(x) for x in cm.group(1).split(",")
                              if x != ""):
                        if i < len(lhs_dims):
                            contract *= lhs_dims[i]
                res_elems = 1
                for d in _first_dims(type_str):
                    res_elems *= d
                flops += 2.0 * res_elems * contract * m
            # ---- HBM traffic at fusion boundaries ----
            if op in ("dynamic-update-slice",):
                arg = line.split("(", 1)[1]
                ops_names = _OPERAND_RE.findall(arg.split(")", 1)[0])
                upd = shapes.get(ops_names[1], "") if len(ops_names) > 1 \
                    else ""
                hbm += 2.0 * _shape_bytes(upd, unknown) * m  # read+write slice
                continue
            if op == "dynamic-slice":
                hbm += 2.0 * out_b * m
                continue
            in_b = 0
            arg_span = line.split("(", 1)[1]
            depth, end = 1, 0
            for i, ch in enumerate(arg_span):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operand_bytes = []
            for on in _OPERAND_RE.findall(arg_span[:end]):
                if on in shapes:
                    b = _shape_bytes(shapes[on], unknown)
                    in_b += b
                    operand_bytes.append((b, shapes[on]))
            op_traffic = out_b + in_b
            # scan residual stashes: XLA aliases dynamic-(update-)slice
            # fusions in place — charging the whole stacked buffer per loop
            # iteration would fabricate TBs of traffic. Count the slice.
            if op == "fusion":
                cm2 = re.search(r"calls=%?([\w\.\-]+)", line)
                body = comps.get(cm2.group(1), []) if cm2 else []
                has_dus = any(" dynamic-update-slice(" in l for l in body)
                has_ds = any(" dynamic-slice(" in l for l in body)
                if has_dus or has_ds:
                    aliased = max((b for b, t in operand_bytes
                                   if t == type_str or b >= 0.9 * out_b),
                                  default=0)
                    if has_dus:
                        op_traffic = max(out_b + in_b - 2 * aliased, 0)
                    else:   # dynamic-slice: read slice, not the buffer
                        op_traffic = max(in_b - aliased, 0) + 2 * out_b
            hbm += op_traffic * m
            if op_traffic * m > 0:
                meta = re.search(r'op_name="([^"]*)"', line)
                hbm_sites.append((op_traffic * m, op,
                                  (meta.group(1)[-110:] if meta else
                                   cname[:60])))

    hbm_sites.sort(key=lambda t: -t[0])
    return {"flops": flops, "hbm_bytes": hbm,
            "hbm_top": [{"bytes": b, "op": o, "where": w}
                        for b, o, w in hbm_sites[:30]],
            "collectives": {"total_bytes": float(sum(by_op.values())),
                            "by_op": {k: float(v) for k, v in by_op.items()},
                            "per_site": sorted(per_site,
                                               key=lambda s: -s["bytes"])[:40]},
            "unresolved_loops": unresolved,
            "unknown_dtypes": sorted(unknown)}


def analyze_collectives(hlo: str) -> Dict:
    """Returns {"total_bytes", "by_op", "per_site", "unresolved_loops",
    "unknown_dtypes"}."""
    comps = _split_computations(hlo)
    entry = _find_entry(hlo, comps)

    # while body -> trip count (from its condition computation)
    trip: Dict[str, int] = {}
    unresolved = 0
    for cname, lines in comps.items():
        for line in lines:
            if " while(" not in line:
                continue
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            if not mb:
                continue
            count = None
            if mc and mc.group(1) in comps:
                consts = [int(x) for x in
                          _CONST_RE.findall("\n".join(comps[mc.group(1)]))]
                consts = [c for c in consts if c > 0]
                if consts:
                    count = max(consts)
            if count is None:
                unresolved += 1
                count = 1
            trip[mb.group(1)] = count

    # multiplier per computation via DFS over the call graph
    mult: Dict[str, float] = {}

    def visit(comp: str, m: float, seen: frozenset):
        if comp not in comps or comp in seen:
            return
        if m <= mult.get(comp, 0.0):
            return                      # already visited at >= multiplier
        mult[comp] = m
        seen = seen | {comp}
        for line in comps[comp]:
            for callee, is_body in _callees(line):
                if callee not in comps:
                    continue
                child_m = m * trip.get(callee, 1) if is_body else m
                visit(callee, child_m, seen)

    if entry:
        visit(entry, 1.0, frozenset())

    by_op: Dict[str, float] = defaultdict(float)
    per_site = []
    unknown: set = set()
    coll_line = re.compile(
        r"%?[\w\.\-]+\s*=\s*(.+?)\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(-start)?\(")
    for cname, lines in comps.items():
        m = mult.get(cname, 1.0) or 1.0
        for line in lines:
            mm = coll_line.match(line.strip())
            if not mm:
                continue
            shape_txt, op = mm.group(1), mm.group(2)
            b = _shape_bytes(shape_txt, unknown) * m
            by_op[op] += b
            per_site.append({"op": op, "computation": cname,
                             "bytes": b, "mult": m,
                             "line": line.strip()[:160]})
    return {"total_bytes": float(sum(by_op.values())),
            "by_op": {k: float(v) for k, v in by_op.items()},
            "per_site": sorted(per_site, key=lambda s: -s["bytes"])[:40],
            "unresolved_loops": unresolved,
            "unknown_dtypes": sorted(unknown)}


# --------------------------------------------------------------------------
# Per-wave collective accounting (sharded serving CI gates)
# --------------------------------------------------------------------------

_COLL_SITE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[\w\[\],\{\}]+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")


def collective_sites(hlo: str) -> List[Dict]:
    """Every collective site in the module, untruncated and loop-agnostic.

    Static site inventory for the sharded-serve CI gate: unlike
    ``analyze_collectives`` this does not weight by trip count or cap the
    site list, so a single stray gather deep in a layer scan still shows
    up. Each entry carries the per-dtype byte breakdown of the result
    type (tuple results contribute one group per element).
    """
    sites = []
    for line in hlo.splitlines():
        mm = _COLL_SITE_RE.search(line)
        if not mm:
            continue
        type_str, op = mm.group(1), mm.group(2)
        groups = []
        unknown: List[str] = []
        for dtype, dims in _SHAPE_RE.findall(type_str):
            if dtype not in _DTYPE_BYTES:
                if dtype not in unknown:
                    unknown.append(dtype)
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            groups.append({"dtype": dtype, "bytes": n * _DTYPE_BYTES[dtype]})
        site = {"op": op, "bytes": sum(g["bytes"] for g in groups),
                "groups": groups, "line": line.strip()[:160]}
        if unknown:
            site["unknown_dtypes"] = unknown
        sites.append(site)
    return sites


def collective_counts(hlo: str) -> Dict[str, int]:
    """Static site count per collective op (all-gather-start/-done pairs
    count once)."""
    counts: Dict[str, int] = defaultdict(int)
    for s in collective_sites(hlo):
        counts[s["op"]] += 1
    return dict(counts)


def pool_allgather_sites(hlo: str, min_bytes: int = 1 << 16) -> List[Dict]:
    """all-gather sites that move a large int8 buffer — the signature of a
    sharded KV block pool (or packed-weight plane) being accidentally
    regathered. Legit TP collectives are f32/bf16 (row-parallel
    all-reduce, sampled-logit gather) or tiny (amax scalars), so any
    s8/u8 all-gather over ``min_bytes`` fails the sharded-serve gate.
    """
    bad = []
    for s in collective_sites(hlo):
        if s["op"] != "all-gather":
            continue
        if any(g["dtype"] in ("s8", "u8") and g["bytes"] >= min_bytes
               for g in s["groups"]):
            bad.append(s)
    return bad


# --------------------------------------------------------------------------
# Serve-graph audit walkers (entry params, alias table, host transfers,
# float intermediates) — the parsing substrate for ``repro.analysis``.
# --------------------------------------------------------------------------

_PARAM_RE = re.compile(r"\bparameter\((\d+)\)")
_OP_NAME_RE = re.compile(r'op_name="([^"]*)"')
_ALIAS_ENTRY_RE = re.compile(
    r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{([\d,\s]*)\}(?:,\s*([\w-]+))?\)")


def _int_tuple(text: str) -> Tuple[int, ...]:
    return tuple(int(x) for x in text.split(",") if x.strip())


def entry_parameters(hlo: str) -> List[Dict]:
    """Entry-computation parameters: [{num, name, dtype, bytes, shape,
    op_name}].

    ``op_name`` carries the jax-side pytree path when XLA preserved the
    metadata (e.g. ``state['k_q']``) — the auditor uses it to name leaked
    donations in terms the engine author recognizes.
    """
    comps = _split_computations(hlo)
    entry = _find_entry(hlo, comps)
    params = []
    for line in comps.get(entry, []) if entry else []:
        om = _OP_RE.match(line)
        if not om or om.group(3) != "parameter":
            continue
        pm = _PARAM_RE.search(line)
        if not pm:
            continue
        type_str = om.group(2)
        sm = _SHAPE_RE.search(type_str)
        nm = _OP_NAME_RE.search(line)
        params.append({
            "num": int(pm.group(1)),
            "name": om.group(1),
            "dtype": sm.group(1) if sm else "",
            "shape": _first_dims(type_str),
            "bytes": _shape_bytes(type_str),
            "op_name": nm.group(1) if nm else "",
        })
    params.sort(key=lambda p: p["num"])
    return params


def input_output_aliases(hlo: str) -> List[Dict]:
    """Parse the module-header ``input_output_alias={...}`` table.

    Each entry maps an output (tuple) index to a parameter and an index
    path within it: [{output_index, param, param_index, kind}]. Donated
    jit arguments that XLA honored appear here; a donated buffer missing
    from this table was silently copied instead of reused.
    """
    key = "input_output_alias={"
    start = hlo.find(key)
    if start < 0:
        return []
    i = start + len(key)
    depth = 1
    while i < len(hlo) and depth > 0:
        if hlo[i] == "{":
            depth += 1
        elif hlo[i] == "}":
            depth -= 1
        i += 1
    region = hlo[start + len(key):i - 1]
    out = []
    for om, pnum, pidx, kind in _ALIAS_ENTRY_RE.findall(region):
        out.append({"output_index": _int_tuple(om),
                    "param": int(pnum),
                    "param_index": _int_tuple(pidx),
                    "kind": kind or "may-alias"})
    return out


# op names that move data between host and device (or synchronize on the
# host) when they appear inside a compiled wave body
_HOST_OPS = {"infeed", "outfeed", "send", "send-done", "recv", "recv-done"}
# custom-call targets that are host round-trips in disguise
_HOST_CALL_PAT = ("callback", "MoveToHost", "MoveToDevice", "SendToHost",
                  "RecvFromHost", "HostExecute")
_CC_TARGET_RE = re.compile(r'custom_call_target="([^"]*)"')


def host_transfer_sites(hlo: str) -> List[Dict]:
    """Ops that imply a host transfer / host sync inside the program.

    Flags (a) infeed/outfeed/send/recv ops, (b) custom-calls whose target
    matches a known host-callback / host-offload pattern, and (c) buffers
    explicitly annotated into host memory space ``S(5)``. One hidden d2h
    inside a decode wave serializes the whole step loop, so the serve
    audit requires this list to be empty for every wave.
    """
    comps = _split_computations(hlo)
    sites = []
    for cname, lines in comps.items():
        for line in lines:
            om = _OP_RE.match(line)
            if not om:
                continue
            op = om.group(3)
            reason = None
            if op in _HOST_OPS:
                reason = f"host op `{op}`"
            elif op == "custom-call":
                tm = _CC_TARGET_RE.search(line)
                target = tm.group(1) if tm else ""
                if any(p.lower() in target.lower() for p in _HOST_CALL_PAT):
                    reason = f'host custom-call "{target}"'
            if reason is None and "S(5)" in line:
                reason = "buffer in host memory space S(5)"
            if reason is not None:
                sites.append({"op": op, "computation": cname,
                              "reason": reason,
                              "line": line.strip()[:160]})
    return sites


def float_intermediate_sites(hlo: str, min_elems: int) -> List[Dict]:
    """Float-typed intermediates of at least ``min_elems`` elements in any
    *executed* computation (entry + while bodies/conditions; fusion bodies
    are interior and excluded — their results are what the fusion op line
    already shows).

    The dequant-placement audit feeds this the int8 pool size: a bf16/f32
    intermediate within a size factor of the pool means a cache plane was
    dequantized wholesale instead of windowed inside the kernel.
    """
    comps = _split_computations(hlo)
    entry, trip, mult, exec_comps, unresolved = _entry_and_mult(hlo, comps)
    skip = _NO_TRAFFIC | {"copy", "convert-done"}
    sites = []
    for cname in exec_comps:
        for line in comps[cname]:
            om = _OP_RE.match(line)
            if not om:
                continue
            name, type_str, op = om.group(1), om.group(2), om.group(3)
            if op in skip:
                continue
            best = None
            for dtype, dims in _SHAPE_RE.findall(type_str):
                if dtype not in FLOAT_DTYPES:
                    continue
                n = 1
                if dims:
                    for d in dims.split(","):
                        n *= int(d)
                if n >= min_elems and (best is None or n > best[1]):
                    best = (dtype, n)
            if best is None:
                continue
            nm = _OP_NAME_RE.search(line)
            sites.append({"op": op, "name": name, "computation": cname,
                          "dtype": best[0], "elems": best[1],
                          "bytes": best[1] * _DTYPE_BYTES[best[0]],
                          "op_name": nm.group(1) if nm else "",
                          "line": line.strip()[:160]})
    sites.sort(key=lambda s: -s["elems"])
    return sites
