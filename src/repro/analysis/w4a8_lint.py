"""w4a8 funnel lint: no serve-path module may matmul a param that has a
packed w4a8 export — a silent bf16 fallback would quietly restore the
weight-HBM streaming the w4a8 layout exists to remove.

Lives in ``repro.analysis`` so the serve-graph auditor can run the static
half as a rule; ``tools/check_w4a8_lint.py`` is a thin shim over ``main``
so the existing CI lint step keeps working unchanged.

Two independent checks:

1. **Static (AST).** Every ``jnp.einsum`` call in the serve-path modules
   (``src/repro/models``, ``src/repro/core/qat.py``) whose operands touch a
   weight — a ``...["w"]`` subscript or a ``quantize_weight_p`` result,
   tracked through same-function assignments — must sit inside a
   whitelisted function:

   * ``qlinear`` — the single funnel; its einsum is the bf16 branch behind
     the ``weights_layout`` dispatch
   * ``_expert_linear`` — MoE expert banks batch over the expert axis and
     have no packed export (``attach_w4a8_exports`` skips them)

   Attention/routing einsums (activations only) pass untouched.

2. **Runtime (NaN poison).** Build a tiny attention engine with
   ``weights_layout="w4a8"``, then poison the bf16 ``w`` of every linear
   that carries a ``w4a8`` export with NaN and serve the same workload
   twice (clean vs poisoned) — exercising batched admission, chunked
   tail-wave prefill, decode, and the spec verify-wave. Identical token
   streams prove no serve-path matmul read a bf16 weight (one NaN read
   would reach the logits). The tied embedding table stays clean: the
   embedding *lookup* is a legitimate bf16 read; its matmul use is covered
   by the head's packed export.

Usage::

    python tools/check_w4a8_lint.py [repo_root]
"""
from __future__ import annotations

import ast
from pathlib import Path

ALLOWED_FUNCS = {"qlinear", "_expert_linear"}
SERVE_PATH_GLOBS = ("src/repro/models/*.py", "src/repro/core/qat.py")


def _is_weighty(node: ast.AST, weighty_names: set) -> bool:
    """Does this expression (transitively) read a weight param?"""
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Subscript)
                and isinstance(sub.slice, ast.Constant)
                and sub.slice.value == "w"):
            return True
        if (isinstance(sub, ast.Call)
                and isinstance(sub.func, (ast.Name, ast.Attribute))
                and (getattr(sub.func, "id", None) == "quantize_weight_p"
                     or getattr(sub.func, "attr", None)
                     == "quantize_weight_p")):
            return True
        if isinstance(sub, ast.Name) and sub.id in weighty_names:
            return True
    return False


def _check_file(path: Path):
    tree = ast.parse(path.read_text(), filename=str(path))
    bad = []

    class V(ast.NodeVisitor):
        def __init__(self):
            self.func_stack = []
            self.weighty = [set()]

        def _visit_func(self, node):
            self.func_stack.append(node.name)
            self.weighty.append(set())
            self.generic_visit(node)
            self.weighty.pop()
            self.func_stack.pop()

        visit_FunctionDef = visit_AsyncFunctionDef = _visit_func

        def visit_Assign(self, node):
            # name = <weight-reading expr>  -> taint the name
            if _is_weighty(node.value, self.weighty[-1]):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        self.weighty[-1].add(t.id)
            self.generic_visit(node)

        def visit_Call(self, node):
            f = node.func
            is_einsum = (isinstance(f, ast.Attribute) and f.attr == "einsum")
            if is_einsum:
                fn = self.func_stack[-1] if self.func_stack else "<module>"
                if fn not in ALLOWED_FUNCS and any(
                        _is_weighty(a, self.weighty[-1])
                        for a in node.args):
                    bad.append((path, node.lineno, fn))
            self.generic_visit(node)

    V().visit(tree)
    return bad


def check_static(root: Path):
    bad = []
    for pattern in SERVE_PATH_GLOBS:
        for path in sorted(root.glob(pattern)):
            bad.extend(_check_file(path))
    return bad


def check_runtime():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_reduced_config
    from repro.core.precision import parse_policy
    from repro.core.qat import calibrate_weight_scales
    from repro.models import init_params
    from repro.serve.engine import Request, ServeEngine

    cfg = get_reduced_config("qwen2.5-3b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    # calibrated scales make the check sound: with init placeholders the
    # bf16 fake-quant branch is degenerate (every weight rounds to zero and
    # all-NaN logits argmax to the same constant stream), so a fallback
    # read could escape detection
    params = calibrate_weight_scales(params, parse_policy("A8d-C8-W4"))

    def serve(p, poisoned):
        eng = ServeEngine(cfg, p, slots=2, cache_len=64, kv_layout="paged",
                          block_size=16, prefill_chunk=8,
                          weights_layout="w4a8", spec={"k": 2})
        if poisoned:
            # engine construction already packed the exports; now wreck
            # every exported linear's bf16 weight in place
            def wreck(tree):
                if isinstance(tree, dict):
                    if "w4a8" in tree and "w" in tree:
                        tree["w"] = jnp.full_like(tree["w"], jnp.nan)
                    for v in tree.values():
                        if isinstance(v, (dict, list, tuple)):
                            wreck(v)
                elif isinstance(tree, (list, tuple)):
                    for v in tree:
                        wreck(v)
            wreck(eng.params)
            wreck(eng.draft_params)
        reqs = [Request(uid=i, prompt=np.arange(20 + i, dtype=np.int32) % 60,
                        max_new_tokens=8) for i in range(4)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        return [list(r.generated) for r in reqs]

    clean = serve(params, poisoned=False)
    dirty = serve(params, poisoned=True)
    assert any(clean), "poison check served no tokens — workload broken"
    assert clean == dirty, (
        "serve path read a poisoned bf16 weight: clean stream "
        f"{clean} != poisoned stream {dirty}")
    return clean


def main(argv):
    root = Path(argv[1]) if len(argv) > 1 else Path(".")
    bad = check_static(root)
    for path, line, fn in bad:
        print(f"{path}:{line}: weight einsum outside whitelist (in {fn}); "
              "route it through qlinear so w4a8 dispatch covers it")
    if bad:
        return 1
    print("static: all weight einsums inside the qlinear funnel")
    streams = check_runtime()
    print(f"runtime: poisoned bf16 weights unread by the w4a8 serve path "
          f"({sum(len(s) for s in streams)} tokens bit-equal)")
    return 0
