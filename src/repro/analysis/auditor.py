"""Serve-graph auditor: run a rule set over every compiled wave.

``audit_engine`` enumerates a :class:`ServeEngine`'s live compiled
executables through ``engine.compiled_waves()`` (duck-typed — anything
with that surface audits), compiles each representative program from
abstract args, and checks every rule; ``audit_waves`` is the pure core
that also accepts synthetic wave dicts so seeded-violation tests can
feed crafted HLO. The result renders as a rule x wave matrix plus
violation details, and serializes to JSON for the CI artifact.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .rules import (Rule, Violation, default_retrace_budgets, default_rules)

_ENGINE_COL = "(engine)"


@dataclass
class AuditReport:
    waves: List[str]                      # wave labels, audit order
    rules: List[str]                      # rule names, audit order
    cells: Dict = field(default_factory=dict)   # (rule, wave) -> "ok"/"FAIL"
    violations: List[Violation] = field(default_factory=list)
    meta: Dict = field(default_factory=dict)
    unknown_dtypes: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def render(self) -> str:
        cols = self.rules
        rows = self.waves
        wave_w = max([len(w) for w in rows] + [4])
        col_ws = [max(len(c), 4) for c in cols]
        lines = []
        title = self.meta.get("title", "serve-graph audit")
        lines.append(f"== {title} ==")
        for k, v in sorted(self.meta.items()):
            if k != "title":
                lines.append(f"   {k}: {v}")
        if self.unknown_dtypes:
            lines.append(f"   unknown dtypes (skipped by byte model): "
                         f"{self.unknown_dtypes}")
        lines.append("")
        hdr = " " * (wave_w + 2) + "  ".join(
            c.ljust(w) for c, w in zip(cols, col_ws))
        lines.append(hdr)
        for wave in rows:
            cells = []
            for c, w in zip(cols, col_ws):
                cells.append(self.cells.get((c, wave), "-").ljust(w))
            lines.append(wave.ljust(wave_w + 2) + "  ".join(cells))
        if self.violations:
            lines.append("")
            lines.append(f"{len(self.violations)} violation(s):")
            for v in self.violations:
                lines.append(str(v))
        else:
            lines.append("")
            lines.append("clean: every wave passes every rule")
        return "\n".join(lines)

    def to_json(self) -> Dict:
        return {
            "ok": self.ok,
            "waves": self.waves,
            "rules": self.rules,
            "matrix": {rule: {wave: self.cells.get((rule, wave), "-")
                              for wave in self.waves}
                       for rule in self.rules},
            "violations": [{"rule": v.rule, "wave": v.wave,
                            "summary": v.summary, "sites": v.sites}
                           for v in self.violations],
            "unknown_dtypes": self.unknown_dtypes,
            "meta": self.meta,
        }


def audit_waves(waves: List[Dict], rules: Optional[List[Rule]] = None,
                ctx: Optional[Dict] = None) -> AuditReport:
    """Pure rule evaluation over compiled wave dicts.

    ``waves``: [{family, label, hlo, donated: [...]}, ...] — what
    ``audit_engine`` builds, or synthetic equivalents in tests.
    ``ctx``: engine-level facts rules read (pool_elems, tp,
    variant_counts, variant_signatures, budgets, weights_layout).
    """
    rules = default_rules() if rules is None else rules
    ctx = ctx or {}
    wave_rules = [r for r in rules if r.scope == "wave"]
    engine_rules = [r for r in rules if r.scope == "engine"]
    labels = [w["label"] for w in waves]
    report = AuditReport(
        waves=labels + ([_ENGINE_COL] if engine_rules else []),
        rules=[r.name for r in rules])
    unknown: set = set()
    for wave in waves:
        # surface skipped dtype tokens from the shared parser substrate
        from repro.runtime.hlo_analysis import analyze_collectives
        unknown.update(analyze_collectives(wave["hlo"])["unknown_dtypes"])
        for rule in wave_rules:
            vs = rule.check(wave, ctx)
            report.cells[(rule.name, wave["label"])] = \
                "FAIL" if vs else "ok"
            report.violations.extend(vs)
    for rule in engine_rules:
        vs = rule.check_engine(ctx)
        report.cells[(rule.name, _ENGINE_COL)] = "FAIL" if vs else "ok"
        report.violations.extend(vs)
    report.unknown_dtypes = sorted(unknown)
    return report


def engine_audit_ctx(engine, budgets: Optional[Dict[str, int]] = None
                     ) -> Dict:
    """Engine-level facts for the rule set (duck-typed engine surface)."""
    return {
        "pool_elems": engine.pool_shard_elems(),
        "tp": getattr(engine, "tp", 1),
        "variant_counts": engine.compile_variant_counts(),
        "variant_signatures": engine.wave_variant_signatures(),
        "budgets": (budgets if budgets is not None
                    else default_retrace_budgets(engine)),
        "weights_layout": getattr(engine, "weights_layout", "bf16"),
    }


def audit_engine(engine, rules: Optional[List[Rule]] = None,
                 budgets: Optional[Dict[str, int]] = None,
                 buckets: int = 1) -> AuditReport:
    """Compile every live wave family abstractly and audit it.

    Compiling from ``ShapeDtypeStruct``s materializes nothing and leaves
    the engine's serving jits (and their variant counts) untouched.
    ``budgets`` overrides the engine-derived retrace budgets; ``buckets``
    widens the admission-length enumeration (see ``compiled_waves``).
    """
    ctx = engine_audit_ctx(engine, budgets)
    waves = []
    for w in engine.compiled_waves(buckets=buckets):
        hlo = w["lower"]().compile().as_text()
        waves.append({**w, "hlo": hlo})
    report = audit_waves(waves, rules, ctx)
    report.meta.update({
        "tp": ctx["tp"],
        "weights_layout": ctx["weights_layout"],
        "pool_elems": ctx["pool_elems"],
        "compile_variants": ctx["variant_counts"],
        "budgets": ctx["budgets"],
    })
    return report
