"""Static serve-graph analysis: compiled-HLO invariant rules + auditor.

The parsing substrate lives in ``repro.runtime.hlo_analysis``; this
package layers the pluggable rule set (:mod:`repro.analysis.rules`), the
per-engine auditor (:mod:`repro.analysis.auditor`), and the w4a8 funnel
lint (:mod:`repro.analysis.w4a8_lint`) on top. ``tools/audit_serve.py``
is the CLI entry; ``docs/architecture.md`` documents the invariants.
"""
from .auditor import (AuditReport, audit_engine, audit_waves,
                      engine_audit_ctx)
from .rules import (CollectiveCensusRule, DequantPlacementRule,
                    DonationRule, HostTransferRule, RetraceBudgetRule,
                    Rule, Violation, W4A8FunnelRule,
                    default_retrace_budgets, default_rules)

__all__ = [
    "AuditReport", "audit_engine", "audit_waves", "engine_audit_ctx",
    "Rule", "Violation", "DonationRule", "HostTransferRule",
    "DequantPlacementRule", "RetraceBudgetRule", "CollectiveCensusRule",
    "W4A8FunnelRule", "default_rules", "default_retrace_budgets",
]
