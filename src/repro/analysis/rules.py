"""Pluggable static rules over a serve wave's compiled HLO.

Each rule checks one compiled-graph invariant the engine's performance
claims rest on (see ``docs/architecture.md`` — "compiled-graph
invariants"). A rule is an object with

  name        — row label in the audit matrix
  scope       — "wave" (checked against every compiled wave) or "engine"
                (checked once against engine-level context)
  check(wave, ctx)         (wave scope)
  check_engine(ctx)        (engine scope)

both returning a list of :class:`Violation`. ``wave`` is a plain dict
from ``ServeEngine.compiled_waves()`` with the compiled HLO text added
under ``"hlo"``; ``ctx`` carries engine-level facts (see
``auditor.audit_engine``). Rules must never mutate either.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional

from repro.runtime.hlo_analysis import (collective_counts, entry_parameters,
                                        float_intermediate_sites,
                                        host_transfer_sites,
                                        input_output_aliases,
                                        pool_allgather_sites)

# numpy dtype name -> HLO dtype token, for matching donated pytree leaves
# against entry-parameter shapes in the compiled module
_HLO_DTYPE = {
    "bool": "pred", "int8": "s8", "uint8": "u8", "int16": "s16",
    "uint16": "u16", "int32": "s32", "uint32": "u32", "int64": "s64",
    "uint64": "u64", "float16": "f16", "bfloat16": "bf16",
    "float32": "f32", "float64": "f64",
}


@dataclass
class Violation:
    rule: str
    wave: str                       # wave label, or "(engine)"
    summary: str
    sites: List[str] = field(default_factory=list)   # op names / details

    def __str__(self):
        out = f"[{self.rule}] {self.wave}: {self.summary}"
        for s in self.sites[:8]:
            out += f"\n    - {s}"
        if len(self.sites) > 8:
            out += f"\n    ... and {len(self.sites) - 8} more"
        return out


class Rule:
    name = "rule"
    scope = "wave"

    def check(self, wave: Dict, ctx: Dict) -> List[Violation]:
        return []

    def check_engine(self, ctx: Dict) -> List[Violation]:
        return []


class DonationRule(Rule):
    """Every large donated input must appear in the executable's
    input-output alias table.

    A donated buffer XLA silently declined to alias is copied instead of
    reused — a transient 2x of that buffer (for the int8 pool, the exact
    regression paging exists to avoid). Donated leaves below ``min_bytes``
    are ignored (scalar counters are donated for convenience, not HBM).
    Leaked leaves are named by matching the donated inventory against the
    aliased entry parameters on (dtype, per-device bytes) — robust to XLA
    pruning unused params and renumbering the rest.
    """
    name = "donation"

    def __init__(self, min_bytes: int = 1 << 16):
        self.min_bytes = min_bytes

    def check(self, wave, ctx):
        big = [d for d in wave.get("donated", ())
               if d["bytes"] >= self.min_bytes]
        if not big:
            return []
        hlo = wave["hlo"]
        aliased_nums = {a["param"] for a in input_output_aliases(hlo)}
        aliased_sizes = Counter(
            (p["dtype"], p["bytes"]) for p in entry_parameters(hlo)
            if p["num"] in aliased_nums and p["bytes"] >= self.min_bytes)
        leaked = []
        for leaf in big:
            key = (_HLO_DTYPE.get(leaf["dtype"], leaf["dtype"]),
                   leaf["bytes"])
            if aliased_sizes[key] > 0:
                aliased_sizes[key] -= 1
            else:
                leaked.append(leaf)
        if not leaked:
            return []
        total = sum(d["bytes"] for d in leaked)
        return [Violation(
            self.name, wave["label"],
            f"{len(leaked)}/{len(big)} large donated leaves not in the "
            f"alias table — {total} bytes copied per call instead of "
            "reused in place",
            [f"{d['path']} ({d['dtype']}, {d['bytes']} B)"
             for d in sorted(leaked, key=lambda d: -d['bytes'])])]


class HostTransferRule(Rule):
    """No d2h/h2d copies, infeed/outfeed, or host custom-calls inside any
    wave body — one hidden host sync serializes the whole step loop."""
    name = "host-transfer"

    def check(self, wave, ctx):
        sites = host_transfer_sites(wave["hlo"])
        if not sites:
            return []
        return [Violation(
            self.name, wave["label"],
            f"{len(sites)} host-transfer site(s) inside the compiled wave",
            [f"{s['computation']}: {s['reason']} — {s['line'][:100]}"
             for s in sites])]


class DequantPlacementRule(Rule):
    """No f32/bf16 intermediate within ``frac`` of the int8 pool plane.

    The A8-C8-W4 memory win requires pool reads to dequantize windowed
    inside kernels; a float intermediate rivaling a full cache plane
    means a plane was dequantized wholesale (the fused-kernel funnel got
    bypassed). Reference size: ``ctx["pool_elems"]``, the per-device
    element count of the largest int8 cache plane.
    """
    name = "dequant-placement"

    def __init__(self, frac: float = 0.5):
        self.frac = frac

    def check(self, wave, ctx):
        pool = int(ctx.get("pool_elems", 0))
        if pool <= 0:
            return []
        min_elems = max(int(self.frac * pool), 1)
        sites = float_intermediate_sites(wave["hlo"], min_elems)
        if not sites:
            return []
        return [Violation(
            self.name, wave["label"],
            f"{len(sites)} float intermediate(s) >= {min_elems} elems "
            f"(pool plane {pool} elems x frac {self.frac}) — a cache "
            "plane is being dequantized outside the kernel window",
            [f"%{s['name']} = {s['dtype']}[{s['elems']}] {s['op']} in "
             f"{s['computation']}"
             + (f" ({s['op_name'][-60:]})" if s['op_name'] else "")
             for s in sites[:12]])]


class RetraceBudgetRule(Rule):
    """Each wave family stays within its declared compile-variant budget.

    Budgets are the combinatoric bounds of the engine's bucketing
    discipline (power-of-two batch pads, length buckets, boolean
    statics); exceeding one means a shape leaked past a bucket and every
    such call pays a multi-second recompile mid-serve. The offending
    shape signatures (recorded live by the engine's wave registry) are
    named.
    """
    name = "retrace-budget"
    scope = "engine"

    def __init__(self, budgets: Optional[Dict[str, int]] = None):
        self.budgets = budgets

    def check_engine(self, ctx):
        budgets = self.budgets if self.budgets is not None \
            else ctx.get("budgets", {})
        counts = ctx.get("variant_counts", {})
        sigs = ctx.get("variant_signatures", {})
        out = []
        for family, count in sorted(counts.items()):
            budget = budgets.get(family)
            if budget is None or count <= budget:
                continue
            over = sigs.get(family, [])[budget:]
            out.append(Violation(
                self.name, "(engine)",
                f"wave family '{family}' compiled {count} variants, "
                f"budget {budget}",
                [f"variant {budget + i + 1}: {s}"
                 for i, s in enumerate(over)] or
                [f"{count - budget} variant(s) over budget "
                 "(signatures unavailable)"]))
        return out


class CollectiveCensusRule(Rule):
    """Only the canonical TP collectives, never an s8 pool gather.

    tp=1 waves must contain no collectives at all. tp>1 waves may use the
    row-parallel all-reduce / logit all-gather, but an s8/u8 all-gather
    over ``min_bytes`` is the signature of the sharded pool (or a packed
    weight plane) being accidentally regathered; and a tp>1 decode wave
    with *no* all-reduce means the TP sharding silently fell apart into
    replicated compute.
    """
    name = "collectives"

    def __init__(self, min_pool_bytes: int = 1 << 16):
        self.min_pool_bytes = min_pool_bytes

    def check(self, wave, ctx):
        tp = int(ctx.get("tp", 1) or 1)
        hlo = wave["hlo"]
        counts = collective_counts(hlo)
        out = []
        if tp <= 1:
            if counts:
                out.append(Violation(
                    self.name, wave["label"],
                    f"collectives in a single-device wave: {counts}"))
            return out
        bad = pool_allgather_sites(hlo, self.min_pool_bytes)
        if bad:
            out.append(Violation(
                self.name, wave["label"],
                f"{len(bad)} large s8/u8 all-gather(s) — the sharded int8 "
                "pool is being regathered",
                [s["line"][:120] for s in bad]))
        if wave["family"] == "decode" and not counts.get("all-reduce"):
            out.append(Violation(
                self.name, wave["label"],
                f"tp={tp} decode wave has no all-reduce — row-parallel "
                "TP compute is not actually partitioned"))
        return out


class W4A8FunnelRule(Rule):
    """Static half of the w4a8 lint as an audit rule: every weight einsum
    in the serve-path modules sits inside the ``qlinear`` funnel, so the
    packed-weight dispatch covers it. Runs only when the audited engine
    serves ``weights_layout="w4a8"`` (the funnel is what makes that
    layout sound)."""
    name = "w4a8-funnel"
    scope = "engine"

    def __init__(self, root: Optional[Path] = None):
        # repo root: src/repro/analysis/rules.py -> three parents up
        self.root = root or Path(__file__).resolve().parents[3]

    def check_engine(self, ctx):
        if ctx.get("weights_layout") != "w4a8":
            return []
        from .w4a8_lint import check_static
        bad = check_static(Path(self.root))
        if not bad:
            return []
        return [Violation(
            self.name, "(engine)",
            f"{len(bad)} weight einsum(s) outside the qlinear funnel",
            [f"{path}:{line} (in {fn})" for path, line, fn in bad])]


def _pow2_variants(n: int) -> int:
    """How many distinct power-of-two pads a dimension in [1, n] can take."""
    seen = set()
    p = 1
    while p < max(n, 1):
        seen.add(p)
        p *= 2
    seen.add(p)
    return len(seen)


def default_retrace_budgets(engine) -> Dict[str, int]:
    """Combinatoric variant bounds implied by the engine's bucketing
    discipline. Every count the discipline permits is budgeted; one more
    means a shape leaked past a bucket."""
    slots = engine.slots
    len_buckets = max(-(-engine.max_seq_len // engine.prefill_bucket), 1) \
        if getattr(engine, "max_seq_len", None) else 8
    budgets = {
        "decode": 2,                      # greedy_only in {False, True}
        "admit_dense": 2 * _pow2_variants(slots) * len_buckets,
        "admit_paged": 2 * _pow2_variants(slots) * len_buckets,
    }
    if getattr(engine, "_paged", False):
        tbl = engine.table_len
        budgets["tail"] = _pow2_variants(tbl)          # hb buckets
        budgets["swap_in"] = _pow2_variants(tbl)       # m_pad buckets
        budgets["cow"] = _pow2_variants(engine.num_blocks)
    if getattr(engine, "spec", None) is not None:
        tbl = engine.table_len
        budgets["spec_draft"] = 2
        budgets["spec_verify"] = 2 * _pow2_variants(tbl)
        budgets["admit_draft"] = _pow2_variants(slots) * len_buckets
    return budgets


def default_rules() -> List[Rule]:
    return [DonationRule(), HostTransferRule(), DequantPlacementRule(),
            RetraceBudgetRule(), CollectiveCensusRule(), W4A8FunnelRule()]
