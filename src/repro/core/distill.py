"""Knowledge-distillation loss for SiLQ (paper §3.1, ablations Table 4).

The teacher is the original unquantized model; the student is the quantized
model. The paper's best configuration is *pure* KD (kd_ratio=1.0) at
temperature 1. ``kd_ratio``/``kd_temperature`` are kept configurable for the
Table-4 ablations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kd_loss(student_logits: jnp.ndarray, teacher_logits: jnp.ndarray,
            temperature: float = 1.0,
            mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Soft cross-entropy against teacher distribution at ``temperature``.

    Scaled by T^2 (Hinton et al., 2015) so gradient magnitude is
    temperature-invariant. Shapes: (..., vocab); mask broadcasts over (...).
    """
    t = jnp.float32(temperature)
    sl = student_logits.astype(jnp.float32) / t
    tl = jax.lax.stop_gradient(teacher_logits.astype(jnp.float32)) / t
    log_p_s = jax.nn.log_softmax(sl, axis=-1)
    p_t = jax.nn.softmax(tl, axis=-1)
    ce = -jnp.sum(p_t * log_p_s, axis=-1) * (t * t)
    return _masked_mean(ce, mask)


def next_token_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                    mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Standard next-token cross entropy (labels already shifted)."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return _masked_mean(logz - gold, mask)


def silq_loss(student_logits: jnp.ndarray, teacher_logits: jnp.ndarray,
              labels: jnp.ndarray, kd_ratio: float = 1.0,
              kd_temperature: float = 1.0,
              mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """kd_ratio * KD + (1 - kd_ratio) * next-token CE (paper default 1.0)."""
    loss = 0.0
    if kd_ratio > 0.0:
        loss = kd_ratio * kd_loss(student_logits, teacher_logits,
                                  kd_temperature, mask)
    if kd_ratio < 1.0:
        loss = loss + (1.0 - kd_ratio) * next_token_loss(
            student_logits, labels, mask)
    return loss


def _masked_mean(x: jnp.ndarray, mask: jnp.ndarray | None) -> jnp.ndarray:
    if mask is None:
        return jnp.mean(x)
    m = mask.astype(jnp.float32)
    return jnp.sum(x * m) / jnp.maximum(jnp.sum(m), 1.0)
