"""SiLQ core: quantizers, calibration, precision policies, distillation."""
from repro.core.distill import kd_loss, next_token_loss, silq_loss
from repro.core.precision import PAPER_POLICIES, PrecisionPolicy, parse_policy
from repro.core.qat import QuantCtx, make_ctx, qlinear, quantize_act
from repro.core.quantizer import (dynamic_fake_quant, lsq_fake_quant, qbounds,
                                  round_ste)

__all__ = [
    "kd_loss", "next_token_loss", "silq_loss",
    "PAPER_POLICIES", "PrecisionPolicy", "parse_policy",
    "QuantCtx", "make_ctx", "qlinear", "quantize_act",
    "dynamic_fake_quant", "lsq_fake_quant", "qbounds", "round_ste",
]
