"""Step-size calibration: percentile (activations) and convex-MSE (weights).

Paper §3.1:

* activations — step size set to the value at the 99.91 / 99.99 / 99.995
  percentile of |x| for 4- / 8- / 16-bit, over 5 calibration batches.
* weights — novel convex approximation of quantization MSE (Eq. 2)::

      eps_hat(s) = sum_i max(s^2/12, H(|w_i| - s*b) * (|w_i| - s*b)^2)

  with ``b = 2^{p-1} - 0.5``. Convex in ``s`` -> minimized by ternary search.
* LSQ-paper initialization (``2<|w|>/sqrt(b_u)``) kept for the Table-4
  ablation.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizer import qbounds

# paper-specified |x| percentiles per activation precision
ACT_PERCENTILE = {4: 99.91, 8: 99.99, 16: 99.995}


def act_percentile_stat(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Per-batch percentile statistic for an activation site (fp32 scalar)."""
    q = ACT_PERCENTILE[bits] / 100.0
    return jnp.quantile(jnp.abs(x.astype(jnp.float32)).reshape(-1), q)


def act_max_stat(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Max-|x| statistic (the paper's ablation baseline)."""
    del bits
    return jnp.max(jnp.abs(x.astype(jnp.float32)))


def act_scale_from_stat(stat: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Step size from a calibrated |x| landmark: s = landmark / b_u."""
    _, qp = qbounds(bits)
    return jnp.maximum(stat / qp, 1e-9)


# --------------------------------------------------------------------------
# Convex-MSE weight calibration (paper Eq. 2)
# --------------------------------------------------------------------------

def mse_objective(absw: jnp.ndarray, s: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Eq. 2 evaluated per channel.

    ``absw``: (..., n) |w| grouped so the last axis shares one step size.
    ``s``: (...,) candidate step sizes. Returns (...,) objective values.
    """
    b = 2.0 ** (bits - 1) - 0.5
    s_ = s[..., None]
    over = jnp.maximum(absw - s_ * b, 0.0)          # H(|w|-sb)(|w|-sb) >= 0
    return jnp.sum(jnp.maximum(s_ ** 2 / 12.0, over ** 2), axis=-1)


def mse_weight_scale(w: jnp.ndarray, bits: int, channel_last: bool = True,
                     iters: int = 64) -> jnp.ndarray:
    """Minimize Eq. 2 per output channel by ternary search (convex in s).

    ``w``: (..., d_in, d_out) -> scales shaped (..., 1, d_out).
    For s >= max|w|/b the clip term vanishes and the objective grows like
    n*s^2/12, so the optimum lies in (0, max|w|/b]; ternary search on that
    bracket converges geometrically (ratio (2/3)^iters).
    """
    wf = jnp.abs(w.astype(jnp.float32))
    if channel_last and w.ndim >= 2:
        absw = jnp.moveaxis(wf, -2, -1)             # (..., d_out, d_in)
    else:
        absw = wf.reshape(-1)[None, :]              # single group
    b = 2.0 ** (bits - 1) - 0.5
    hi = jnp.maximum(jnp.max(absw, axis=-1) / b, 1e-8)
    lo = jnp.full_like(hi, 1e-9)

    def body(_, bracket):
        lo, hi = bracket
        m1 = lo + (hi - lo) / 3.0
        m2 = hi - (hi - lo) / 3.0
        f1 = mse_objective(absw, m1, bits)
        f2 = mse_objective(absw, m2, bits)
        lo = jnp.where(f1 > f2, m1, lo)
        hi = jnp.where(f1 > f2, hi, m2)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    s = (lo + hi) / 2.0                             # (..., d_out)
    if channel_last and w.ndim >= 2:
        return s[..., None, :]                      # (..., 1, d_out)
    return s.reshape(())


def lsq_weight_scale(w: jnp.ndarray, bits: int,
                     channel_last: bool = True) -> jnp.ndarray:
    """LSQ-paper initialization: s = 2 * mean|w| / sqrt(b_u) (ablation)."""
    _, qp = qbounds(bits)
    wf = jnp.abs(w.astype(jnp.float32))
    if channel_last and w.ndim >= 2:
        mean = jnp.mean(wf, axis=-2, keepdims=True)  # (..., 1, d_out)
    else:
        mean = jnp.mean(wf)
    return jnp.maximum(2.0 * mean / jnp.sqrt(float(qp)), 1e-9)


def weight_scale(w: jnp.ndarray, bits: int, method: str = "mse") -> jnp.ndarray:
    if method == "mse":
        return mse_weight_scale(w, bits)
    if method == "lsq":
        return lsq_weight_scale(w, bits)
    raise ValueError(f"unknown weight calibration method {method!r}")
