"""Weight-rotation analysis (paper §3.4 / Fig. 3) and a QuaRot/SpinQuant-
style rotation PTQ transform to compare against.

Procrustes factorization of a weight change A -> B:
    d_p(A,B)   = min_R ||RA - B||_F  (left)  or  min_R ||AR - B||_F (right)
               = sqrt(||A||^2 + ||B||^2 - 2 * sum(svdvals(B A^T)))
    non-rotational distance = min(d_p_left, d_p_right)
    rotational distance     = d_F(A,B) - non-rotational
both normalized by ||A||_F. SiLQ's claim: its weight changes are ~43%
rotational vs ~90% for SpinQuant — i.e. QAT finds solutions rotation-based
PTQ cannot.

The rotation transform here is the *exactly function-preserving* residual
rotation (R1 of SpinQuant) for RMSNorm transformers: fold norm scales into
the adjacent linears (RMSNorm is then rotation-equivariant), then rotate
the residual stream basis with a random orthogonal R.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTENTION_BLOCKS, ModelConfig
from repro.models.model import segment_plan


# --------------------------------------------------------------------------
# Procrustes distances
# --------------------------------------------------------------------------

def procrustes_distances(A: jnp.ndarray, B: jnp.ndarray) -> Dict[str, float]:
    """Rotational / non-rotational / total distance, normalized by ||A||."""
    A = np.asarray(A, np.float64)
    B = np.asarray(B, np.float64)
    nA = np.linalg.norm(A)
    total = np.linalg.norm(B - A)
    sq = np.linalg.norm(A) ** 2 + np.linalg.norm(B) ** 2

    def d_p(M):   # M = B A^T (left) or A^T B (right)
        s = np.linalg.svd(M, compute_uv=False)
        return float(np.sqrt(max(sq - 2.0 * s.sum(), 0.0)))

    non_rot = min(d_p(B @ A.T), d_p(A.T @ B))
    return {"total": float(total / nA),
            "non_rotational": float(non_rot / nA),
            "rotational": float(max(total - non_rot, 0.0) / nA)}


# --------------------------------------------------------------------------
# Function-preserving residual rotation (R1)
# --------------------------------------------------------------------------

def random_rotation(d: int, key) -> jnp.ndarray:
    q, r = jnp.linalg.qr(jax.random.normal(key, (d, d), jnp.float32))
    return q * jnp.sign(jnp.diagonal(r))[None, :]   # proper orthonormal


def _fold_norm_into(norm_p: Dict, linears) -> None:
    """W' = diag(norm_w) @ W; norm_w := 1 (RMSNorm becomes rotation-equiv)."""
    nw = norm_p["w"].astype(jnp.float32)            # (rep, d) or (d,)
    for lin in linears:
        w = lin["w"].astype(jnp.float32)
        lin["w"] = (w * nw[..., :, None]).astype(lin["w"].dtype)
    norm_p["w"] = jnp.ones_like(norm_p["w"])


def _rot_in(lin: Dict, R: jnp.ndarray) -> None:
    """Reading the rotated residual: W' = R^T W (input-side)."""
    w = lin["w"].astype(jnp.float32)
    lin["w"] = jnp.einsum("dk,...do->...ko", R, w).astype(lin["w"].dtype)


def _rot_out(lin: Dict, R: jnp.ndarray) -> None:
    """Writing to the rotated residual: W' = W R (output-side)."""
    w = lin["w"].astype(jnp.float32)
    lin["w"] = jnp.einsum("...do,ok->...dk", w, R).astype(lin["w"].dtype)


def rotate_residual(cfg: ModelConfig, params: Dict, key) -> Dict:
    """Fold norms, then rotate the residual-stream basis. Only supported for
    rms-norm attention/MoE decoder families (the paper's setting)."""
    assert cfg.norm_type == "rms" and not cfg.is_encdec
    params = jax.tree.map(lambda x: x, params)
    R = random_rotation(cfg.d_model, key)

    # embedding / head / final norm
    emb = dict(params["embed"])
    emb["w"] = (emb["w"].astype(jnp.float32) @ R).astype(emb["w"].dtype)
    params["embed"] = emb
    fn = dict(params["final_norm"])
    if not cfg.tie_embeddings:
        head = dict(params["head"])
        _fold_norm_into(fn, [head])
        _rot_in(head, R)
        params["head"] = head
    else:
        # tied head reads embed^T: folding final norm would break the tie;
        # keep final norm (rotation-equivariant part is exact anyway)
        pass
    params["final_norm"] = fn

    for seg_i, (kinds, rep) in enumerate(segment_plan(cfg)):
        seg = params["segments"][seg_i]
        for i, kind in enumerate(kinds):
            if kind not in ATTENTION_BLOCKS:
                raise NotImplementedError(
                    "residual rotation targets attention families")
            blk = seg[str(i)]
            attn = {k: dict(v) if isinstance(v, dict) else v
                    for k, v in blk["attn"].items()}
            _fold_norm_into(blk["ln1"], [attn["wq"], attn["wk"], attn["wv"]])
            _rot_in(attn["wq"], R)
            _rot_in(attn["wk"], R)
            _rot_in(attn["wv"], R)
            _rot_out(attn["wo"], R)
            blk["attn"] = attn
            mlp_key = "moe" if cfg.is_moe else "mlp"
            mlp = {k: dict(v) if isinstance(v, dict) else v
                   for k, v in blk[mlp_key].items()}
            if cfg.is_moe:
                _fold_norm_into(blk["ln2"], [mlp["router"]])
                # note: norm already folded into router; expert weights get
                # the rotation only (they share the same normed input)
                _rot_in(mlp["router"], R)
                for k in ("wg", "wu"):
                    _rot_in(mlp[k], R)
                _rot_out(mlp["wd"], R)
            else:
                _fold_norm_into(blk["ln2"], [mlp["wg"], mlp["wu"]])
                _rot_in(mlp["wg"], R)
                _rot_in(mlp["wu"], R)
                _rot_out(mlp["wd"], R)
            blk[mlp_key] = mlp
    return params


# --------------------------------------------------------------------------
# Per-layer-type rotation report (Fig. 3)
# --------------------------------------------------------------------------

_LAYER_TYPES = ("wq", "wk", "wg", "wu", "wd")   # v/o omitted (paper §3.4)


def rotation_report(cfg: ModelConfig, params_before: Dict,
                    params_after: Dict) -> Dict[str, Dict[str, float]]:
    """Average rotational / non-rotational distance by layer type."""
    out: Dict[str, list] = {k: [] for k in _LAYER_TYPES}
    for seg_i, (kinds, rep) in enumerate(segment_plan(cfg)):
        for i, kind in enumerate(kinds):
            if kind not in ATTENTION_BLOCKS:
                continue
            b0 = params_before["segments"][seg_i][str(i)]
            b1 = params_after["segments"][seg_i][str(i)]
            for group, sub in (("attn", ("wq", "wk")),
                               ("moe" if cfg.is_moe else "mlp",
                                ("wg", "wu", "wd"))):
                for name in sub:
                    if name not in b0.get(group, {}):
                        continue
                    w0 = np.asarray(b0[group][name]["w"], np.float32)
                    w1 = np.asarray(b1[group][name]["w"], np.float32)
                    for r in range(w0.shape[0]):   # per scanned layer
                        a, b = w0[r], w1[r]
                        if a.ndim == 3:            # MoE experts: average
                            for e in range(a.shape[0]):
                                out[name].append(
                                    procrustes_distances(a[e], b[e]))
                        else:
                            out[name].append(procrustes_distances(a, b))
    report = {}
    for name, ds in out.items():
        if not ds:
            continue
        report[name] = {k: float(np.mean([d[k] for d in ds]))
                        for k in ("total", "rotational", "non_rotational")}
    return report
