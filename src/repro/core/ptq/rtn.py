"""Round-to-nearest PTQ baseline: calibrate scales, no training.

The weakest baseline in the paper's comparison set: per-output-channel
weight scales (same convex-MSE calibration as SiLQ — isolating the value of
*training* from the value of *calibration*), percentile activation scales
from calibration data, then freeze. Produces a params tree directly usable
by the quantized forward (identical format to a QAT checkpoint, minus the
learning)."""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.precision import PrecisionPolicy
from repro.core.qat import calibrate_weight_scales, make_ctx, merge_act_scales
from repro.models import forward


def rtn_quantize(cfg: ModelConfig, params: Dict, policy: PrecisionPolicy,
                 calib_batches: List[Dict], *,
                 wgt_method: str = "mse",
                 act_method: str = "quantile") -> Dict:
    params = calibrate_weight_scales(params, policy, wgt_method)
    if policy.enabled and policy.acts_static and calib_batches:
        ctx = make_ctx(policy, mode="calib", act_calib_method=act_method)
        stats = []
        fwd = jax.jit(lambda p, b: forward(cfg, p, ctx, b,
                                           collect_stats=True)[1]["qstats"])
        for b in calib_batches:
            stats.append(fwd(params, {"tokens": jnp.asarray(b["tokens"])}))
        params = merge_act_scales(params, stats, policy)
    return params
