"""SmoothQuant PTQ baseline (Xiao et al., 2023), as compared in Table 1.

Per-channel smoothing factors migrate activation outliers into the weights
before round-to-nearest quantization::

    s_j = max|X_j|^alpha / max|W_j|^(1-alpha)        (SiLQ App. D: alpha=0.4)
    X' = X / s   — folded into the producing norm's scale
    W' = W * s   — folded into the consuming linear's rows

Folding sites mirror the reference implementation: attention input norm ->
wq/wk/wv, MLP input norm -> wg/wu (or w1); for the recurrent families the
analogous (norm -> input-projection) pairs. Per-channel activation maxima
come from real calibration batches via the ``chan_max`` stats collector.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.precision import PrecisionPolicy
from repro.core.ptq.rtn import rtn_quantize
from repro.core.qat import make_ctx
from repro.models import forward
from repro.models.model import segment_plan


def _get(tree, path: str):
    for k in path.split("/"):
        if not isinstance(tree, dict) or k not in tree:
            return None
        tree = tree[k]
    return tree


def collect_chan_maxima(cfg: ModelConfig, params: Dict,
                        calib_batches: List[Dict]) -> Dict:
    """Stats tree whose ``s_in`` leaves are per-channel |x| maxima."""
    ctx = make_ctx("A8s-C8-W4", mode="calib", act_calib_method="chan_max")
    fwd = jax.jit(lambda p, b: forward(cfg, p, ctx, b,
                                       collect_stats=True)[1]["qstats"])
    agg = None
    for b in calib_batches:
        stats = fwd(params, {"tokens": jnp.asarray(b["tokens"])})
        agg = stats if agg is None else jax.tree.map(jnp.maximum, agg, stats)
    return agg


# (norm key, linear keys smoothing-folded against it) per block kind
def _pairs_for(cfg: ModelConfig, kind: str, blk: Dict):
    pairs = []
    if kind in ("attn", "local_attn"):
        pairs.append(("ln1", ["attn/wq", "attn/wk", "attn/wv"]))
        if "mlp" in blk:
            pairs.append(("ln2", ["mlp/w1"] if cfg.mlp_type == "gelu"
                          else ["mlp/wg", "mlp/wu"]))
    elif kind == "rglru":
        pairs.append(("ln1", ["rglru/w_in", "rglru/w_gate"]))
        pairs.append(("ln2", ["mlp/wg", "mlp/wu"]))
    elif kind == "mlstm":
        pairs.append(("ln1", ["cell/w_up"]))
    elif kind == "slstm":
        pairs.append(("ln1", ["cell/w_x"]))
    return pairs


def fold_smoothing(cfg: ModelConfig, params: Dict, alpha: float,
                   calib_batches: List[Dict]) -> Dict:
    """Returns a new params tree with smoothing folded in."""
    params = jax.tree.map(lambda x: x, params)   # fresh containers
    stats = collect_chan_maxima(cfg, params, calib_batches) \
        if calib_batches else None

    plan = segment_plan(cfg)
    for seg_i, (kinds, rep) in enumerate(plan):
        seg = params["segments"][seg_i]
        seg_stats = (stats["segments"][seg_i] if stats else None)
        for i, kind in enumerate(kinds):
            blk = seg[str(i)]
            blk_stats = seg_stats[str(i)] if seg_stats else None
            for norm_key, lin_keys in _pairs_for(cfg, kind, blk):
                if norm_key not in blk:
                    continue
                lins = [(k, _get(blk, k)) for k in lin_keys]
                lins = [(k, l) for k, l in lins if l is not None]
                if not lins:
                    continue
                nw = blk[norm_key]["w"].astype(jnp.float32)   # (rep, d)
                # activation per-channel maxima: measured, else norm proxy
                act_max = None
                if blk_stats is not None:
                    st = _get(blk_stats, lin_keys[0].split("/")[0])
                    st = st.get(lin_keys[0].split("/")[1], {}) \
                        if isinstance(st, dict) else {}
                    if isinstance(st, dict) and "s_in" in st:
                        act_max = st["s_in"].astype(jnp.float32)
                if act_max is None:
                    act_max = jnp.abs(nw)
                act_max = jnp.maximum(act_max, 1e-5)
                w_max = jnp.maximum(jnp.max(jnp.stack(
                    [jnp.max(jnp.abs(l["w"].astype(jnp.float32)), axis=-1)
                     for _, l in lins]), axis=0), 1e-5)       # (rep, d)
                s = jnp.clip(act_max ** alpha / w_max ** (1.0 - alpha),
                             1e-3, 1e3)
                blk[norm_key] = dict(blk[norm_key])
                blk[norm_key]["w"] = (nw / s).astype(params["embed"]["w"].dtype)
                for k, lin in lins:
                    parent = _get(blk, "/".join(k.split("/")[:-1]))
                    new_lin = dict(lin)
                    new_lin["w"] = (lin["w"].astype(jnp.float32)
                                    * s[..., :, None]).astype(lin["w"].dtype)
                    parent[k.split("/")[-1]] = new_lin
    return params


def smoothquant_quantize(cfg: ModelConfig, params: Dict,
                         policy: PrecisionPolicy,
                         calib_batches: List[Dict],
                         alpha: float = 0.4) -> Dict:
    """Full SmoothQuant pipeline: fold smoothing, then RTN quantize."""
    params = fold_smoothing(cfg, params, alpha, calib_batches)
    return rtn_quantize(cfg, params, policy, calib_batches)
