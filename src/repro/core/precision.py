"""Named precision policies: the paper's A-C-W notation.

``A8d-C8-W4`` = 8-bit token-dynamic activations, 8-bit KV cache, 4-bit
weights. ``A8s``. = static (learned per-tensor scale) activations. The fp16
baseline is ``A16-C16-W16`` with quantization disabled entirely.

Fixed site policies from the paper (§3.2, Fig. 2):
* head (final vocab linear): 8-bit input activations, 8-bit weights
* embedding: fp16 (never quantized)
* query into QK^T: INT16 static; softmax output: unquantized during training
  (flash-attention encapsulation), INT16 at deployment
* norms, rotaries, element-wise ops: fp16
* MoE router linear: 8-bit (accuracy-critical, tiny)
"""
from __future__ import annotations

import re
from dataclasses import dataclass


@dataclass(frozen=True)
class PrecisionPolicy:
    name: str
    enabled: bool = True
    act_bits: int = 8
    act_dynamic: bool = True          # 'd' vs 's'
    cache_bits: int = 8
    weight_bits: int = 4
    head_bits: int = 8                # head input + head weight
    query_bits: int = 16              # query operand of QK^T (static)
    softmax_out_bits: int = 16        # deploy-time only; not trained (flash)
    quantize_softmax_out: bool = False

    @property
    def acts_static(self) -> bool:
        return not self.act_dynamic


_PAT = re.compile(r"^A(\d+)([ds]?)-C(\d+)-W(\d+)$")


def parse_policy(name: str) -> PrecisionPolicy:
    """Parse 'A8d-C8-W4' style names; 'A16-C16-W16' disables quantization."""
    if name in ("A16-C16-W16", "fp16", "baseline", "none"):
        return PrecisionPolicy(name="A16-C16-W16", enabled=False,
                               act_bits=16, cache_bits=16, weight_bits=16,
                               head_bits=16)
    m = _PAT.match(name)
    if not m:
        raise ValueError(f"unparseable precision policy {name!r}")
    a, mode, c, w = int(m.group(1)), m.group(2) or "d", int(m.group(3)), int(m.group(4))
    return PrecisionPolicy(name=name, act_bits=a, act_dynamic=(mode == "d"),
                           cache_bits=c, weight_bits=w)


# the configurations demonstrated in the paper
PAPER_POLICIES = ("A8d-C8-W4", "A8s-C8-W4", "A8d-C4-W4", "A16-C16-W16")
