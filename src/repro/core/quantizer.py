"""SiLQ quantizers: STE fake-quantization and LSQ learned step sizes.

Implements paper Eq. 1::

    x_hat = round(clip(x / s, b_l, b_u)) * s

with the straight-through estimator for the round op and LSQ (Esser et al.,
2019) gradients for the step size ``s``. All quantization math runs in fp32
internally (bf16's 8-bit mantissa cannot represent 16-bit quantization
levels) and results are cast back to the input dtype.

Conventions
-----------
* symmetric signed integers: ``b_l = -2^{p-1}``, ``b_u = 2^{p-1} - 1``
* weights: one step size per *output* channel (last axis of ``w``)
* static activations / cache: one learned step size per tensor site
* dynamic activations: per-token absmax (stop-gradient through the scale)

The pure-jnp functions here are the reference semantics; the Pallas kernels
in ``repro.kernels.quant`` implement the identical fwd/bwd math for the TPU
hot path and are validated against these in tests.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

_EPS = 1e-9


def qbounds(bits: int) -> Tuple[int, int]:
    """Lower/upper integer bounds for symmetric signed quantization."""
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


def round_ste(x: jnp.ndarray) -> jnp.ndarray:
    """Round-to-nearest with a straight-through gradient."""
    return x + lax.stop_gradient(jnp.round(x) - x)


def _reduce_to_shape(t: jnp.ndarray, shape: Tuple[int, ...]) -> jnp.ndarray:
    """Sum-reduce ``t`` down to ``shape`` (inverse of broadcasting)."""
    if t.shape == tuple(shape):
        return t
    ndim_diff = t.ndim - len(shape)
    lead = tuple(range(ndim_diff))
    t = jnp.sum(t, axis=lead) if lead else t
    axes = tuple(i for i, d in enumerate(shape) if d == 1 and t.shape[i] != 1)
    if axes:
        t = jnp.sum(t, axis=axes, keepdims=True)
    return t.reshape(shape)


# --------------------------------------------------------------------------
# LSQ fake quantization (static, learned step size)
# --------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(2,))
def lsq_fake_quant(x: jnp.ndarray, s: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Quant-dequant with learned step size; LSQ gradients for ``s``.

    ``s`` must be broadcastable to ``x`` (scalar for per-tensor, shape with
    singleton non-channel dims for per-channel).
    """
    out, _ = _lsq_fwd(x, s, bits)
    return out


def _lsq_fwd(x, s, bits):
    qn, qp = qbounds(bits)
    xf = x.astype(jnp.float32)
    sf = jnp.maximum(s.astype(jnp.float32), _EPS)
    v = xf / sf
    q = jnp.round(jnp.clip(v, qn, qp))
    out = (q * sf).astype(x.dtype)
    return out, (x, s)


def _lsq_bwd(bits, res, g):
    qn, qp = qbounds(bits)
    x, s = res
    gf = g.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    sf = jnp.maximum(s.astype(jnp.float32), _EPS)
    v = xf / sf
    within = (v >= qn) & (v <= qp)
    dx = jnp.where(within, gf, 0.0).astype(x.dtype)
    # d(out)/d(s): round(v) - v inside the range, clip value when clipped.
    dq_ds = jnp.where(within, jnp.round(v) - v, jnp.clip(v, qn, qp))
    n_per_scale = max(x.size // max(s.size, 1), 1)
    gscale = 1.0 / jnp.sqrt(jnp.float32(n_per_scale * qp))
    ds = _reduce_to_shape(gf * dq_ds, s.shape) * gscale
    return dx, ds.astype(s.dtype)


lsq_fake_quant.defvjp(_lsq_fwd, _lsq_bwd)


# --------------------------------------------------------------------------
# Dynamic (per-token) fake quantization — the "d" in A8d
# --------------------------------------------------------------------------

def dynamic_fake_quant(x: jnp.ndarray, bits: int, axis: int = -1) -> jnp.ndarray:
    """Token-wise dynamic symmetric quantization (absmax over ``axis``).

    The scale is data-derived and stop-gradiented; the round op uses STE.
    Nothing clips by construction (absmax maps to exactly ``b_u``).
    """
    qn, qp = qbounds(bits)
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    s = lax.stop_gradient(jnp.maximum(absmax / qp, _EPS))
    v = xf / s
    # absmax scaling cannot clip (|v| <= qp by construction); the clip is
    # defensive only, so it is straight-through like the round
    v = v + lax.stop_gradient(jnp.clip(v, qn, qp) - v)
    return (round_ste(v) * s).astype(x.dtype)


def dynamic_fake_quant_per_tensor(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Whole-tensor dynamic quantization (used by gradient compression)."""
    qn, qp = qbounds(bits)
    xf = x.astype(jnp.float32)
    s = lax.stop_gradient(jnp.maximum(jnp.max(jnp.abs(xf)) / qp, _EPS))
    v = jnp.clip(xf / s, qn, qp)
    return (round_ste(v) * s).astype(x.dtype)


# --------------------------------------------------------------------------
# Integer conversion for deployment (serving path / kernels)
# --------------------------------------------------------------------------

def quantize_to_int(x: jnp.ndarray, s: jnp.ndarray, bits: int,
                    dtype=jnp.int8) -> jnp.ndarray:
    """Real integer quantization: ``round(clip(x/s))`` as ints (no dequant)."""
    qn, qp = qbounds(bits)
    v = x.astype(jnp.float32) / jnp.maximum(s.astype(jnp.float32), _EPS)
    return jnp.round(jnp.clip(v, qn, qp)).astype(dtype)


def dequantize_int(q: jnp.ndarray, s: jnp.ndarray,
                   dtype=jnp.bfloat16) -> jnp.ndarray:
    return (q.astype(jnp.float32) * s.astype(jnp.float32)).astype(dtype)


def dynamic_quantize_to_int(x: jnp.ndarray, bits: int, axis: int = -1,
                            dtype=jnp.int8):
    """Per-token integer quantization; returns (q, scale)."""
    qn, qp = qbounds(bits)
    xf = x.astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(xf), axis=axis, keepdims=True) / qp, _EPS)
    q = jnp.round(jnp.clip(xf / s, qn, qp)).astype(dtype)
    return q, s


def pack_int4(q: jnp.ndarray) -> jnp.ndarray:
    """Pack int4 values (int8 storage, range [-8,7]) two-per-byte on the last
    axis. Layout: low nibble = even index, high nibble = odd index."""
    assert q.shape[-1] % 2 == 0, "int4 packing needs an even last dim"
    u = (q.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    lo, hi = u[..., 0::2], u[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(p: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_int4`; returns int8 values in [-8, 7]."""
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*p.shape[:-1], p.shape[-1] * 2)


# --------------------------------------------------------------------------
# Site-level helpers used by the model code
# --------------------------------------------------------------------------

def weight_scale_shape(w_shape: Tuple[int, ...]) -> Tuple[int, ...]:
    """Per-output-channel scale shape for a weight of ``w_shape``.

    Output channel is the last axis; leading expert/layer axes keep their own
    scales (e.g. MoE experts quantize independently).
    """
    return tuple(list(w_shape[:-2]) + [1] * (1 if len(w_shape) >= 2 else 0)
                 + [w_shape[-1]]) if len(w_shape) >= 2 else (1,)


def quantize_weight(w: jnp.ndarray, s: jnp.ndarray, bits: int) -> jnp.ndarray:
    """LSQ fake-quant for weights (per-output-channel step size)."""
    return lsq_fake_quant(w, s, bits)


def quantize_act_static(x: jnp.ndarray, s: jnp.ndarray, bits: int) -> jnp.ndarray:
    """LSQ fake-quant for a static per-tensor activation site."""
    return lsq_fake_quant(x, s, bits)


def quantize_act_dynamic(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Token-wise dynamic activation quantization (last axis = features)."""
    return dynamic_fake_quant(x, bits, axis=-1)
