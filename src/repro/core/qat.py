"""QAT integration: quantization context, site helpers, and calibration flow.

Design
------
Quantizer step sizes live *inside* the parameter pytree, under reserved keys
beginning with ``s_`` next to the tensors they quantize::

    linear  = {"w": (d_in, d_out), ["b": (d_out,)],
               "s_w": (1, d_out),          # per-output-channel weight scale
               "s_in": ()}                 # per-tensor activation scale
    attn    = {... , "s_q": (), "s_k": (), "s_v": ()}   # query + cache sites

This makes scan-over-layers, sharding, checkpointing, and the optimizer's
parameter groups (no weight decay on scales; 50x LR boost on *activation*
scales, paper §3.1) uniform tree operations.

Modes
-----
* ``train``  — fake-quant active (LSQ for static scales, STE everywhere)
* ``calib``  — quantization *observed not applied* at activation sites;
               each site writes its |x|-percentile statistic into a collector
               dict that mirrors the params structure (scan stacks it)
* ``off``    — no quantization (fp16 teacher / baseline)
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import calibration as calib
from repro.core.precision import PrecisionPolicy, parse_policy
from repro.core.quantizer import (dynamic_fake_quant, lsq_fake_quant,
                                  pack_int4, quantize_to_int, unpack_int4,
                                  weight_scale_shape)

# Param-dict keys holding quantizer step sizes
SCALE_KEYS = ("s_w", "s_in", "s_q", "s_k", "s_v", "s_state")
ACT_SCALE_KEYS = ("s_in", "s_q", "s_k", "s_v", "s_state")  # 50x LR boost set
# map scale key -> which policy bits apply
_SITE_BITS = {
    "s_in": "act", "s_q": "query", "s_k": "cache", "s_v": "cache",
    "s_state": "cache", "s_w": "weight",
}


@dataclass(frozen=True)
class QuantCtx:
    policy: PrecisionPolicy
    mode: str = "train"                  # train | calib | off
    act_calib_method: str = "quantile"   # quantile | max
    # distribution hints (set by the launch layer; empty = no constraints):
    # attn_shard_mode: "" | "kv_rep" (replicate K/V, shard q heads) |
    #                  "seq" (sequence-parallel attention, replicate K/V)
    attn_shard_mode: str = ""
    batch_axes: tuple = ()
    # Serving weight layout: "bf16" keeps fake-quant einsums on bf16 params;
    # "w4a8" routes every qlinear through the packed-int4 x int8 matmul
    # (requires attach_w4a8_exports on the served tree — strict, no fallback).
    weights_layout: str = "bf16"
    w4a8_backend: str = "auto"           # auto | pallas | ref

    @property
    def off(self) -> bool:
        return self.mode == "off" or not self.policy.enabled

    def bits_for(self, site: str) -> int:
        kind = _SITE_BITS[site]
        p = self.policy
        return {"act": p.act_bits, "query": p.query_bits,
                "cache": p.cache_bits, "weight": p.weight_bits}[kind]

    def with_mode(self, mode: str) -> "QuantCtx":
        return replace(self, mode=mode)


def make_ctx(policy: str | PrecisionPolicy, mode: str = "train",
             act_calib_method: str = "quantile",
             attn_shard_mode: str = "", batch_axes: tuple = (),
             weights_layout: str = "bf16",
             w4a8_backend: str = "auto") -> QuantCtx:
    if isinstance(policy, str):
        policy = parse_policy(policy)
    return QuantCtx(policy=policy, mode=mode,
                    act_calib_method=act_calib_method,
                    attn_shard_mode=attn_shard_mode, batch_axes=batch_axes,
                    weights_layout=weights_layout, w4a8_backend=w4a8_backend)


# --------------------------------------------------------------------------
# Site helpers (called from model code)
# --------------------------------------------------------------------------

def _stat(ctx: QuantCtx, x: jnp.ndarray, bits: int) -> jnp.ndarray:
    if ctx.act_calib_method == "max":
        return calib.act_max_stat(x, bits)
    if ctx.act_calib_method == "chan_max":
        # per-channel |x| maxima (SmoothQuant calibration)
        xf = jnp.abs(x.astype(jnp.float32))
        return jnp.max(xf.reshape(-1, x.shape[-1]), axis=0)
    return calib.act_percentile_stat(x, bits)


def quantize_act(ctx: QuantCtx, x: jnp.ndarray, p: Dict[str, Any], site: str,
                 col: Optional[Dict[str, Any]] = None,
                 bits: Optional[int] = None) -> jnp.ndarray:
    """Quantize an activation-class site (``s_in``/``s_q``/``s_k``/``s_v``).

    ``p`` is the owning param dict (provides the learned scale in static
    mode); ``col`` is the calibration collector.
    """
    if ctx.off:
        return x
    bits = bits if bits is not None else ctx.bits_for(site)
    if bits >= 16 and site == "s_in":
        return x  # 16-bit body activations: disabled policy artifact
    if ctx.mode == "calib":
        if col is not None:
            col[site] = _stat(ctx, x, bits)
        return x
    if ctx.policy.act_dynamic:
        return dynamic_fake_quant(x, bits, axis=-1)
    return lsq_fake_quant(x, p[site], bits)


def quantize_weight_p(ctx: QuantCtx, p: Dict[str, Any],
                      bits: Optional[int] = None,
                      key: str = "w") -> jnp.ndarray:
    """Fake-quant a weight from its param dict (LSQ per-output-channel)."""
    w = p[key]
    if ctx.off:
        return w
    bits = bits if bits is not None else ctx.policy.weight_bits
    if bits >= 16:
        return w
    return lsq_fake_quant(w, p["s_w"], bits)


def qlinear(ctx: QuantCtx, x: jnp.ndarray, p: Dict[str, Any],
            col: Optional[Dict[str, Any]] = None,
            act_bits: Optional[int] = None,
            weight_bits: Optional[int] = None) -> jnp.ndarray:
    """Quantized linear: fake-quant input + weight, then matmul (+ bias).

    ``act_bits``/``weight_bits`` override the body policy for special sites
    (head: 8/8; router: 8/8).

    Under ``weights_layout="w4a8"`` the matmul instead consumes the packed
    int4 export attached next to this linear (see ``attach_w4a8_exports``)
    with per-token dynamic int8 activations — real integer arithmetic, not
    fake-quant. Missing exports raise: a silent bf16 fallback would defeat
    the whole point of the layout (weight-HBM streaming).
    """
    if ctx.weights_layout == "w4a8" and ctx.mode != "calib" and not ctx.off:
        exp = p.get("w4a8")
        if exp is None:
            raise ValueError(
                "weights_layout='w4a8' but this linear carries no packed "
                "export; run qat.attach_w4a8_exports(params, policy) on the "
                "served tree (keys present: %s)" % sorted(p.keys()))
        return w4a8_qlinear(ctx, x, exp)
    xq = quantize_act(ctx, x, p, "s_in", col, bits=act_bits)
    wq = quantize_weight_p(ctx, p, bits=weight_bits)
    y = jnp.einsum("...i,io->...o", xq, wq)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def w4a8_use_pallas(ctx: QuantCtx) -> bool:
    """Backend pick for the packed matmul: Pallas on TPU, XLA ref elsewhere
    (``w4a8_backend`` forces either; off-TPU "pallas" runs interpret mode)."""
    if ctx.w4a8_backend == "pallas":
        return True
    if ctx.w4a8_backend == "ref":
        return False
    return jax.default_backend() == "tpu"


def w4a8_qlinear(ctx: QuantCtx, x: jnp.ndarray, exp: Dict[str, Any]) -> jnp.ndarray:
    """Packed-int4-weight x dynamic-int8-activation linear (serve hot path)."""
    from repro.kernels.w4a8.ops import w4a8_linear
    return w4a8_linear(x, exp, out_dtype=x.dtype,
                       use_pallas=w4a8_use_pallas(ctx))


def cache_dtype(ctx: QuantCtx):
    """Storage dtype for cache tensors under this policy."""
    import jax.numpy as jnp
    if ctx.off or ctx.policy.cache_bits >= 16:
        return jnp.bfloat16
    return jnp.int8


def cache_quantize(ctx: QuantCtx, x, axis: int = -1):
    """Quantize a tensor for cache storage; returns (stored, scale).

    C16 / disabled policies store bf16 with unit scales (same cache
    structure either way, so serve code is policy-agnostic)."""
    import jax.numpy as jnp
    from repro.core.quantizer import dynamic_quantize_to_int
    if ctx.off or ctx.policy.cache_bits >= 16:
        s_shape = x.shape[:-1] + (1,) if axis in (-1, x.ndim - 1) else x.shape
        return x.astype(jnp.bfloat16), jnp.ones(s_shape, jnp.float32)
    return dynamic_quantize_to_int(x, ctx.policy.cache_bits, axis=axis)


# --------------------------------------------------------------------------
# Parameter-tree plumbing
# --------------------------------------------------------------------------

def scale_params_for_weight(w: jnp.ndarray) -> jnp.ndarray:
    """Placeholder per-output-channel scale (calibrated before training)."""
    return jnp.ones(weight_scale_shape(w.shape), jnp.float32)


def init_linear(key, d_in: int, d_out: int, bias: bool = False,
                dtype=jnp.bfloat16, scale: Optional[float] = None) -> Dict:
    std = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * std
    p = {"w": w.astype(dtype), "s_w": scale_params_for_weight(w),
         "s_in": jnp.float32(1.0)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def is_scale_key(k: str) -> bool:
    return isinstance(k, str) and k.startswith("s_") and k in SCALE_KEYS


def scale_mask(params) -> Any:
    """Pytree of bools: True on quantizer-scale leaves (no weight decay)."""
    return _mask_by_key(params, lambda k: is_scale_key(k))


def act_scale_mask(params) -> Any:
    """True only on activation/cache/query scale leaves (50x LR boost)."""
    return _mask_by_key(params, lambda k: k in ACT_SCALE_KEYS)


def _mask_by_key(tree, pred):
    if isinstance(tree, dict):
        return {k: (jax.tree.map(lambda _: pred(k), v)
                    if not isinstance(v, (dict, list, tuple)) else
                    _mask_by_key(v, pred) if not pred(k) else
                    jax.tree.map(lambda _: True, v))
                for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        t = [_mask_by_key(v, pred) for v in tree]
        return type(tree)(t)
    return False


# --------------------------------------------------------------------------
# Calibration passes
# --------------------------------------------------------------------------

def calibrate_weight_scales(params, policy: PrecisionPolicy,
                            method: str = "mse"):
    """Recompute every ``s_w`` from its sibling ``w`` (Eq. 2 by default).

    The head is a special site: quantized at ``head_bits`` (8, not W-bits),
    and when embeddings are tied it has no ``w`` sibling — its scale is
    calibrated from the transposed embedding table."""
    if not policy.enabled:
        return params

    def walk(tree):
        if isinstance(tree, dict):
            out = dict(tree)
            if "w" in tree and "s_w" in tree:
                bits = policy.weight_bits
                out["s_w"] = calib.weight_scale(tree["w"], bits, method=method)
            for k, v in tree.items():
                if isinstance(v, (dict, list, tuple)) and k != "w":
                    out[k] = walk(v)
            return out
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v) for v in tree)
        return tree

    out = walk(params)
    if isinstance(out, dict) and "head" in out and "s_w" in out["head"]:
        head = dict(out["head"])
        w_head = head["w"] if "w" in head else out["embed"]["w"].T
        head["s_w"] = calib.weight_scale(w_head, policy.head_bits,
                                         method=method)
        out["head"] = head
    return out


def merge_act_scales(params, stats_batches, policy: PrecisionPolicy):
    """Average per-batch calibration stats and write activation scales.

    ``stats_batches``: list of collector pytrees (same structure), each leaf a
    percentile landmark of |x|. Scale = landmark / b_u for the site's bits.
    """
    if not stats_batches:
        return params
    mean_stats = jax.tree.map(lambda *xs: jnp.mean(jnp.stack(xs), axis=0),
                              *stats_batches)

    def walk(p, s):
        if isinstance(p, dict):
            out = dict(p)
            for k, v in p.items():
                if isinstance(s, dict) and k in s:
                    if k in ACT_SCALE_KEYS:
                        bits = _bits_of(policy, k)
                        out[k] = calib.act_scale_from_stat(
                            s[k].astype(jnp.float32), bits).astype(v.dtype) \
                            if hasattr(v, "dtype") else s[k]
                    elif isinstance(v, (dict, list, tuple)):
                        out[k] = walk(v, s[k])
            return out
        if isinstance(p, (list, tuple)) and isinstance(s, (list, tuple)):
            return type(p)(walk(a, b) for a, b in zip(p, s))
        return p

    return walk(params, mean_stats)


def _bits_of(policy: PrecisionPolicy, key: str) -> int:
    kind = _SITE_BITS[key]
    return {"act": policy.act_bits, "query": policy.query_bits,
            "cache": policy.cache_bits, "weight": policy.weight_bits}[kind]


# --------------------------------------------------------------------------
# Deployment export (real integers for the serving path / kernels)
# --------------------------------------------------------------------------

def export_linear_int(p: Dict[str, Any], weight_bits: int) -> Dict[str, Any]:
    """Convert a fake-quant linear to deployable integers.

    4-bit weights are nibble-packed along d_in pairs (kernel layout);
    8-bit kept as int8. Returns {"wq", "s_w", ["b"], "packed": bool}.
    """
    w, s_w = p["w"], p["s_w"]
    q = quantize_to_int(w, s_w, weight_bits)          # int8 values
    out = {"s_w": s_w.astype(jnp.float32)}
    if "b" in p:
        out["b"] = p["b"]
    if weight_bits <= 4:
        out["wq"] = pack_int4(jnp.swapaxes(q, -1, -2))  # (d_out, d_in/2) packed
        out["packed"] = True
    else:
        out["wq"] = q
        out["packed"] = False
    if "s_in" in p:
        out["s_in"] = p["s_in"].astype(jnp.float32)
    return out


def export_linear_w4(p: Dict[str, Any], trained_bits: int = 4) -> Dict[str, Any]:
    """Pack one linear into the serve-path int4 layout.

    Returns ``{"wq": (d_out, d_in/2) uint8, "s_w": f32 per-out-channel,
    ["b"]}`` — exactly what ``kernels.w4a8.ops.w4a8_linear`` consumes. Two
    scale fixups happen here rather than at load time:

    * a site trained at ``trained_bits > 4`` (the 8-bit head) is re-gridded
      onto the int4 lattice: ``s4 = s_trained * (q_max(trained) / 7)``
    * uncalibrated placeholder scales (``init_linear``'s all-ones) would
      quantize real weights to all-zeros, so exactly-1.0 channels fall back
      to per-channel absmax / 7

    No Python-bool leaves (``export_linear_int``'s ``"packed"``): the export
    rides the param pytree through ``jax.jit`` / ``lax.scan``, where a bool
    leaf would become a tracer.
    """
    from repro.core.quantizer import qbounds
    w = p["w"]
    if w.shape[-2] % 2:
        raise ValueError(f"int4 packing needs even d_in, got {w.shape[-2]}")
    raw = p["s_w"].astype(jnp.float32)
    qp_t = qbounds(trained_bits)[1]
    absmax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True)
    s4 = jnp.where(raw == 1.0, jnp.maximum(absmax / 7.0, 1e-9),
                   raw * (qp_t / 7.0))
    q = quantize_to_int(w, s4, 4)
    out = {"wq": pack_int4(jnp.swapaxes(q, -1, -2)), "s_w": s4}
    if "b" in p:
        out["b"] = p["b"]
    return out


def attach_w4a8_exports(params, policy: PrecisionPolicy):
    """Attach a packed ``"w4a8"`` export inside every served linear dict.

    Returns a new tree (input untouched). Walk rules mirror
    :func:`calibrate_weight_scales`:

    * any dict with ``w``/``s_w`` siblings is a linear — body sites pack at
      ``policy.weight_bits``'s lattice (re-gridded to int4)
    * MoE expert banks (``wg``/``wu``/``wd`` next to a ``router``) are
      skipped: ``_expert_linear`` batches over the expert axis with its own
      einsum and has no packed kernel — only the router is exported
    * the head packs at ``policy.head_bits``; when embeddings are tied it has
      no ``w`` and exports from the transposed embedding table

    Scan-stacked segment linears keep their leading ``(rep,)`` axis on
    ``wq``/``s_w``, so exports slice per-layer inside ``lax.scan`` exactly
    like the weights they shadow.
    """
    if not policy.enabled:
        raise ValueError("w4a8 export needs a quantized policy "
                         f"(got {policy.name})")

    def walk(tree):
        if isinstance(tree, dict):
            moe = "router" in tree
            out = {}
            for k, v in tree.items():
                if isinstance(v, dict) and "w" in v and "s_w" in v:
                    if moe and k in ("wg", "wu", "wd", "w1", "w2"):
                        out[k] = v
                        continue
                    nv = dict(v)
                    nv["w4a8"] = export_linear_w4(v, policy.weight_bits)
                    out[k] = nv
                elif isinstance(v, (dict, list, tuple)):
                    out[k] = walk(v)
                else:
                    out[k] = v
            return out
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v) for v in tree)
        return tree

    out = walk(params)
    if isinstance(out, dict) and "head" in out and "s_w" in out["head"]:
        head = dict(out["head"])
        hp = {"w": head["w"] if "w" in head else out["embed"]["w"].T,
              "s_w": head["s_w"]}
        if "b" in head:
            hp["b"] = head["b"]
        head["w4a8"] = export_linear_w4(hp, policy.head_bits)
        out["head"] = head
    return out


def attach_w4a8_ref_planes(params):
    """Cache each export's unpacked ``(d_in, d_out)`` int8 plane as
    ``w4a8["wf"]`` — the XLA-ref backend's decode-time weight form.

    The Pallas kernel unpacks nibbles in-registers per tile, which is free
    on TPU; XLA:CPU cannot fuse the unpack into its BLAS gemm, so without
    this cache the ref serve path re-materializes the unpacked matrix on
    every decode step — measurably slower than the bf16 fake-quant path it
    replaces. Unpacking once at engine construction restores parity. The
    plane is derived purely from ``wq`` (bf16 ``w`` stays unread: the NaN-
    poison lint still binds), costs half the bytes of the bf16 weights it
    shadows, and feeds the exact same integer gemm, so ref results stay
    bit-identical to Pallas. Call only when serving with the ref backend —
    a TPU engine never needs it.
    """

    def walk(tree):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if k == "w4a8" and isinstance(v, dict) and "wq" in v:
                    nv = dict(v)
                    nv["wf"] = jnp.swapaxes(unpack_int4(v["wq"]), -1, -2)
                    out[k] = nv
                elif isinstance(v, (dict, list, tuple)):
                    out[k] = walk(v)
                else:
                    out[k] = v
            return out
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v) for v in tree)
        return tree

    return walk(params)


def w4a8_weight_bytes(params) -> Dict[str, int]:
    """HBM weight-streaming accounting for an export-attached tree.

    ``packed``: bytes the w4a8 serve path reads per full forward (wq + s_w +
    b of every export); ``replaced``: bytes the bf16 layout would have
    streamed for the same matmuls (tied head counts the embedding table —
    the logits matmul reads it every step either way). The ``wf`` ref-
    backend plane is excluded: it is a CPU decode cache, not part of the
    streamed packed layout.
    """
    packed = replaced = 0

    def walk(tree):
        nonlocal packed, replaced
        if isinstance(tree, dict):
            if "w4a8" in tree:
                for key, leaf in tree["w4a8"].items():
                    if key == "wf":
                        continue
                    packed += int(leaf.size) * leaf.dtype.itemsize
                if "w" in tree:
                    replaced += int(tree["w"].size) * tree["w"].dtype.itemsize
                if "b" in tree:
                    replaced += int(tree["b"].size) * tree["b"].dtype.itemsize
            for v in tree.values():
                if isinstance(v, (dict, list, tuple)):
                    walk(v)
        elif isinstance(tree, (list, tuple)):
            for v in tree:
                walk(v)

    walk(params)
    if (isinstance(params, dict) and "head" in params
            and "w4a8" in params.get("head", {})
            and "w" not in params["head"] and "embed" in params):
        w = params["embed"]["w"]
        replaced += int(w.size) * w.dtype.itemsize
    return {"packed": packed, "replaced": replaced}
