"""QAT integration: quantization context, site helpers, and calibration flow.

Design
------
Quantizer step sizes live *inside* the parameter pytree, under reserved keys
beginning with ``s_`` next to the tensors they quantize::

    linear  = {"w": (d_in, d_out), ["b": (d_out,)],
               "s_w": (1, d_out),          # per-output-channel weight scale
               "s_in": ()}                 # per-tensor activation scale
    attn    = {... , "s_q": (), "s_k": (), "s_v": ()}   # query + cache sites

This makes scan-over-layers, sharding, checkpointing, and the optimizer's
parameter groups (no weight decay on scales; 50x LR boost on *activation*
scales, paper §3.1) uniform tree operations.

Modes
-----
* ``train``  — fake-quant active (LSQ for static scales, STE everywhere)
* ``calib``  — quantization *observed not applied* at activation sites;
               each site writes its |x|-percentile statistic into a collector
               dict that mirrors the params structure (scan stacks it)
* ``off``    — no quantization (fp16 teacher / baseline)
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import calibration as calib
from repro.core.precision import PrecisionPolicy, parse_policy
from repro.core.quantizer import (dynamic_fake_quant, lsq_fake_quant,
                                  pack_int4, quantize_to_int,
                                  weight_scale_shape)

# Param-dict keys holding quantizer step sizes
SCALE_KEYS = ("s_w", "s_in", "s_q", "s_k", "s_v", "s_state")
ACT_SCALE_KEYS = ("s_in", "s_q", "s_k", "s_v", "s_state")  # 50x LR boost set
# map scale key -> which policy bits apply
_SITE_BITS = {
    "s_in": "act", "s_q": "query", "s_k": "cache", "s_v": "cache",
    "s_state": "cache", "s_w": "weight",
}


@dataclass(frozen=True)
class QuantCtx:
    policy: PrecisionPolicy
    mode: str = "train"                  # train | calib | off
    act_calib_method: str = "quantile"   # quantile | max
    # distribution hints (set by the launch layer; empty = no constraints):
    # attn_shard_mode: "" | "kv_rep" (replicate K/V, shard q heads) |
    #                  "seq" (sequence-parallel attention, replicate K/V)
    attn_shard_mode: str = ""
    batch_axes: tuple = ()

    @property
    def off(self) -> bool:
        return self.mode == "off" or not self.policy.enabled

    def bits_for(self, site: str) -> int:
        kind = _SITE_BITS[site]
        p = self.policy
        return {"act": p.act_bits, "query": p.query_bits,
                "cache": p.cache_bits, "weight": p.weight_bits}[kind]

    def with_mode(self, mode: str) -> "QuantCtx":
        return replace(self, mode=mode)


def make_ctx(policy: str | PrecisionPolicy, mode: str = "train",
             act_calib_method: str = "quantile",
             attn_shard_mode: str = "", batch_axes: tuple = ()) -> QuantCtx:
    if isinstance(policy, str):
        policy = parse_policy(policy)
    return QuantCtx(policy=policy, mode=mode,
                    act_calib_method=act_calib_method,
                    attn_shard_mode=attn_shard_mode, batch_axes=batch_axes)


# --------------------------------------------------------------------------
# Site helpers (called from model code)
# --------------------------------------------------------------------------

def _stat(ctx: QuantCtx, x: jnp.ndarray, bits: int) -> jnp.ndarray:
    if ctx.act_calib_method == "max":
        return calib.act_max_stat(x, bits)
    if ctx.act_calib_method == "chan_max":
        # per-channel |x| maxima (SmoothQuant calibration)
        xf = jnp.abs(x.astype(jnp.float32))
        return jnp.max(xf.reshape(-1, x.shape[-1]), axis=0)
    return calib.act_percentile_stat(x, bits)


def quantize_act(ctx: QuantCtx, x: jnp.ndarray, p: Dict[str, Any], site: str,
                 col: Optional[Dict[str, Any]] = None,
                 bits: Optional[int] = None) -> jnp.ndarray:
    """Quantize an activation-class site (``s_in``/``s_q``/``s_k``/``s_v``).

    ``p`` is the owning param dict (provides the learned scale in static
    mode); ``col`` is the calibration collector.
    """
    if ctx.off:
        return x
    bits = bits if bits is not None else ctx.bits_for(site)
    if bits >= 16 and site == "s_in":
        return x  # 16-bit body activations: disabled policy artifact
    if ctx.mode == "calib":
        if col is not None:
            col[site] = _stat(ctx, x, bits)
        return x
    if ctx.policy.act_dynamic:
        return dynamic_fake_quant(x, bits, axis=-1)
    return lsq_fake_quant(x, p[site], bits)


def quantize_weight_p(ctx: QuantCtx, p: Dict[str, Any],
                      bits: Optional[int] = None,
                      key: str = "w") -> jnp.ndarray:
    """Fake-quant a weight from its param dict (LSQ per-output-channel)."""
    w = p[key]
    if ctx.off:
        return w
    bits = bits if bits is not None else ctx.policy.weight_bits
    if bits >= 16:
        return w
    return lsq_fake_quant(w, p["s_w"], bits)


def qlinear(ctx: QuantCtx, x: jnp.ndarray, p: Dict[str, Any],
            col: Optional[Dict[str, Any]] = None,
            act_bits: Optional[int] = None,
            weight_bits: Optional[int] = None) -> jnp.ndarray:
    """Quantized linear: fake-quant input + weight, then matmul (+ bias).

    ``act_bits``/``weight_bits`` override the body policy for special sites
    (head: 8/8; router: 8/8).
    """
    xq = quantize_act(ctx, x, p, "s_in", col, bits=act_bits)
    wq = quantize_weight_p(ctx, p, bits=weight_bits)
    y = jnp.einsum("...i,io->...o", xq, wq)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def cache_dtype(ctx: QuantCtx):
    """Storage dtype for cache tensors under this policy."""
    import jax.numpy as jnp
    if ctx.off or ctx.policy.cache_bits >= 16:
        return jnp.bfloat16
    return jnp.int8


def cache_quantize(ctx: QuantCtx, x, axis: int = -1):
    """Quantize a tensor for cache storage; returns (stored, scale).

    C16 / disabled policies store bf16 with unit scales (same cache
    structure either way, so serve code is policy-agnostic)."""
    import jax.numpy as jnp
    from repro.core.quantizer import dynamic_quantize_to_int
    if ctx.off or ctx.policy.cache_bits >= 16:
        s_shape = x.shape[:-1] + (1,) if axis in (-1, x.ndim - 1) else x.shape
        return x.astype(jnp.bfloat16), jnp.ones(s_shape, jnp.float32)
    return dynamic_quantize_to_int(x, ctx.policy.cache_bits, axis=axis)


# --------------------------------------------------------------------------
# Parameter-tree plumbing
# --------------------------------------------------------------------------

def scale_params_for_weight(w: jnp.ndarray) -> jnp.ndarray:
    """Placeholder per-output-channel scale (calibrated before training)."""
    return jnp.ones(weight_scale_shape(w.shape), jnp.float32)


def init_linear(key, d_in: int, d_out: int, bias: bool = False,
                dtype=jnp.bfloat16, scale: Optional[float] = None) -> Dict:
    std = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * std
    p = {"w": w.astype(dtype), "s_w": scale_params_for_weight(w),
         "s_in": jnp.float32(1.0)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def is_scale_key(k: str) -> bool:
    return isinstance(k, str) and k.startswith("s_") and k in SCALE_KEYS


def scale_mask(params) -> Any:
    """Pytree of bools: True on quantizer-scale leaves (no weight decay)."""
    return _mask_by_key(params, lambda k: is_scale_key(k))


def act_scale_mask(params) -> Any:
    """True only on activation/cache/query scale leaves (50x LR boost)."""
    return _mask_by_key(params, lambda k: k in ACT_SCALE_KEYS)


def _mask_by_key(tree, pred):
    if isinstance(tree, dict):
        return {k: (jax.tree.map(lambda _: pred(k), v)
                    if not isinstance(v, (dict, list, tuple)) else
                    _mask_by_key(v, pred) if not pred(k) else
                    jax.tree.map(lambda _: True, v))
                for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        t = [_mask_by_key(v, pred) for v in tree]
        return type(tree)(t)
    return False


# --------------------------------------------------------------------------
# Calibration passes
# --------------------------------------------------------------------------

def calibrate_weight_scales(params, policy: PrecisionPolicy,
                            method: str = "mse"):
    """Recompute every ``s_w`` from its sibling ``w`` (Eq. 2 by default).

    The head is a special site: quantized at ``head_bits`` (8, not W-bits),
    and when embeddings are tied it has no ``w`` sibling — its scale is
    calibrated from the transposed embedding table."""
    if not policy.enabled:
        return params

    def walk(tree):
        if isinstance(tree, dict):
            out = dict(tree)
            if "w" in tree and "s_w" in tree:
                bits = policy.weight_bits
                out["s_w"] = calib.weight_scale(tree["w"], bits, method=method)
            for k, v in tree.items():
                if isinstance(v, (dict, list, tuple)) and k != "w":
                    out[k] = walk(v)
            return out
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v) for v in tree)
        return tree

    out = walk(params)
    if isinstance(out, dict) and "head" in out and "s_w" in out["head"]:
        head = dict(out["head"])
        w_head = head["w"] if "w" in head else out["embed"]["w"].T
        head["s_w"] = calib.weight_scale(w_head, policy.head_bits,
                                         method=method)
        out["head"] = head
    return out


def merge_act_scales(params, stats_batches, policy: PrecisionPolicy):
    """Average per-batch calibration stats and write activation scales.

    ``stats_batches``: list of collector pytrees (same structure), each leaf a
    percentile landmark of |x|. Scale = landmark / b_u for the site's bits.
    """
    if not stats_batches:
        return params
    mean_stats = jax.tree.map(lambda *xs: jnp.mean(jnp.stack(xs), axis=0),
                              *stats_batches)

    def walk(p, s):
        if isinstance(p, dict):
            out = dict(p)
            for k, v in p.items():
                if isinstance(s, dict) and k in s:
                    if k in ACT_SCALE_KEYS:
                        bits = _bits_of(policy, k)
                        out[k] = calib.act_scale_from_stat(
                            s[k].astype(jnp.float32), bits).astype(v.dtype) \
                            if hasattr(v, "dtype") else s[k]
                    elif isinstance(v, (dict, list, tuple)):
                        out[k] = walk(v, s[k])
            return out
        if isinstance(p, (list, tuple)) and isinstance(s, (list, tuple)):
            return type(p)(walk(a, b) for a, b in zip(p, s))
        return p

    return walk(params, mean_stats)


def _bits_of(policy: PrecisionPolicy, key: str) -> int:
    kind = _SITE_BITS[key]
    return {"act": policy.act_bits, "query": policy.query_bits,
            "cache": policy.cache_bits, "weight": policy.weight_bits}[kind]


# --------------------------------------------------------------------------
# Deployment export (real integers for the serving path / kernels)
# --------------------------------------------------------------------------

def export_linear_int(p: Dict[str, Any], weight_bits: int) -> Dict[str, Any]:
    """Convert a fake-quant linear to deployable integers.

    4-bit weights are nibble-packed along d_in pairs (kernel layout);
    8-bit kept as int8. Returns {"wq", "s_w", ["b"], "packed": bool}.
    """
    w, s_w = p["w"], p["s_w"]
    q = quantize_to_int(w, s_w, weight_bits)          # int8 values
    out = {"s_w": s_w.astype(jnp.float32)}
    if "b" in p:
        out["b"] = p["b"]
    if weight_bits <= 4:
        out["wq"] = pack_int4(jnp.swapaxes(q, -1, -2))  # (d_out, d_in/2) packed
        out["packed"] = True
    else:
        out["wq"] = q
        out["packed"] = False
    if "s_in" in p:
        out["s_in"] = p["s_in"].astype(jnp.float32)
    return out
