"""Host-side data loading: device placement, sharding, prefetch.

``ShardedLoader`` wraps an iterator of numpy batches, places each batch on
the mesh with the batch axis over ("pod", "data") and prefetches one batch
ahead (overlapping host generation with device compute).
"""
from __future__ import annotations

import collections
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ShardedLoader:
    def __init__(self, it: Iterator[Dict[str, np.ndarray]],
                 mesh: Optional[Mesh] = None,
                 batch_axes: tuple = ("data",), prefetch: int = 1):
        self._it = it
        self._mesh = mesh
        self._spec = P(batch_axes)
        self._q: collections.deque = collections.deque()
        self._prefetch = max(prefetch, 0)
        self._lock = threading.Lock()

    def _place(self, batch: Dict[str, np.ndarray]):
        if self._mesh is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        sh = NamedSharding(self._mesh, self._spec)
        return {k: jax.device_put(v, sh) for k, v in batch.items()}

    def __iter__(self):
        return self

    def __next__(self):
        with self._lock:
            while len(self._q) <= self._prefetch:
                self._q.append(self._place(next(self._it)))
            return self._q.popleft()
