"""Synthetic token pipeline standing in for DCLM / SFT mixtures.

The container is offline, so the data substrate generates structured
synthetic language: a seeded first-order Markov chain over the vocabulary
(Zipf-distributed unigrams, low-entropy bigram structure) — enough signal
that (a) a small model trained on it learns something distillable, and
(b) activation statistics exercise realistic dynamic ranges for percentile
calibration.

Two "sources" emulate the paper's mixture: ``dclm`` (long-range, uniform
documents) and ``sft`` (prompt/response with a loss mask on the response
only). ``MixtureIterator`` samples sources per example (paper: 25% DCLM /
75% SFT for instruct models) and is checkpointable (state = step counter;
regeneration is deterministic).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass
class SyntheticConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    dclm_ratio: float = 0.25
    seed: int = 0
    zipf_a: float = 1.2
    n_states: int = 64      # Markov states (coarse "topics")


class MixtureIterator:
    """Deterministic, checkpointable mixture of synthetic sources."""

    def __init__(self, cfg: SyntheticConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # Zipfian unigram over vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._unigram = ranks ** -cfg.zipf_a
        self._unigram /= self._unigram.sum()
        # per-state token-bias: each state prefers a band of the vocab
        self._state_shift = rng.integers(0, v, size=cfg.n_states)
        self._trans = rng.dirichlet(np.ones(cfg.n_states) * 0.2,
                                    size=cfg.n_states)

    # ---- checkpointing ----
    def state_dict(self) -> Dict:
        return {"step": self.step}

    def load_state_dict(self, d: Dict) -> None:
        self.step = int(d["step"])

    # ---- generation ----
    def _sample_doc(self, rng: np.random.Generator, n: int) -> np.ndarray:
        cfg = self.cfg
        states = np.zeros(n, np.int64)
        s = rng.integers(0, cfg.n_states)
        for i in range(0, n, 16):          # state persists ~16 tokens
            s = rng.choice(cfg.n_states, p=self._trans[s])
            states[i:i + 16] = s
        toks = rng.choice(cfg.vocab_size, size=n, p=self._unigram)
        return (toks + self._state_shift[states]) % cfg.vocab_size

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, self.step))
        B, S = cfg.batch_size, cfg.seq_len
        tokens = np.zeros((B, S + 1), np.int32)
        mask = np.ones((B, S), np.float32)
        is_dclm = rng.random(B) < cfg.dclm_ratio
        for b in range(B):
            doc = self._sample_doc(rng, S + 1)
            tokens[b] = doc
            if not is_dclm[b]:
                # SFT-style: mask the "prompt" third from the loss
                cut = S // 3 + int(rng.integers(0, S // 8))
                mask[b, :cut] = 0.0
        self.step += 1
        return {"tokens": tokens[:, :-1],
                "labels": tokens[:, 1:].astype(np.int32),
                "loss_mask": mask}


def calibration_batches(cfg: SyntheticConfig, n_batches: int):
    """The paper's 5x128 calibration sample stream (deterministic)."""
    it = MixtureIterator(cfg, start_step=10_000_019)  # disjoint from training
    return [next(it) for _ in range(n_batches)]
