from repro.data.loader import ShardedLoader
from repro.data.synthetic import (MixtureIterator, SyntheticConfig,
                                  calibration_batches)

__all__ = ["ShardedLoader", "MixtureIterator", "SyntheticConfig",
           "calibration_batches"]
