"""Chrome/Perfetto export and text reports for serve traces.

:func:`chrome_trace` turns a :class:`repro.obs.trace.Tracer` into the
Chrome ``trace_event`` JSON object format (loadable at
https://ui.perfetto.dev or ``chrome://tracing``):

* **pid 0 — engine waves.** Each span name gets its own named track
  (tid), so the step timeline reads as stacked lanes: ``step`` on top,
  ``admit`` / ``prefill_wave`` / ``tail_wave`` / ``decode`` /
  ``decode_chunk`` / spec / swap / ``harvest`` below, with the blocking
  ``sync`` gaps visible inside each wave. Spans are ``ph:"X"`` complete
  events; a span whose jit call compiled a fresh variant carries
  ``args.compiled`` (set by the wave registry via ``Tracer.annotate``).
* **pid 1 — requests.** Each request uid becomes one async span
  (``ph:"b"``/``"n"``/``"e"``, ``id`` = uid) running submit→terminal,
  with every lifecycle event as an instant on it. Requests still live
  when the trace was cut get a synthetic end marked ``truncated``.

The report functions (:func:`step_breakdown`,
:func:`request_attribution`, :func:`compile_split`,
:func:`render_report`) operate on the *chrome dict*, not the live
tracer, so ``tools/trace_report.py`` works on the exported artifact —
the same file CI uploads.

Stdlib-only.
"""
from __future__ import annotations

import json
from collections import defaultdict
from typing import Dict, List, Optional, Sequence

from repro.obs.trace import SPAN_NAMES, Tracer

__all__ = ["chrome_trace", "write_trace", "load_trace", "step_breakdown",
           "request_attribution", "compile_split", "render_report"]

WAVE_PID = 0
REQUEST_PID = 1

# terminal lifecycle events: close the request's async span
_TERMINAL = frozenset({"finished", "shed"})


def _percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (matches ``serve.scheduler.percentile``,
    reimplemented locally so report code never imports the serve layer)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    rank = max(1, int(-(-q / 100.0 * len(s) // 1)))
    return float(s[min(rank, len(s)) - 1])


def chrome_trace(tracer: Tracer,
                 compile_variants: Optional[Dict] = None) -> Dict:
    """Export ``tracer``'s buffer as a Chrome ``trace_event`` object.

    ``compile_variants`` is ``engine.wave_variant_signatures()`` — the
    PR 9 compile-variant registry; it rides along in ``otherData`` so
    the compile-vs-execute report can name each recompile's argument
    signature.
    """
    records = tracer.events()
    ev: List[Dict] = [
        {"ph": "M", "name": "process_name", "pid": WAVE_PID, "tid": 0,
         "args": {"name": "engine waves"}},
        {"ph": "M", "name": "process_name", "pid": REQUEST_PID, "tid": 0,
         "args": {"name": "requests"}},
    ]

    # stable track ids: known span vocabulary first, stragglers appended
    tids = {name: i for i, name in enumerate(SPAN_NAMES)}
    for r in records:
        if r["ph"] == "span" and r["name"] not in tids:
            tids[r["name"]] = len(tids)
    seen = {r["name"] for r in records if r["ph"] == "span"}
    for name, tid in sorted(tids.items(), key=lambda kv: kv[1]):
        if name in seen:
            ev.append({"ph": "M", "name": "thread_name", "pid": WAVE_PID,
                       "tid": tid, "args": {"name": name}})

    def us(t: float) -> float:
        return (t - tracer.t0) * 1e6

    open_reqs: Dict[int, float] = {}     # uid -> last event ts (µs)
    for r in records:
        if r["ph"] == "span":
            args = {"step": r["step"], "depth": r["depth"]}
            if r["args"]:
                args.update(r["args"])
            ev.append({"ph": "X", "name": r["name"], "cat": "wave",
                       "pid": WAVE_PID, "tid": tids[r["name"]],
                       "ts": us(r["t0"]), "dur": r["dur"] * 1e6,
                       "args": args})
        else:
            uid = r["uid"]
            if uid is None:              # engine-level instant, own lane
                ev.append({"ph": "i", "name": r["name"], "s": "p",
                           "pid": WAVE_PID, "tid": tids.get("step", 0),
                           "ts": us(r["t"]),
                           "args": {"step": r["step"], **(r["args"] or {})}})
                continue
            ts = us(r["t"])
            name = f"req:{uid}"
            args = {"event": r["name"], "step": r["step"]}
            if r["args"]:
                args.update(r["args"])
            if uid not in open_reqs:
                ev.append({"ph": "b", "cat": "request", "name": name,
                           "id": uid, "pid": REQUEST_PID, "tid": 0,
                           "ts": ts, "args": args})
            ev.append({"ph": "n", "cat": "request", "name": name,
                       "id": uid, "pid": REQUEST_PID, "tid": 0,
                       "ts": ts, "args": args})
            if r["name"] in _TERMINAL:
                ev.append({"ph": "e", "cat": "request", "name": name,
                           "id": uid, "pid": REQUEST_PID, "tid": 0,
                           "ts": ts, "args": {}})
                open_reqs.pop(uid, None)
            else:
                open_reqs[uid] = ts
    # requests with no terminal event inside the window: close the async
    # span so the viewer renders it, flagged truncated
    for uid, ts in open_reqs.items():
        ev.append({"ph": "e", "cat": "request", "name": f"req:{uid}",
                   "id": uid, "pid": REQUEST_PID, "tid": 0, "ts": ts,
                   "args": {"truncated": True}})

    return {
        "traceEvents": ev,
        "displayTimeUnit": "ms",
        "otherData": {
            "generator": "repro.obs",
            "wall_t0": tracer.wall_t0,
            "dropped_records": tracer.dropped,
            "compile_variants": compile_variants or {},
        },
    }


def write_trace(path: str, tracer: Tracer,
                compile_variants: Optional[Dict] = None) -> Dict:
    """Write the Perfetto JSON to ``path``; returns the exported dict."""
    trace = chrome_trace(tracer, compile_variants)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace


def load_trace(path: str) -> Dict:
    with open(path) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# reports (input: the chrome dict)
# ---------------------------------------------------------------------------

def _wave_events(trace: Dict) -> List[Dict]:
    return [e for e in trace["traceEvents"]
            if e["ph"] == "X" and e["pid"] == WAVE_PID]


def step_breakdown(trace: Dict) -> Dict[str, Dict]:
    """Wall-time totals per wave family.

    Returns ``{family: {"count", "total_s", "mean_ms", "pct_of_step"}}``.
    Families overlap by nesting (``decode`` contains ``decode_chunk``
    and ``harvest``; waves contain their ``sync``), so percentages are
    each family's share of total ``step`` time, not a partition.
    """
    acc: Dict[str, List[float]] = defaultdict(list)
    for e in _wave_events(trace):
        acc[e["name"]].append(e["dur"] / 1e6)
    step_total = sum(acc.get("step", [])) or sum(
        sum(v) for k, v in acc.items() if k != "step") or 1.0
    out = {}
    for name, durs in acc.items():
        total = sum(durs)
        out[name] = {"count": len(durs), "total_s": total,
                     "mean_ms": 1e3 * total / len(durs),
                     "pct_of_step": 100.0 * total / step_total}
    return out


def request_attribution(trace: Dict) -> Dict:
    """Per-request latency attribution from the lifecycle events.

    Splits each finished request's submit→finish span into queue delay
    (submit→admitted), TTFT (submit→first_token) and decode
    (first_token→finished); derives TPOT from decode time over the
    ``tokens`` count the scheduler stamps on ``finished``. Also
    reconciles the trace-side latency (finished ts − submit ts) against
    the scheduler-clock ``latency_s`` carried on the ``finished`` event
    — ``reconcile_max_err`` is the worst relative disagreement, the
    quantity the acceptance gate bounds at 5%.
    """
    by_uid: Dict[int, Dict[str, Dict]] = defaultdict(dict)
    for e in trace["traceEvents"]:
        if e.get("cat") == "request" and e["ph"] == "n":
            by_uid[e["id"]].setdefault(e["args"]["event"], e)

    queue, ttft, decode, tpot, latency = [], [], [], [], []
    errs = []
    n_finished = 0
    for uid, evs in by_uid.items():
        sub, fin = evs.get("submit"), evs.get("finished")
        if sub is None or fin is None:
            continue
        n_finished += 1
        lat = (fin["ts"] - sub["ts"]) / 1e6
        latency.append(lat)
        if "admitted" in evs:
            queue.append((evs["admitted"]["ts"] - sub["ts"]) / 1e6)
        if "first_token" in evs:
            ft = (evs["first_token"]["ts"] - sub["ts"]) / 1e6
            ttft.append(ft)
            dec = lat - ft
            decode.append(dec)
            toks = fin["args"].get("tokens") or 0
            if toks > 1:
                tpot.append(dec / (toks - 1))
        sched_lat = fin["args"].get("latency_s")
        if sched_lat:
            errs.append(abs(lat - sched_lat) / sched_lat)

    def pcts(xs):
        return {"p50_s": _percentile(xs, 50), "p95_s": _percentile(xs, 95),
                "mean_s": sum(xs) / len(xs) if xs else 0.0, "n": len(xs)}

    return {"finished": n_finished,
            "queue_delay": pcts(queue), "ttft": pcts(ttft),
            "decode": pcts(decode), "tpot": pcts(tpot),
            "latency": pcts(latency),
            "reconcile_max_err": max(errs) if errs else 0.0}


def compile_split(trace: Dict) -> Dict[str, Dict]:
    """Compile-vs-execute wall time per wave family.

    A span is *compile-tainted* when the wave registry annotated it
    ``compiled`` (its jit call built a fresh variant — the first call of
    each argument signature in the PR 9 registry); everything else is
    steady-state execution. ``variants`` carries the registry's recorded
    argument signatures from ``otherData``.
    """
    out: Dict[str, Dict] = {}
    for e in _wave_events(trace):
        d = out.setdefault(e["name"], {"compile_s": 0.0, "execute_s": 0.0,
                                       "compile_calls": 0,
                                       "execute_calls": 0})
        if e["args"].get("compiled"):
            d["compile_s"] += e["dur"] / 1e6
            d["compile_calls"] += 1
        else:
            d["execute_s"] += e["dur"] / 1e6
            d["execute_calls"] += 1
    variants = trace.get("otherData", {}).get("compile_variants", {})
    for fam, sigs in variants.items():
        key = {"admit_dense": "prefill_wave", "admit_paged": "prefill_wave",
               "admit_draft": "prefill_wave", "tail": "tail_wave",
               "decode": "decode_chunk"}.get(fam, fam)
        if key in out:
            out[key].setdefault("variants", []).extend(
                str(s) for s in sigs)
    return out


def render_report(trace: Dict) -> str:
    """The ``tools/trace_report.py`` text: step-time breakdown, request
    attribution percentiles, compile-vs-execute split."""
    lines = ["serve trace report", "=================="]
    od = trace.get("otherData", {})
    if od.get("dropped_records"):
        lines.append(f"[window truncated: {od['dropped_records']} oldest "
                     "records evicted by the ring bound]")

    bd = step_breakdown(trace)
    lines += ["", "step-time breakdown by wave family",
              f"{'family':<14}{'count':>7}{'total s':>10}{'mean ms':>10}"
              f"{'% of step':>11}"]
    order = {n: i for i, n in enumerate(SPAN_NAMES)}
    for name in sorted(bd, key=lambda n: order.get(n, 99)):
        d = bd[name]
        lines.append(f"{name:<14}{d['count']:>7}{d['total_s']:>10.3f}"
                     f"{d['mean_ms']:>10.2f}{d['pct_of_step']:>10.1f}%")

    ra = request_attribution(trace)
    lines += ["", f"request attribution ({ra['finished']} finished)",
              f"{'phase':<14}{'n':>5}{'p50 ms':>10}{'p95 ms':>10}"
              f"{'mean ms':>10}"]
    for phase in ("queue_delay", "ttft", "decode", "tpot", "latency"):
        d = ra[phase]
        lines.append(f"{phase:<14}{d['n']:>5}{1e3 * d['p50_s']:>10.2f}"
                     f"{1e3 * d['p95_s']:>10.2f}{1e3 * d['mean_s']:>10.2f}")
    lines.append(f"trace vs scheduler latency: max rel err "
                 f"{100.0 * ra['reconcile_max_err']:.2f}%")

    cs = compile_split(trace)
    lines += ["", "compile vs execute",
              f"{'family':<14}{'compiles':>9}{'compile s':>11}"
              f"{'exec calls':>11}{'exec s':>9}"]
    for name in sorted(cs, key=lambda n: order.get(n, 99)):
        d = cs[name]
        lines.append(f"{name:<14}{d['compile_calls']:>9}"
                     f"{d['compile_s']:>11.3f}{d['execute_calls']:>11}"
                     f"{d['execute_s']:>9.3f}")
        for sig in d.get("variants", []):
            sig = sig if len(sig) <= 68 else sig[:65] + "..."
            lines.append(f"  variant {sig}")
    return "\n".join(lines)
