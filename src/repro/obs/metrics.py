"""Prometheus-style metrics for the serve engine.

Two halves, matching how serving state is actually owned:

* **Histograms** (TTFT / TPOT / request latency) need every observation,
  so the engine pushes into them as requests are admitted and finished
  (:class:`ServeMetrics` rides on the engine; observes are O(#buckets)
  appends on the request path, never the token path).
* **Counters and gauges** already live in ``engine.stats()`` — the
  single source of truth every bench gate reads. Rather than maintain a
  second copy that could drift, :meth:`ServeMetrics.render` maps the
  stats dict onto Prometheus samples at scrape time, so ``GET
  /v1/metrics`` is *by construction* consistent with ``GET /v1/stats``.

The text output is the Prometheus exposition format (``text/plain;
version=0.0.4``): ``# HELP`` / ``# TYPE`` headers, ``_bucket`` samples
with cumulative ``le`` labels plus ``_sum`` / ``_count`` for
histograms. :func:`parse_prometheus` is the matching minimal parser
(tests and the live-dashboard example use it).

Stdlib-only; imports nothing from ``repro.serve``.
"""
from __future__ import annotations

from bisect import bisect_left
from typing import Dict, List, Optional, Tuple

__all__ = ["Histogram", "ServeMetrics", "parse_prometheus",
           "CONTENT_TYPE"]

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

# latency bucket bounds in seconds: log-ish 1ms .. 30s (serve TTFTs on
# CPU CI land mid-range; real accelerators at the low end)
LATENCY_BUCKETS_S = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                     0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)


def _fmt(v) -> str:
    """Prometheus sample value: integers bare, floats via repr (full
    precision, scientific notation is accepted by the format)."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    return repr(float(v))


class Histogram:
    """Fixed-bucket cumulative histogram (Prometheus semantics).

    >>> h = Histogram("x_seconds", "test", buckets=(0.1, 1.0))
    >>> for v in (0.05, 0.5, 0.5, 2.0): h.observe(v)
    >>> h.count, round(h.sum, 2)
    (4, 3.05)
    >>> h.quantile(50)
    1.0
    >>> print(h.render().splitlines()[2])
    x_seconds_bucket{le="0.1"} 1
    """

    def __init__(self, name: str, help_: str,
                 buckets: Tuple[float, ...] = LATENCY_BUCKETS_S):
        self.name = name
        self.help = help_
        self.bounds = tuple(sorted(buckets))
        self.counts = [0] * (len(self.bounds) + 1)   # last: +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: Optional[float]) -> None:
        if v is None:
            return
        v = float(v)
        self.counts[bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def quantile(self, q: float) -> float:
        """Upper-bound estimate of the q-th percentile from the bucket
        counts (the finest answer a fixed-bucket histogram can give;
        observations past the last bound report that bound)."""
        if not self.count:
            return 0.0
        rank = max(1, int(-(-q / 100.0 * self.count // 1)))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank:
                return self.bounds[min(i, len(self.bounds) - 1)]
        return self.bounds[-1]

    def render(self) -> str:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} histogram"]
        cum = 0
        for bound, c in zip(self.bounds, self.counts):
            cum += c
            lines.append(f'{self.name}_bucket{{le="{_fmt(bound)}"}} {cum}')
        lines.append(f'{self.name}_bucket{{le="+Inf"}} {self.count}')
        lines.append(f"{self.name}_sum {_fmt(self.sum)}")
        lines.append(f"{self.name}_count {self.count}")
        return "\n".join(lines)

    def snapshot(self) -> Dict:
        return {"count": self.count, "sum": self.sum,
                "p50_s": self.quantile(50), "p95_s": self.quantile(95)}


# engine.stats() key -> (metric name, type, help). Keys absent from a
# stats dict (dense layout, spec off) simply don't render — the scrape
# surface tracks the engine configuration like stats() does.
STAT_METRICS = (
    ("tokens_out", "serve_tokens_out_total", "counter",
     "Tokens returned to requests (first prefill token + committed "
     "decode tokens)"),
    ("decode_steps", "serve_decode_steps_total", "counter",
     "Device decode steps executed"),
    ("decode_rounds", "serve_decode_rounds_total", "counter",
     "Engine steps that ran a decode chunk or spec wave"),
    ("prefill_calls", "serve_prefill_calls_total", "counter",
     "Compiled prefill/tail-finish admission waves"),
    ("prefill_chunks", "serve_prefill_chunks_total", "counter",
     "Tail-wave rows advanced (batched chunks)"),
    ("prompt_tokens_prefilled", "serve_prompt_tokens_prefilled_total",
     "counter", "Prompt tokens actually computed (prefix hits excluded)"),
    ("prefix_hit_tokens", "serve_prefix_hit_tokens_total", "counter",
     "Prompt tokens served from the prefix cache"),
    ("prefix_lookups", "serve_prefix_lookups_total", "counter",
     "Prefix-index probes"),
    ("prefix_evictions", "serve_prefix_evictions_total", "counter",
     "Indexed blocks reclaimed by allocation pressure"),
    ("cow_copies", "serve_cow_copies_total", "counter",
     "Copy-on-write block clones"),
    ("preemptions", "serve_preemptions_total", "counter",
     "Residents swapped out (optimistic admission)"),
    ("swap_out_bytes", "serve_swap_out_bytes_total", "counter",
     "Quantized cache bytes gathered to host by preemption"),
    ("swap_in_bytes", "serve_swap_in_bytes_total", "counter",
     "Quantized cache bytes restored from host"),
    ("requests_finished", "serve_requests_finished_total", "counter",
     "Requests fully served"),
    ("requests_shed", "serve_requests_shed_total", "counter",
     "Requests rejected by SLO shed-load"),
    ("requests_downgraded", "serve_requests_downgraded_total", "counter",
     "Requests demoted to best-effort by SLO shed-load"),
    ("spec_waves", "serve_spec_waves_total", "counter",
     "Speculative verify-waves run"),
    ("spec_drafted", "serve_spec_drafted_total", "counter",
     "Draft tokens proposed"),
    ("spec_accepted", "serve_spec_accepted_total", "counter",
     "Draft tokens accepted"),
    ("spec_accept_rate", "serve_spec_accept_rate", "gauge",
     "Accepted / drafted draft tokens"),
    ("pending_requests", "serve_pending_requests", "gauge",
     "Requests waiting in the scheduler queue"),
    ("resident_requests", "serve_resident_requests", "gauge",
     "Requests resident in slots (decode + in-flight tail prefills)"),
    ("swapped_requests", "serve_swapped_requests", "gauge",
     "Preempted requests awaiting restore"),
    ("max_residents", "serve_max_residents", "gauge",
     "Peak concurrently resident requests"),
    ("free_blocks", "serve_free_blocks", "gauge",
     "Free cache blocks in the paged pool"),
    ("pool_occupancy", "serve_pool_occupancy", "gauge",
     "Fraction of the paged pool's blocks in use"),
    ("prefix_cache_blocks", "serve_prefix_cache_blocks", "gauge",
     "Evictable blocks alive only in the prefix index"),
    ("cache_tokens_capacity", "serve_cache_tokens_capacity", "gauge",
     "Pool/stripe capacity in tokens"),
    ("peak_cache_tokens", "serve_peak_cache_tokens", "gauge",
     "Peak cache occupancy in tokens"),
    ("cache_bytes", "serve_cache_bytes", "gauge",
     "Total cache allocation in bytes"),
    ("per_device_pool_bytes", "serve_per_device_pool_bytes", "gauge",
     "One device's share of the KV cache"),
    ("per_device_weight_bytes", "serve_per_device_weight_bytes", "gauge",
     "One device's share of the served weights"),
    ("tp_degree", "serve_tp_degree", "gauge",
     "Tensor-parallel degree of the serving mesh"),
    ("decode_step_s", "serve_decode_step_seconds", "gauge",
     "Mean wall seconds per device decode step"),
    ("ttft_p50_s", "serve_ttft_p50_seconds", "gauge",
     "Submit-to-first-token p50 over all finished requests"),
    ("ttft_p95_s", "serve_ttft_p95_seconds", "gauge",
     "Submit-to-first-token p95 over all finished requests"),
    ("latency_p50_s", "serve_latency_p50_seconds", "gauge",
     "Submit-to-finish p50 over all finished requests"),
    ("latency_p95_s", "serve_latency_p95_seconds", "gauge",
     "Submit-to-finish p95 over all finished requests"),
)


class ServeMetrics:
    """The engine's metrics surface: pushed histograms + scrape-time
    projection of ``engine.stats()`` (see module docstring)."""

    def __init__(self):
        self.reset()

    def reset(self) -> None:
        self.ttft = Histogram(
            "serve_ttft_seconds",
            "Submit-to-first-token latency (admission wave granularity)")
        self.tpot = Histogram(
            "serve_tpot_seconds",
            "Per-output-token latency after the first token")
        self.latency = Histogram(
            "serve_request_latency_seconds",
            "Submit-to-finish request latency")

    # ---- engine-side pushes ----
    def observe_ttft(self, seconds: Optional[float]) -> None:
        self.ttft.observe(seconds)

    def observe_finished(self, latency_s: Optional[float],
                         decode_s: Optional[float], n_tokens: int) -> None:
        """One finished request: total latency plus its mean TPOT
        (decode seconds over the tokens after the first)."""
        self.latency.observe(latency_s)
        if decode_s is not None and n_tokens > 1:
            self.tpot.observe(decode_s / (n_tokens - 1))

    # ---- scrape-time rendering ----
    def render(self, stats: Dict) -> str:
        """Prometheus text for ``stats`` (an ``engine.stats()`` dict)
        plus the pushed histograms."""
        lines: List[str] = []
        for key, name, typ, help_ in STAT_METRICS:
            v = stats.get(key)
            if v is None or isinstance(v, (str, dict, list)):
                continue
            lines += [f"# HELP {name} {help_}", f"# TYPE {name} {typ}",
                      f"{name} {_fmt(v)}"]
        cv = stats.get("compile_variants") or {}
        if cv:
            lines += ["# HELP serve_compile_variants Live compiled "
                      "variants per wave family",
                      "# TYPE serve_compile_variants gauge"]
            lines += [f'serve_compile_variants{{family="{f}"}} {_fmt(n)}'
                      for f, n in sorted(cv.items())]
        for h in (self.ttft, self.tpot, self.latency):
            lines.append(h.render())
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict:
        """JSON-safe digest of the pushed histograms (rides along in
        ``AsyncFrontend.stats()`` / ``GET /v1/stats``)."""
        return {"ttft": self.ttft.snapshot(), "tpot": self.tpot.snapshot(),
                "latency": self.latency.snapshot()}


def parse_prometheus(text: str) -> Dict[str, float]:
    """Minimal exposition-format parser: ``{"name": v, 'name{le="x"}': v}``.

    Raises ValueError on any malformed sample line, so tests double as a
    well-formedness check of :meth:`ServeMetrics.render` output.

    >>> parse_prometheus('# HELP x y\\n# TYPE x counter\\nx 3\\n')
    {'x': 3.0}
    """
    out: Dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name, _, value = line.rpartition(" ")
        if not name:
            raise ValueError(f"malformed sample line: {line!r}")
        out[name] = float(value)        # ValueError on garbage values
    return out
