"""Runtime observability for the serve stack.

Three small, dependency-free layers (the runtime twin of the static
serve-graph auditor in ``repro.analysis``):

* :mod:`repro.obs.trace` — a bounded ring-buffer tracer: engine-step
  spans (``admit`` / ``prefill_wave`` / ``tail_wave`` / ``decode_chunk``
  / ``spec_draft`` / ``spec_verify`` / ``swap_out`` / ``swap_in`` /
  ``cow`` / ``harvest`` plus host-side ``schedule`` / ``sync`` gaps) and
  per-request lifecycle events, correlated by request uid + step index.
* :mod:`repro.obs.export` — Chrome/Perfetto ``trace_event`` JSON export
  and the report functions behind ``tools/trace_report.py``.
* :mod:`repro.obs.metrics` — counter/gauge/histogram registry rendered
  as Prometheus text at ``GET /v1/metrics``.

``trace``/``metrics`` import nothing from ``repro.serve`` (the serve
layer imports *them*), so there is no import cycle; ``export`` is pulled
in explicitly by its consumers.
"""
from repro.obs.metrics import ServeMetrics, parse_prometheus
from repro.obs.trace import NULL_TRACER, Span, Tracer

__all__ = ["Tracer", "Span", "NULL_TRACER", "ServeMetrics",
           "parse_prometheus"]
