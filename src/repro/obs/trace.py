"""Bounded ring-buffer runtime tracer for the serve engine.

One :class:`Tracer` rides on a ``ServeEngine``. The engine opens a
:class:`Span` around every host-side phase of a step — admission, each
compiled wave dispatch, the blocking device syncs, harvest, swap traffic
— and emits instant *events* for the per-request lifecycle
(``submit → queued → admitted → first_token → … →
finished | shed | preempted | swap_resumed``). Spans and events both
carry the engine step index, events additionally the request uid, so a
trace correlates "what the engine was doing" with "where each request's
latency went".

Design constraints, in order:

1. **Disabled means free.** The engine's TTFT/rate bookkeeping reads
   span durations, so a span always measures its wall time (two
   ``perf_counter`` calls — exactly the ``t0``/``dt`` plumbing it
   replaced); but with ``enabled=False`` nothing is recorded: ``event``
   / ``annotate`` return on one predicate, ``Span.__exit__`` commits
   nothing, and the nesting stack is never touched. The
   ``observability`` benchmark section CI-gates this at < 2% tok/s.
2. **Bounded memory.** The buffer is a ``deque(maxlen=capacity)``:
   long-running servers evict the oldest records instead of growing;
   ``dropped`` counts evictions so exports can say the window is
   truncated.
3. **No dependencies.** Pure stdlib — importable from the scheduler /
   allocator layers without touching jax.

Record shapes (plain dicts, the export layer's input contract)::

    {"ph": "span", "name": ..., "t0": s, "dur": s, "step": i,
     "depth": d, "args": {...} | None}
    {"ph": "event", "name": ..., "uid": u | None, "t": s, "step": i,
     "args": {...} | None}

Timestamps are raw ``perf_counter`` seconds; ``Tracer.t0`` (reset by
``clear``) is the export origin.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional

__all__ = ["Span", "Tracer", "NULL_TRACER", "SPAN_NAMES"]

# the span vocabulary the engine emits (docs + export track ordering;
# unknown names still trace fine — they get tracks after these)
SPAN_NAMES = ("step", "admit", "schedule", "prefill_wave", "tail_wave",
              "decode", "decode_chunk", "spec_draft", "spec_verify",
              "harvest", "swap_out", "swap_in", "cow", "sync")

DEFAULT_CAPACITY = 1 << 16


class Span:
    """One timed host-side phase. Use as a context manager::

        with tracer.span("decode_chunk", rows=3) as sp:
            ...
        elapsed = sp.dt          # measured even when tracing is off

    ``args`` is a mutable dict — callers may add fields before exit
    (e.g. row counts known only after the work ran).
    """

    __slots__ = ("_tracer", "name", "args", "t0", "dt")

    def __init__(self, tracer: "Tracer", name: str, args: Optional[Dict]):
        self._tracer = tracer
        self.name = name
        self.args = args if args is not None else {}
        self.t0 = 0.0
        self.dt = 0.0

    def __enter__(self) -> "Span":
        tr = self._tracer
        if tr.enabled:
            tr._stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.dt = time.perf_counter() - self.t0
        tr = self._tracer
        if tr.enabled:
            if tr._stack and tr._stack[-1] is self:
                tr._stack.pop()
            tr._commit(self)


class Tracer:
    """Bounded ring-buffer tracer (see module docstring).

    Args:
        capacity: ring size in records; the oldest records are evicted
            once exceeded (``dropped`` counts them).
        enabled: record anything at all. A disabled tracer still hands
            out measuring spans (the engine's rate bookkeeping reads
            their ``dt``) but commits nothing.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 enabled: bool = True):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.enabled = enabled
        self.clear()

    def clear(self) -> None:
        """Drop every record and restart the export time origin (the
        engine clears its tracer on ``reset()`` so benchmark reruns
        don't inherit the warmup pass's records)."""
        self._buf: deque = deque(maxlen=self.capacity)
        self._total = 0
        self._stack: List[Span] = []
        self.step = 0                    # engine step index, set per step
        self.t0 = time.perf_counter()    # export origin
        self.wall_t0 = time.time()       # wall-clock anchor for reports

    # ---- recording ----
    def span(self, name: str, **args) -> Span:
        """Open a span; always measures, records only when enabled."""
        return Span(self, name, args or None)

    def event(self, name: str, uid: Optional[int] = None, **args) -> None:
        """Record one instant (request-lifecycle) event."""
        if not self.enabled:
            return
        self._total += 1
        self._buf.append({"ph": "event", "name": name, "uid": uid,
                          "t": time.perf_counter(), "step": self.step,
                          "args": args or None})

    def annotate(self, **kv) -> None:
        """Attach fields to the innermost open span (no-op when none is
        open or tracing is off). The wave registry uses this to mark the
        enclosing span when its jit call compiled a fresh variant —
        the trace-side half of the compile-vs-execute split."""
        if self.enabled and self._stack:
            self._stack[-1].args.update(kv)

    def _commit(self, span: Span) -> None:
        self._total += 1
        self._buf.append({"ph": "span", "name": span.name, "t0": span.t0,
                          "dur": span.dt, "step": self.step,
                          "depth": len(self._stack),
                          "args": span.args or None})

    # ---- reading ----
    def events(self) -> List[Dict]:
        """Snapshot of the buffered records, oldest first."""
        return list(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def dropped(self) -> int:
        """Records evicted by the ring bound since the last clear."""
        return self._total - len(self._buf)


# shared disabled tracer: the default for components constructed without
# one (scheduler, engine), so call sites never branch on None
NULL_TRACER = Tracer(capacity=1, enabled=False)
