"""Architecture registry: ``--arch <id>`` lookup for full and reduced configs."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, TrainConfig

# arch id -> module name
_ARCH_MODULES = {
    "qwen2.5-3b": "qwen2_5_3b",
    "qwen2-7b": "qwen2_7b",
    "qwen3-14b": "qwen3_14b",
    "qwen3-32b": "qwen3_32b",
    "whisper-large-v3": "whisper_large_v3",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "xlstm-125m": "xlstm_125m",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def _module(arch: str):
    try:
        mod = _ARCH_MODULES[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_reduced_config(arch: str) -> ModelConfig:
    return _module(arch).reduced()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def arch_shape_cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; long_500k only where sub-quadratic.

    Encoder-decoder archs keep decode shapes (the decoder decodes);
    pure full-attention archs skip long_500k per the assignment.
    """
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            skip = shape.name == "long_500k" and not cfg.supports_long_context
            if skip and not include_skipped:
                continue
            cells.append((arch, shape.name) if not include_skipped
                         else (arch, shape.name, skip))
    return cells


__all__ = [
    "ARCH_IDS", "SHAPES", "ModelConfig", "ShapeConfig", "TrainConfig",
    "get_config", "get_reduced_config", "get_shape", "arch_shape_cells",
]
