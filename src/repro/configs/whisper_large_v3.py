"""whisper-large-v3 — encoder-decoder backbone; conv frontend is a STUB
(input_specs provides precomputed 1500-frame embeddings). [arXiv:2212.04356]

MHA (kv=20 == heads): GQA degenerate case. Decoder layers carry self- and
cross-attention; both caches are quantized to C-bits.
"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,              # decoder layers
    encoder_layers=32,
    encoder_seq=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51_866,
    tie_embeddings=True,
    rope_theta=0.0,           # no rope: learned absolute positions
    norm_type="ln",
    mlp_type="gelu",
    max_position_embeddings=36_864,
    block_pattern=(BLOCK_ATTN,),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(name="whisper-large-v3-reduced", n_layers=2,
                          encoder_layers=2, encoder_seq=32, d_model=64,
                          n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
                          vocab_size=256, max_position_embeddings=128)
