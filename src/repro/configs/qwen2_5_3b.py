"""qwen2.5-3b — dense GQA decoder, QKV bias. [hf:Qwen/Qwen2.5-0.5B family]"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_ff=11008,
    vocab_size=151_936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    block_pattern=(BLOCK_ATTN,),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(name="qwen2.5-3b-reduced", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=256)
