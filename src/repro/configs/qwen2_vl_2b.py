"""qwen2-vl-2b — VLM decoder backbone with M-RoPE; the vision tower is a STUB
(input_specs provides precomputed patch embeddings as a prefix).
[arXiv:2409.12191]"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151_936,
    qkv_bias=True,
    tie_embeddings=True,
    mrope=True,
    vision_tokens=256,         # precomputed patch-embedding prefix length
    rope_theta=1_000_000.0,
    block_pattern=(BLOCK_ATTN,),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(name="qwen2-vl-2b-reduced", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=256, vision_tokens=8)
