"""moonshot-v1-16b-a3b — MoE decoder, 64 experts top-6, per-expert d_ff=1408.
[hf:moonshotai/Moonlight-16B-A3B]"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163_840,
    n_experts=64,
    n_experts_active=6,
    rope_theta=50_000.0,
    block_pattern=(BLOCK_ATTN,),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(name="moonshot-v1-16b-a3b-reduced", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                          d_ff=64, vocab_size=256, n_experts=8,
                          n_experts_active=2)
