"""Base configuration dataclasses for the SiLQ framework.

Every architecture in ``src/repro/configs/<arch>.py`` exports a full-size
``CONFIG`` (exact public-literature dims) and a ``reduced()`` factory used by
the CPU smoke tests. Shapes are the four assigned (seq_len, global_batch)
cells; decode shapes drive ``serve_step`` rather than ``train_step``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Block kinds understood by models/model.py
BLOCK_ATTN = "attn"            # global causal attention
BLOCK_LOCAL_ATTN = "local_attn"  # sliding-window causal attention
BLOCK_RGLRU = "rglru"          # RecurrentGemma RG-LRU recurrent block
BLOCK_MLSTM = "mlstm"          # xLSTM matrix-memory block
BLOCK_SLSTM = "slstm"          # xLSTM scalar-memory block

ATTENTION_BLOCKS = (BLOCK_ATTN, BLOCK_LOCAL_ATTN)
RECURRENT_BLOCKS = (BLOCK_RGLRU, BLOCK_MLSTM, BLOCK_SLSTM)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # attention details ------------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0         # 0 -> no SWA for BLOCK_ATTN layers
    local_window: int = 2048        # window for BLOCK_LOCAL_ATTN layers
    # block pattern ----------------------------------------------------------
    # Repeating pattern of block kinds; tiled/truncated to n_layers.
    block_pattern: Tuple[str, ...] = (BLOCK_ATTN,)
    # MoE ---------------------------------------------------------------------
    n_experts: int = 0
    n_experts_active: int = 0
    # encoder-decoder (whisper) ------------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 1500         # precomputed frame embeddings from the stub
    # vlm ----------------------------------------------------------------------
    mrope: bool = False             # multimodal rotary (3 components)
    vision_tokens: int = 0          # prefix of precomputed patch embeddings
    # recurrent dims ------------------------------------------------------------
    lru_width: int = 0              # RG-LRU width (0 -> d_model)
    conv1d_width: int = 4           # temporal conv width in RG-LRU block
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    # misc -----------------------------------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dropout: float = 0.0            # SiLQ disables dropout (KD interplay)
    norm_type: str = "rms"          # rms | ln (whisper)
    mlp_type: str = "swiglu"        # swiglu | gelu (whisper)
    max_position_embeddings: int = 0  # >0 -> learned absolute positions (whisper)

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    def layer_kinds(self) -> Tuple[str, ...]:
        """Block kind for every decoder layer (pattern tiled to n_layers)."""
        pat = self.block_pattern
        reps = (self.n_layers + len(pat) - 1) // len(pat)
        return tuple((pat * reps)[: self.n_layers])

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def supports_long_context(self) -> bool:
        """True if decode memory is sub-linear in context (bounded cache)."""
        kinds = set(self.layer_kinds())
        if kinds & {BLOCK_RGLRU, BLOCK_MLSTM, BLOCK_SLSTM}:
            return True
        # pure attention: only if *every* attention layer is window-bounded
        if BLOCK_ATTN in kinds and self.sliding_window == 0:
            return False
        return True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for 6*N*D roofline bookkeeping) -----------------
    def param_counts(self) -> dict:
        """Analytic parameter counts: total and active (MoE-aware)."""
        d, hd = self.d_model, self.resolved_head_dim
        qd, kvd = self.q_dim, self.kv_dim
        per_layer = {}
        attn = d * qd + 2 * d * kvd + qd * d  # q,k,v,o
        if self.qkv_bias:
            attn += qd + 2 * kvd
        dense_mlp = 3 * d * self.d_ff  # SwiGLU gate/up/down
        moe_mlp = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        active_moe_mlp = self.n_experts_active * 3 * d * self.d_ff + d * self.n_experts
        lru = self.resolved_lru_width
        rglru_blk = (2 * d * lru + lru * d            # in x2 (gate), out
                     + self.conv1d_width * lru + 2 * lru * lru)  # conv + a/x gates
        m_in = int(self.mlstm_proj_factor * d)
        mlstm_blk = 2 * d * m_in + m_in * d + 3 * m_in * m_in + 2 * m_in
        s_in = int(self.slstm_proj_factor * d)
        slstm_blk = 8 * d * d + 2 * d * s_in  # w_x + r_h (4d each) + up/down
        total = active = 0
        for kind in self.layer_kinds():
            if kind in ATTENTION_BLOCKS:
                t = attn + (moe_mlp if self.is_moe else dense_mlp)
                a = attn + (active_moe_mlp if self.is_moe else dense_mlp)
            elif kind == BLOCK_RGLRU:
                t = a = rglru_blk + dense_mlp
            elif kind == BLOCK_MLSTM:
                t = a = mlstm_blk + (dense_mlp if self.d_ff else 0)
            elif kind == BLOCK_SLSTM:
                t = a = slstm_blk + (dense_mlp if self.d_ff else 0)
            else:
                raise ValueError(kind)
            total += t
            active += a
        if self.encoder_layers:
            enc = self.encoder_layers * (attn + dense_mlp)
            xattn = self.n_layers * (d * qd + 2 * d * kvd + qd * d)
            total += enc + xattn
            active += enc + xattn
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        return {
            "total": total + emb + head,
            "active": active + emb + head,
            "body_total": total,
            "body_active": active,
        }


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclass(frozen=True)
class TrainConfig:
    """SiLQ training hyper-parameters (paper Appendix B)."""
    precision: str = "A8d-C8-W4"
    learning_rate: float = 5e-6
    ref_steps: int = 8_000          # LR sqrt-rescaling reference (power sched)
    total_steps: int = 8_000
    warmup_steps: int = 0
    min_lr_ratio: float = 0.1
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-10
    batch_size: int = 128
    seq_len: int = 1024
    kd_ratio: float = 1.0           # 1.0 = pure knowledge distillation
    kd_temperature: float = 1.0
    dclm_ratio: float = 0.25        # DCLM share in instruct mixture
    act_scale_lr_mult: float = 50.0 # LSQ activation-scale LR boost
    grad_clip: float = 1.0          # global-norm gradient clipping (0 = off)
    act_calib_method: str = "quantile"   # quantile | max
    wgt_calib_method: str = "mse"        # mse | lsq
    calib_batches: int = 5
    calib_batch_size: int = 128
    grad_compression: str = "none"  # none | int8  (beyond-paper DP trick)
    remat: str = "none"             # none | block  (activation checkpointing)
    seed: int = 0

    def scaled_lr(self) -> float:
        """Power-scheduler rule: lr ~ 1/sqrt(steps / ref_steps)."""
        return self.learning_rate * (self.ref_steps / self.total_steps) ** 0.5
