"""xlstm-125m — sLSTM + mLSTM blocks (no separate FFN, d_ff=0); mLSTM matrix
memory is the cache analogue. [arXiv:2405.04517]"""
from repro.configs.base import BLOCK_MLSTM, BLOCK_SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                    # blocks carry their own up-projections
    vocab_size=50_304,
    block_pattern=(BLOCK_MLSTM,) * 5 + (BLOCK_SLSTM,),  # ~5:1 mix
    mlstm_proj_factor=2.0,
    slstm_proj_factor=4.0 / 3.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(name="xlstm-125m-reduced", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, head_dim=16, vocab_size=256,
                          block_pattern=(BLOCK_MLSTM, BLOCK_SLSTM))
