"""recurrentgemma-2b — hybrid RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427]"""
from repro.configs.base import BLOCK_LOCAL_ATTN, BLOCK_RGLRU, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,             # MQA on the local-attention layers
    d_ff=7680,
    vocab_size=256_000,
    local_window=2048,
    lru_width=2560,
    conv1d_width=4,
    tie_embeddings=True,
    block_pattern=(BLOCK_RGLRU, BLOCK_RGLRU, BLOCK_LOCAL_ATTN),
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(name="recurrentgemma-2b-reduced", n_layers=3,
                          d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
                          d_ff=128, vocab_size=256, local_window=16,
                          lru_width=64)
