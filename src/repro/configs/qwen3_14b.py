"""qwen3-14b — dense GQA decoder with qk_norm. [hf:Qwen/Qwen3-8B family]"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    block_pattern=(BLOCK_ATTN,),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(name="qwen3-14b-reduced", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=256)
