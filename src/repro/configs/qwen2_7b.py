"""qwen2-7b — dense GQA decoder, QKV bias. [arXiv:2407.10671]"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    block_pattern=(BLOCK_ATTN,),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(name="qwen2-7b-reduced", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=256)
