"""mixtral-8x7b — MoE decoder, 8 experts top-2, sliding-window attention.
[arXiv:2401.04088]"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32_000,
    n_experts=8,
    n_experts_active=2,
    sliding_window=4096,       # SWA bounds decode cache -> long_500k eligible
    rope_theta=1_000_000.0,
    block_pattern=(BLOCK_ATTN,),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(name="mixtral-8x7b-reduced", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64,
                          vocab_size=256, n_experts=4, n_experts_active=2,
                          sliding_window=32)
