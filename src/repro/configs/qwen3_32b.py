"""qwen3-32b — dense GQA decoder with qk_norm; head_dim=128 (q_dim > d_model).
[hf:Qwen/Qwen3-8B family]"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    block_pattern=(BLOCK_ATTN,),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(name="qwen3-32b-reduced", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=256)
