"""Unified model stack covering all assigned architectures.

One decoder skeleton parameterized by ``ModelConfig.block_pattern``:
dense/MoE GQA transformers (qwen*, mixtral, moonshot), hybrid RG-LRU +
local-attention (recurrentgemma), mLSTM/sLSTM (xlstm), an encoder-decoder
wrapper (whisper), and an M-RoPE VLM backbone (qwen2-vl).

Layers are scanned: the repeating super-block (= block_pattern) is stacked
along a leading ``repeat`` axis and driven by ``lax.scan``, keeping HLO size
depth-independent (critical for the 512-device dry-run compile). Pattern
remainders form a second, repeat-1 segment.

Three entry points per model:
* ``forward``      — training / teacher path (logits [+ calib stats, moe aux])
* ``prefill``      — forward + emit quantized caches for serving
* ``decode_step``  — one token against the quantized cache
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTENTION_BLOCKS, BLOCK_ATTN,
                                BLOCK_LOCAL_ATTN, BLOCK_MLSTM, BLOCK_RGLRU,
                                BLOCK_SLSTM, ModelConfig)
from repro.core.qat import QuantCtx, init_linear, qlinear
from repro.models import blocks as B
from repro.models import recurrent as R
from repro.models.common import (init_norm, mrope_tables, norm, rope_tables,
                                 subcol)


# --------------------------------------------------------------------------
# Layer plan
# --------------------------------------------------------------------------

def segment_plan(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    """[(kinds, repeat), ...] — full-pattern segment + optional remainder."""
    pat = cfg.block_pattern
    n_full, rem = divmod(cfg.n_layers, len(pat))
    plan = []
    if n_full:
        plan.append((pat, n_full))
    if rem:
        plan.append((pat[:rem], 1))
    return plan


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def _init_block(cfg: ModelConfig, kind: str, key, *, decoder_cross: bool,
                dtype) -> Dict:
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"ln1": init_norm(cfg.d_model, cfg.norm_type, dtype)}
    if kind in ATTENTION_BLOCKS:
        p["attn"] = B.init_attention(cfg, ks[0], dtype=dtype)
        if decoder_cross:
            p["ln_x"] = init_norm(cfg.d_model, cfg.norm_type, dtype)
            p["xattn"] = B.init_attention(cfg, ks[1], cross=True, dtype=dtype)
        p["ln2"] = init_norm(cfg.d_model, cfg.norm_type, dtype)
        p["moe" if cfg.is_moe else "mlp"] = (
            B.init_moe(cfg, ks[2], dtype) if cfg.is_moe
            else B.init_mlp(cfg, ks[2], dtype))
    elif kind == BLOCK_RGLRU:
        p["rglru"] = R.init_rglru(cfg, ks[0], dtype)
        p["ln2"] = init_norm(cfg.d_model, cfg.norm_type, dtype)
        p["mlp"] = B.init_mlp(cfg, ks[1], dtype)
    elif kind == BLOCK_MLSTM:
        p["cell"] = R.init_mlstm(cfg, ks[0], dtype)
    elif kind == BLOCK_SLSTM:
        p["cell"] = R.init_slstm(cfg, ks[0], dtype)
    else:
        raise ValueError(kind)
    return p


def _init_segment(cfg, kinds, repeat, key, *, decoder_cross, dtype):
    def one(k):
        kk = jax.random.split(k, len(kinds))
        return {str(i): _init_block(cfg, kind, kk[i],
                                    decoder_cross=decoder_cross, dtype=dtype)
                for i, kind in enumerate(kinds)}
    layers = [one(k) for k in jax.random.split(key, repeat)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict:
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": {"w": (jax.random.normal(ks[0], (cfg.vocab_size,
                                                  cfg.d_model), jnp.float32)
                        * 0.02).astype(dtype)},
        "final_norm": init_norm(cfg.d_model, cfg.norm_type, dtype),
        "segments": [
            _init_segment(cfg, kinds, rep, jax.random.fold_in(ks[1], i),
                          decoder_cross=cfg.is_encdec, dtype=dtype)
            for i, (kinds, rep) in enumerate(segment_plan(cfg))],
    }
    if cfg.tie_embeddings:
        # tied head still owns its quantizer scales (8-bit head site)
        params["head"] = {"s_w": jnp.ones((1, cfg.vocab_size), jnp.float32),
                          "s_in": jnp.float32(1.0)}
    else:
        params["head"] = init_linear(ks[2], cfg.d_model, cfg.vocab_size,
                                     dtype=dtype)
    if cfg.max_position_embeddings:
        params["pos_embed"] = {
            "w": (jax.random.normal(ks[3], (cfg.max_position_embeddings,
                                            cfg.d_model), jnp.float32)
                  * 0.02).astype(dtype)}
    if cfg.is_encdec:
        params["encoder"] = {
            "pos_embed": {"w": (jax.random.normal(
                ks[4], (cfg.encoder_seq, cfg.d_model), jnp.float32)
                * 0.02).astype(dtype)},
            "segments": [_init_segment(cfg, (BLOCK_ATTN,), cfg.encoder_layers,
                                       ks[5], decoder_cross=False,
                                       dtype=dtype)],
            "final_norm": init_norm(cfg.d_model, cfg.norm_type, dtype),
        }
    return params


# --------------------------------------------------------------------------
# Forward (train / teacher / calibration)
# --------------------------------------------------------------------------

def _rope_for(cfg: ModelConfig, batch: Dict, S: int):
    if not cfg.rope_theta:
        return None
    hd = cfg.resolved_head_dim
    if cfg.mrope and "positions" in batch:
        return mrope_tables(batch["positions"], hd, cfg.rope_theta)
    return rope_tables(jnp.arange(S), hd, cfg.rope_theta)


def _ffn_tail(cfg, ctx, p, x, col=None):
    """ln2 + MoE/MLP + residual — the post-attention half of an attention
    block, shared by the forward/prefill, decode, and chunked-prefill
    paths. Returns (x, moe_aux)."""
    h = norm(x, p["ln2"], cfg.norm_type, cfg.norm_eps)
    if cfg.is_moe:
        y, aux = B.moe_fwd(cfg, ctx, p["moe"], h, subcol(col, "moe"))
    else:
        y = B.mlp_fwd(cfg, ctx, p["mlp"], h, subcol(col, "mlp"))
        aux = jnp.float32(0.0)
    return x + y, aux


def _block_fwd(cfg, ctx, kind, p, x, consts, col, *, prefill=False):
    """Returns (x, aux, cache|None)."""
    aux = jnp.float32(0.0)
    cache = None
    if kind in ATTENTION_BLOCKS:
        window = (cfg.local_window if kind == BLOCK_LOCAL_ATTN
                  else cfg.sliding_window)
        h = norm(x, p["ln1"], cfg.norm_type, cfg.norm_eps)
        if prefill:
            a, cache_sa = B.attn_prefill(
                cfg, ctx, p["attn"], h, consts["rope"], subcol(col, "attn"),
                window=window, cache_len=consts.get("cache_len", 0),
                lengths=consts.get("lengths"),
                page_size=consts.get("page_size", 0))
            cache = {"self": cache_sa}
        else:
            a = B.attn_fwd(cfg, ctx, p["attn"], h, consts["rope"],
                           subcol(col, "attn"), window=window)
        x = x + a
        if "xattn" in p:
            h = norm(x, p["ln_x"], cfg.norm_type, cfg.norm_eps)
            if prefill:
                a, cache_xa = B.attn_prefill(
                    cfg, ctx, p["xattn"], h, None, subcol(col, "xattn"),
                    enc_out=consts["enc_out"])
                cache["cross"] = cache_xa
            else:
                a = B.attn_fwd(cfg, ctx, p["xattn"], h, None,
                               subcol(col, "xattn"),
                               enc_out=consts["enc_out"])
            x = x + a
        x, aux = _ffn_tail(cfg, ctx, p, x, col)
    elif kind == BLOCK_RGLRU:
        h = norm(x, p["ln1"], cfg.norm_type, cfg.norm_eps)
        if prefill:
            y, cache = R.rglru_prefill(cfg, ctx, p["rglru"], h,
                                       subcol(col, "rglru"))
        else:
            y = R.rglru_fwd(cfg, ctx, p["rglru"], h, subcol(col, "rglru"))
        x = x + y
        h = norm(x, p["ln2"], cfg.norm_type, cfg.norm_eps)
        x = x + B.mlp_fwd(cfg, ctx, p["mlp"], h, subcol(col, "mlp"))
    elif kind in (BLOCK_MLSTM, BLOCK_SLSTM):
        h = norm(x, p["ln1"], cfg.norm_type, cfg.norm_eps)
        mod = R.mlstm_prefill if kind == BLOCK_MLSTM else R.slstm_prefill
        fwd = R.mlstm_fwd if kind == BLOCK_MLSTM else R.slstm_fwd
        if prefill:
            y, cache = mod(cfg, ctx, p["cell"], h, subcol(col, "cell"))
        else:
            y = fwd(cfg, ctx, p["cell"], h, subcol(col, "cell"))
        x = x + y
    else:
        raise ValueError(kind)
    return x, aux, cache


def _run_stack(cfg, ctx, segments_params, plan, x, consts, *,
               collect: bool, prefill: bool = False, remat: bool = False):
    """Scan every segment. Returns (x, cols, auxs, caches)."""
    cols, auxs, caches = [], [], []
    for seg_p, (kinds, rep) in zip(segments_params, plan):
        def body(xc, layer_p):
            col = {} if collect else None
            aux = jnp.float32(0.0)
            cache = {}
            for i, kind in enumerate(kinds):
                xc, a, c = _block_fwd(cfg, ctx, kind, layer_p[str(i)], xc,
                                      consts, subcol(col, str(i)),
                                      prefill=prefill)
                aux = aux + a
                if prefill:
                    cache[str(i)] = c
            ys = (col if collect else {}, aux, cache if prefill else {})
            return xc, ys
        if remat:
            body = jax.checkpoint(body)  # per-layer activation rematerialization
        x, (col_s, aux_s, cache_s) = jax.lax.scan(body, x, seg_p)
        cols.append(col_s)
        auxs.append(jnp.sum(aux_s))
        caches.append(cache_s)
    return x, cols, auxs, caches


def _embed(cfg: ModelConfig, params, batch: Dict) -> jnp.ndarray:
    tokens = batch["tokens"]
    x = jnp.take(params["embed"]["w"], tokens, axis=0)
    if "patches" in batch:          # VLM: precomputed patch-embedding prefix
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    if "pos_embed" in params:
        S = x.shape[1]
        off = batch.get("pos_offset", 0)
        pos = params["pos_embed"]["w"]
        x = x + jax.lax.dynamic_slice_in_dim(pos, off, S, 0)[None]
    return x


def _encode(cfg, ctx, params, batch, col):
    enc = params["encoder"]
    h = batch["frames"].astype(enc["pos_embed"]["w"].dtype)
    h = h + enc["pos_embed"]["w"][None, :h.shape[1]]
    consts = {"rope": None, "enc_out": None}
    plan = [((BLOCK_ATTN,), cfg.encoder_layers)]
    # encoder attention is bidirectional: causal off via window=0 & flag
    def body(xc, layer_p):
        cc = {} if col is not None else None
        hh = norm(xc, layer_p["0"]["ln1"], cfg.norm_type, cfg.norm_eps)
        a = B.attn_fwd(cfg, ctx, layer_p["0"]["attn"], hh, None,
                       subcol(cc, "0attn"), causal=False)
        xc = xc + a
        hh = norm(xc, layer_p["0"]["ln2"], cfg.norm_type, cfg.norm_eps)
        xc = xc + B.mlp_fwd(cfg, ctx, layer_p["0"]["mlp"], hh,
                            subcol(cc, "0mlp"))
        return xc, (cc if col is not None else {})
    h, enc_cols = jax.lax.scan(body, h, enc["segments"][0])
    if col is not None:
        col["encoder"] = enc_cols
    return norm(h, enc["final_norm"], cfg.norm_type, cfg.norm_eps)


def head_logits(cfg: ModelConfig, params, ctx: QuantCtx, x: jnp.ndarray,
                col: Optional[Dict] = None) -> jnp.ndarray:
    hb = ctx.policy.head_bits
    if cfg.tie_embeddings:
        p = {"w": params["embed"]["w"].T, "s_w": params["head"]["s_w"],
             "s_in": params["head"]["s_in"]}
        if "w4a8" in params["head"]:
            # packed export of embed.w.T (attach_w4a8_exports tied-head case)
            p["w4a8"] = params["head"]["w4a8"]
    else:
        p = params["head"]
    return qlinear(ctx, x, p, subcol(col, "head"),
                   act_bits=hb, weight_bits=hb)


def forward(cfg: ModelConfig, params: Dict, ctx: QuantCtx, batch: Dict,
            collect_stats: bool = False, remat: bool = False):
    """Training/teacher forward. Returns (logits, {"moe_aux", "qstats"})."""
    x = _embed(cfg, params, batch)
    S = x.shape[1]
    col: Optional[Dict] = {} if collect_stats else None
    consts = {"rope": _rope_for(cfg, batch, S), "enc_out": None}
    if cfg.is_encdec:
        consts["enc_out"] = _encode(cfg, ctx, params, batch, col)
    x, cols, auxs, _ = _run_stack(cfg, ctx, params["segments"],
                                  segment_plan(cfg), x, consts,
                                  collect=collect_stats, remat=remat)
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    logits = head_logits(cfg, params, ctx, x, col)
    aux = {"moe_aux": sum(auxs) if auxs else jnp.float32(0.0)}
    if collect_stats:
        col["segments"] = cols
        aux["qstats"] = col
    return logits, aux


# --------------------------------------------------------------------------
# Prefill / decode (serving)
# --------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: Dict, ctx: QuantCtx, batch: Dict,
            cache_budget: int = 0, page_size: int = 0):
    """Forward pass that also emits the quantized serving cache.

    ``cache_budget``: total cache capacity (>= prompt length; extra room for
    decode steps). ``batch["lengths"]`` (B,) optionally marks the valid
    prefix of right-padded rows (batched mixed-length admission): logits are
    taken at each row's last *real* token and the cache records true
    lengths/positions. ``page_size`` > 0 emits *block-shaped* attention
    caches (B, nb, Hkv, page_size, D) for the paged serve engine to scatter
    into its global pool (attention-only decoders). Returns
    (logits, cache_pytree).
    """
    lengths = batch.get("lengths")
    if (lengths is not None or page_size) and (
            cfg.is_encdec
            or any(k not in ATTENTION_BLOCKS for k in cfg.block_pattern)):
        # recurrent scans fold right-padding into their state; only causal
        # attention isolates real tokens from pads
        raise ValueError(
            "batch['lengths'] (right-padded prefill) and page_size (paged "
            "cache) require an attention-only decoder; "
            f"{cfg.name!r} has block pattern {cfg.block_pattern}")
    x = _embed(cfg, params, batch)
    S = x.shape[1]
    consts = {"rope": _rope_for(cfg, batch, S), "enc_out": None,
              "cache_len": cache_budget or S, "lengths": lengths,
              "page_size": page_size}
    if cfg.is_encdec:
        consts["enc_out"] = _encode(cfg, ctx, params, batch, None)
    x, _, _, caches = _run_stack(cfg, ctx, params["segments"],
                                 segment_plan(cfg), x, consts,
                                 collect=False, prefill=True)
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    if lengths is None:
        x_last = x[:, -1:]
        position = jnp.full((x.shape[0],), S, jnp.int32)
    else:
        x_last = jnp.take_along_axis(
            x, jnp.maximum(lengths - 1, 0)[:, None, None], axis=1)
        position = lengths.astype(jnp.int32)
    logits = head_logits(cfg, params, ctx, x_last)
    return logits, {"segments": caches, "position": position}


def _block_decode(cfg, ctx, kind, p, x1, cache, positions, block_tbl=None):
    if kind in ATTENTION_BLOCKS:
        window = (cfg.local_window if kind == BLOCK_LOCAL_ATTN
                  else cfg.sliding_window)
        h = norm(x1, p["ln1"], cfg.norm_type, cfg.norm_eps)
        a, new_sa = B.attn_decode(cfg, ctx, p["attn"], h, cache["self"],
                                  positions, window=window,
                                  block_tbl=block_tbl)
        x1 = x1 + a
        new_cache = {"self": new_sa}
        if "xattn" in p:
            h = norm(x1, p["ln_x"], cfg.norm_type, cfg.norm_eps)
            a, _ = B.attn_decode(cfg, ctx, p["xattn"], h, cache["cross"],
                                 positions, cross=True)
            x1 = x1 + a
            new_cache["cross"] = cache["cross"]
        x1, _ = _ffn_tail(cfg, ctx, p, x1)
        return x1, new_cache
    if kind == BLOCK_RGLRU:
        h = norm(x1, p["ln1"], cfg.norm_type, cfg.norm_eps)
        y, new_c = R.rglru_decode(cfg, ctx, p["rglru"], h, cache)
        x1 = x1 + y
        h = norm(x1, p["ln2"], cfg.norm_type, cfg.norm_eps)
        return x1 + B.mlp_fwd(cfg, ctx, p["mlp"], h), new_c
    h = norm(x1, p["ln1"], cfg.norm_type, cfg.norm_eps)
    dec = R.mlstm_decode if kind == BLOCK_MLSTM else R.slstm_decode
    y, new_c = dec(cfg, ctx, p["cell"], h, cache)
    return x1 + y, new_c


def decode_step(cfg: ModelConfig, params: Dict, ctx: QuantCtx,
                tokens1: jnp.ndarray, cache: Dict):
    """One decode step. tokens1 (B, 1) -> (logits (B, 1, V), new cache).

    A ``block_tbl`` key in the cache switches attention layers to the paged
    layout: commits and reads route through the per-slot block table into
    the global pool (see ``init_cache`` with ``num_blocks``).
    """
    positions = cache["position"]
    block_tbl = cache.get("block_tbl")
    batch = {"tokens": tokens1, "pos_offset": 0}
    x = jnp.take(params["embed"]["w"], tokens1, axis=0)
    if "pos_embed" in params:
        x = x + jnp.take(params["pos_embed"]["w"],
                         jnp.minimum(positions,
                                     params["pos_embed"]["w"].shape[0] - 1),
                         axis=0)[:, None]
    new_caches = []
    for seg_p, seg_c, (kinds, rep) in zip(params["segments"],
                                          cache["segments"],
                                          segment_plan(cfg)):
        def body(xc, inp):
            layer_p, layer_c = inp
            new_lc = {}
            for i, kind in enumerate(kinds):
                xc, nc = _block_decode(cfg, ctx, kind, layer_p[str(i)], xc,
                                       layer_c[str(i)], positions,
                                       block_tbl)
                new_lc[str(i)] = nc
            return xc, new_lc
        x, new_c = jax.lax.scan(body, x, (seg_p, seg_c))
        new_caches.append(new_c)
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    logits = head_logits(cfg, params, ctx, x)
    new_cache = {"segments": new_caches, "position": positions + 1}
    if block_tbl is not None:
        new_cache["block_tbl"] = block_tbl
    return logits, new_cache


def _tail_prologue(cfg: ModelConfig, params: Dict, tokens: jnp.ndarray,
                   cache: Dict, slot: jnp.ndarray, offset: jnp.ndarray,
                   hist_blocks: int, caller: str):
    """Shared entry of the batched-window paths (``prefill_tail`` and the
    speculative ``spec_verify``): embed one window per row at per-row
    absolute offsets, build per-position RoPE tables, and pull each
    row's (optionally ``hist_blocks``-truncated) block table."""
    if "block_tbl" not in cache:
        raise ValueError(f"{caller} requires a paged cache "
                         "(init_cache(..., num_blocks=...))")
    C = tokens.shape[1]
    positions = offset[:, None] + jnp.arange(C)[None]       # (n, C)
    x = jnp.take(params["embed"]["w"], tokens, axis=0)      # (n, C, d)
    if "pos_embed" in params:
        pe = params["pos_embed"]["w"]
        x = x + jnp.take(pe, jnp.minimum(positions, pe.shape[0] - 1),
                         axis=0)
    rope = None
    if cfg.rope_theta:
        rope = rope_tables(positions, cfg.resolved_head_dim, cfg.rope_theta)
    tbl = cache["block_tbl"][slot]                          # (n, T)
    if hist_blocks:
        tbl = tbl[:, :hist_blocks]
    return x, rope, tbl


def _tail_stack(cfg: ModelConfig, params: Dict, ctx: QuantCtx,
                x: jnp.ndarray, rope, cache: Dict, tbl: jnp.ndarray,
                slot: jnp.ndarray, offset: jnp.ndarray,
                chunk_len: jnp.ndarray, attn_fn):
    """Scan the decoder stack over one batched window, committing every
    layer's K/V through the block table. ``attn_fn`` is the per-layer
    attention: ``blocks.attn_chunk_prefill`` for tail/chunked prefill
    (exact bf16 window K/V) or ``blocks.attn_spec_verify`` for the
    speculative verify-wave (decode-exact quantized reads) — both share
    this loop so the batched-window contract can't diverge between the
    two paths. Returns (final-norm'd x, new cache segments)."""
    new_segments = []
    for seg_p, seg_c, (kinds, rep) in zip(params["segments"],
                                          cache["segments"],
                                          segment_plan(cfg)):
        def body(xc, inp):
            layer_p, layer_c = inp
            new_lc = {}
            for i, kind in enumerate(kinds):
                p = layer_p[str(i)]
                h = norm(xc, p["ln1"], cfg.norm_type, cfg.norm_eps)
                a, new_sa = attn_fn(cfg, ctx, p["attn"], h, rope,
                                    layer_c[str(i)]["self"], tbl, slot,
                                    offset, chunk_len)
                xc = xc + a
                xc, _ = _ffn_tail(cfg, ctx, p, xc)
                new_lc[str(i)] = {"self": new_sa}
            return xc, new_lc
        x, new_c = jax.lax.scan(body, x, (seg_p, seg_c))
        new_segments.append(new_c)
    return norm(x, params["final_norm"], cfg.norm_type,
                cfg.norm_eps), new_segments


def prefill_tail(cfg: ModelConfig, params: Dict, ctx: QuantCtx,
                 tokens: jnp.ndarray, cache: Dict, slot: jnp.ndarray,
                 start: jnp.ndarray, n_tokens: jnp.ndarray,
                 hist_blocks: int = 0):
    """Partial prefill from per-row token offsets for a batch of slots.

    The entry point behind both *prefix-shared admission* (the first
    ``start[i]`` tokens were found in the prefix cache and their pool
    blocks are already mapped into ``cache["block_tbl"][slot[i]]`` — only
    the uncached tail is computed) and *chunked prefill* (one fixed-size
    window of a long prompt per call). ``tokens`` (n, C) int32 holds one
    window per row, row i's first token sitting at absolute position
    ``start[i]``; only the first ``n_tokens[i]`` are real (windows are
    right-padded so every call compiles to the same program). Rows with
    ``slot`` at the out-of-range sentinel and ``n_tokens == 0`` are
    padding — the engine buckets the wave width to a power of two — and
    commit nothing.

    Each row's queries attend over the ``start[i]`` tokens already
    resident in the pool — gathered through the row's table and
    dequantized at read, exactly what decode reads
    (``blocks.attn_chunk_prefill``) — plus the window itself (causal,
    exact bf16). The window's K/V are quantized and committed through the
    table at per-row write offsets; the engine must have grown each table
    to cover ``start + n_tokens`` tokens and resolved copy-on-write for
    any shared block in that write range *before* calling. Rows are
    independent, so a batched tail-wave produces exactly the tokens the
    serialized single-slot path would.

    ``hist_blocks`` (trace-time constant > 0) truncates the table walk to
    each row's first ``hist_blocks`` entries so the history gather scales
    with the longest co-batched prompt, not ``max_seq_len`` — it must
    cover every row's ``start + n_tokens`` tokens (the engine buckets it
    to a power of two to bound compile variants). Requires the paged
    attention-only cache (see ``init_cache`` with ``num_blocks``).

    Returns (logits (n, V) at each row's last real token, new cache) —
    meaningful for rows on the final window of their prompt (they feed
    the first sampled token).
    """
    offset, chunk_len = start, n_tokens
    x, rope, tbl = _tail_prologue(cfg, params, tokens, cache, slot, offset,
                                  hist_blocks, caller="prefill_tail")
    x, new_segments = _tail_stack(cfg, params, ctx, x, rope, cache, tbl,
                                  slot, offset, chunk_len,
                                  B.attn_chunk_prefill)
    x_last = jnp.take_along_axis(
        x, jnp.maximum(chunk_len - 1, 0)[:, None, None], axis=1)
    logits = head_logits(cfg, params, ctx, x_last)[:, 0]
    return logits, {
        "segments": new_segments,
        "position": cache["position"].at[slot].set(offset + chunk_len,
                                                   mode="drop"),
        "block_tbl": cache["block_tbl"]}


def spec_verify(cfg: ModelConfig, params: Dict, ctx: QuantCtx,
                tokens: jnp.ndarray, cache: Dict, slot: jnp.ndarray,
                start: jnp.ndarray, n_tokens: jnp.ndarray,
                hist_blocks: int = 0):
    """Speculative-decode verify pass: target logits at EVERY window
    position of a batch of slots, in one compiled call.

    Same per-row ``(start, n_tokens)`` batched-window contract as
    :func:`prefill_tail` — ``tokens`` (n, C) holds row i's window
    ``[last_committed_token, draft_1..draft_k]`` starting at absolute
    position ``start[i]``, padded rows carry ``n_tokens == 0`` and the
    slot sentinel — but where a chunked prefill attends with exact bf16
    window K/V, the verify pass commits the window's *quantized* K/V to
    the pool first and reads them back dequantized
    (``blocks.attn_spec_verify``), reproducing sequential decode-step
    numerics bit-for-bit: logits at window position j equal what
    ``decode_step`` would produce after consuming the window prefix
    through j. The caller samples/accepts against these logits and rolls
    the committed suffix back (device counters + allocator ``trim``) for
    the rejected positions.

    ``hist_blocks`` bounds the per-row table walk like in
    ``prefill_tail`` (must cover every row's ``start + n_tokens``).
    Returns (logits (n, C, V), new cache) — the cache's ``length`` /
    ``position`` are advanced to the full window extent; the engine
    re-clamps them to the accepted extent after acceptance.
    """
    offset, chunk_len = start, n_tokens
    x, rope, tbl = _tail_prologue(cfg, params, tokens, cache, slot, offset,
                                  hist_blocks, caller="spec_verify")
    x, new_segments = _tail_stack(cfg, params, ctx, x, rope, cache, tbl,
                                  slot, offset, chunk_len,
                                  B.attn_spec_verify)
    logits = head_logits(cfg, params, ctx, x)
    return logits, {
        "segments": new_segments,
        "position": cache["position"].at[slot].set(offset + chunk_len,
                                                   mode="drop"),
        "block_tbl": cache["block_tbl"]}


# --------------------------------------------------------------------------
# Cache allocation (for dry-run ShapeDtypeStructs and the serve engine)
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, ctx: QuantCtx, batch_size: int,
               cache_len: int, *, num_blocks: int = 0, page_size: int = 0,
               table_len: int = 0) -> Dict:
    """Blank serving cache with total capacity ``cache_len``.

    ``num_blocks`` > 0 switches attention layers to the *paged* layout: one
    global pool of ``num_blocks`` x ``page_size``-token quantized blocks per
    layer plus a top-level ``block_tbl`` (batch_size, table_len) int32
    mapping each slot's logical block i to a pool block (initialized to the
    ``num_blocks`` sentinel = unallocated). Requires an attention-only,
    non-windowed decoder — the host block allocator owns table contents.
    """
    from repro.core.qat import cache_dtype
    qdt = cache_dtype(ctx)
    if num_blocks and (cfg.is_encdec or cfg.sliding_window or any(
            k != BLOCK_ATTN for k in cfg.block_pattern)):
        raise ValueError(
            "paged KV cache requires a full-attention decoder (no sliding "
            f"window, no recurrence, no cross-attention); {cfg.name!r} has "
            f"block pattern {cfg.block_pattern}")

    def block_cache(kind):
        if kind in ATTENTION_BLOCKS:
            if num_blocks:
                return {"self": B.init_paged_attn_cache(
                    cfg, batch_size, num_blocks, page_size, dtype=qdt)}
            window = (cfg.local_window if kind == BLOCK_LOCAL_ATTN
                      else cfg.sliding_window)
            c = {"self": B.init_attn_cache(cfg, batch_size, cache_len,
                                           window=window, dtype=qdt)}
            if cfg.is_encdec:
                c["cross"] = B.init_attn_cache(cfg, batch_size,
                                               cfg.encoder_seq, dtype=qdt)
            return c
        if kind == BLOCK_RGLRU:
            return R.init_rglru_cache(cfg, batch_size, dtype=qdt)
        if kind == BLOCK_MLSTM:
            return R.init_mlstm_cache(cfg, batch_size, dtype=qdt)
        return R.init_slstm_cache(cfg, batch_size, dtype=qdt)

    segments = []
    for kinds, rep in segment_plan(cfg):
        layer = {str(i): block_cache(kind) for i, kind in enumerate(kinds)}
        segments.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (rep,) + x.shape), layer))
    cache = {"segments": segments,
             "position": jnp.zeros((batch_size,), jnp.int32)}
    if num_blocks:
        cache["block_tbl"] = jnp.full(
            (batch_size, table_len or num_blocks), num_blocks, jnp.int32)
    return cache
