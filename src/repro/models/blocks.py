"""Transformer block family: GQA attention (global / sliding-window / cross),
dense SwiGLU / GELU MLP, and GShard-style top-k MoE — all with SiLQ
quantization sites attached per paper Fig. 2:

* every linear: input A-bits (``s_in``), weight W-bits per-out-channel (``s_w``)
* query into QK^T: 16-bit (``s_q``)
* K/V written to cache: C-bits (``s_k``/``s_v``)
* softmax output: unquantized during training (flash-attention policy)
* MoE router: 8-bit weight/act (accuracy-critical, tiny)
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qat import (QuantCtx, init_linear, qlinear, quantize_act,
                            quantize_weight_p)
from repro.core.quantizer import dynamic_quantize_to_int, quantize_to_int
from repro.models.common import (apply_rope, blockwise_attention,
                                 decode_attention_intcache, head_rms_norm,
                                 init_norm, norm, subcol)

MOE_CAPACITY_FACTOR = 1.25
MOE_CHUNK_S = 1024      # sequence-chunk for the dispatch working set


def _decode_attn(q, k_q, v_q, s_k, s_v, lengths) -> jnp.ndarray:
    """Decode attention over the int cache for a full slot batch.

    On TPU this is the Pallas flash-decode kernel (int8 tiles dequantized
    VMEM-locally, one grid row per slot); elsewhere the fused XLA path.
    Both take the same batched (B, ...) operands, so the serve engine's
    whole-slot decode step is backend-independent.
    """
    if jax.default_backend() == "tpu":
        from repro.kernels.kvq_attn.ops import kvq_decode_attn
        return kvq_decode_attn(q, k_q, v_q, s_k, s_v, lengths)
    return decode_attention_intcache(q, k_q, v_q, s_k, s_v, lengths)


def _decode_attn_paged(q, k_pool, v_pool, s_k, s_v, block_tbl,
                       lengths) -> jnp.ndarray:
    """Decode attention through a block table over the global cache pool.

    On TPU the Pallas paged kernel walks the slot's blocks directly (the
    table is a scalar-prefetch operand of the grid); elsewhere we gather the
    slot's blocks into a contiguous view and reuse the same fused XLA path
    as the dense cache, so dense and paged decode agree bitwise on CPU.
    """
    if jax.default_backend() == "tpu":
        from repro.kernels.kvq_attn.ops import kvq_paged_decode_attn
        return kvq_paged_decode_attn(q, k_pool, v_pool, s_k, s_v,
                                     block_tbl, lengths)
    from repro.kernels.kvq_attn.ref import gather_paged_kv
    return decode_attention_intcache(
        q, gather_paged_kv(k_pool, block_tbl),
        gather_paged_kv(v_pool, block_tbl),
        gather_paged_kv(s_k, block_tbl),
        gather_paged_kv(s_v, block_tbl), lengths)


def _spec_verify_attn(q, k_pool, v_pool, s_k, s_v, block_tbl,
                      lengths) -> jnp.ndarray:
    """Multi-query decode attention for the speculative verify-wave.

    q (n, C, H, D): C window queries per slot whose quantized K/V are
    already committed to the pool; lengths (n, C): query j reads cache
    positions ``< lengths[n, j]``. On TPU one widened Pallas kernel
    serves all C queries per block-table walk; elsewhere the gather +
    per-position decode oracle runs — each position computes exactly the
    ops a sequential ``decode_step`` would, so the verified stream is
    bitwise identical to plain decode.
    """
    from repro.kernels.kvq_attn.ops import kvq_spec_verify_attn
    return kvq_spec_verify_attn(q, k_pool, v_pool, s_k, s_v, block_tbl,
                                lengths,
                                use_pallas=jax.default_backend() == "tpu")


# ==========================================================================
# Dense MLPs
# ==========================================================================

def init_mlp(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {"wg": init_linear(ks[0], d, f, dtype=dtype),
                "wu": init_linear(ks[1], d, f, dtype=dtype),
                "wd": init_linear(ks[2], f, d, dtype=dtype)}
    return {"w1": init_linear(ks[0], d, f, bias=True, dtype=dtype),
            "w2": init_linear(ks[1], f, d, bias=True, dtype=dtype)}


def mlp_fwd(cfg: ModelConfig, ctx: QuantCtx, p: Dict, x: jnp.ndarray,
            col: Optional[Dict] = None) -> jnp.ndarray:
    if cfg.mlp_type == "swiglu":
        g = qlinear(ctx, x, p["wg"], subcol(col, "wg"))
        u = qlinear(ctx, x, p["wu"], subcol(col, "wu"))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return qlinear(ctx, h, p["wd"], subcol(col, "wd"))
    h = qlinear(ctx, x, p["w1"], subcol(col, "w1"))
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return qlinear(ctx, h, p["w2"], subcol(col, "w2"))


# ==========================================================================
# Mixture of Experts (GShard capacity dispatch, chunked over tokens)
# ==========================================================================

def init_moe(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)

    def expert_w(k, din, dout):
        w = (jax.random.normal(k, (e, din, dout), jnp.float32)
             * din ** -0.5).astype(dtype)
        return {"w": w, "s_w": jnp.ones((e, 1, dout), jnp.float32),
                "s_in": jnp.float32(1.0)}

    return {"router": init_linear(ks[0], d, e, dtype=dtype),
            "wg": expert_w(ks[1], d, f),
            "wu": expert_w(ks[2], d, f),
            "wd": expert_w(ks[3], f, d)}


def _expert_linear(ctx: QuantCtx, x: jnp.ndarray, p: Dict,
                   col: Optional[Dict]) -> jnp.ndarray:
    """x: (B, E, C, din) -> (B, E, C, dout), quantized acts + expert weights."""
    xq = quantize_act(ctx, x, p, "s_in", col)
    wq = quantize_weight_p(ctx, p)
    return jnp.einsum("becd,edf->becf", xq, wq)


def moe_fwd(cfg: ModelConfig, ctx: QuantCtx, p: Dict, x: jnp.ndarray,
            col: Optional[Dict] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token-choice top-k MoE with *per-batch-row* capacity dispatch.

    Sharding-aware by construction: routing, position-in-expert, and the
    dispatch/combine one-hots are computed independently per batch row, so
    the batch axis stays data-sharded end to end (no sharded-dim scan, no
    cross-device cumsum) and the experts axis shards over "model" (EP) or
    d_ff does (TP). Chunked over sequence to bound the one-hot working set.
    Returns (y, load-balance aux).
    """
    e, k = cfg.n_experts, cfg.n_experts_active
    B, S, d = x.shape
    sc = min(MOE_CHUNK_S, S)
    nchunk = -(-S // sc)
    pad = nchunk * sc - S
    xs = jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x
    cap = max(1, int(round(sc * k / e * MOE_CAPACITY_FACTOR)))
    cap = min(cap + (-cap) % 4 if cap >= 4 else cap, sc * k)

    def chunk(carry, xc):                               # xc: (B, sc, d)
        logits = qlinear(ctx, xc, p["router"], subcol(col, "router"),
                         act_bits=8, weight_bits=8).astype(jnp.float32)
        vals, idx = jax.lax.top_k(logits, k)            # (B, sc, k)
        gates = jax.nn.softmax(vals, axis=-1)
        oh = jax.nn.one_hot(idx, e, dtype=jnp.bfloat16)  # (B, sc, k, e)
        # position of each (token, slot) within its expert, counted along
        # the flattened (s, k) order *within this row*
        flat = oh.astype(jnp.float32).reshape(B, sc * k, e)
        pos = (jnp.cumsum(flat, axis=1) - flat).reshape(B, sc, k, e)
        pos = jnp.sum(pos * oh.astype(jnp.float32), axis=-1)  # (B, sc, k)
        keep = pos < cap
        pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.bfloat16) \
            * keep[..., None]                           # (B, sc, k, cap)
        dispatch = jnp.einsum("bske,bskc->bsec", oh, pos_oh,
                              preferred_element_type=jnp.bfloat16)
        combine = jnp.einsum("bske,bskc,bsk->bsec", oh, pos_oh,
                             gates.astype(jnp.bfloat16),
                             preferred_element_type=jnp.float32)
        xe = jnp.einsum("bsec,bsd->becd", dispatch, xc.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        xe = xe.astype(x.dtype)                         # (B, e, cap, d)
        g = _expert_linear(ctx, xe, p["wg"], subcol(col, "wg"))
        u = _expert_linear(ctx, xe, p["wu"], subcol(col, "wu"))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        ye = _expert_linear(ctx, h, p["wd"], subcol(col, "wd"))
        yc = jnp.einsum("bsec,becd->bsd", combine.astype(jnp.bfloat16),
                ye.astype(jnp.bfloat16),
                preferred_element_type=jnp.float32)
        # load-balance aux (Switch): e * sum_e(frac_tokens_e * frac_prob_e)
        probs = jax.nn.softmax(logits, axis=-1)
        frac_tok = jnp.mean(jnp.sum(oh, axis=2), axis=(0, 1))
        frac_prob = jnp.mean(probs, axis=(0, 1))
        aux = e * jnp.sum(frac_tok * frac_prob)
        return carry, (yc.astype(x.dtype), aux)

    if nchunk == 1:
        _, (y, aux) = chunk(None, xs)
        y, auxs = y, aux[None]
    else:
        _, (ys, auxs) = jax.lax.scan(
            chunk, None,
            jnp.moveaxis(xs.reshape(B, nchunk, sc, d), 1, 0))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, nchunk * sc, d)
    return y[:, :S], jnp.mean(auxs)


# ==========================================================================
# Attention block (self / cross), with quantized KV cache
# ==========================================================================

def init_attention(cfg: ModelConfig, key, cross: bool = False,
                   dtype=jnp.bfloat16) -> Dict:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    p = {"wq": init_linear(ks[0], d, qd, bias=cfg.qkv_bias, dtype=dtype),
         "wk": init_linear(ks[1], d, kvd, bias=cfg.qkv_bias, dtype=dtype),
         "wv": init_linear(ks[2], d, kvd, bias=cfg.qkv_bias, dtype=dtype),
         "wo": init_linear(ks[3], qd, d, dtype=dtype),
         "s_q": jnp.float32(1.0), "s_k": jnp.float32(1.0),
         "s_v": jnp.float32(1.0)}
    if cfg.qk_norm and not cross:
        hd = cfg.resolved_head_dim
        p["q_norm"] = {"w": jnp.ones((hd,), dtype)}
        p["k_norm"] = {"w": jnp.ones((hd,), dtype)}
    return p


def _qkv(cfg: ModelConfig, ctx: QuantCtx, p: Dict, xq: jnp.ndarray,
         xkv: jnp.ndarray, rope, col, *, skip_rope: bool = False):
    hd = cfg.resolved_head_dim
    B, Sq = xq.shape[0], xq.shape[1]
    Skv = xkv.shape[1]
    q = qlinear(ctx, xq, p["wq"], subcol(col, "wq")).reshape(
        B, Sq, cfg.n_heads, hd)
    k = qlinear(ctx, xkv, p["wk"], subcol(col, "wk")).reshape(
        B, Skv, cfg.n_kv_heads, hd)
    v = qlinear(ctx, xkv, p["wv"], subcol(col, "wv")).reshape(
        B, Skv, cfg.n_kv_heads, hd)
    if "q_norm" in p:
        q = head_rms_norm(q, p["q_norm"]["w"], cfg.norm_eps)
        k = head_rms_norm(k, p["k_norm"]["w"], cfg.norm_eps)
    if rope is not None and not skip_rope:
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    # paper sites: query INT16, cache C-bits
    q = quantize_act(ctx, q, p, "s_q", col)
    k = quantize_act(ctx, k, p, "s_k", col)
    v = quantize_act(ctx, v, p, "s_v", col)
    # distribution hints: when GQA kv-heads don't divide the TP axis, GSPMD
    # otherwise splits head_dim and all-reduces every score tile (the
    # dominant collective). Replicate K/V over "model" and shard either the
    # q heads ("kv_rep") or the q sequence ("seq") instead.
    if ctx.attn_shard_mode:
        from repro.models.common import shard_hint
        dp = ctx.batch_axes or None
        if ctx.attn_shard_mode == "tp":
            # serve-side tensor parallelism: q AND kv heads shard over
            # "model" (the engine only selects this mode when both head
            # counts divide), so attention is head-local per device and
            # the block-pool commit stays collective-free
            q = shard_hint(q, dp, None, "model", None)
            k = shard_hint(k, dp, None, "model", None)
            v = shard_hint(v, dp, None, "model", None)
            return q, k, v
        if ctx.attn_shard_mode == "kv_rep":
            q = shard_hint(q, dp, None, "model", None)
        elif ctx.attn_shard_mode == "seq":
            q = shard_hint(q, dp, "model", None, None)
        k = shard_hint(k, dp, None, None, None)
        v = shard_hint(v, dp, None, None, None)
    return q, k, v


def attn_fwd(cfg: ModelConfig, ctx: QuantCtx, p: Dict, x: jnp.ndarray,
             rope, col: Optional[Dict] = None, *, window: int = 0,
             enc_out: Optional[jnp.ndarray] = None,
             causal: bool = True) -> jnp.ndarray:
    """Self- (enc_out=None) or cross-attention, training/prefill path."""
    B, S, _ = x.shape
    xkv = enc_out if enc_out is not None else x
    q, k, v = _qkv(cfg, ctx, p, x, xkv, rope, col,
                   skip_rope=enc_out is not None)
    # sequence-parallel attention keeps q positions sharded: one q block
    # (chunking the sharded S would put a scan on a sharded axis)
    qc = S if ctx.attn_shard_mode == "seq" else 1024
    out = blockwise_attention(q, k, v,
                              causal=causal and enc_out is None,
                              window=window, q_chunk=qc,
                              kv_chunk=512 if qc == S else 1024)
    out = out.reshape(B, S, cfg.q_dim)
    return qlinear(ctx, out, p["wo"], subcol(col, "wo"))


def quantize_kv_for_cache(ctx: QuantCtx, p: Dict, k: jnp.ndarray,
                          v: jnp.ndarray):
    """(B,S,Hkv,D) bf16 -> cache layout (B,Hkv,S,D) + (B,Hkv,S) scales.

    Dynamic policy: per-token absmax int scales. Static policy: the learned
    LSQ scale broadcast per token. C16/off: bf16 storage, unit scales
    (uniform cache format across policies).
    """
    from repro.core.qat import cache_quantize
    bits = ctx.policy.cache_bits
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if ctx.off or bits >= 16 or ctx.policy.act_dynamic:
        k_q, s_k = cache_quantize(ctx, kt, axis=-1)
        v_q, s_v = cache_quantize(ctx, vt, axis=-1)
        return k_q, v_q, s_k[..., 0], s_v[..., 0]
    s_k = jnp.broadcast_to(p["s_k"], kt.shape[:-1]).astype(jnp.float32)
    s_v = jnp.broadcast_to(p["s_v"], vt.shape[:-1]).astype(jnp.float32)
    return (quantize_to_int(kt, s_k[..., None], bits),
            quantize_to_int(vt, s_v[..., None], bits), s_k, s_v)


def attn_prefill(cfg: ModelConfig, ctx: QuantCtx, p: Dict, x: jnp.ndarray,
                 rope, col=None, *, window: int = 0, cache_len: int = 0,
                 enc_out: Optional[jnp.ndarray] = None,
                 lengths: Optional[jnp.ndarray] = None,
                 page_size: int = 0):
    """Like attn_fwd but also emits the quantized cache for serving.

    ``lengths`` (B,) marks the valid (right-padded) prefix of each row:
    pad-position K/V are dropped from the cache and ``cache["length"]``
    tracks the true per-row length, so a single padded prefill call can
    admit prompts of different lengths (causality keeps real-token outputs
    independent of the padding).

    ``page_size`` > 0 switches the emitted cache to *block shape*
    (B, nb, Hkv, page_size, D): the engine scatters those blocks into the
    global pool through the slot's block table instead of copying a dense
    stripe. Attention math is identical either way; only the commit layout
    changes. Requires window == 0 (paged layers are full attention).
    """
    B, S, _ = x.shape
    xkv = enc_out if enc_out is not None else x
    q, k, v = _qkv(cfg, ctx, p, x, xkv, rope, col,
                   skip_rope=enc_out is not None)
    qc = S if ctx.attn_shard_mode == "seq" else 1024
    out = blockwise_attention(q, k, v, causal=enc_out is None, window=window,
                              q_chunk=qc, kv_chunk=512 if qc == S else 1024)
    out = out.reshape(B, S, cfg.q_dim)
    y = qlinear(ctx, out, p["wo"], subcol(col, "wo"))
    k_q, v_q, s_k, s_v = quantize_kv_for_cache(ctx, p, k, v)
    S_in = k.shape[1]
    if page_size:
        if window:
            raise ValueError("paged cache layout requires full attention "
                             "(window == 0)")
        if lengths is None:
            lengths = jnp.full((B,), S_in, jnp.int32)
        cache = _paginate_kv(k_q, v_q, s_k, s_v, page_size)
        cache["length"] = lengths.astype(jnp.int32)
        return y, cache
    Sc = cache_len or S_in
    if window:
        Sc = min(Sc, window)   # ring eviction enforces the sliding window
    cache = _blank_attn_cache(B, cfg, Sc, k_q.dtype)
    if lengths is None:
        lengths = jnp.full((B,), S_in, jnp.int32)
    # token at absolute position j lives at ring slot j % Sc ("length" stays
    # monotonic; decode masks with min(length, Sc)). Per-row masked scatter:
    # keep the last min(len, Sc) real tokens of each row, drop padding.
    j = jnp.arange(S_in)[None]                       # (1, S_in)
    valid = (j < lengths[:, None]) & (j >= lengths[:, None] - Sc)
    dest = jnp.where(valid, j % Sc, Sc)              # Sc = out-of-range: drop
    bidx = jnp.arange(B)[:, None]
    # advanced-index semantics: result dims (B, S_in) lead, so values are
    # (B, S_in, Hkv[, D]) = cache-layout tensors with S moved ahead of Hkv
    cache["k_q"] = cache["k_q"].at[bidx, :, dest].set(
        jnp.swapaxes(k_q, 1, 2), mode="drop")
    cache["v_q"] = cache["v_q"].at[bidx, :, dest].set(
        jnp.swapaxes(v_q, 1, 2), mode="drop")
    cache["s_k"] = cache["s_k"].at[bidx, :, dest].set(
        jnp.swapaxes(s_k, 1, 2), mode="drop")
    cache["s_v"] = cache["s_v"].at[bidx, :, dest].set(
        jnp.swapaxes(s_v, 1, 2), mode="drop")
    cache["length"] = lengths.astype(jnp.int32)
    return y, cache


def _paginate_kv(k_q, v_q, s_k, s_v, page_size: int) -> Dict:
    """Cache-layout K/V (B, Hkv, S, D) + scales (B, Hkv, S) -> block shape
    (B, nb, Hkv, page_size, D) / (B, nb, Hkv, page_size); the trailing
    partial block is zero-padded (masked by ``length`` at read, overwritten
    in place by decode)."""
    B, Hkv, S = k_q.shape[0], k_q.shape[1], k_q.shape[2]
    nb = -(-S // page_size)
    pad = nb * page_size - S

    def blk(x):
        widths = ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 3)
        xp = jnp.pad(x, widths)
        xp = xp.reshape((B, Hkv, nb, page_size) + x.shape[3:])
        return jnp.moveaxis(xp, 2, 1)                # (B, nb, Hkv, bs, ...)

    return {"k_q": blk(k_q), "v_q": blk(v_q),
            "s_k": blk(s_k), "s_v": blk(s_v)}


def _blank_attn_cache(B: int, cfg: ModelConfig, S: int, qdtype=jnp.int8):
    hd = cfg.resolved_head_dim
    return {
        "k_q": jnp.zeros((B, cfg.n_kv_heads, S, hd), qdtype),
        "v_q": jnp.zeros((B, cfg.n_kv_heads, S, hd), qdtype),
        "s_k": jnp.zeros((B, cfg.n_kv_heads, S), jnp.float32),
        "s_v": jnp.zeros((B, cfg.n_kv_heads, S), jnp.float32),
        "length": jnp.zeros((B,), jnp.int32),
    }


def init_attn_cache(cfg: ModelConfig, B: int, S: int, *, window: int = 0,
                    dtype=jnp.int8):
    """window > 0 -> ring buffer bounded at window size (SWA decode)."""
    Sc = min(S, window) if window else S
    return _blank_attn_cache(B, cfg, Sc, dtype)


def init_paged_attn_cache(cfg: ModelConfig, B: int, num_blocks: int,
                          page_size: int, dtype=jnp.int8):
    """Global block pool for one attention layer: ``num_blocks`` blocks of
    ``page_size`` tokens, shared by every slot through the block table."""
    hd = cfg.resolved_head_dim
    return {
        "k_q": jnp.zeros((num_blocks, cfg.n_kv_heads, page_size, hd), dtype),
        "v_q": jnp.zeros((num_blocks, cfg.n_kv_heads, page_size, hd), dtype),
        "s_k": jnp.zeros((num_blocks, cfg.n_kv_heads, page_size),
                         jnp.float32),
        "s_v": jnp.zeros((num_blocks, cfg.n_kv_heads, page_size),
                         jnp.float32),
        "length": jnp.zeros((B,), jnp.int32),
    }


def attn_decode(cfg: ModelConfig, ctx: QuantCtx, p: Dict, x1: jnp.ndarray,
                cache: Dict, positions: jnp.ndarray, *, window: int = 0,
                cross: bool = False,
                block_tbl: Optional[jnp.ndarray] = None):
    """One-token decode step. x1: (B, 1, d). Returns (y1, new_cache).

    Self-attention writes the new K/V into the (ring-buffered when SWA)
    int cache; cross-attention reads a frozen cache. ``block_tbl`` (B, T)
    switches the layer to the paged layout: the commit is routed through
    the slot's block table into the global pool (slots whose table entry is
    the out-of-range sentinel scatter nothing — that is how the engine
    parks finished slots), and attention walks the table instead of a
    contiguous stripe.
    """
    from repro.models.common import rope_tables  # local to avoid cycle
    B = x1.shape[0]
    hd = cfg.resolved_head_dim
    if cross:
        q = qlinear(ctx, x1, p["wq"]).reshape(B, 1, cfg.n_heads, hd)
        q = quantize_act(ctx, q, p, "s_q")
        out = _decode_attn(
            q[:, 0], cache["k_q"], cache["v_q"], cache["s_k"], cache["s_v"],
            cache["length"])
        y = qlinear(ctx, out.reshape(B, 1, cfg.q_dim)[:, 0], p["wo"])
        return y[:, None], cache
    rope = None
    if cfg.rope_theta:
        rope = rope_tables(positions[:, None], hd, cfg.rope_theta)
    q, k, v = _qkv(cfg, ctx, p, x1, x1, rope, None)
    k_q1, v_q1, s_k1, s_v1 = quantize_kv_for_cache(ctx, p, k, v)
    if block_tbl is not None:
        if window:
            raise ValueError("paged cache layout requires full attention "
                             "(window == 0)")
        bs = cache["k_q"].shape[2]
        T = block_tbl.shape[1]
        pos = cache["length"]                        # tokens written so far
        blk = jnp.take_along_axis(
            block_tbl, jnp.minimum(pos // bs, T - 1)[:, None], axis=1)[:, 0]
        off = pos % bs
        new = dict(cache)
        # blk (B,) / off (B,) advanced indices around the head slice ->
        # (B, Hkv, ...) result rows; sentinel blk drops the whole commit
        new["k_q"] = cache["k_q"].at[blk, :, off].set(k_q1[:, :, 0],
                                                      mode="drop")
        new["v_q"] = cache["v_q"].at[blk, :, off].set(v_q1[:, :, 0],
                                                      mode="drop")
        new["s_k"] = cache["s_k"].at[blk, :, off].set(s_k1[:, :, 0],
                                                      mode="drop")
        new["s_v"] = cache["s_v"].at[blk, :, off].set(s_v1[:, :, 0],
                                                      mode="drop")
        new["length"] = pos + 1
        out = _decode_attn_paged(q[:, 0], new["k_q"], new["v_q"],
                                 new["s_k"], new["s_v"], block_tbl,
                                 new["length"])
        y = qlinear(ctx, out.reshape(B, cfg.q_dim), p["wo"])
        return y[:, None], new
    Sc = cache["k_q"].shape[2]
    slot = cache["length"] % Sc            # ring slot (== length pre-wrap)
    bidx = jnp.arange(B)
    new = dict(cache)
    new["k_q"] = cache["k_q"].at[bidx, :, slot].set(k_q1[:, :, 0])
    new["v_q"] = cache["v_q"].at[bidx, :, slot].set(v_q1[:, :, 0])
    new["s_k"] = cache["s_k"].at[bidx, :, slot].set(s_k1[:, :, 0])
    new["s_v"] = cache["s_v"].at[bidx, :, slot].set(s_v1[:, :, 0])
    new["length"] = cache["length"] + 1
    out = _decode_attn(
        q[:, 0], new["k_q"], new["v_q"], new["s_k"], new["s_v"],
        jnp.minimum(new["length"], Sc))
    y = qlinear(ctx, out.reshape(B, cfg.q_dim), p["wo"])
    return y[:, None], new


def attn_chunk_prefill(cfg: ModelConfig, ctx: QuantCtx, p: Dict,
                       x: jnp.ndarray, rope, cache: Dict,
                       tbl: jnp.ndarray, slot: jnp.ndarray,
                       offset: jnp.ndarray, chunk_len: jnp.ndarray):
    """One fixed-size window of an incremental (chunked / tail) prefill
    for a *batch* of slots with per-row offsets.

    x (n, C, d): each row is a window of one slot's prompt whose first
    token sits at absolute position ``offset[i]``; only the first
    ``chunk_len[i]`` positions are real (windows are right-padded, and
    whole padding rows carry ``chunk_len == 0``). Queries attend to the
    ``offset[i]`` tokens already committed to the pool (gathered through
    the row's table ``tbl[i]`` and dequantized at read, like decode) plus
    the window itself (causal, exact bf16 K/V). The window's K/V are
    quantized and scattered through the table with per-row write offsets
    (``kernels.kvq_attn.ops.commit_chunk_kv``), appending blocks the
    allocator grew for each row's window.

    Prefix sharing rides on this contract unchanged: for a prefix-hit
    admission ``offset[i]`` is the cached-token count, so the "history" is
    another request's blocks mapped into ``tbl[i]`` (refcounted by the
    allocator) — including a shared *split block* the offset may point
    into mid-block. The engine resolves copy-on-write for every shared
    block in each row's write range [offset, offset + chunk_len) before
    calling, so the scatter only ever lands in blocks the row's slot
    exclusively owns; the history mask (``kpos < offset``) keeps reads
    inside the shared extent. Rows are mutually independent — a batched
    wave computes exactly what the same windows would serially.

    Note: history keys are read back *quantized*, so a chunked/tail
    prefill is numerically the serving-cache path, not bit-identical to a
    one-shot prefill — same contract as any PagedAttention-style chunked
    prefill over a quantized cache.
    """
    from repro.kernels.kvq_attn.ops import (commit_chunk_kv,
                                            gather_dequant_paged_kv)
    B, C, _ = x.shape                                 # B = slot-batch n
    q, k, v = _qkv(cfg, ctx, p, x, x, rope, None)
    bs = cache["k_q"].shape[2]
    T = tbl.shape[1]
    Lh = T * bs
    # dequantized history, head-major (n, Hkv, Lh, D) -> seq-major; on TPU
    # a fused Pallas gather-dequant walks each row's table (no int8
    # intermediate in HBM), elsewhere the two-gather XLA reference
    kh = gather_dequant_paged_kv(cache["k_q"], cache["s_k"], tbl)
    vh = gather_dequant_paged_kv(cache["v_q"], cache["s_v"], tbl)
    kh = jnp.swapaxes(kh, 1, 2)
    vh = jnp.swapaxes(vh, 1, 2)
    kall = jnp.concatenate([kh, k.astype(jnp.float32)], axis=1)
    vall = jnp.concatenate([vh, v.astype(jnp.float32)], axis=1)
    group = cfg.n_heads // cfg.n_kv_heads
    if group > 1:
        kall = jnp.repeat(kall, group, axis=2)
        vall = jnp.repeat(vall, group, axis=2)
    scale = cfg.resolved_head_dim ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bqhk", q.astype(jnp.float32) * scale,
                        kall)
    # key j < Lh is history (valid iff j < offset[row]: allocated-but-
    # unwritten tail positions hold garbage); key j >= Lh is chunk token
    # j - Lh (causal within the chunk, pad keys beyond chunk_len masked)
    kj = jnp.arange(Lh + C)
    qi = jnp.arange(C)
    hist = kj < Lh
    kpos = jnp.where(hist, kj, kj - Lh)
    mask = jnp.where(hist[None, None, :],
                     kpos[None, None, :] < offset[:, None, None],
                     (kpos[None, None, :] <= qi[None, :, None])
                     & (kpos[None, None, :] < chunk_len[:, None, None]))
    scores = jnp.where(mask[:, :, None, :], scores, -1e30)
    pr = jax.nn.softmax(scores, axis=-1)
    pr = jnp.where(mask[:, :, None, :], pr, 0.0)
    out = jnp.einsum("bqhk,bkhd->bqhd", pr, vall)
    y = qlinear(ctx, out.reshape(B, C, cfg.q_dim).astype(x.dtype), p["wo"])
    # commit every row's window through its table (per-row write offsets)
    k_q1, v_q1, s_k1, s_v1 = quantize_kv_for_cache(ctx, p, k, v)
    new = commit_chunk_kv(cache, k_q1, v_q1, s_k1, s_v1, tbl, offset,
                          chunk_len)
    new["length"] = cache["length"].at[slot].set(offset + chunk_len,
                                                 mode="drop")
    return y, new


def attn_spec_verify(cfg: ModelConfig, ctx: QuantCtx, p: Dict,
                     x: jnp.ndarray, rope, cache: Dict,
                     tbl: jnp.ndarray, slot: jnp.ndarray,
                     offset: jnp.ndarray, chunk_len: jnp.ndarray):
    """One attention layer of the speculative *verify-wave*.

    Same per-row ``(offset, chunk_len)`` batched-window contract as
    :func:`attn_chunk_prefill` — x (n, C, d) holds one slot's window
    ``[last_token, draft_1..draft_k]`` per row, committed through the
    block table with per-row write offsets (``commit_chunk_kv``) — but
    the attention *numerics are plain decode's, not prefill's*: the
    window K/V are committed to the pool FIRST (quantized) and every
    window position then reads the pool back dequantized, exactly as the
    ``k + 1`` sequential decode steps it replaces would. Window position
    j attends to ``offset + j + 1`` tokens (history + window through
    itself); positions at or beyond ``chunk_len`` commit nothing (their
    reads are garbage and the engine's acceptance mask discards them).
    Rejected-suffix commits are *rolled back by the caller* (device
    length/position reset + ``BlockAllocator.trim``); the engine must
    have grown the table to ``offset + chunk_len`` tokens and resolved
    copy-on-write for the write range before calling, like any chunk.

    Returns (y (n, C, d), new cache) with ``length`` advanced to the
    full ``offset + chunk_len`` (the engine re-clamps it to the accepted
    extent after acceptance).
    """
    from repro.kernels.kvq_attn.ops import commit_chunk_kv
    B, C, _ = x.shape
    q, k, v = _qkv(cfg, ctx, p, x, x, rope, None)
    k_q1, v_q1, s_k1, s_v1 = quantize_kv_for_cache(ctx, p, k, v)
    new = commit_chunk_kv(cache, k_q1, v_q1, s_k1, s_v1, tbl, offset,
                          chunk_len)
    new["length"] = cache["length"].at[slot].set(offset + chunk_len,
                                                 mode="drop")
    # per-query valid extent: history + the window prefix through itself
    lens = offset[:, None] + 1 + jnp.arange(C)[None]
    out = _spec_verify_attn(q, new["k_q"], new["v_q"], new["s_k"],
                            new["s_v"], tbl, lens)
    y = qlinear(ctx, out.reshape(B, C, cfg.q_dim).astype(x.dtype), p["wo"])
    return y, new
