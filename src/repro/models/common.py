"""Shared model components: norms, rotary embeddings, chunked attention.

All attention here is memory-aware (blockwise online-softmax — the pure-JAX
analogue of flash attention) so 32k prefill never materializes an S x S score
matrix. The softmax output is intentionally NOT quantized during training
(paper §3.2: it is encapsulated by the attention kernel).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.qat import QuantCtx, quantize_act

_NEG = -1e30


# --------------------------------------------------------------------------
# Norms (fp16/bf16 compute — never quantized, per the paper)
# --------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, p: Dict, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["w"].astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jnp.ndarray, p: Dict, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)
            ).astype(x.dtype)


def norm(x: jnp.ndarray, p: Dict, kind: str, eps: float) -> jnp.ndarray:
    return rms_norm(x, p, eps) if kind == "rms" else layer_norm(x, p, eps)


def init_norm(d: int, kind: str, dtype=jnp.bfloat16) -> Dict:
    p = {"w": jnp.ones((d,), dtype)}
    if kind == "ln":
        p["b"] = jnp.zeros((d,), dtype)
    return p


def head_rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    """qk_norm: RMS over head_dim (x: (..., H, D))."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) *
            w.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# --------------------------------------------------------------------------

def rope_tables(positions: jnp.ndarray, head_dim: int,
                theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions (..., S) -> cos/sin tables (..., S, head_dim/2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_tables(positions3: jnp.ndarray, head_dim: int,
                 theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Qwen2-VL multimodal rotary: 3 position streams (t, h, w) own
    interleaved thirds of the frequency spectrum.

    positions3: (3, B, S) -> cos/sin (B, S, head_dim/2).
    """
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    sect = jnp.arange(half) % 3                                  # stream id
    ang_all = positions3.astype(jnp.float32)[..., None] * freqs  # (3,B,S,half)
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_all, 0, -1), sect[None, None, :, None], axis=-1
    )[..., 0]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray,
               sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, D); cos/sin: (B, S, half) or (S, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos, sin = cos[None], sin[None]
    c = cos[:, :, None, :].astype(jnp.float32)
    s = sin[:, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# Blockwise (flash-style) attention for training / prefill
# --------------------------------------------------------------------------

def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True, window: int = 0,
                        q_chunk: int = 1024, kv_chunk: int = 1024,
                        q_offset: int = 0,
                        p_dtype=jnp.bfloat16) -> jnp.ndarray:
    """Online-softmax attention, O(S * chunk) memory.

    q: (B, S, H, D); k/v: (B, Skv, Hkv, D) — GQA broadcast by head repeat.
    ``window`` > 0 restricts attention to the last ``window`` positions
    (sliding window). ``q_offset`` shifts query positions (decode suffix).
    """
    B, S, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, Skv)
    nq, nk = -(-S // q_chunk), -(-Skv // kv_chunk)
    # pad to chunk multiples
    qp = jnp.pad(q, ((0, 0), (0, nq * q_chunk - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kv_chunk - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kv_chunk - Skv), (0, 0), (0, 0)))
    scale = D ** -0.5

    # (nq, B, qc, H, D) chunk-major layouts for scan
    qc = jnp.moveaxis(qp.reshape(B, nq, q_chunk, H, D), 1, 0)
    kc = jnp.moveaxis(kp.reshape(B, nk, kv_chunk, Hkv, D), 1, 0)
    vc = jnp.moveaxis(vp.reshape(B, nk, kv_chunk, Hkv, D), 1, 0)

    def q_block(qi, q_i):
        q_i = q_i.astype(jnp.float32) * scale
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_block(carry, inp):
            m, l, acc = carry
            ki, k_j, v_j = inp
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            # scores: (B, qc, H, kc) via GQA head grouping; fp32 accumulate,
            # probability tensor materialized bf16 (it is the HBM hot spot;
            # the accumulators m/l/acc stay fp32 so softmax numerics hold)
            kf = k_j.astype(jnp.float32)
            s_ = jnp.einsum("bqhd,bkhd->bqhk", q_i, kf)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            mask &= kpos[None, :] < Skv
            mask &= (qpos[:, None] < q_offset + S)
            if window:
                mask &= qpos[:, None] - kpos[None, :] < window
            s_ = jnp.where(mask[None, :, None, :], s_, _NEG)
            m_new = jnp.maximum(m, jnp.max(s_, axis=-1))
            p = jnp.exp(s_ - m_new[..., None])
            p = jnp.where(mask[None, :, None, :], p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqhk,bkhd->bqhd", p.astype(p_dtype),
                            v_j.astype(p_dtype),
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, q_chunk, H), _NEG, jnp.float32),
                jnp.zeros((B, q_chunk, H), jnp.float32),
                jnp.zeros((B, q_chunk, H, D), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_block, init, (jnp.arange(nk), kc, vc))
        return acc / jnp.maximum(l[..., None], 1e-20)

    # GQA: expand kv heads to q heads by index mapping inside the einsum is
    # awkward; instead repeat kv heads (cheap views under XLA).
    if group > 1:
        kc = jnp.repeat(kc, group, axis=3)
        vc = jnp.repeat(vc, group, axis=3)

    out = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qc))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * q_chunk, H, D)
    return out[:, :S].astype(q.dtype)


# --------------------------------------------------------------------------
# Decode attention over an integer-quantized cache (XLA path)
# --------------------------------------------------------------------------

def decode_attention_intcache(q: jnp.ndarray, k_q: jnp.ndarray,
                              v_q: jnp.ndarray, s_k: jnp.ndarray,
                              s_v: jnp.ndarray,
                              lengths: jnp.ndarray) -> jnp.ndarray:
    """Single-token attention against an int8 cache.

    The int8->bf16 converts fuse into the dots under XLA; per-token scales
    fold into the score/probability tensors, so no dequantized K/V copy is
    ever materialized in HBM (mirrors the Pallas kernel's VMEM strategy).

    q (B,H,D); k_q/v_q (B,Hkv,S,D) int8; s_k/s_v (B,Hkv,S); lengths (B,).
    """
    B, H, D = q.shape
    Hkv, S = k_q.shape[1], k_q.shape[2]
    group = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, group, D) * (D ** -0.5)
    scores = jnp.einsum("bngd,bnsd->bngs", qf, k_q.astype(jnp.float32))
    scores = scores * s_k[:, :, None, :].astype(jnp.float32)
    mask = (jnp.arange(S)[None, :] < lengths[:, None])[:, None, None, :]
    scores = jnp.where(mask, scores, _NEG)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(mask, p, 0.0)
    ps = p * s_v[:, :, None, :].astype(jnp.float32)
    out = jnp.einsum("bngs,bnsd->bngd", ps, v_q.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)


# --------------------------------------------------------------------------
# Sharding hints (no-ops outside a mesh context)
# --------------------------------------------------------------------------

def shard_hint(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """with_sharding_constraint that degrades to identity without a mesh."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError, TypeError, NameError):
        return x


# --------------------------------------------------------------------------
# Calibration collector plumbing
# --------------------------------------------------------------------------

def subcol(col: Optional[Dict], key: str) -> Optional[Dict]:
    """Child collector dict mirroring the params structure (or None)."""
    if col is None:
        return None
    return col.setdefault(key, {})
