"""Pure-JAX functional model zoo with SiLQ quantization sites."""
from repro.models.model import (decode_step, forward, head_logits, init_cache,
                                init_params, prefill, prefill_tail,
                                segment_plan, spec_verify)

__all__ = ["decode_step", "forward", "head_logits", "init_cache",
           "init_params", "prefill", "prefill_tail", "segment_plan",
           "spec_verify"]
