"""Recurrent block family: RG-LRU (RecurrentGemma/Griffin), mLSTM and sLSTM
(xLSTM). SiLQ sites: all projection/gate *linears* carry A-bit input + W4
per-channel weight quantizers; the element-wise recurrences themselves run
fp32 (they are fp16 ops on NorthPole too — DESIGN.md §Arch-applicability).
The stored recurrent state is the cache analogue and is quantized to C-bits
on the serving path (``state_q`` + scale).

TPU adaptation notes:
* RG-LRU is a diagonal linear recurrence -> ``jax.lax.associative_scan``
  (log-depth, MXU-free but VPU-parallel) instead of a CUDA sequential scan.
* mLSTM's matrix-memory recurrence is linear in the state -> chunked
  parallel form (GLA-style): intra-chunk attention-like einsums feed the
  MXU; inter-chunk state carried by a short scan. Exponential input gating
  is replaced by sigmoid gating for unconditional numerical stability in
  bf16 (documented deviation; the chunked algebra is exact for the gates
  used).
* sLSTM has a non-linearizable hidden->gate feedback -> lax.scan over time
  (small matvecs; it exists in 2/12 layers of the assigned config).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qat import (QuantCtx, cache_dtype, cache_quantize,
                            init_linear, qlinear, quantize_act)
from repro.core.quantizer import dequantize_int, dynamic_quantize_to_int
from repro.models.common import subcol

MLSTM_CHUNK = 256


# ==========================================================================
# RG-LRU block (Griffin / RecurrentGemma temporal-mixing block)
# ==========================================================================

def init_rglru(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict:
    d, w = cfg.d_model, cfg.resolved_lru_width
    ks = jax.random.split(key, 6)
    # Lambda init so a = exp(-8*softplus(L)*r) spreads over (0.9, 0.999)
    lam = jax.random.uniform(ks[0], (w,), jnp.float32, 0.01, 0.1)
    return {
        "w_in": init_linear(ks[1], d, w, dtype=dtype),
        "w_gate": init_linear(ks[2], d, w, dtype=dtype),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv1d_width, w),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_ig": init_linear(ks[4], w, w, dtype=dtype),   # input gate
        "w_rg": init_linear(ks[5], w, w, dtype=dtype),   # recurrence gate
        "lam": lam,
        "w_out": init_linear(jax.random.fold_in(key, 7), w, d, dtype=dtype),
        "s_state": jnp.float32(1.0),
    }


def _causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                   buf: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv, width K. x (B,S,W); buf (B,K-1,W) history."""
    K = w.shape[0]
    if buf is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([buf.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    y = b.astype(jnp.float32)
    for j in range(K):
        y = y + w[j].astype(jnp.float32) * \
            xp[:, j:j + S].astype(jnp.float32)
    return y.astype(x.dtype)


def _rglru_coeffs(cfg, ctx, p, u, col):
    """Gate math shared by train/decode. u: (B,S,W) conv output."""
    if ctx.batch_axes:
        # gate linears are (W, W) with a W-sharded input: without a hint
        # GSPMD all-reduces three fp32 (B,S,W) partial sums per layer; one
        # bf16 all-gather of u is ~8x fewer bytes (EXPERIMENTS.md §Perf D)
        from repro.models.common import shard_hint
        u = shard_hint(u, ctx.batch_axes, None, None)
    i = jax.nn.sigmoid(qlinear(ctx, u, p["w_ig"],
                               subcol(col, "w_ig")).astype(jnp.float32))
    r = jax.nn.sigmoid(qlinear(ctx, u, p["w_rg"],
                               subcol(col, "w_rg")).astype(jnp.float32))
    log_a = -8.0 * jax.nn.softplus(p["lam"]) * r            # (B,S,W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * \
        u.astype(jnp.float32)
    return a, gated


def rglru_fwd(cfg: ModelConfig, ctx: QuantCtx, p: Dict, x: jnp.ndarray,
              col: Optional[Dict] = None) -> jnp.ndarray:
    """Training/prefill path: associative scan over the diagonal recurrence."""
    gate = jax.nn.gelu(qlinear(ctx, x, p["w_gate"],
                               subcol(col, "w_gate")).astype(jnp.float32))
    u = qlinear(ctx, x, p["w_in"], subcol(col, "w_in"))
    u = _causal_conv1d(u, p["conv_w"], p["conv_b"])
    a, gated = _rglru_coeffs(cfg, ctx, p, u, col)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    h = quantize_act(ctx, h.astype(x.dtype), p, "s_state", col)
    y = (h.astype(jnp.float32) * gate).astype(x.dtype)
    return qlinear(ctx, y, p["w_out"], subcol(col, "w_out"))


def init_rglru_cache(cfg: ModelConfig, B: int, dtype=jnp.int8) -> Dict:
    w = cfg.resolved_lru_width
    return {"state_q": jnp.zeros((B, w), dtype),
            "s_state": jnp.zeros((B, 1), jnp.float32),
            "conv_buf": jnp.zeros((B, cfg.conv1d_width - 1, w),
                                  jnp.bfloat16)}


def rglru_prefill(cfg, ctx, p, x, col=None):
    """Prefill: run the parallel scan, emit final quantized state."""
    gate = jax.nn.gelu(qlinear(ctx, x, p["w_gate"],
                               subcol(col, "w_gate")).astype(jnp.float32))
    u = qlinear(ctx, x, p["w_in"], subcol(col, "w_in"))
    uc = _causal_conv1d(u, p["conv_w"], p["conv_b"])
    a, gated = _rglru_coeffs(cfg, ctx, p, uc, col)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    hq = quantize_act(ctx, h.astype(x.dtype), p, "s_state", col)
    y = (hq.astype(jnp.float32) * gate).astype(x.dtype)
    y = qlinear(ctx, y, p["w_out"], subcol(col, "w_out"))
    state_q, s_state = cache_quantize(ctx, h[:, -1].astype(jnp.bfloat16))
    K = cfg.conv1d_width
    cache = {"state_q": state_q, "s_state": s_state,
             "conv_buf": u[:, -(K - 1):].astype(jnp.bfloat16)}
    return y, cache


def rglru_decode(cfg: ModelConfig, ctx: QuantCtx, p: Dict, x1: jnp.ndarray,
                 cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    gate = jax.nn.gelu(qlinear(ctx, x1, p["w_gate"]).astype(jnp.float32))
    u = qlinear(ctx, x1, p["w_in"])                       # (B,1,W)
    uc = _causal_conv1d(u, p["conv_w"], p["conv_b"], buf=cache["conv_buf"])
    a, gated = _rglru_coeffs(cfg, ctx, p, uc, None)       # (B,1,W)
    h_prev = dequantize_int(cache["state_q"], cache["s_state"],
                            jnp.float32)                  # (B,W)
    h = a[:, 0] * h_prev + gated[:, 0]
    state_q, s_state = cache_quantize(ctx, h.astype(jnp.bfloat16))
    y = (h[:, None] * gate).astype(x1.dtype)
    y = qlinear(ctx, y, p["w_out"])
    new_buf = jnp.concatenate([cache["conv_buf"][:, 1:],
                               u.astype(jnp.bfloat16)], axis=1)
    return y, {"state_q": state_q, "s_state": s_state, "conv_buf": new_buf}


# ==========================================================================
# mLSTM block (xLSTM matrix memory, chunked parallel form)
# ==========================================================================

def init_mlstm(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict:
    d = cfg.d_model
    m = int(cfg.mlstm_proj_factor * d)
    ks = jax.random.split(key, 6)
    return {
        "w_up": init_linear(ks[0], d, 2 * m, dtype=dtype),
        "w_q": init_linear(ks[1], m, m, dtype=dtype),
        "w_k": init_linear(ks[2], m, m, dtype=dtype),
        "w_v": init_linear(ks[3], m, m, dtype=dtype),
        "w_gates": init_linear(ks[4], m, 2 * cfg.n_heads, bias=True,
                               dtype=dtype),
        "w_down": init_linear(ks[5], m, d, dtype=dtype),
        "s_q": jnp.float32(1.0), "s_k": jnp.float32(1.0),
        "s_v": jnp.float32(1.0), "s_state": jnp.float32(1.0),
    }


def _mlstm_qkv(cfg, ctx, p, x, col):
    m = int(cfg.mlstm_proj_factor * cfg.d_model)
    H = cfg.n_heads
    dh = m // H
    B, S, _ = x.shape
    up = qlinear(ctx, x, p["w_up"], subcol(col, "w_up"))
    u, z = up[..., :m], up[..., m:]
    q = qlinear(ctx, u, p["w_q"], subcol(col, "w_q")).reshape(B, S, H, dh)
    k = qlinear(ctx, u, p["w_k"], subcol(col, "w_k")).reshape(B, S, H, dh)
    v = qlinear(ctx, u, p["w_v"], subcol(col, "w_v")).reshape(B, S, H, dh)
    q = quantize_act(ctx, q, p, "s_q", col)
    k = quantize_act(ctx, k, p, "s_k", col)
    v = quantize_act(ctx, v, p, "s_v", col)
    gates = qlinear(ctx, u, p["w_gates"],
                    subcol(col, "w_gates")).astype(jnp.float32)
    ig = jax.nn.sigmoid(gates[..., :H])                  # (B,S,H)
    lf = jax.nn.log_sigmoid(gates[..., H:])              # log forget gate
    return q, k, v, z, ig, lf, dh


def mlstm_fwd(cfg: ModelConfig, ctx: QuantCtx, p: Dict, x: jnp.ndarray,
              col: Optional[Dict] = None, *, return_state: bool = False):
    """Chunked linear recurrence: C_t = f_t C_{t-1} + i_t k_t v_t^T,
    h_t = (q_t C_t) / max(|q_t n_t|, 1) with the normalizer n carried as an
    extra value column."""
    B, S, d = x.shape
    q, k, v, z, ig, lf, dh = _mlstm_qkv(cfg, ctx, p, x, col)
    H = cfg.n_heads
    L = min(MLSTM_CHUNK, S)
    nc = -(-S // L)
    pad = nc * L - S

    def pad_t(t, val=0.0):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2),
                       constant_values=val) if pad else t

    qc = pad_t(q).reshape(B, nc, L, H, dh)
    kc = pad_t(k).reshape(B, nc, L, H, dh)
    vc = pad_t(v).reshape(B, nc, L, H, dh)
    igc = pad_t(ig).reshape(B, nc, L, H)
    lfc = pad_t(lf).reshape(B, nc, L, H)   # pad log-f with 0 (f=1, harmless)
    scale = dh ** -0.5

    def vi_n(vi):
        """Append the normalizer ones-column to a value chunk."""
        return jnp.concatenate(
            [vi.astype(jnp.float32),
             jnp.ones_like(vi[..., :1], jnp.float32)], axis=-1)

    def chunk(state, inp):
        qi, ki, vi, ii, lfi = inp            # (B,L,H,*) for this chunk
        cum = jnp.cumsum(lfi, axis=1)        # inclusive cumsum of log f
        # intra-chunk: decay(t, tau) = exp(cum_t - cum_tau) for tau <= t
        qf = qi.astype(jnp.float32) * scale
        kf = ki.astype(jnp.float32)
        scores = jnp.einsum("bthd,bshd->bhts", qf, kf)
        decay = cum[:, :, None] - cum[:, None, :, :]     # (B,t,s,H)
        tri = jnp.tril(jnp.ones((L, L), bool))
        # mask BEFORE exp: upper-triangle decay is positive and can overflow,
        # poisoning the where() gradient with inf * 0 = NaN
        decay = jnp.where(tri[None, :, :, None], decay, -jnp.inf)
        dmask = jnp.exp(decay)
        w_ts = scores * jnp.moveaxis(dmask, 3, 1) * \
            jnp.moveaxis(ii, 2, 1)[:, :, None, :].astype(jnp.float32)
        intra = jnp.einsum("bhts,bshe->bthe", w_ts, vi_n(vi))
        # inter-chunk: q_t exp(cum_t) @ state
        qdec = qf * jnp.exp(cum)[..., None]
        inter = jnp.einsum("bthd,bhde->bthe", qdec, state)
        out = intra + inter                                # (B,L,H,dh+1)
        # state update
        tot = cum[:, -1]                                   # (B,H)
        kdec = kf * (jnp.exp(tot[:, None] - cum) *
                     ii.astype(jnp.float32))[..., None]
        kv = jnp.einsum("bshd,bshe->bhde", kdec, vi_n(vi))
        state = state * jnp.exp(tot)[..., None, None] + kv
        return state, out

    state0 = jnp.zeros((B, H, dh, dh + 1), jnp.float32)
    state, outs = jax.lax.scan(
        chunk, state0,
        (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0),
         jnp.moveaxis(vc, 1, 0), jnp.moveaxis(igc, 1, 0),
         jnp.moveaxis(lfc, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nc * L, H, dh + 1)[:, :S]
    num, den = out[..., :dh], out[..., dh]
    h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    m = int(cfg.mlstm_proj_factor * d)
    h = h.reshape(B, S, m).astype(x.dtype)
    h = quantize_act(ctx, h, p, "s_state", col)
    y = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = qlinear(ctx, y, p["w_down"], subcol(col, "w_down"))
    if return_state:
        return y, state
    return y


def init_mlstm_cache(cfg: ModelConfig, B: int, dtype=jnp.int8) -> Dict:
    m = int(cfg.mlstm_proj_factor * cfg.d_model)
    H = cfg.n_heads
    dh = m // H
    return {"state_q": jnp.zeros((B, H, dh, dh + 1), dtype),
            "s_state": jnp.zeros((B, H, 1, 1), jnp.float32)}


def mlstm_prefill(cfg, ctx, p, x, col=None):
    y, state = mlstm_fwd(cfg, ctx, p, x, col, return_state=True)
    B, H = state.shape[:2]
    sq, ss = cache_quantize(ctx, state.reshape(B, H, -1).astype(jnp.bfloat16))
    return y, {"state_q": sq.reshape(state.shape),
               "s_state": ss[..., None]}


def mlstm_decode(cfg: ModelConfig, ctx: QuantCtx, p: Dict, x1: jnp.ndarray,
                 cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    B = x1.shape[0]
    q, k, v, z, ig, lf, dh = _mlstm_qkv(cfg, ctx, p, x1, None)
    H = cfg.n_heads
    state = dequantize_int(cache["state_q"], cache["s_state"], jnp.float32)
    f = jnp.exp(lf[:, 0]).astype(jnp.float32)             # (B,H)
    i = ig[:, 0].astype(jnp.float32)
    vn = jnp.concatenate([v[:, 0].astype(jnp.float32),
                          jnp.ones((B, H, 1), jnp.float32)], axis=-1)
    kv = jnp.einsum("bhd,bhe->bhde", k[:, 0].astype(jnp.float32) *
                    i[..., None], vn)
    state = state * f[..., None, None] + kv
    qf = q[:, 0].astype(jnp.float32) * dh ** -0.5
    out = jnp.einsum("bhd,bhde->bhe", qf, state)
    num, den = out[..., :dh], out[..., dh]
    h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    m = int(cfg.mlstm_proj_factor * cfg.d_model)
    h = h.reshape(B, 1, m).astype(x1.dtype)
    h = quantize_act(ctx, h, p, "s_state")
    y = h * jax.nn.silu(z.astype(jnp.float32)).astype(x1.dtype)
    y = qlinear(ctx, y, p["w_down"])
    sq, ss = cache_quantize(ctx, state.reshape(B, H, -1).astype(jnp.bfloat16))
    return y, {"state_q": sq.reshape(state.shape), "s_state": ss[..., None]}


# ==========================================================================
# sLSTM block (scalar memory, sequential scan)
# ==========================================================================

def init_slstm(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict:
    d = cfg.d_model
    s_in = int(cfg.slstm_proj_factor * d)
    ks = jax.random.split(key, 4)
    return {
        "w_x": init_linear(ks[0], d, 4 * d, bias=True, dtype=dtype),
        "r_h": init_linear(ks[1], d, 4 * d, dtype=dtype),
        "w_up": init_linear(ks[2], d, s_in, dtype=dtype),
        "w_down": init_linear(ks[3], s_in, d, dtype=dtype),
        "s_state": jnp.float32(1.0),
    }


def _slstm_cell(cfg, ctx, p, gx_t, h_prev, c_prev):
    """One sLSTM step. gx_t: precomputed W_x x_t (B,4d)."""
    d = cfg.d_model
    rh = qlinear(ctx, h_prev, p["r_h"])
    g = (gx_t + rh).astype(jnp.float32)
    i, f, zz, o = jnp.split(g, 4, axis=-1)
    c = jax.nn.sigmoid(f) * c_prev + jax.nn.sigmoid(i) * jnp.tanh(zz)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h.astype(gx_t.dtype), c


def slstm_fwd(cfg: ModelConfig, ctx: QuantCtx, p: Dict, x: jnp.ndarray,
              col: Optional[Dict] = None, *, return_state: bool = False):
    B, S, d = x.shape
    gx = qlinear(ctx, x, p["w_x"], subcol(col, "w_x"))     # (B,S,4d)

    def step(carry, gx_t):
        h, c = carry
        h, c = _slstm_cell(cfg, ctx, p, gx_t, h, c)
        return (h, c), h

    h0 = jnp.zeros((B, d), gx.dtype)
    c0 = jnp.zeros((B, d), jnp.float32)
    (hT, cT), hs = jax.lax.scan(step, (h0, c0), jnp.moveaxis(gx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1)                             # (B,S,d)
    h = quantize_act(ctx, h, p, "s_state", col)
    u = qlinear(ctx, h, p["w_up"], subcol(col, "w_up"))
    u = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    y = qlinear(ctx, u, p["w_down"], subcol(col, "w_down"))
    if return_state:
        return y, (hT, cT)
    return y


def init_slstm_cache(cfg: ModelConfig, B: int, dtype=jnp.int8) -> Dict:
    d = cfg.d_model
    return {"state_q": jnp.zeros((B, d), dtype),
            "s_state": jnp.zeros((B, 1), jnp.float32),
            "c": jnp.zeros((B, d), jnp.float32)}


def slstm_prefill(cfg, ctx, p, x, col=None):
    y, (hT, cT) = slstm_fwd(cfg, ctx, p, x, col, return_state=True)
    hq, hs = cache_quantize(ctx, hT.astype(jnp.bfloat16))
    return y, {"state_q": hq, "s_state": hs, "c": cT.astype(jnp.float32)}


def slstm_decode(cfg: ModelConfig, ctx: QuantCtx, p: Dict, x1: jnp.ndarray,
                 cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    gx = qlinear(ctx, x1, p["w_x"])[:, 0]
    h_prev = dequantize_int(cache["state_q"], cache["s_state"],
                            x1.dtype)
    h, c = _slstm_cell(cfg, ctx, p, gx, h_prev, cache["c"])
    hq2 = quantize_act(ctx, h[:, None], p, "s_state")
    u = qlinear(ctx, hq2, p["w_up"])
    u = jax.nn.gelu(u.astype(jnp.float32)).astype(x1.dtype)
    y = qlinear(ctx, u, p["w_down"])
    hq, hs = cache_quantize(ctx, h.astype(jnp.bfloat16))
    return y, {"state_q": hq, "s_state": hs, "c": c.astype(jnp.float32)}
