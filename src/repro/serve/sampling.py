"""On-device token sampling for the serve engine.

Everything here is shape-stable and batched over slots so the whole decode
loop (sampling included) stays inside one compiled program: per-slot
temperature / top-k / PRNG keys are device arrays, greedy vs. stochastic is
a ``jnp.where`` select, and the PRNG stream is derived deterministically by
folding the per-request key with the slot's generated-token count (no host
RNG state to sync). Per-request knobs ride on ``engine.Request``
(temperature <= 0 means greedy; top_k == 0 disables filtering).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import shard_hint

_NEG = -1e30


def _replicated(logits: jnp.ndarray) -> jnp.ndarray:
    """Gather vocab-sharded logits before sampling (no-op off-mesh).

    Under tensor-parallel serving the head is column-parallel, so logits
    arrive sharded over the vocab. The PRNG is *not* partitionable
    (legacy threefry: a categorical draw over a sharded operand generates
    different bits per shard layout), so sampling on sharded logits
    breaks tp=1-vs-tp=N stream parity. This all-gather of the sampled
    logits is one of the two canonical TP collectives per wave; the rest
    of the sampler then runs replicated and bit-identical to tp=1.
    """
    return shard_hint(logits, *([None] * logits.ndim))


def make_slot_keys(seeds: jnp.ndarray) -> jnp.ndarray:
    """(n,) int seeds -> (n, 2) uint32 raw PRNG keys (one stream per slot)."""
    return jax.vmap(jax.random.PRNGKey)(seeds.astype(jnp.uint32))


def fold_step(keys: jnp.ndarray, counters: jnp.ndarray) -> jnp.ndarray:
    """Derive this step's per-slot keys from persistent keys + counters."""
    return jax.vmap(jax.random.fold_in)(keys, counters)


TOP_K_CAP = 64      # static bound on per-request top_k (O(V*K) threshold
                    # search instead of a full-vocab sort per decode step)


def sample_tokens(logits: jnp.ndarray, keys: jnp.ndarray,
                  temperature: jnp.ndarray, top_k: jnp.ndarray,
                  greedy_only: bool = False) -> jnp.ndarray:
    """Batched greedy / temperature / top-k sampling.

    logits (B, V) float; keys (B, 2) uint32; temperature (B,) f32 (<=0 means
    greedy); top_k (B,) int32 (0 disables; values above ``TOP_K_CAP`` are
    rejected at submit). Returns (B,) int32 tokens. ``greedy_only``
    (trace-time constant) compiles the argmax-only variant — no top-k
    search / categorical draw in the decode loop when no resident request
    samples.
    """
    logits = _replicated(logits.astype(jnp.float32))
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if greedy_only:
        return greedy
    masked = _topk_masked(logits, top_k)
    temp = jnp.maximum(temperature, 1e-6)[:, None]
    drawn = jax.vmap(jax.random.categorical)(keys, masked / temp)
    return jnp.where(temperature > 0.0, drawn.astype(jnp.int32), greedy)


def _topk_masked(logits: jnp.ndarray, top_k: jnp.ndarray) -> jnp.ndarray:
    """Logits with everything below each row's k-th largest pushed to
    -inf (top_k == 0 disables). The shared filter behind sampling and
    the speculative-decode acceptance probabilities."""
    V = logits.shape[-1]
    kc = min(TOP_K_CAP, V)
    desc = jax.lax.top_k(logits, kc)[0]                       # (B, kc)
    kth = jnp.take_along_axis(
        desc, jnp.clip(top_k - 1, 0, kc - 1)[:, None], axis=-1)
    return jnp.where((top_k[:, None] > 0) & (logits < kth), _NEG, logits)


def token_probs(logits: jnp.ndarray, temperature: jnp.ndarray,
                top_k: jnp.ndarray) -> jnp.ndarray:
    """The categorical distribution :func:`sample_tokens` draws from.

    logits (B, V); temperature (B,); top_k (B,). Stochastic rows get the
    post-temperature, top-k-filtered softmax; greedy rows (temp <= 0)
    get a one-hot at the argmax — so speculative rejection sampling
    against these probabilities reduces to exact argmax matching for
    greedy requests. Returns (B, V) fp32 rows summing to 1.
    """
    logits = _replicated(logits.astype(jnp.float32))
    masked = _topk_masked(logits, top_k)
    temp = jnp.maximum(temperature, 1e-6)[:, None]
    p = jax.nn.softmax(masked / temp, axis=-1)
    one_hot = jax.nn.one_hot(jnp.argmax(logits, axis=-1), logits.shape[-1],
                             dtype=jnp.float32)
    return jnp.where(temperature[:, None] > 0.0, p, one_hot)
