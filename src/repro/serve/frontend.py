"""Asyncio serving frontend: open-loop arrivals over the slot engine.

``ServeEngine`` is a synchronous batch machine — ``submit`` everything,
``step`` until drained. Production traffic is the opposite shape:
requests arrive continuously, every caller wants its tokens *as they
decode*, and nobody is willing to wait for the batch to finish. This
module is the production front half:

* :class:`AsyncFrontend` — the asyncio host loop. Requests enter through
  ``await frontend.submit(...)`` at any time; a single pump task steps
  the engine whenever there is work, running each (blocking, device-
  bound) ``engine.step()`` in a worker thread so the event loop keeps
  accepting arrivals, serving HTTP, and flushing token streams *while*
  the device computes. Engine state is only ever touched from the pump —
  arrivals land in an inbox the pump drains between steps — so the
  single-threaded engine needs no locks.
* :class:`RequestStream` — the per-request handle. Async-iterate it for
  tokens as they decode (``async for tok in handle``), or ``await
  handle.tokens()`` for the collected list. Token spans surface at
  ``decode_block`` / spec-wave granularity straight from the engine's
  incremental harvest hook (``Request.on_tokens``), bridged onto the
  event loop with ``call_soon_threadsafe``.
* **SLO plumbing** — ``submit`` takes ``deadline_ms`` / ``priority``
  per request (defaults configurable on the frontend); pair the engine
  with ``sched_policy="edf"`` and ``slo_shed="reject"|"downgrade"`` for
  earliest-deadline-first admission and shed-load under overload. A
  shed request's stream ends immediately with ``handle.shed == True``.

The wave loop stays decoupled from the host loop by construction — the
pump owns stepping, arrival/egress own the event loop — which is the
precondition for disaggregating prefill and decode waves onto separate
devices/streams later.

Typical use::

    frontend = AsyncFrontend(engine)
    async with frontend:
        handle = await frontend.submit(prompt_ids, max_new_tokens=64,
                                       deadline_ms=500)
        async for tok in handle:
            ...                       # tokens at decode-chunk granularity

(See ``serve.http`` for the OpenAI-style endpoint on top of this, and
``docs/serving_api.md`` for the full knob table.)
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import itertools
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.serve.engine import Request

_END = object()         # stream sentinel: request left the engine


class RequestStream:
    """Async handle for one in-flight request.

    Iterate it for tokens as they decode::

        handle = await frontend.submit(prompt)
        async for tok in handle:
            ...

    or collect everything at once with ``await handle.tokens()``. After
    the stream ends, ``handle.request`` carries the engine's finished
    :class:`~repro.serve.engine.Request` (``generated`` / ``done`` /
    ``shed``), ``handle.shed`` says whether SLO admission control
    rejected the request, and ``handle.first_token_t`` /
    ``handle.finish_t`` are event-loop timestamps of the first drained
    span and the terminal event (open-loop benchmarks derive client-side
    TTFT/TPOT from them).
    """

    def __init__(self, req: Request, loop: asyncio.AbstractEventLoop):
        self.request = req
        self.submit_t = time.perf_counter()
        self.first_token_t: Optional[float] = None
        self.finish_t: Optional[float] = None
        self._queue: asyncio.Queue = asyncio.Queue()
        self._loop = loop
        self._ended = False

    # -- engine side (worker thread): Request.on_tokens target ----------
    def _on_tokens(self, _req, toks: List[int], done: bool) -> None:
        self._loop.call_soon_threadsafe(self._post, toks, done)

    # -- loop side -------------------------------------------------------
    def _post(self, toks: List[int], done: bool) -> None:
        now = time.perf_counter()
        if toks and self.first_token_t is None:
            self.first_token_t = now
        for t in toks:
            # plain ints: engine rows arrive as numpy scalars, which JSON
            # encoders (serve.http) and equality-asserting tests reject
            self._queue.put_nowait(int(t))
        if done:
            self.finish_t = now
            self._queue.put_nowait(_END)

    @property
    def shed(self) -> bool:
        """True when SLO admission control rejected the request."""
        return self.request.shed

    def __aiter__(self):
        return self

    async def __anext__(self) -> int:
        if self._ended:
            raise StopAsyncIteration
        item = await self._queue.get()
        if item is _END:
            self._ended = True
            raise StopAsyncIteration
        return item

    async def tokens(self) -> List[int]:
        """Drain the stream to completion and return all tokens."""
        return [t async for t in self]


class AsyncFrontend:
    """Asyncio host loop over a :class:`~repro.serve.engine.ServeEngine`.

    Args:
        engine: the (already constructed) engine. The frontend owns its
            stepping for the lifetime of the context; do not call
            ``engine.step`` / ``run_until_drained`` concurrently.
        default_deadline_ms / default_priority: applied to submissions
            that don't specify their own.
        idle_sleep_s: pump back-off while the engine is empty (an
            arrival event wakes it immediately; this only bounds the
            latency of wakeups racing a step).

    Use as an async context manager (``async with AsyncFrontend(engine)
    as fe:``) or call :meth:`start` / :meth:`aclose` explicitly.
    """

    def __init__(self, engine, *, default_deadline_ms: Optional[float] = None,
                 default_priority: int = 0, idle_sleep_s: float = 0.02):
        self.engine = engine
        self.default_deadline_ms = default_deadline_ms
        self.default_priority = default_priority
        self.idle_sleep_s = idle_sleep_s
        self._uids = itertools.count()
        self._inbox: List[RequestStream] = []
        self._streams: List[RequestStream] = []
        self._wake: Optional[asyncio.Event] = None
        self._pump_task: Optional[asyncio.Task] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._closing = False
        # one dedicated worker: engine.step is single-threaded by design
        self._executor = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="serve-step")

    # ---- lifecycle ----
    async def start(self) -> "AsyncFrontend":
        """Start the pump task (idempotent)."""
        if self._pump_task is None:
            self._loop = asyncio.get_running_loop()
            self._wake = asyncio.Event()
            self._closing = False
            self._pump_task = asyncio.create_task(self._pump(),
                                                  name="serve-pump")
        return self

    async def aclose(self) -> None:
        """Stop the pump. In-flight streams are ended (``done`` stays
        False on their requests); the engine keeps its state.

        Shutdown is cooperative (a flag the pump checks each iteration),
        NOT ``task.cancel()``: on Python < 3.12 a cancel landing while
        ``asyncio.wait_for`` resolves its inner future is silently
        swallowed, leaving the pump alive and ``await task`` wedged.
        """
        task, self._pump_task = self._pump_task, None
        if task is not None:
            self._closing = True
            self._wake.set()        # pump exits at its next iteration
            try:
                await task
            finally:
                self._executor.shutdown(wait=True)
        else:
            self._executor.shutdown(wait=True)
        for h in self._streams:
            if h.finish_t is None:
                h._post([], done=True)
        self._streams.clear()

    async def __aenter__(self) -> "AsyncFrontend":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    # ---- submission ----
    async def submit(self, prompt: Sequence[int], *,
                     max_new_tokens: int = 32, temperature: float = 0.0,
                     top_k: int = 0, seed: int = 0, eos_id: int = -1,
                     deadline_ms: Optional[float] = None,
                     priority: Optional[int] = None) -> RequestStream:
        """Submit one request; returns its :class:`RequestStream`.

        Args mirror :class:`~repro.serve.engine.Request`; ``prompt`` is a
        sequence of int token ids. ``deadline_ms`` / ``priority`` default
        to the frontend's configured defaults.

        Raises:
            ValueError: same never-admittable conditions as
                ``ServeEngine.submit`` (checked on the event loop, before
                the request reaches the queue — the caller gets the
                error, not a poisoned engine).
            RuntimeError: if the frontend is not started.
        """
        if self._pump_task is None:
            raise RuntimeError("AsyncFrontend is not started; use "
                               "'async with AsyncFrontend(engine):' or "
                               "await start()")
        req = Request(
            uid=next(self._uids),
            prompt=np.asarray(prompt, np.int32),
            max_new_tokens=max_new_tokens, temperature=temperature,
            top_k=top_k, seed=seed, eos_id=eos_id,
            deadline_ms=(self.default_deadline_ms if deadline_ms is None
                         else deadline_ms),
            priority=(self.default_priority if priority is None
                      else priority))
        self._precheck(req)
        handle = RequestStream(req, self._loop)
        req.on_tokens = handle._on_tokens
        self._inbox.append(handle)
        self._wake.set()
        return handle

    def _precheck(self, req: Request) -> None:
        """Run the engine's never-admittable submit validation without
        touching engine state (pure reads of sizing attributes)."""
        eng = self.engine
        if req.max_new_tokens > eng.max_new_cap:
            raise ValueError(f"max_new_tokens={req.max_new_tokens} exceeds "
                             f"max_new_cap={eng.max_new_cap}")
        need = len(req.prompt) + req.max_new_tokens - 1
        limit = eng.max_seq_len if eng._paged else (
            eng.cache_len if eng._cache_bound else None)
        if limit is not None and need > limit:
            raise ValueError(f"request needs {need} cache tokens but this "
                             f"engine serves at most {limit} per request")

    # ---- pump ----
    def _work_pending(self) -> bool:
        eng = self.engine
        return bool(self._inbox or eng.scheduler.pending or eng._slot_req
                    or eng._tail_jobs or eng._swapped)

    def _drain_inbox(self) -> None:
        """Move arrivals into the engine queue (pump/loop thread only,
        never concurrent with a step)."""
        while self._inbox:
            handle = self._inbox.pop(0)
            self._streams.append(handle)
            try:
                self.engine.submit(handle.request)
            except ValueError:
                # raced past _precheck (e.g. engine reconfigured):
                # surface as a shed/rejected stream, don't kill the pump
                handle.request.shed = True
                handle._post([], done=True)
        self._streams = [h for h in self._streams if h.finish_t is None]

    async def _pump(self) -> None:
        """The host loop: drain arrivals, step the engine in a worker
        thread (the event loop keeps serving arrivals / HTTP / streams
        while the device computes), park on the wake event when idle.

        Exits when :meth:`aclose` raises the closing flag. If a step
        raises, every open stream is ended first (``request.done`` stays
        False — how clients distinguish an engine failure from a normal
        finish) so no awaiter hangs, then the error surfaces in
        ``aclose``."""
        loop = asyncio.get_running_loop()
        try:
            while not self._closing:
                self._drain_inbox()
                if self._work_pending():
                    await loop.run_in_executor(self._executor,
                                               self.engine.step)
                else:
                    self._wake.clear()
                    if self._closing:
                        break
                    try:
                        await asyncio.wait_for(self._wake.wait(),
                                               self.idle_sleep_s)
                    except asyncio.TimeoutError:
                        pass
        except Exception:
            for h in self._inbox + self._streams:
                if h.finish_t is None:
                    h._post([], done=True)
            self._inbox.clear()
            raise

    # ---- conveniences ----
    async def complete(self, prompt: Sequence[int], **kw) -> Request:
        """Submit and wait for the full completion (non-streaming path);
        returns the finished engine Request."""
        handle = await self.submit(prompt, **kw)
        await handle.tokens()
        return handle.request

    async def stats(self) -> dict:
        """Engine stats snapshot (keys in ``ServeEngine.stats``), plus a
        ``"metrics"`` digest of the pushed TTFT/TPOT/latency histograms
        (``ServeMetrics.snapshot``).

        Runs on the step worker so the device fetch serializes with any
        step in flight — a step's donated state buffers must never be
        read mid-flight."""
        def snap():
            st = self.engine.stats()
            st["metrics"] = self.engine.metrics.snapshot()
            return st
        if self._pump_task is None:
            return snap()
        loop = asyncio.get_running_loop()
        return await loop.run_in_executor(self._executor, snap)
