"""Request scheduling + latency accounting for the serve engine.

The scheduler owns the waiting queue and all per-request timing; the engine
asks it for the next admission batch whenever slots free up. Policies are
pluggable:

* ``fcfs`` — first-come-first-served (arrival order)
* ``sjf``  — shortest-prompt-first (minimizes mean TTFT under load; ties
  broken by arrival so it stays starvation-bounded for equal lengths)
* ``edf``  — earliest-deadline-first **within priority class**: requests
  order by ``(priority, absolute deadline, arrival)``. ``priority`` is an
  int on the request (lower = more urgent, default 0); requests without a
  deadline sort behind every deadlined request of the same class. The
  SLO-aware policy for open-loop serving — pair it with
  :meth:`Scheduler.shed_overdue` for shed-load behavior under overload.

Batched prefill wants co-admitted prompts of similar length; ``select``
therefore groups the policy-ordered head of the queue into one prefill
bucket: padded engines take any lengths (bucketed up to a common padded
length), exact-length engines (recurrent archs, where right-padding would
corrupt the scan state) only take requests sharing the leader's length.

Prefix-affinity grouping (``group_key`` / ``hot``) layers on top of any
base policy, EDF included: the base order decides each group's rank via
its first occurrence, then sharers of one cached chain admit
back-to-back.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.obs.trace import NULL_TRACER

POLICIES = ("fcfs", "sjf", "edf")
SHED_MODES = ("none", "reject", "downgrade")
# priority class a downgraded request lands in: behind every explicit
# class, so on-time work always outranks work that already missed its SLO
BEST_EFFORT_PRIORITY = 1 << 30
PREEMPT_POLICIES = ("last_admitted", "longest_remaining")
# how many non-head admissions may jump the policy head via hot-chain
# affinity before grouping pauses and the head admits (starvation bound)
HOT_BYPASS_CAP = 16


@dataclass
class RequestTiming:
    submit_t: float
    admit_t: Optional[float] = None     # prefill done, first token exists
    finish_t: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        return None if self.admit_t is None else self.admit_t - self.submit_t

    @property
    def latency(self) -> Optional[float]:
        return None if self.finish_t is None else self.finish_t - self.submit_t


def percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile — the one definition every serve stat uses
    (benchmarks import this so seed/v2 numbers stay comparable).

    >>> percentile([0.4, 0.1, 0.3, 0.2], 50)
    0.3
    >>> percentile([], 95)
    0.0
    """
    if not xs:
        return 0.0
    ys = sorted(xs)
    i = min(len(ys) - 1, max(0, int(round(q / 100.0 * (len(ys) - 1)))))
    return ys[i]


class Scheduler:
    """Queue + admission policy + per-request latency bookkeeping."""

    def __init__(self, policy: str = "fcfs", trace=None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; known: {POLICIES}")
        self.policy = policy
        # request-lifecycle event sink (a repro.obs Tracer; the engine
        # passes its own so queue events land in the same trace as waves)
        self.trace = trace if trace is not None else NULL_TRACER
        self._queue: List = []                   # waiting Requests
        # timing rides on the request object (uids may collide); the
        # scheduler keeps the full list for aggregate stats
        self._timings: List[RequestTiming] = []
        self._seq = 0                            # arrival tiebreaker
        self._bypass_head = None     # policy head being jumped via hot
        self._bypass_count = 0       # non-head removals while it waits
        self.shed_rejected = 0       # requests dropped by shed_overdue
        self.shed_downgraded = 0     # requests demoted to best-effort

    # ---- queue ----
    def submit(self, req, now: Optional[float] = None) -> None:
        """Enqueue ``req`` and start its latency clock.

        Stamps the request's arrival order (the FCFS / tiebreak key), its
        submit time, and — when the request carries a ``deadline_ms`` —
        its *absolute* first-token deadline ``submit_t + deadline_ms/1e3``
        (what EDF ordering and :meth:`shed_overdue` compare against).
        ``now`` overrides the wall clock for deterministic tests.
        """
        req._arrival = self._seq
        self._seq += 1
        t = time.perf_counter() if now is None else now
        req._timing = RequestTiming(submit_t=t)
        dl = getattr(req, "deadline_ms", None)
        req._deadline_t = None if dl is None else t + dl / 1e3
        self._timings.append(req._timing)
        self._queue.append(req)
        uid = getattr(req, "uid", None)
        self.trace.event("submit", uid=uid,
                         prompt_tokens=len(getattr(req, "prompt", ())))
        self.trace.event("queued", uid=uid, queue_len=len(self._queue))

    @property
    def pending(self) -> int:
        return len(self._queue)

    @staticmethod
    def _edf_key(r):
        dl = getattr(r, "_deadline_t", None)
        return (getattr(r, "priority", 0),
                dl if dl is not None else float("inf"), r._arrival)

    def _ordered(self, group_key=None, hot=(), skip=()) -> List:
        base = self._queue if not skip else \
            [r for r in self._queue if r not in skip]
        if self.policy == "sjf":
            base = sorted(base, key=lambda r: (len(r.prompt), r._arrival))
        elif self.policy == "edf":
            base = sorted(base, key=self._edf_key)
        else:
            base = list(base)
        if group_key is None:
            return base
        # prefix-aware affinity: requests sharing a cached chain (equal
        # non-None key) are pulled back-to-back behind the group's first
        # occurrence, so the chain admits while it is still hot in the
        # allocator's LRU. Keys in ``hot`` belong to chains with an
        # admission already in flight — their sharers rank ahead of
        # everything (the anchor that earned the group its position has
        # left the queue, so rank-by-first-occurrence alone would let a
        # stranger split the group). Keyless requests keep their policy
        # position; cold groups never jump an earlier-ranked stranger.
        # Hot jumping is starvation-bounded: once HOT_BYPASS_CAP non-head
        # admissions have passed the same waiting policy head, grouping
        # pauses until the head itself is taken (a steady sharer stream
        # must not pin a stranger at the head forever).
        if hot and base and self._bypass_head is base[0] \
                and self._bypass_count >= HOT_BYPASS_CAP:
            hot = ()
        first_at: Dict = {}
        ranked = []
        for i, r in enumerate(base):
            k = group_key(r)
            if k is None:
                ranked.append(((i, i), r))
            elif k in hot:
                ranked.append(((-1, i), r))
            else:
                first_at.setdefault(k, i)
                ranked.append(((first_at[k], i), r))
        ranked.sort(key=lambda t: t[0])
        return [r for _, r in ranked]

    def first(self, group_key=None, hot=(), skip=()):
        """Policy-ordered head of the queue (None when empty or fully
        skipped). The paged engine peeks it to route prefix-hit / long
        prompts into tail admission; ``group_key``/``hot`` apply the
        same prefix-affinity grouping as ``select``; ``skip`` excludes
        requests the engine is holding this step (cross-wave dedup) so
        unrelated work behind them still admits."""
        ordered = self._ordered(group_key, hot, skip)
        return ordered[0] if ordered else None

    def _policy_head(self):
        """Ungrouped policy head (what pure FCFS/SJF would admit next)."""
        if not self._queue:
            return None
        if self.policy == "sjf":
            return min(self._queue,
                       key=lambda r: (len(r.prompt), r._arrival))
        if self.policy == "edf":
            return min(self._queue, key=self._edf_key)
        return self._queue[0]

    def _note_removal(self, req, head) -> None:
        """Track admissions that bypass the waiting policy head (the
        hot-chain starvation bound; see ``_ordered``)."""
        if req is head or head is None:
            self._bypass_head = None
            self._bypass_count = 0
        else:
            if self._bypass_head is not head:
                self._bypass_head = head
                self._bypass_count = 0
            self._bypass_count += 1

    def take(self, req) -> None:
        """Remove a specific queued request (paired with ``first``)."""
        head = self._policy_head()
        self._queue.remove(req)
        self._note_removal(req, head)

    def select(self, max_n: int, *, equal_length_only: bool = False,
               admit_ok=None, group_key=None, hot=(), skip=()) -> List:
        """Pop up to ``max_n`` requests for one batched prefill.

        ``equal_length_only``: restrict the batch to the leader's exact
        prompt length (recurrent caches can't absorb right-padding).
        ``admit_ok``: per-request admission predicate (e.g. "enough free
        cache blocks"). Selection stops at the first failing request —
        head-of-line blocking, so a big request can't be starved by smaller
        ones arriving behind it. The predicate may commit resources
        (reservations) for requests it accepts: everything it accepted is
        admitted. ``group_key`` (callable req -> hashable | None) groups
        requests with equal keys back-to-back, and ``hot`` keys (chains
        with an admission in flight) rank first (prefix-affinity; see
        ``_ordered``) before the scan. ``skip`` excludes requests the
        engine is holding this step (cross-wave dedup).
        """
        if max_n <= 0 or not self._queue:
            return []
        ordered = self._ordered(group_key, hot, skip)
        batch: List = []
        for r in ordered:
            if len(batch) >= max_n:
                break
            if batch and equal_length_only and \
                    len(r.prompt) != len(batch[0].prompt):
                continue
            if admit_ok is not None and not admit_ok(r):
                break
            batch.append(r)
        head = self._policy_head()
        for r in batch:
            self._queue.remove(r)
        if batch:
            # one bypass event per admission batch: either the head went
            # (reset) or everything admitted jumped it (count once)
            self._note_removal(head if head in batch else batch[0], head)
        return batch

    # ---- SLO shed-load ----
    def shed_overdue(self, predict_s, mode: str = "reject",
                     now: Optional[float] = None) -> List:
        """Shed queued requests whose first-token deadline is already
        unreachable (SLO-aware admission control under overload).

        Walks the queue in policy order accumulating the prefill work
        queued *ahead* of each request; for every request with a
        deadline, the predicted TTFT is ``elapsed-so-far +
        predict_s(tokens_ahead + own prompt)`` where ``predict_s`` maps a
        prompt-token backlog to estimated seconds until the first token
        (the engine supplies one fitted from its measured prefill/decode
        rates). A request predicted to miss is handled per ``mode``:

        * ``"reject"``   — removed from the queue and returned; the
          caller marks it shed and closes its stream. Serving capacity
          is spent only on requests that can still meet their SLO
          (goodput over throughput).
        * ``"downgrade"`` — kept, but its deadline is cleared and its
          priority drops to ``BEST_EFFORT_PRIORITY``: it still serves
          eventually, ordered behind every on-time request, and is never
          shed again (a cleared deadline can't re-trigger).

        Deadline-less requests are never touched. Returns the list of
        rejected requests (empty in ``downgrade`` mode).
        """
        if mode not in SHED_MODES:
            raise ValueError(f"unknown shed mode {mode!r}; known: "
                             f"{SHED_MODES}")
        if mode == "none" or not self._queue:
            return []
        t = time.perf_counter() if now is None else now
        shed: List = []
        ahead = 0
        for r in self._ordered():
            work = ahead + len(r.prompt)
            dl = getattr(r, "_deadline_t", None)
            if dl is not None and t + predict_s(work) > dl:
                if mode == "reject":
                    shed.append(r)
                    continue            # its work never joins the backlog
                r._deadline_t = None
                r.deadline_ms = None
                r.priority = BEST_EFFORT_PRIORITY
                self.shed_downgraded += 1
                self.trace.event("downgraded", uid=getattr(r, "uid", None))
            ahead = work
        for r in shed:
            self._queue.remove(r)
            self.shed_rejected += 1
        return shed

    # ---- preemption ----
    @staticmethod
    def pick_victim(candidates, mode: str = "last_admitted"):
        """Choose which resident the engine swaps out when the block pool
        runs dry under optimistic admission.

        ``candidates``: (slot, admit_seq, remaining_tokens) triples for the
        preemptible residents. ``last_admitted`` evicts the newest resident
        (FCFS-fair: the oldest work keeps its cache warm);
        ``longest_remaining`` evicts the resident with the most tokens
        still to serve (frees the most block-seconds per swap, ties broken
        newest-first). Returns the victim slot, or None when there is
        nothing to preempt.
        """
        if mode not in PREEMPT_POLICIES:
            raise ValueError(
                f"unknown preemption policy {mode!r}; known: "
                f"{PREEMPT_POLICIES}")
        if not candidates:
            return None
        if mode == "longest_remaining":
            return max(candidates, key=lambda c: (c[2], c[1]))[0]
        return max(candidates, key=lambda c: c[1])[0]

    # ---- accounting ----
    def on_admitted(self, reqs, now: Optional[float] = None) -> None:
        t = time.perf_counter() if now is None else now
        for r in reqs:
            r._timing.admit_t = t
            self.trace.event("admitted", uid=getattr(r, "uid", None),
                             queue_delay_s=t - r._timing.submit_t)

    def on_finished(self, req, now: Optional[float] = None) -> None:
        t = time.perf_counter() if now is None else now
        req._timing.finish_t = t
        # latency_s here is the scheduler-clock measurement the trace
        # report reconciles its own event-delta latency against
        self.trace.event("finished", uid=getattr(req, "uid", None),
                         latency_s=req._timing.latency,
                         tokens=len(getattr(req, "generated", ()) or ()))

    def stats(self) -> Dict[str, float]:
        """Aggregate latency/SLO stats over every request ever submitted
        (see ``ServeEngine.stats`` for the full key table)."""
        ttfts = [t.ttft for t in self._timings if t.ttft is not None]
        lats = [t.latency for t in self._timings if t.latency is not None]
        return {
            "requests_finished": len(lats),
            "requests_shed": self.shed_rejected,
            "requests_downgraded": self.shed_downgraded,
            "ttft_p50_s": percentile(ttfts, 50),
            "ttft_p95_s": percentile(ttfts, 95),
            "latency_p50_s": percentile(lats, 50),
            "latency_p95_s": percentile(lats, 95),
        }
