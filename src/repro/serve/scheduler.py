"""Request scheduling + latency accounting for the serve engine.

The scheduler owns the waiting queue and all per-request timing; the engine
asks it for the next admission batch whenever slots free up. Policies are
pluggable:

* ``fcfs`` — first-come-first-served (arrival order)
* ``sjf``  — shortest-prompt-first (minimizes mean TTFT under load; ties
  broken by arrival so it stays starvation-bounded for equal lengths)

Batched prefill wants co-admitted prompts of similar length; ``select``
therefore groups the policy-ordered head of the queue into one prefill
bucket: padded engines take any lengths (bucketed up to a common padded
length), exact-length engines (recurrent archs, where right-padding would
corrupt the scan state) only take requests sharing the leader's length.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

POLICIES = ("fcfs", "sjf")
PREEMPT_POLICIES = ("last_admitted", "longest_remaining")


@dataclass
class RequestTiming:
    submit_t: float
    admit_t: Optional[float] = None     # prefill done, first token exists
    finish_t: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        return None if self.admit_t is None else self.admit_t - self.submit_t

    @property
    def latency(self) -> Optional[float]:
        return None if self.finish_t is None else self.finish_t - self.submit_t


def percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile — the one definition every serve stat uses
    (benchmarks import this so seed/v2 numbers stay comparable)."""
    if not xs:
        return 0.0
    ys = sorted(xs)
    i = min(len(ys) - 1, max(0, int(round(q / 100.0 * (len(ys) - 1)))))
    return ys[i]


class Scheduler:
    """Queue + admission policy + per-request latency bookkeeping."""

    def __init__(self, policy: str = "fcfs"):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; known: {POLICIES}")
        self.policy = policy
        self._queue: List = []                   # waiting Requests
        # timing rides on the request object (uids may collide); the
        # scheduler keeps the full list for aggregate stats
        self._timings: List[RequestTiming] = []
        self._seq = 0                            # arrival tiebreaker

    # ---- queue ----
    def submit(self, req, now: Optional[float] = None) -> None:
        req._arrival = self._seq
        self._seq += 1
        req._timing = RequestTiming(
            submit_t=time.perf_counter() if now is None else now)
        self._timings.append(req._timing)
        self._queue.append(req)

    @property
    def pending(self) -> int:
        return len(self._queue)

    def _ordered(self) -> List:
        if self.policy == "sjf":
            return sorted(self._queue,
                          key=lambda r: (len(r.prompt), r._arrival))
        return list(self._queue)

    def first(self):
        """Policy-ordered head of the queue (None when empty). The paged
        engine peeks it to route long prompts into chunked admission."""
        return self._ordered()[0] if self._queue else None

    def take(self, req) -> None:
        """Remove a specific queued request (paired with ``first``)."""
        self._queue.remove(req)

    def select(self, max_n: int, *, equal_length_only: bool = False,
               admit_ok=None) -> List:
        """Pop up to ``max_n`` requests for one batched prefill.

        ``equal_length_only``: restrict the batch to the leader's exact
        prompt length (recurrent caches can't absorb right-padding).
        ``admit_ok``: per-request admission predicate (e.g. "enough free
        cache blocks"). Selection stops at the first failing request —
        head-of-line blocking, so a big request can't be starved by smaller
        ones arriving behind it. The predicate may commit resources
        (reservations) for requests it accepts: everything it accepted is
        admitted.
        """
        if max_n <= 0 or not self._queue:
            return []
        ordered = self._ordered()
        batch: List = []
        for r in ordered:
            if len(batch) >= max_n:
                break
            if batch and equal_length_only and \
                    len(r.prompt) != len(batch[0].prompt):
                continue
            if admit_ok is not None and not admit_ok(r):
                break
            batch.append(r)
        for r in batch:
            self._queue.remove(r)
        return batch

    # ---- preemption ----
    @staticmethod
    def pick_victim(candidates, mode: str = "last_admitted"):
        """Choose which resident the engine swaps out when the block pool
        runs dry under optimistic admission.

        ``candidates``: (slot, admit_seq, remaining_tokens) triples for the
        preemptible residents. ``last_admitted`` evicts the newest resident
        (FCFS-fair: the oldest work keeps its cache warm);
        ``longest_remaining`` evicts the resident with the most tokens
        still to serve (frees the most block-seconds per swap, ties broken
        newest-first). Returns the victim slot, or None when there is
        nothing to preempt.
        """
        if mode not in PREEMPT_POLICIES:
            raise ValueError(
                f"unknown preemption policy {mode!r}; known: "
                f"{PREEMPT_POLICIES}")
        if not candidates:
            return None
        if mode == "longest_remaining":
            return max(candidates, key=lambda c: (c[2], c[1]))[0]
        return max(candidates, key=lambda c: c[1])[0]

    # ---- accounting ----
    def on_admitted(self, reqs, now: Optional[float] = None) -> None:
        t = time.perf_counter() if now is None else now
        for r in reqs:
            r._timing.admit_t = t

    def on_finished(self, req, now: Optional[float] = None) -> None:
        req._timing.finish_t = time.perf_counter() if now is None else now

    def stats(self) -> Dict[str, float]:
        ttfts = [t.ttft for t in self._timings if t.ttft is not None]
        lats = [t.latency for t in self._timings if t.latency is not None]
        return {
            "requests_finished": len(lats),
            "ttft_p50_s": percentile(ttfts, 50),
            "ttft_p95_s": percentile(ttfts, 95),
            "latency_p50_s": percentile(lats, 50),
            "latency_p95_s": percentile(lats, 95),
        }
