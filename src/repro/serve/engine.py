"""Continuous-batching serve engine v2 over the quantized cache.

vLLM-style slot engine, rebuilt so the host only touches the device at
admission boundaries:

* **Batched prefill** — the scheduler hands over up to ``slots`` queued
  requests at once; they are right-padded to a length bucket and prefilled
  in one compiled call (per-row ``lengths`` keep the cache and logits exact;
  see ``models.prefill``). Architectures with recurrent blocks, where
  padding would corrupt the scan state, admit exact-length groups instead.
* **On-device decode loop** — sampling (greedy / temperature / top-k),
  per-slot EOS + max-token tracking, and the generated-token buffers all
  live in the device state pytree; ``lax.while_loop`` runs up to
  ``decode_block`` steps per compiled call and stops early once every slot
  is inactive. No ``int(...)`` / ``np.asarray`` per token — the host syncs
  once per chunk to harvest finished slots and admit new work.
* **Paged KV cache** (``kv_layout="paged"``) — instead of reserving a dense
  ``cache_len`` stripe per slot, attention layers share one global pool of
  fixed-size quantized blocks addressed through a per-slot block table
  (``serve.block_alloc`` owns the free list on the host). Admission switches
  from "fits in cache_len" to "enough free blocks", blocks are allocated
  lazily as decode crosses block boundaries, and harvest returns them to the
  pool — so capacity tracks actual token residency, not the worst-case
  request. Prompts longer than ``prefill_chunk`` are admitted as a sequence
  of fixed-size **chunked prefill** calls that append blocks incrementally
  (``models.prefill_chunk``), removing the cache_len bound on prompt length.
* **Scheduler** (``serve.scheduler``) — pluggable FCFS / shortest-prompt
  policies plus per-request TTFT/latency accounting; paged admission uses
  its head-of-line ``admit_ok`` hook so big requests aren't starved.

All per-slot cache state (int8 KV / recurrent) stays in one pytree so the
decode chunk is a single compiled program regardless of slot occupancy;
inactive slots ride along masked (their commits are dropped — in paged mode
by parking their block-table rows on the out-of-range sentinel) and are
recycled by the next admission.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTENTION_BLOCKS, BLOCK_ATTN, ModelConfig
from repro.core.qat import make_ctx
from repro.models import decode_step, init_cache, prefill
from repro.models import prefill_chunk as model_prefill_chunk
from repro.serve.block_alloc import BlockAllocator
from repro.serve.sampling import TOP_K_CAP, fold_step, sample_tokens
from repro.serve.scheduler import Scheduler

_POOL_KEYS = ("k_q", "v_q", "s_k", "s_v")   # pool-shaped paged cache leaves


@dataclass(eq=False)                    # identity equality: the ndarray
class Request:                          # prompt field breaks value __eq__
    uid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                    # -1: never stops early
    temperature: float = 0.0            # <= 0: greedy
    top_k: int = 0                      # 0: no top-k filtering
    seed: int = 0
    generated: List[int] = field(default_factory=list)
    done: bool = False
    _arrival: int = 0                   # set by the scheduler


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, policy: str = "A8d-C8-W4",
                 slots: int = 8, cache_len: int = 512,
                 max_new_cap: int = 256,
                 decode_block: Union[int, str] = 8,
                 sched_policy: str = "fcfs", prefill_bucket: int = 16,
                 kv_layout: str = "dense", block_size: int = 64,
                 num_blocks: Optional[int] = None,
                 max_seq_len: Optional[int] = None,
                 prefill_chunk: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.ctx = make_ctx(policy)
        self.slots = slots
        self.cache_len = cache_len
        self.max_new_cap = max_new_cap
        self.prefill_bucket = prefill_bucket
        self.scheduler = Scheduler(sched_policy)
        # right-padded batched prefill is exact only when every block is
        # attention (causality isolates real tokens from padding); recurrent
        # scans absorb pad steps into their state, so those admit
        # exact-length groups instead.
        self._pad_ok = (all(k in ATTENTION_BLOCKS for k in cfg.block_pattern)
                        and not cfg.is_encdec)
        # full (non-sliding) attention caches are a hard capacity bound;
        # ring-buffered / recurrent state is not
        self._cache_bound = (BLOCK_ATTN in cfg.block_pattern
                             and not cfg.sliding_window)
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"kv_layout must be 'dense' or 'paged', "
                             f"got {kv_layout!r}")
        self._paged = kv_layout == "paged"
        if self._paged:
            if (cfg.is_encdec or cfg.sliding_window
                    or any(k != BLOCK_ATTN for k in cfg.block_pattern)):
                raise ValueError(
                    "kv_layout='paged' requires a full-attention decoder "
                    "(no sliding window / recurrence / cross-attention); "
                    f"{cfg.name!r} has block pattern {cfg.block_pattern}")
            self.block_size = block_size
            # default pool = the dense engine's total reservation, so the
            # two layouts are comparable at equal HBM
            self.num_blocks = num_blocks or max(
                1, slots * cache_len // block_size)
            # default per-request cap matches the dense stripe: the table
            # width bounds how many keys each decode step walks, so leaving
            # it at the whole pool would cost slots-times the attention
            # work of the dense layout
            self.max_seq_len = max_seq_len or min(
                cache_len, self.num_blocks * block_size)
            self.table_len = -(-self.max_seq_len // block_size)
            self.prefill_chunk = prefill_chunk or 4 * prefill_bucket
        auto_block = decode_block == "auto"
        self.decode_block = 8 if auto_block else int(decode_block)
        self.reset()
        if auto_block:
            self.decode_block = self._probe_decode_block()
        # greedy_only is a trace-time constant: two compiled variants at
        # most. The state pytree is donated so the slot caches are updated
        # in place (no 2x cache copy per chunk; a no-op on backends
        # without donation support, e.g. CPU).
        self._decode_jit = jax.jit(self._decode_chunk, static_argnums=(2,),
                                   donate_argnums=(1,))
        self._admit_jit = jax.jit(self._admit_batch, static_argnums=(10,),
                                  donate_argnums=(1,))
        if self._paged:
            self._admit_paged_jit = jax.jit(
                self._admit_batch_paged, static_argnums=(11,),
                donate_argnums=(1,))
            self._chunk_jit = jax.jit(
                lambda params, cache, toks, slot, off, clen, hb:
                model_prefill_chunk(self.cfg, params, self.ctx, toks,
                                    cache, slot, off, clen,
                                    hist_blocks=hb),
                static_argnums=(6,), donate_argnums=(1,))

    # ------------------------------------------------------------------
    # Compiled programs
    # ------------------------------------------------------------------

    def _decode_chunk(self, params, state, greedy_only):
        """Up to ``decode_block`` decode steps, entirely on device."""
        slots, cap = self.slots, self.max_new_cap

        def cond(st):
            return (st["i"] < self.decode_block) & jnp.any(st["active"])

        def body(st):
            logits, cache = decode_step(self.cfg, params, self.ctx,
                                        st["tokens"], st["cache"])
            keys_t = fold_step(st["keys"], st["n_gen"])
            toks = sample_tokens(logits[:, -1], keys_t, st["temp"],
                                 st["top_k"], greedy_only=greedy_only)
            act = st["active"]
            # commit only active slots; inactive rows scatter out of range
            row = jnp.where(act, st["n_gen"], cap)
            out = st["out"].at[jnp.arange(slots), row].set(toks, mode="drop")
            n_gen = st["n_gen"] + act.astype(jnp.int32)
            still = act & (toks != st["eos"]) & (n_gen < st["max_new"])
            return {**st, "cache": cache,
                    "tokens": jnp.where(act[:, None], toks[:, None],
                                        st["tokens"]),
                    "out": out, "n_gen": n_gen, "active": still,
                    "steps": st["steps"] + 1,
                    "committed": st["committed"] + jnp.sum(
                        act.astype(jnp.int32)),
                    "i": st["i"] + 1}

        st = {**state, "i": jnp.int32(0)}
        st = jax.lax.while_loop(cond, body, st)
        st.pop("i")
        return st

    def _post_prefill_state(self, state, new_cache, first, slot_idx, eos,
                            max_new, temp, top_k, keys):
        """Scatter n freshly-prefilled rows' sampling/output state into
        their slots (shared by the dense and paged admission programs)."""
        out = state["out"].at[slot_idx].set(0, mode="drop")
        return {**state, "cache": new_cache,
                "tokens": state["tokens"].at[slot_idx, 0].set(first,
                                                              mode="drop"),
                "out": out.at[slot_idx, 0].set(first, mode="drop"),
                "n_gen": state["n_gen"].at[slot_idx].set(1, mode="drop"),
                "active": state["active"].at[slot_idx].set(
                    (first != eos) & (max_new > 1), mode="drop"),
                "eos": state["eos"].at[slot_idx].set(eos, mode="drop"),
                "max_new": state["max_new"].at[slot_idx].set(max_new,
                                                             mode="drop"),
                "temp": state["temp"].at[slot_idx].set(temp, mode="drop"),
                "top_k": state["top_k"].at[slot_idx].set(top_k, mode="drop"),
                "keys": state["keys"].at[slot_idx].set(keys, mode="drop")}

    def _admit_batch(self, params, state, tokens, lengths, slot_idx, eos,
                     max_new, temp, top_k, keys, greedy_only):
        """One batched prefill + scatter of n fresh rows into their slots.

        Rows may be padding (the host pads the admission batch up to a
        power of two to bound compile variants); their ``slot_idx`` is
        out of range and every scatter drops them.
        """
        batch = {"tokens": tokens}
        if self._pad_ok:
            batch["lengths"] = lengths
        logits, cache_n = prefill(self.cfg, params, self.ctx, batch,
                                  cache_budget=self.cache_len)
        n = tokens.shape[0]
        first = sample_tokens(logits[:, 0],
                              fold_step(keys, jnp.zeros((n,), jnp.int32)),
                              temp, top_k, greedy_only=greedy_only)
        cache = state["cache"]
        # cache leaves are scan-stacked (repeat, slots, ...); position (slots,)
        segments = [jax.tree.map(
            lambda d, s: d.at[:, slot_idx].set(s, mode="drop"), ds, ss)
            for ds, ss in zip(cache["segments"], cache_n["segments"])]
        new_cache = {"segments": segments,
                     "position": cache["position"].at[slot_idx].set(
                         cache_n["position"], mode="drop")}
        return self._post_prefill_state(state, new_cache, first, slot_idx,
                                        eos, max_new, temp, top_k, keys)

    def _admit_batch_paged(self, params, state, tokens, lengths, slot_idx,
                           blk_ids, eos, max_new, temp, top_k, keys,
                           greedy_only):
        """Paged admission: prefill emits block-shaped caches, scattered
        into the global pool through the rows' allocated block ids.

        ``blk_ids`` (n, nb) int32: pool destinations for each row's prompt
        blocks; entries past a row's ``ceil(len/bs)`` blocks (and whole
        padding rows) hold the out-of-range sentinel and drop.
        """
        batch = {"tokens": tokens, "lengths": lengths}
        logits, cache_n = prefill(self.cfg, params, self.ctx, batch,
                                  page_size=self.block_size)
        n = tokens.shape[0]
        first = sample_tokens(logits[:, 0],
                              fold_step(keys, jnp.zeros((n,), jnp.int32)),
                              temp, top_k, greedy_only=greedy_only)
        cache = state["cache"]

        def scatter(path, d, s):
            if getattr(path[-1], "key", None) in _POOL_KEYS:
                # d (rep, NB, ...), s (rep, n, nb, ...): block scatter
                return d.at[:, blk_ids].set(s, mode="drop")
            return d.at[:, slot_idx].set(s, mode="drop")   # per-slot leaves

        segments = [jax.tree_util.tree_map_with_path(scatter, ds, ss)
                    for ds, ss in zip(cache["segments"],
                                      cache_n["segments"])]
        new_cache = {"segments": segments,
                     "position": cache["position"].at[slot_idx].set(
                         cache_n["position"], mode="drop"),
                     "block_tbl": cache["block_tbl"]}
        return self._post_prefill_state(state, new_cache, first, slot_idx,
                                        eos, max_new, temp, top_k, keys)

    # ------------------------------------------------------------------
    # Request lifecycle (host side)
    # ------------------------------------------------------------------

    def _blank_state(self) -> Dict:
        slots = self.slots
        if self._paged:
            cache = init_cache(self.cfg, self.ctx, slots, self.cache_len,
                               num_blocks=self.num_blocks,
                               page_size=self.block_size,
                               table_len=self.table_len)
        else:
            cache = init_cache(self.cfg, self.ctx, slots, self.cache_len)
        return {
            "cache": cache,
            "tokens": jnp.zeros((slots, 1), jnp.int32),
            "out": jnp.zeros((slots, self.max_new_cap), jnp.int32),
            "n_gen": jnp.zeros((slots,), jnp.int32),
            "active": jnp.zeros((slots,), bool),
            "eos": jnp.full((slots,), -1, jnp.int32),
            "max_new": jnp.ones((slots,), jnp.int32),
            "temp": jnp.zeros((slots,), jnp.float32),
            "top_k": jnp.zeros((slots,), jnp.int32),
            "keys": jnp.zeros((slots, 2), jnp.uint32),
            "steps": jnp.int32(0),
            "committed": jnp.int32(0),
        }

    def reset(self) -> None:
        """Clear all serving state but keep compiled programs warm."""
        self.state = self._blank_state()
        self.alloc = (BlockAllocator(self.num_blocks, self.block_size,
                                     self.slots, self.table_len)
                      if self._paged else None)
        self._slot_req = {}
        self._written: Dict[int, int] = {}   # paged: tokens committed/slot
        self._tbl_dirty = False              # host table mirror vs device
        self._chunk_job: Optional[Dict] = None   # in-progress chunked prefill
        self._max_residents = 0
        self.scheduler = Scheduler(self.scheduler.policy)
        self._host = {"decode_s": 0.0, "prefill_s": 0.0, "prefill_calls": 0,
                      "prefill_tokens": 0, "prefill_chunks": 0}
        self._cache_bytes = sum(
            leaf.nbytes for seg in self.state["cache"]["segments"]
            for leaf in jax.tree.leaves(seg))

    def submit(self, req: Request) -> None:
        if req.max_new_tokens > self.max_new_cap:
            raise ValueError(
                f"max_new_tokens={req.max_new_tokens} exceeds this engine's "
                f"max_new_cap={self.max_new_cap} (the on-device token "
                f"buffer); construct ServeEngine with a larger max_new_cap")
        if req.top_k > TOP_K_CAP:
            raise ValueError(f"top_k={req.top_k} exceeds TOP_K_CAP="
                             f"{TOP_K_CAP} (static sampling bound)")
        # peak cache occupancy is prompt + max_new - 1: the last sampled
        # token is returned but its KV is never written while resident
        need = len(req.prompt) + req.max_new_tokens - 1
        if self._paged:
            if need > self.max_seq_len:
                raise ValueError(
                    f"request needs {need} cache tokens (prompt "
                    f"{len(req.prompt)} + max_new_tokens "
                    f"{req.max_new_tokens} - 1) but max_seq_len="
                    f"{self.max_seq_len}; raise max_seq_len or shorten "
                    f"the request")
            nb = self.alloc.blocks_for_tokens(need)
            if nb > self.num_blocks:
                raise ValueError(
                    f"request needs {nb} cache blocks ({need} tokens at "
                    f"block_size={self.block_size}) but the pool only has "
                    f"num_blocks={self.num_blocks}, so it can never be "
                    f"admitted; raise num_blocks")
        elif self._cache_bound and need > self.cache_len:
            raise ValueError(
                f"request needs {need} cache tokens (prompt "
                f"{len(req.prompt)} + max_new_tokens {req.max_new_tokens} "
                f"- 1) but cache_len={self.cache_len} on a full-attention "
                f"model; raise cache_len or shorten the request")
        self.scheduler.submit(req)

    def _note_residency(self) -> None:
        n = len(self._slot_req) + (self._chunk_job is not None)
        self._max_residents = max(self._max_residents, n)

    def _admit(self) -> None:
        if self._paged:
            self._admit_paged()
            return
        free = self._free_slots()
        if not free or not self.scheduler.pending:
            return
        reqs = self.scheduler.select(len(free),
                                     equal_length_only=not self._pad_ok)
        if not reqs:
            return
        self._admit_wave(reqs, free[:len(reqs)])
        self._note_residency()

    def _free_slots(self) -> List[int]:
        busy = set(self._slot_req)
        if self._chunk_job is not None:
            busy.add(self._chunk_job["slot"])
        return [s for s in range(self.slots) if s not in busy]

    def _admit_paged(self) -> None:
        """Paged admission loop: free-block criterion with head-of-line
        blocking; prompts longer than ``prefill_chunk`` start a chunked
        prefill job that ``step`` advances one chunk at a time (decode for
        resident slots keeps running between chunks)."""
        while self.scheduler.pending:
            free = self._free_slots()
            if not free:
                return
            head = self.scheduler.first()
            need = len(head.prompt) + head.max_new_tokens - 1
            if len(head.prompt) > self.prefill_chunk:
                if self._chunk_job is not None:
                    return                  # one chunked admission at a time
                if not self.alloc.reserve(free[0], need):
                    return                  # pool exhausted: head waits
                self.scheduler.take(head)
                self._chunk_job = {"req": head, "slot": free[0], "c0": 0}
                self._note_residency()
                continue
            taken: List[int] = []

            def ok(r):
                if len(r.prompt) > self.prefill_chunk:
                    return False            # long prompt: chunked next round
                if not self.alloc.reserve(
                        free[len(taken)],
                        len(r.prompt) + r.max_new_tokens - 1):
                    return False
                taken.append(free[len(taken)])
                return True

            reqs = self.scheduler.select(len(free), admit_ok=ok)
            if not reqs:
                return
            # lazy prefill allocation: just the prompt's blocks for now
            for s, r in zip(taken, reqs):
                self._ensure(s, len(r.prompt))
            self._admit_wave(reqs, taken, paged=True)
            self._note_residency()

    def _admit_wave(self, reqs, taken, paged: bool = False) -> None:
        """One batched prefill admission (dense or paged)."""
        n = len(reqs)
        # pad the admission batch up to a power of two (dummy rows scatter
        # out of range and drop) so compile variants are O(log slots) per
        # length bucket instead of one per free-slot count
        n_pad = 1
        while n_pad < n:
            n_pad *= 2
        n_pad = min(n_pad, self.slots)
        lens = np.ones((n_pad,), np.int32)
        lens[:n] = [len(r.prompt) for r in reqs]
        if self._pad_ok:
            L = -(-int(lens.max()) // self.prefill_bucket) \
                * self.prefill_bucket
        else:
            L = int(lens[0])
        toks = np.zeros((n_pad, L), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :lens[i]] = r.prompt[:L]
        slot_idx = np.full((n_pad,), self.slots, np.int32)   # dummy: dropped
        slot_idx[:n] = taken[:n]
        keys = np.zeros((n_pad, 2), np.uint32)
        keys[:n] = np.stack([jax.random.fold_in(jax.random.PRNGKey(r.seed),
                                                r.uid) for r in reqs])

        def col(fn, fill, dtype):
            v = np.full((n_pad,), fill, dtype)
            v[:n] = [fn(r) for r in reqs]
            return jnp.asarray(v)

        greedy_only = all(r.temperature <= 0.0 for r in reqs)
        t0 = time.perf_counter()
        common = (jnp.asarray(toks), jnp.asarray(lens), jnp.asarray(slot_idx))
        tail = (col(lambda r: r.eos_id, -1, np.int32),
                col(lambda r: r.max_new_tokens, 1, np.int32),
                col(lambda r: r.temperature, 0.0, np.float32),
                col(lambda r: r.top_k, 0, np.int32), jnp.asarray(keys),
                greedy_only)
        if paged:
            # prefill emits ceil(L / block_size) blocks per row (bucket-
            # padded); rows point their own allocated blocks at the pool
            # and sentinel out both their tail blocks and the dummy rows
            nb = self.alloc.blocks_for_tokens(L)
            ids = np.full((n_pad, nb), self.num_blocks, np.int32)
            for i, (s, r) in enumerate(zip(taken, reqs)):
                nb_i = self.alloc.blocks_for_tokens(len(r.prompt))
                ids[i, :nb_i] = self.alloc.tables[s, :nb_i]
            self._push_tables()
            self.state = self._admit_paged_jit(
                self.params, self.state, *common, jnp.asarray(ids), *tail)
        else:
            self.state = self._admit_jit(self.params, self.state, *common,
                                         *tail)
        jax.block_until_ready(self.state["tokens"])
        self._host["prefill_s"] += time.perf_counter() - t0
        self._host["prefill_calls"] += 1
        self._host["prefill_tokens"] += n     # first token of each request
        self.scheduler.on_admitted(reqs)
        for s, r in zip(taken, reqs):
            self._slot_req[s] = r
            if self._paged:
                self._written[s] = len(r.prompt)

    def _advance_chunk_job(self) -> None:
        """Run ONE prefill chunk of the in-progress chunked admission
        (prompts longer than ``prefill_chunk``), appending cache blocks
        incrementally. One chunk per engine step: resident slots keep
        decoding between chunks, so a long prompt can't freeze everyone
        else's inter-token latency. The final chunk samples the first
        token and arms the slot exactly like a batched admission."""
        job = self._chunk_job
        req, slot, c0 = job["req"], job["slot"], job["c0"]
        C = self.prefill_chunk
        plen = len(req.prompt)
        t0 = time.perf_counter()
        cl = min(C, plen - c0)
        self._ensure(slot, c0 + cl)
        self._push_tables()
        toks = np.zeros((1, C), np.int32)
        toks[0, :cl] = req.prompt[c0:c0 + cl]
        # table walk bounded by the tokens this chunk can touch, bucketed
        # to a power of two to bound compile variants
        hb = 1
        while hb < self.alloc.blocks_for_tokens(c0 + C):
            hb *= 2
        logits, self.state["cache"] = self._chunk_jit(
            self.params, self.state["cache"], jnp.asarray(toks),
            jnp.int32(slot), jnp.int32(c0), jnp.int32(cl),
            min(hb, self.table_len))
        self._host["prefill_chunks"] += 1
        job["c0"] = c0 + C
        if job["c0"] < plen:                # more chunks to go
            jax.block_until_ready(self.state["cache"]["position"])
            self._host["prefill_s"] += time.perf_counter() - t0
            return
        keys = jax.random.fold_in(jax.random.PRNGKey(req.seed),
                                  req.uid)[None]
        temp = jnp.asarray([req.temperature], jnp.float32)
        top_k = jnp.asarray([req.top_k], jnp.int32)
        first = sample_tokens(
            logits, fold_step(keys, jnp.zeros((1,), jnp.int32)), temp,
            top_k, greedy_only=req.temperature <= 0.0)
        self.state = self._post_prefill_state(
            self.state, self.state["cache"], first,
            jnp.asarray([slot], jnp.int32),
            jnp.asarray([req.eos_id], jnp.int32),
            jnp.asarray([req.max_new_tokens], jnp.int32), temp, top_k,
            keys)
        jax.block_until_ready(self.state["tokens"])
        self._host["prefill_s"] += time.perf_counter() - t0
        self._host["prefill_calls"] += 1
        self._host["prefill_tokens"] += 1
        self.scheduler.on_admitted([req])
        self._slot_req[slot] = req
        self._written[slot] = plen
        self._chunk_job = None

    def _ensure(self, slot: int, n_tokens: int) -> None:
        if self.alloc.ensure(slot, n_tokens):
            self._tbl_dirty = True

    def _push_tables(self) -> None:
        """Push the host block-table mirror to the device iff it changed
        since the last push (block growth or a harvest-time release — the
        release is what retires freed slots' rows to the sentinel so their
        masked commits drop)."""
        if self._tbl_dirty:
            self.state["cache"]["block_tbl"] = jnp.asarray(self.alloc.tables)
            self._tbl_dirty = False

    def _ensure_decode_blocks(self) -> None:
        """Grow resident slots' block tables to cover the upcoming decode
        chunk (lazy allocation at block-boundary crossings)."""
        for s, r in self._slot_req.items():
            cap = len(r.prompt) + r.max_new_tokens - 1
            self._ensure(s, min(self._written[s] + self.decode_block, cap))
        self._push_tables()

    def _harvest(self) -> None:
        """Admission-boundary sync: pull finished slots' token buffers."""
        if not self._slot_req:
            return
        act, n_gen = jax.device_get((self.state["active"],
                                     self.state["n_gen"]))
        if self._paged:
            # a slot still active after a chunk ran every one of its steps
            for s, r in self._slot_req.items():
                if act[s]:
                    cap = len(r.prompt) + r.max_new_tokens - 1
                    self._written[s] = min(
                        self._written[s] + self.decode_block, cap)
        finished = [s for s in self._slot_req if not act[s]]
        if not finished:
            return
        rows = jax.device_get(self.state["out"][np.asarray(finished)])
        for i, s in enumerate(finished):
            req = self._slot_req.pop(s)
            req.generated = rows[i, :n_gen[s]].tolist()
            req.done = True
            self.scheduler.on_finished(req)
            if self._paged:
                self.alloc.release(s)       # blocks return to the pool
                self._written.pop(s, None)
                self._tbl_dirty = True      # row parked on the sentinel

    # ------------------------------------------------------------------
    # Drive
    # ------------------------------------------------------------------

    def step(self) -> None:
        """One admission + at most one prefill chunk of an in-progress
        chunked admission + one on-device decode chunk + harvest."""
        self._admit()
        if self._chunk_job is not None:
            self._advance_chunk_job()
        if self._slot_req:
            greedy_only = all(r.temperature <= 0.0
                              for r in self._slot_req.values())
            t0 = time.perf_counter()
            if self._paged:
                self._ensure_decode_blocks()
            self.state = self._decode_jit(self.params, self.state,
                                          greedy_only)
            self._harvest()               # device_get doubles as the sync
            self._host["decode_s"] += time.perf_counter() - t0

    def _flush_partial(self) -> None:
        """Surface still-resident slots' tokens (budget-aborted drain):
        their buffers are on device and already counted in the stats."""
        if not self._slot_req:
            return
        resident = sorted(self._slot_req)
        n_gen = jax.device_get(self.state["n_gen"])
        rows = jax.device_get(self.state["out"][np.asarray(resident)])
        for i, s in enumerate(resident):
            self._slot_req[s].generated = rows[i, :n_gen[s]].tolist()

    def run_until_drained(self, max_steps: int = 10_000) -> Dict:
        """Serve until queue + slots are empty; ``max_steps`` bounds the
        total decode-step budget (chunk-granular). If the budget aborts the
        drain, in-flight requests keep their partial ``generated`` output
        (``done`` stays False)."""
        chunks = 0
        while ((self.scheduler.pending or self._slot_req
                or self._chunk_job is not None)
               and chunks * self.decode_block < max_steps):
            self.step()
            chunks += 1
        self._flush_partial()
        return self.stats()

    # ------------------------------------------------------------------
    # decode_block auto-tuning
    # ------------------------------------------------------------------

    def _probe_state(self) -> Dict:
        """Fresh state with every slot armed to run a full decode chunk."""
        st = self._blank_state()
        st["active"] = jnp.ones((self.slots,), bool)
        st["max_new"] = jnp.full((self.slots,), self.max_new_cap, jnp.int32)
        return st

    def _probe_decode_block(self, candidates=(4, 8, 16, 32)) -> int:
        """Measured decode-step latency probe (``decode_block="auto"``).

        Times one compiled decode chunk at lengths 1 and 8 to split the
        per-chunk cost into a fixed part (dispatch + the host sync that
        follows every chunk) and a per-step part, then picks the smallest
        candidate whose amortized fixed cost is under 15% of compute —
        bigger chunks waste steps on slots that finish mid-chunk, so we
        want the smallest chunk that the host overhead can afford.
        Passing an int ``decode_block`` to the constructor overrides this.
        """
        def chunk_time(c: int) -> float:
            self.decode_block = c
            # donate each probe state: the probe must not stack extra full
            # cache pytrees on top of the engine's own state (the paged
            # pool can be sized near device HBM)
            fn = jax.jit(self._decode_chunk, static_argnums=(2,),
                         donate_argnums=(1,))
            jax.block_until_ready(
                fn(self.params, self._probe_state(), True)["tokens"])
            best = float("inf")
            for _ in range(3):          # min-of-N: shed host scheduler noise
                st = self._probe_state()
                t0 = time.perf_counter()
                jax.block_until_ready(fn(self.params, st, True)["tokens"])
                best = min(best, time.perf_counter() - t0)
            return best

        t1 = chunk_time(1)
        t8 = chunk_time(8)
        per_step = max((t8 - t1) / 7.0, 1e-9)
        overhead = max(t1 - per_step, 0.0)
        for c in candidates:
            if overhead <= 0.15 * c * per_step:
                return c
        return candidates[-1]

    # ------------------------------------------------------------------
    # Stats
    # ------------------------------------------------------------------

    def stats(self) -> Dict:
        steps, committed = jax.device_get((self.state["steps"],
                                           self.state["committed"]))
        d = dict(self._host)
        prefill_tokens = d.pop("prefill_tokens")
        d["decode_steps"] = int(steps)
        d["tokens_out"] = int(committed) + prefill_tokens
        d["decode_step_s"] = (d["decode_s"] / max(int(steps), 1))
        d["max_residents"] = self._max_residents
        if self._paged:
            cap_tokens = self.num_blocks * self.block_size
            d["cache_tokens_capacity"] = cap_tokens
            d["peak_cache_tokens"] = self.alloc.peak_blocks * self.block_size
        else:
            cap_tokens = self.slots * self.cache_len
            d["cache_tokens_capacity"] = cap_tokens
            # a dense stripe is reserved whole for a slot's lifetime:
            # reservation *is* usage, fragmentation included — but only
            # for the stripes that were actually occupied at peak
            d["peak_cache_tokens"] = self._max_residents * self.cache_len
        d["cache_bytes"] = self._cache_bytes
        d["peak_cache_bytes"] = int(
            self._cache_bytes * d["peak_cache_tokens"] / max(cap_tokens, 1))
        d.update(self.scheduler.stats())
        return d
