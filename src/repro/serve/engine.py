"""Continuous-batching serve engine v2 over the quantized cache.

vLLM-style slot engine, rebuilt so the host only touches the device at
admission boundaries:

* **Batched prefill** — the scheduler hands over up to ``slots`` queued
  requests at once; they are right-padded to a length bucket and prefilled
  in one compiled call (per-row ``lengths`` keep the cache and logits exact;
  see ``models.prefill``). Architectures with recurrent blocks, where
  padding would corrupt the scan state, admit exact-length groups instead.
* **On-device decode loop** — sampling (greedy / temperature / top-k),
  per-slot EOS + max-token tracking, and the generated-token buffers all
  live in the device state pytree; ``lax.while_loop`` runs up to
  ``decode_block`` steps per compiled call and stops early once every slot
  is inactive. No ``int(...)`` / ``np.asarray`` per token — the host syncs
  once per chunk to harvest finished slots and admit new work.
* **Paged KV cache** (``kv_layout="paged"``) — instead of reserving a dense
  ``cache_len`` stripe per slot, attention layers share one global pool of
  fixed-size quantized blocks addressed through a per-slot block table
  (``serve.block_alloc`` owns the refcounted pool on the host). Admission
  switches from "fits in cache_len" to "enough free blocks", blocks are
  allocated lazily as decode crosses block boundaries, and harvest returns
  them to the pool — so capacity tracks actual token residency, not the
  worst-case request. Prompts longer than ``prefill_chunk`` are admitted as
  a sequence of fixed-size **chunked prefill** calls that append blocks
  incrementally (``models.prefill_tail``), removing the cache_len bound on
  prompt length.
* **Prefix sharing** (``prefix_cache=True``, paged only) — full blocks of
  written tokens are content-addressed in the allocator's rolling-hash
  index; a request whose prompt extends a cached prefix maps those pool
  blocks into its table (refcount++) and prefills **only the uncached
  tail** (``models.prefill_tail`` starting at the cached offset). The
  *split block* — the partial block where two prompts diverge — is shared
  too and cloned device-side on first write (copy-on-write,
  ``kernels.kvq_attn.ops.copy_pool_blocks``). Shared-prompt workloads
  (system-prompted chat, few-shot eval, best-of-n) drop from O(prompt) to
  O(tail) prefill per request.
* **Batched tail prefill** — up to ``tail_batch`` tail/chunked prefills
  are in flight at once, and every engine step advances ALL of them by
  one window in a single compiled tail-wave (per-row ``(c0, tail_len)``
  offsets, pad-masked like the cold wave), so a burst of prefix-hit
  arrivals no longer serializes one tail per step — warm TTFT under
  concurrency matches the cold batched wave. ``prefix_affinity`` orders
  the queue so requests sharing a cached chain admit back-to-back while
  the chain is hot in the allocator's LRU.
* **Preemption / swap-out** (``admission="optimistic"``) — instead of
  debiting a request's worst-case block count at admission, only its
  prompt footprint is allocated; when the pool later runs dry the engine
  picks a victim (``preempt="last_admitted"`` or ``"longest_remaining"``),
  swaps its quantized blocks to a host buffer (int8 payloads move 4x
  cheaper than fp32), requeues it, and restores it bit-exactly once the
  pool recovers — decode resumes mid-stream with identical tokens.
* **Scheduler** (``serve.scheduler``) — pluggable FCFS / shortest-prompt /
  EDF policies plus per-request TTFT/latency accounting; paged admission
  uses its head-of-line ``admit_ok`` hook so big requests aren't starved,
  and its ``pick_victim`` hook chooses preemption victims.
* **Streaming + SLO-aware admission** — a request may carry an
  ``on_tokens`` callback: freshly decoded spans drain incrementally from
  ``_harvest`` at decode-chunk / spec-wave granularity (and at swap-out)
  instead of only at finish. Requests may also carry a first-token
  ``deadline_ms`` and a ``priority`` class: ``sched_policy="edf"``
  admits earliest-deadline-first within priority, and ``slo_shed``
  (``"reject"`` / ``"downgrade"``) drops or demotes queued requests
  whose predicted TTFT — fitted from this engine's measured prefill and
  decode rates — already misses their deadline. ``serve.frontend``
  builds the asyncio host loop and the HTTP endpoint on these hooks.

All per-slot cache state (int8 KV / recurrent) stays in one pytree so the
decode chunk is a single compiled program regardless of slot occupancy;
inactive slots ride along masked (their commits are dropped — in paged mode
by parking their block-table rows on the out-of-range sentinel) and are
recycled by the next admission.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ATTENTION_BLOCKS, BLOCK_ATTN, ModelConfig
from repro.core.precision import parse_policy
from repro.core.qat import (attach_w4a8_exports, attach_w4a8_ref_planes,
                            make_ctx, w4a8_use_pallas, w4a8_weight_bytes)
from repro.kernels.kvq_attn.ops import copy_pool_blocks
from repro.models import (decode_step, init_cache, prefill, prefill_tail,
                          spec_verify)
from repro.obs.metrics import ServeMetrics
from repro.obs.trace import NULL_TRACER, Tracer
from repro.runtime.sharding import (param_shardings, serve_cache_shardings,
                                    serve_state_shardings)
from repro.serve.block_alloc import BlockAllocator, PoolDry
from repro.serve.sampling import (TOP_K_CAP, fold_step, sample_tokens,
                                  token_probs)
from repro.serve.scheduler import (PREEMPT_POLICIES, SHED_MODES, Scheduler)
from repro.serve.spec import (SpecConfig, accept_exact, accept_rejection,
                              make_draft)

_POOL_KEYS = ("k_q", "v_q", "s_k", "s_v")   # pool-shaped paged cache leaves


def _pow2_ceil(n: int) -> int:
    """Smallest power of two >= max(n, 1) — used to bucket dynamic batch
    dimensions so compile variants stay logarithmic."""
    p = 1
    while p < n:
        p *= 2
    return p


def _clamp_lengths(segments, lens):
    """Re-clamp every attention layer's per-slot ``length`` leaf to
    ``lens`` — the device half of speculative rollback (the draft cache
    before drafting, the target cache after acceptance)."""
    def clamp(path, leaf):
        if getattr(path[-1], "key", None) == "length":
            return jnp.broadcast_to(lens[None], leaf.shape)
        return leaf
    return [jax.tree_util.tree_map_with_path(clamp, seg)
            for seg in segments]


def _jsonable(x):
    """Recursively cast numpy/jax scalars and arrays to native Python
    types. ``stats()`` is an HTTP boundary (``/v1/stats``,
    ``/v1/metrics``): a stray ``np.int64`` deep in the dict is invisible
    until ``json.dumps`` raises in the server."""
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, np.bool_):
        return bool(x)
    if isinstance(x, np.integer):
        return int(x)
    if isinstance(x, np.floating):
        return float(x)
    if isinstance(x, (np.ndarray, jax.Array)):
        return _jsonable(x.tolist())
    return x


# decode_block="auto" probe results, memoized per process so benchmark
# scripts constructing several engines don't re-pay the probe compiles
_PROBE_CACHE: Dict[tuple, int] = {}


def _device_local_bytes(tree) -> int:
    """One device's share of a pytree: sharded leaves count their shard
    bytes, replicated / single-device leaves their full size."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        sh = getattr(leaf, "sharding", None)
        if sh is not None and hasattr(sh, "shard_shape"):
            total += (int(np.prod(sh.shard_shape(leaf.shape)))
                      * leaf.dtype.itemsize)
        else:
            total += getattr(leaf, "nbytes", 0)
    return total


def _arg_signature(args) -> str:
    """Compact shape signature of one wave call — built only when the
    call triggered a fresh compile, so it may walk the pytrees freely.
    Scalars (the static argnums ride along positionally) print verbatim,
    single arrays as dtype[shape], larger pytrees as a leaf-count digest:
    the varying axes that cause retraces live in the top-level arrays."""
    parts = []
    for a in args:
        if a is None or isinstance(a, (bool, int, float, str)):
            parts.append(repr(a))
            continue
        leaves = jax.tree.leaves(a)
        if len(leaves) == 1 and hasattr(leaves[0], "shape"):
            leaf = leaves[0]
            parts.append(f"{leaf.dtype}{list(leaf.shape)}")
        else:
            parts.append(f"tree#{len(leaves)}")
    return "(" + ", ".join(parts) + ")"


@dataclass(eq=False)                    # identity equality: the ndarray
class Request:                          # prompt field breaks value __eq__
    uid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                    # -1: never stops early
    temperature: float = 0.0            # <= 0: greedy
    top_k: int = 0                      # 0: no top-k filtering
    seed: int = 0
    # --- SLO class (scheduler policy "edf" + engine slo_shed) ---
    deadline_ms: Optional[float] = None  # first-token SLO, from submit
    priority: int = 0                    # lower = more urgent (EDF class)
    # --- streaming ---
    # called as on_tokens(req, new_tokens, done) with each freshly
    # decoded span (decode_block / spec-wave granularity) instead of only
    # at finish; may fire from whatever thread steps the engine
    on_tokens: Optional[Callable] = None
    generated: List[int] = field(default_factory=list)
    done: bool = False
    shed: bool = False                  # rejected by SLO admission control
    _arrival: int = 0                   # set by the scheduler
    _streamed: int = 0                  # tokens already sent to on_tokens


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, policy: str = "A8d-C8-W4",
                 slots: int = 8, cache_len: int = 512,
                 max_new_cap: int = 256,
                 decode_block: Union[int, str] = 8,
                 sched_policy: str = "fcfs", prefill_bucket: int = 16,
                 kv_layout: str = "dense", block_size: int = 64,
                 num_blocks: Optional[int] = None,
                 max_seq_len: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 table_len: Optional[int] = None,
                 prefix_cache: bool = True,
                 admission: str = "reserve",
                 preempt: str = "last_admitted",
                 tail_batch: int = 0,
                 prefix_affinity: bool = True,
                 slo_shed: str = "none",
                 spec: Optional[SpecConfig] = None,
                 weights_layout: str = "bf16",
                 w4a8_backend: str = "auto",
                 trace: Optional[Tracer] = None,
                 mesh: Optional[Mesh] = None):
        self.cfg = cfg
        # observability rides on the engine from construction: the tracer
        # (a disabled NULL_TRACER unless the caller wants a trace — spans
        # still measure, nothing is recorded) and the pushed-histogram
        # half of the /v1/metrics surface
        self.trace = trace if trace is not None else NULL_TRACER
        self.metrics = ServeMetrics()
        self.mesh = mesh
        self.tp = 1
        if mesh is not None:
            if "model" not in mesh.axis_names:
                raise ValueError(
                    "serving mesh needs a 'model' axis for tensor "
                    f"parallelism; got axes {tuple(mesh.axis_names)}")
            self.tp = int(mesh.shape["model"])
        if weights_layout not in ("bf16", "w4a8"):
            raise ValueError(f"weights_layout must be 'bf16' or 'w4a8', "
                             f"got {weights_layout!r}")
        self.weights_layout = weights_layout
        self._w4a8_bytes = {"packed": 0, "replaced": 0}
        if weights_layout == "w4a8":
            pol = parse_policy(policy)
            # the packed path is real integer arithmetic at int8 activations
            # x int4 weights; a policy trained differently would serve
            # numerics it never saw
            if not (pol.enabled and pol.act_bits == 8 and pol.act_dynamic
                    and pol.weight_bits <= 4):
                raise ValueError(
                    "weights_layout='w4a8' needs a dynamic-A8 W4 policy "
                    f"(e.g. 'A8d-C8-W4'); got {policy!r}")
            params = attach_w4a8_exports(params, pol)
            self._w4a8_bytes = w4a8_weight_bytes(params)
        # activation hints only when every head count divides the TP axis;
        # otherwise the params already fell back to replication and a hint
        # would fight GSPMD's propagation
        attn_mode = "tp" if (self.tp > 1
                             and cfg.n_heads % self.tp == 0
                             and cfg.n_kv_heads % self.tp == 0) else ""
        self.ctx = make_ctx(policy, weights_layout=weights_layout,
                            w4a8_backend=w4a8_backend,
                            attn_shard_mode=attn_mode)
        if weights_layout == "w4a8" and not w4a8_use_pallas(self.ctx):
            # XLA:CPU can't fuse the nibble unpack into its gemm the way the
            # Pallas kernel does in-registers; cache the unpacked int8 plane
            # once so ref decode steps don't re-materialize it (results stay
            # bit-identical — same integer gemm)
            params = attach_w4a8_ref_planes(params)
        if mesh is not None:
            # commit the full weight tree (packed planes included) to the
            # mesh: column/row-parallel linears split over "model", so the
            # draft built below slices already-sharded leaves
            params = jax.device_put(
                params, param_shardings(cfg, mesh, params))
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.max_new_cap = max_new_cap
        self.prefill_bucket = prefill_bucket
        self.scheduler = Scheduler(sched_policy, trace=self.trace)
        # right-padded batched prefill is exact only when every block is
        # attention (causality isolates real tokens from padding); recurrent
        # scans absorb pad steps into their state, so those admit
        # exact-length groups instead.
        self._pad_ok = (all(k in ATTENTION_BLOCKS for k in cfg.block_pattern)
                        and not cfg.is_encdec)
        # full (non-sliding) attention caches are a hard capacity bound;
        # ring-buffered / recurrent state is not
        self._cache_bound = (BLOCK_ATTN in cfg.block_pattern
                             and not cfg.sliding_window)
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"kv_layout must be 'dense' or 'paged', "
                             f"got {kv_layout!r}")
        self._paged = kv_layout == "paged"
        if self._paged:
            if (cfg.is_encdec or cfg.sliding_window
                    or any(k != BLOCK_ATTN for k in cfg.block_pattern)):
                raise ValueError(
                    "kv_layout='paged' requires a full-attention decoder "
                    "(no sliding window / recurrence / cross-attention); "
                    f"{cfg.name!r} has block pattern {cfg.block_pattern}")
            self.block_size = block_size
            # default pool = the dense engine's total reservation, so the
            # two layouts are comparable at equal HBM
            self.num_blocks = num_blocks or max(
                1, slots * cache_len // block_size)
            # default per-request cap matches the dense stripe: the table
            # width bounds how many keys each decode step walks, so leaving
            # it at the whole pool would cost slots-times the attention
            # work of the dense layout
            self.max_seq_len = max_seq_len or min(
                cache_len, self.num_blocks * block_size)
            self.table_len = table_len or -(-self.max_seq_len // block_size)
            self.prefill_chunk = prefill_chunk or 4 * prefill_bucket
            if admission not in ("reserve", "optimistic"):
                raise ValueError(f"admission must be 'reserve' or "
                                 f"'optimistic', got {admission!r}")
            if preempt not in PREEMPT_POLICIES:
                raise ValueError(f"preempt must be one of "
                                 f"{PREEMPT_POLICIES}, got {preempt!r}")
            # tail_batch caps how many tail/chunked prefills ride one
            # batched wave; 0 = every slot, 1 = the serialized legacy path
            if not 0 <= tail_batch <= slots:
                raise ValueError(f"tail_batch must be in [0, slots={slots}]"
                                 f", got {tail_batch}")
            self.tail_batch = tail_batch or slots
        self.prefix_cache = prefix_cache and self._paged
        self.prefix_affinity = prefix_affinity and self.prefix_cache
        self.admission = admission
        self.preempt = preempt
        if slo_shed not in SHED_MODES:
            raise ValueError(f"slo_shed must be one of {SHED_MODES}, "
                             f"got {slo_shed!r}")
        self.slo_shed = slo_shed
        self.spec = None
        if spec is not None:
            if not self._paged:
                raise ValueError("speculative decoding requires "
                                 "kv_layout='paged' (the rollback path is "
                                 "the paged allocator's trim)")
            self.spec = spec if isinstance(spec, SpecConfig) \
                else SpecConfig(**spec)
            # the draft slices the (already export-attached) target tree, so
            # under w4a8 it serves the same packed weights; a draft_policy
            # override only retunes its activation/cache bits
            self.draft_cfg, self.draft_params = make_draft(cfg, params,
                                                           self.spec)
            self.draft_ctx = make_ctx(self.spec.draft_policy or policy,
                                      weights_layout=weights_layout,
                                      w4a8_backend=w4a8_backend,
                                      attn_shard_mode=attn_mode)
            # the draft over-commits up to k positions past the accepted
            # extent before rollback; its dense ring must never wrap
            # into live history
            self._draft_cache_len = self.max_seq_len + self.spec.k + 1
        auto_block = decode_block == "auto"
        self.decode_block = 8 if auto_block else int(decode_block)
        self._decode_block_mode = "auto" if auto_block else "fixed"
        if self.spec is not None:
            # the spec loop owns step granularity: one draft+verify wave
            # per engine step commits up to k+1 tokens per slot, so the
            # decode-chunk latency probe is meaningless (and never run)
            self.decode_block = self.spec.k + 1
            self._decode_block_mode = "spec"
        self.reset()
        if auto_block and self.spec is None:
            # spec config is part of the key: toggling spec on/off across
            # engines in one process must not replay a stale probe
            # weights_layout is part of the key: a bf16-probed block must
            # not be replayed for the packed-weight step function (different
            # per-step cost) or vice versa
            # mesh shape is part of the key: a tp=2 probe's per-step cost
            # (collectives, per-device gemm sizes) must not be replayed
            # for tp=1 or a different mesh, and vice versa
            probe_key = (cfg.name, policy, slots, kv_layout, cache_len,
                         max_new_cap, block_size if self._paged else 0,
                         self.num_blocks if self._paged else 0,
                         self.table_len if self._paged else 0,
                         weights_layout,
                         tuple(sorted(self.mesh.shape.items()))
                         if self.mesh is not None else None)
            if probe_key not in _PROBE_CACHE:
                _PROBE_CACHE[probe_key] = self._probe_decode_block()
            self.decode_block = _PROBE_CACHE[probe_key]
        # greedy_only is a trace-time constant: two compiled variants at
        # most. The state pytree is donated so the slot caches are updated
        # in place (no 2x cache copy per chunk; a no-op on backends
        # without donation support, e.g. CPU).
        #
        # Every wave goes through _wave(family, ...): the registry runs it
        # under the mesh and records a shape signature whenever the call
        # triggered a fresh compile, so stats()["compile_variants"] and
        # the retrace-budget audit read live per-family variant counts.
        self._wave_jits: Dict[str, object] = {}
        self._wave_variants: Dict[str, List[str]] = {}
        self._decode_jit = self._wave("decode", jax.jit(
            self._decode_chunk, static_argnums=(2,), donate_argnums=(1,)))
        self._admit_jit = self._wave("admit_dense", jax.jit(
            self._admit_batch, static_argnums=(10,), donate_argnums=(1,)))
        if self._paged:
            self._admit_paged_jit = self._wave("admit_paged", jax.jit(
                self._admit_batch_paged, static_argnums=(11,),
                donate_argnums=(1,)))
            # one compiled program advances a whole wave of tail/chunked
            # prefills: per-row (slot, c0, tail_len), pad rows dropped
            self._tail_jit = self._wave("tail", jax.jit(
                self._tail_wave, static_argnums=(6,), donate_argnums=(1,)))
            # swap-in restore: one donated scatter for the whole payload
            # (per-leaf .at[].set calls would each materialize a second
            # pool — transient 2x cache HBM on every restore)
            self._swap_in_jit = self._wave("swap_in", jax.jit(
                self._swap_in_scatter, donate_argnums=(0,)))
            # donated so the COW clone rewrites pool blocks in place
            # instead of materializing a second pool
            self._cow_jit = self._wave("cow", jax.jit(
                self._cow_copy, donate_argnums=(0,)))
        if self.spec is not None:
            # draft loop: k+1 draft decode steps in one compiled scan
            # (the last step only commits the final proposal's KV)
            self._draft_jit = self._wave("spec_draft", jax.jit(
                self._spec_draft, static_argnums=(8,), donate_argnums=(1,)))
            # verify-wave: commit + all-position logits + acceptance +
            # rollback of the device counters, one compiled program
            self._spec_jit = self._wave("spec_verify", jax.jit(
                self._spec_wave, static_argnums=(5, 6), donate_argnums=(1,)))
            # draft-side admission: prefill the draft cache for freshly
            # armed decode residents
            self._draft_admit_jit = self._wave("admit_draft", jax.jit(
                self._draft_admit, donate_argnums=(1,)))

    def _wave(self, family: str, jitted):
        """Register a compiled wave family and wrap its jit for serving.

        The wrapper runs the call inside the mesh context (like
        ``_under_mesh``) and compares the jit's compile-cache size across
        the call: when it grew, this call traced a fresh variant, and its
        argument shape signature is recorded. Steady-state overhead is two
        integer reads per wave — the signature is only built on compiles.
        """
        self._wave_jits[family] = jitted
        variants = self._wave_variants.setdefault(family, [])
        mesh = self.mesh

        def run(*args):
            try:
                before = jitted._cache_size()
            except Exception:
                before = None
            if mesh is not None:
                with mesh:
                    out = jitted(*args)
            else:
                out = jitted(*args)
            if before is not None:
                try:
                    grew = jitted._cache_size() > before
                except Exception:
                    grew = False
                if grew:
                    variants.append(_arg_signature(args))
                    # taint the enclosing open span so the trace-side
                    # compile-vs-execute split matches this registry
                    self.trace.annotate(compiled=family)
            return out
        return run

    def _tail_wave(self, params, cache, toks, slots_, c0s, clens, hb):
        """Tail-wave forward: one batched ``prefill_tail`` window over
        every in-progress tail/chunked prefill (per-row slot/c0/len)."""
        return prefill_tail(self.cfg, params, self.ctx, toks, cache,
                            slots_, c0s, clens, hist_blocks=hb)

    def _cow_copy(self, cache, src, dst):
        """Copy-on-write block clone: pool leaves copy ``src`` block rows
        onto ``dst`` (sentinel dsts drop), everything else passes through."""
        def cp(path, leaf):
            if getattr(path[-1], "key", None) in _POOL_KEYS:
                return copy_pool_blocks(leaf, src, dst)
            return leaf
        return jax.tree_util.tree_map_with_path(cp, cache)

    def _under_mesh(self, fn):
        """Wrap a compiled program so it traces and runs inside the mesh
        context — the bare-axis ``shard_hint`` constraints in the model
        code resolve against it, and GSPMD partitions the wave across the
        mesh instead of batching per-device copies. Identity without a
        mesh."""
        if self.mesh is None:
            return fn
        mesh = self.mesh

        def run(*args, **kwargs):
            with mesh:
                return fn(*args, **kwargs)
        return run

    def _served_weight_leaves(self) -> List:
        """The weight leaves the serve forward actually streams: under
        w4a8 the packed export planes, under bf16 the whole tree."""
        if self.weights_layout != "w4a8":
            return jax.tree.leaves(self.params)
        flat, _ = jax.tree_util.tree_flatten_with_path(self.params)
        return [leaf for path, leaf in flat
                if any(getattr(p, "key", None) == "w4a8" for p in path)]

    def _shard_state(self, state: Dict) -> Dict:
        """Commit the device state pytree to the mesh: KV pool sharded
        over "model" on the KV-head dim, everything else replicated."""
        if self.mesh is None:
            return state
        return jax.device_put(
            state, serve_state_shardings(self.cfg, self.mesh, state))

    # ------------------------------------------------------------------
    # Compiled programs
    # ------------------------------------------------------------------

    def _decode_chunk(self, params, state, greedy_only):
        """Up to ``decode_block`` decode steps, entirely on device."""
        slots, cap = self.slots, self.max_new_cap

        def cond(st):
            return (st["i"] < self.decode_block) & jnp.any(st["active"])

        def body(st):
            logits, cache = decode_step(self.cfg, params, self.ctx,
                                        st["tokens"], st["cache"])
            keys_t = fold_step(st["keys"], st["n_gen"])
            toks = sample_tokens(logits[:, -1], keys_t, st["temp"],
                                 st["top_k"], greedy_only=greedy_only)
            act = st["active"]
            # commit only active slots; inactive rows scatter out of range
            row = jnp.where(act, st["n_gen"], cap)
            out = st["out"].at[jnp.arange(slots), row].set(toks, mode="drop")
            n_gen = st["n_gen"] + act.astype(jnp.int32)
            still = act & (toks != st["eos"]) & (n_gen < st["max_new"])
            return {**st, "cache": cache,
                    "tokens": jnp.where(act[:, None], toks[:, None],
                                        st["tokens"]),
                    "out": out, "n_gen": n_gen, "active": still,
                    "steps": st["steps"] + 1,
                    "committed": st["committed"] + jnp.sum(
                        act.astype(jnp.int32)),
                    "i": st["i"] + 1}

        st = {**state, "i": jnp.int32(0)}
        st = jax.lax.while_loop(cond, body, st)
        st.pop("i")
        return st

    def _post_prefill_state(self, state, new_cache, first, slot_idx, eos,
                            max_new, temp, top_k, keys):
        """Scatter n freshly-prefilled rows' sampling/output state into
        their slots (shared by the dense and paged admission programs)."""
        out = state["out"].at[slot_idx].set(0, mode="drop")
        return {**state, "cache": new_cache,
                "tokens": state["tokens"].at[slot_idx, 0].set(first,
                                                              mode="drop"),
                "out": out.at[slot_idx, 0].set(first, mode="drop"),
                "n_gen": state["n_gen"].at[slot_idx].set(1, mode="drop"),
                "active": state["active"].at[slot_idx].set(
                    (first != eos) & (max_new > 1), mode="drop"),
                "eos": state["eos"].at[slot_idx].set(eos, mode="drop"),
                "max_new": state["max_new"].at[slot_idx].set(max_new,
                                                             mode="drop"),
                "temp": state["temp"].at[slot_idx].set(temp, mode="drop"),
                "top_k": state["top_k"].at[slot_idx].set(top_k, mode="drop"),
                "keys": state["keys"].at[slot_idx].set(keys, mode="drop")}

    def _admit_batch(self, params, state, tokens, lengths, slot_idx, eos,
                     max_new, temp, top_k, keys, greedy_only):
        """One batched prefill + scatter of n fresh rows into their slots.

        Rows may be padding (the host pads the admission batch up to a
        power of two to bound compile variants); their ``slot_idx`` is
        out of range and every scatter drops them.
        """
        batch = {"tokens": tokens}
        if self._pad_ok:
            batch["lengths"] = lengths
        logits, cache_n = prefill(self.cfg, params, self.ctx, batch,
                                  cache_budget=self.cache_len)
        n = tokens.shape[0]
        first = sample_tokens(logits[:, 0],
                              fold_step(keys, jnp.zeros((n,), jnp.int32)),
                              temp, top_k, greedy_only=greedy_only)
        cache = state["cache"]
        # cache leaves are scan-stacked (repeat, slots, ...); position (slots,)
        segments = [jax.tree.map(
            lambda d, s: d.at[:, slot_idx].set(s, mode="drop"), ds, ss)
            for ds, ss in zip(cache["segments"], cache_n["segments"])]
        new_cache = {"segments": segments,
                     "position": cache["position"].at[slot_idx].set(
                         cache_n["position"], mode="drop")}
        return self._post_prefill_state(state, new_cache, first, slot_idx,
                                        eos, max_new, temp, top_k, keys)

    def _admit_batch_paged(self, params, state, tokens, lengths, slot_idx,
                           blk_ids, eos, max_new, temp, top_k, keys,
                           greedy_only):
        """Paged admission: prefill emits block-shaped caches, scattered
        into the global pool through the rows' allocated block ids.

        ``blk_ids`` (n, nb) int32: pool destinations for each row's prompt
        blocks; entries past a row's ``ceil(len/bs)`` blocks (and whole
        padding rows) hold the out-of-range sentinel and drop.
        """
        batch = {"tokens": tokens, "lengths": lengths}
        logits, cache_n = prefill(self.cfg, params, self.ctx, batch,
                                  page_size=self.block_size)
        n = tokens.shape[0]
        first = sample_tokens(logits[:, 0],
                              fold_step(keys, jnp.zeros((n,), jnp.int32)),
                              temp, top_k, greedy_only=greedy_only)
        cache = state["cache"]

        def scatter(path, d, s):
            if getattr(path[-1], "key", None) in _POOL_KEYS:
                # d (rep, NB, ...), s (rep, n, nb, ...): block scatter
                return d.at[:, blk_ids].set(s, mode="drop")
            return d.at[:, slot_idx].set(s, mode="drop")   # per-slot leaves

        segments = [jax.tree_util.tree_map_with_path(scatter, ds, ss)
                    for ds, ss in zip(cache["segments"],
                                      cache_n["segments"])]
        new_cache = {"segments": segments,
                     "position": cache["position"].at[slot_idx].set(
                         cache_n["position"], mode="drop"),
                     "block_tbl": cache["block_tbl"]}
        return self._post_prefill_state(state, new_cache, first, slot_idx,
                                        eos, max_new, temp, top_k, keys)

    # ------------------------------------------------------------------
    # Speculative decoding: draft scan + verify-wave (compiled)
    # ------------------------------------------------------------------

    def _spec_draft(self, dparams, dcache, tokens, temp, top_k, keys,
                    n_gen, lens, greedy_only):
        """Draft ``k`` proposals per slot, entirely on device.

        The draft cache's counters are first re-clamped to ``lens`` (the
        target's committed extent) — that is the draft-side rollback of
        positions over-drafted before the previous wave's rejections.
        The scan runs ``k + 1`` draft decode steps: step j consumes the
        previous proposal (step 0 the slot's last committed token) and
        samples proposal j+1 with the plain-decode key stream
        ``fold_in(key, n_gen + j)`` — so a self-draft proposes exactly
        the tokens plain decode would emit and everything is accepted.
        The final step only commits its input's KV (its proposal is
        discarded): the draft cache ends the wave covering every token
        the target might accept. In ``rejection`` mode the per-proposal
        draft distribution rides along for the acceptance test.
        """
        k = self.spec.k
        dcache = {"segments": _clamp_lengths(dcache["segments"], lens),
                  "position": lens}
        want_q = self.spec.accept_mode == "rejection" and not greedy_only

        def step(carry, j):
            tok, cache = carry
            logits, cache = decode_step(self.draft_cfg, dparams,
                                        self.draft_ctx, tok, cache)
            nxt = sample_tokens(logits[:, -1], fold_step(keys, n_gen + j),
                                temp, top_k, greedy_only=greedy_only)
            q = (token_probs(logits[:, -1], temp, top_k) if want_q
                 else jnp.zeros((tok.shape[0], 0), jnp.float32))
            return (nxt[:, None], cache), (nxt, q)

        (_, dcache), (dt, dq) = jax.lax.scan(
            step, (tokens, dcache), jnp.arange(k + 1, dtype=jnp.int32))
        dtoks = jnp.moveaxis(dt[:k], 0, 1)                     # (S, k)
        dqs = jnp.moveaxis(dq[:k], 0, 1) if want_q else None   # (S, k, V)
        return dtoks, dqs, dcache

    def _spec_wave(self, params, state, dtoks, dq, tail_len, hist_blocks,
                   greedy_only):
        """Verify every resident's drafted window in ONE compiled call
        and commit the accepted prefix.

        The window ``[last_token, draft_1..draft_k]`` is verified by
        ``models.spec_verify`` (per-row ``(c0, tail_len)`` batched-chunk
        contract, decode-exact numerics), the target's own samples are
        drawn with the plain-decode key stream, and acceptance picks how
        many tokens commit: the leading draft matches plus one target
        token (the correction at the first mismatch, or the bonus when
        everything survives), truncated at the first committed EOS and
        the row's remaining ``max_new`` budget. Rejected positions roll
        back on device here — per-layer ``length`` and ``position``
        re-clamp to the accepted extent, so the stale KV past it is
        unreadable — and the host releases their whole blocks via
        ``BlockAllocator.trim`` right after (the per-slot committed
        count is recovered host-side from the harvest's ``n_gen`` fetch,
        keeping the wave at one sync like a decode chunk).
        """
        S, C = self.slots, self.spec.k + 1
        cap = self.max_new_cap
        cache = state["cache"]
        c0 = cache["position"]
        slot_idx = jnp.arange(S, dtype=jnp.int32)
        window = jnp.concatenate([state["tokens"], dtoks], axis=1)
        logits, cache = spec_verify(self.cfg, params, self.ctx, window,
                                    cache, slot_idx, c0, tail_len,
                                    hist_blocks=hist_blocks)
        n_gen, act = state["n_gen"], state["active"]
        # one flattened (S*C)-row sampling call: per-row ops (argmax /
        # top-k mask / per-key categorical) are exactly what C sequential
        # decode steps would run, at a C-independent op count
        V = logits.shape[-1]
        flat = logits.reshape(S * C, V)
        keys_rep = jnp.repeat(state["keys"], C, axis=0)
        ctr = (n_gen[:, None] + jnp.arange(C)[None]).reshape(S * C)
        temp_rep = jnp.repeat(state["temp"], C)
        topk_rep = jnp.repeat(state["top_k"], C)
        tt = sample_tokens(flat, fold_step(keys_rep, ctr), temp_rep,
                           topk_rep,
                           greedy_only=greedy_only).reshape(S, C)
        n_draft = jnp.maximum(tail_len - 1, 0)
        if self.spec.accept_mode == "rejection" and not greedy_only:
            p = token_probs(flat, temp_rep, topk_rep).reshape(S, C, V)
            n_acc, committed = accept_rejection(dtoks, dq, p, tt,
                                                state["keys"], n_gen,
                                                n_draft)
        else:
            n_acc, committed = accept_exact(dtoks, tt, n_draft), tt
        m = n_acc + 1
        is_eos = committed == state["eos"][:, None]
        m = jnp.where(jnp.any(is_eos, axis=1),
                      jnp.minimum(m, jnp.argmax(is_eos, axis=1) + 1), m)
        m = jnp.where(act, jnp.minimum(m, jnp.maximum(tail_len, 1)), 0)
        jj = jnp.arange(C)[None]
        row = jnp.where(jj < m[:, None], n_gen[:, None] + jj, cap)
        out = state["out"].at[slot_idx[:, None], row].set(committed,
                                                          mode="drop")
        n_gen2 = n_gen + m
        lastj = jnp.maximum(m - 1, 0)[:, None]
        last = jnp.take_along_axis(committed, lastj, axis=1)[:, 0]
        hit_eos = jnp.take_along_axis(is_eos, lastj, axis=1)[:, 0]
        still = act & ~hit_eos & (n_gen2 < state["max_new"])
        new_len = c0 + m
        cache = {"segments": _clamp_lengths(cache["segments"], new_len),
                 "position": new_len, "block_tbl": cache["block_tbl"]}
        return {**state, "cache": cache,
                "tokens": jnp.where(act[:, None], last[:, None],
                                    state["tokens"]),
                "out": out, "n_gen": n_gen2, "active": still,
                "steps": state["steps"] + 1,
                "committed": state["committed"] + jnp.sum(m)}

    def _draft_admit(self, dparams, dcache, tokens, lengths, slot_idx):
        """Prefill the draft model's dense cache rows for freshly armed
        decode residents (padding rows' ``slot_idx`` sentinel drops),
        mirroring the dense half of ``_admit_batch``."""
        batch = {"tokens": tokens, "lengths": lengths}
        _, cache_n = prefill(self.draft_cfg, dparams, self.draft_ctx, batch,
                             cache_budget=self._draft_cache_len)
        segments = [jax.tree.map(
            lambda d, s: d.at[:, slot_idx].set(s, mode="drop"), ds, ss)
            for ds, ss in zip(dcache["segments"], cache_n["segments"])]
        return {"segments": segments,
                "position": dcache["position"].at[slot_idx].set(
                    cache_n["position"], mode="drop")}

    # ------------------------------------------------------------------
    # Request lifecycle (host side)
    # ------------------------------------------------------------------

    def _blank_state(self) -> Dict:
        slots = self.slots
        if self._paged:
            cache = init_cache(self.cfg, self.ctx, slots, self.cache_len,
                               num_blocks=self.num_blocks,
                               page_size=self.block_size,
                               table_len=self.table_len)
        else:
            cache = init_cache(self.cfg, self.ctx, slots, self.cache_len)
        return {
            "cache": cache,
            "tokens": jnp.zeros((slots, 1), jnp.int32),
            "out": jnp.zeros((slots, self.max_new_cap), jnp.int32),
            "n_gen": jnp.zeros((slots,), jnp.int32),
            "active": jnp.zeros((slots,), bool),
            "eos": jnp.full((slots,), -1, jnp.int32),
            "max_new": jnp.ones((slots,), jnp.int32),
            "temp": jnp.zeros((slots,), jnp.float32),
            "top_k": jnp.zeros((slots,), jnp.int32),
            "keys": jnp.zeros((slots, 2), jnp.uint32),
            "steps": jnp.int32(0),
            "committed": jnp.int32(0),
        }

    def reset(self) -> None:
        """Clear all serving state but keep compiled programs warm.

        Drops every queued / resident / swapped request, reinitializes
        the cache pytree and the block allocator (paged), zeroes all
        stats, and replaces the scheduler with a fresh one of the same
        policy. Compiled programs and the ``decode_block="auto"`` probe
        result survive, so a reset-and-rerun (the benchmark pattern)
        pays no recompile. Requests submitted before the reset must not
        be resubmitted to the old engine's allocator state — their
        prefix-lookup memos are invalidated by an epoch bump.
        """
        self.state = self._shard_state(self._blank_state())
        # monotone epoch invalidates per-request lookup memos across
        # resets (an id()-based token could collide on address reuse)
        self._alloc_epoch = getattr(self, "_alloc_epoch", -1) + 1
        self.alloc = (BlockAllocator(self.num_blocks, self.block_size,
                                     self.slots, self.table_len,
                                     prefix_cache=self.prefix_cache)
                      if self._paged else None)
        self._slot_req = {}
        self._written: Dict[int, int] = {}   # paged: tokens committed/slot
        self._tbl_dirty = False              # host table mirror vs device
        self._tail_jobs: List[Dict] = []     # in-progress tail prefills
        self._swapped: List[Dict] = []       # preempted, awaiting restore
        self._admit_seq: Dict[int, int] = {}     # slot -> admission order
        self._seq = 0
        self._max_residents = 0
        self.scheduler = Scheduler(self.scheduler.policy, trace=self.trace)
        # a fresh run gets a fresh observability window: reruns (the
        # benchmark warmup→reset→timed pattern) must not inherit the
        # previous pass's spans or histogram mass
        self.trace.clear()
        self.metrics.reset()
        self._step_idx = 0
        self._pred_per_tok: Optional[float] = None   # fastest s/prompt-tok
        self._pred_round_s: Optional[float] = None   # fastest decode round
        self._host = {"decode_s": 0.0, "decode_rounds": 0,
                      "prefill_s": 0.0, "prefill_calls": 0,
                      "prefill_tokens": 0, "prefill_chunks": 0,
                      "prompt_tokens": 0, "prefix_hit_tokens": 0,
                      "cow_copies": 0, "preemptions": 0,
                      "swap_out_bytes": 0, "swap_in_bytes": 0,
                      "swap_s": 0.0}
        if self.spec is not None:
            self._draft_cache = init_cache(self.draft_cfg, self.draft_ctx,
                                           self.slots,
                                           self._draft_cache_len)
            if self.mesh is not None:
                self._draft_cache = jax.device_put(
                    self._draft_cache,
                    serve_cache_shardings(self.draft_cfg, self.mesh,
                                          self._draft_cache))
            self._host.update({"spec_waves": 0, "spec_drafted": 0,
                               "spec_accepted": 0, "spec_rolled_back": 0,
                               "spec_draft_prefill_tokens": 0})
        self._cache_bytes = sum(
            leaf.nbytes for seg in self.state["cache"]["segments"]
            for leaf in jax.tree.leaves(seg))

    def submit(self, req: Request) -> None:
        """Enqueue one request for serving.

        Args:
            req: a :class:`Request`. ``prompt`` is a 1-D int32 token-id
                array; ``max_new_tokens`` bounds generation (the first
                token comes from prefill); ``temperature <= 0`` means
                greedy and ``top_k == 0`` disables filtering;
                ``deadline_ms`` / ``priority`` feed the ``edf``
                scheduler policy and ``slo_shed`` admission control;
                ``on_tokens`` (if set) receives every freshly decoded
                span as ``on_tokens(req, tokens, done)``.

        Returns:
            None. The request is queued; the engine admits it on a later
            :meth:`step`. Completion is signalled by ``req.done`` (tokens
            in ``req.generated``), by the ``on_tokens`` callback, or by
            ``req.shed`` if SLO admission control rejected it.

        Raises:
            ValueError: if the request can *never* be admitted on this
                engine — ``max_new_tokens`` above ``max_new_cap``,
                ``top_k`` above ``TOP_K_CAP``, or a token footprint
                (``prompt + max_new_tokens - 1``) exceeding
                ``max_seq_len`` / the block table / the pool (paged) or
                ``cache_len`` (dense full-attention). The message names
                the computed need and the knob to raise.
        """
        if req.max_new_tokens > self.max_new_cap:
            raise ValueError(
                f"max_new_tokens={req.max_new_tokens} exceeds this engine's "
                f"max_new_cap={self.max_new_cap} (the on-device token "
                f"buffer); construct ServeEngine with a larger max_new_cap")
        if req.top_k > TOP_K_CAP:
            raise ValueError(f"top_k={req.top_k} exceeds TOP_K_CAP="
                             f"{TOP_K_CAP} (static sampling bound)")
        # peak cache occupancy is prompt + max_new - 1: the last sampled
        # token is returned but its KV is never written while resident
        need = len(req.prompt) + req.max_new_tokens - 1
        if self._paged:
            if need > self.max_seq_len:
                raise ValueError(
                    f"request needs {need} cache tokens (prompt "
                    f"{len(req.prompt)} + max_new_tokens "
                    f"{req.max_new_tokens} - 1) but max_seq_len="
                    f"{self.max_seq_len}; raise max_seq_len or shorten "
                    f"the request")
            nb = self.alloc.blocks_for_tokens(need)
            if nb > self.table_len:
                raise ValueError(
                    f"request needs {nb} block-table entries ({need} tokens "
                    f"at block_size={self.block_size}) but the block table "
                    f"is only table_len={self.table_len} entries wide, so "
                    f"it can never be admitted; raise table_len or "
                    f"max_seq_len")
            if nb > self.num_blocks:
                raise ValueError(
                    f"request needs {nb} cache blocks ({need} tokens at "
                    f"block_size={self.block_size}) but the pool only has "
                    f"num_blocks={self.num_blocks}, so it can never be "
                    f"admitted; raise num_blocks")
        elif self._cache_bound and need > self.cache_len:
            raise ValueError(
                f"request needs {need} cache tokens (prompt "
                f"{len(req.prompt)} + max_new_tokens {req.max_new_tokens} "
                f"- 1) but cache_len={self.cache_len} on a full-attention "
                f"model; raise cache_len or shorten the request")
        self.scheduler.submit(req)

    def _note_residency(self) -> None:
        n = len(self._slot_req) + len(self._tail_jobs)
        self._max_residents = max(self._max_residents, n)

    # ------------------------------------------------------------------
    # SLO-aware admission + streaming drain
    # ------------------------------------------------------------------

    def _predict_ttft_s(self, backlog_tokens: int) -> float:
        """Estimate seconds until a queued request's first token given
        ``backlog_tokens`` prompt tokens must prefill before it (requests
        ahead in policy order plus its own prompt). Fitted from this
        engine's own measured rates — prefill seconds per prompt token
        plus one decode round (the wave in flight when it reaches the
        head) — so the estimate tracks the deployment, not a constant.
        Returns 0.0 until the engine has measured anything (a cold engine
        never sheds blind). Rates are the *fastest* observed per call —
        a min, not a mean — so the one-time XLA compile cost of each
        program variant (seconds, folded into the first call's wall
        time) can't masquerade as steady-state service time and shed the
        whole queue on a freshly constructed engine."""
        if self._pred_per_tok is None:
            return 0.0
        return (self._pred_per_tok * backlog_tokens
                + (self._pred_round_s or 0.0))

    def _note_rate(self, attr: str, value: float) -> None:
        """Min-track a measured rate for the TTFT predictor."""
        cur = getattr(self, attr)
        setattr(self, attr, value if cur is None else min(cur, value))

    def _shed_overdue(self) -> None:
        """Shed-load pass before admission (``slo_shed != "none"``):
        requests whose predicted TTFT already exceeds their deadline are
        rejected (``req.shed = True``, stream closed with no tokens) or
        downgraded to best-effort, per the engine's ``slo_shed`` mode."""
        if self.slo_shed == "none" or not self.scheduler.pending:
            return
        for r in self.scheduler.shed_overdue(self._predict_ttft_s,
                                             self.slo_shed):
            r.shed = True
            r.done = True
            self.trace.event("shed", uid=r.uid)
            self._emit_stream(r, (), done=True)

    @staticmethod
    def _emit_stream(req, toks, done: bool) -> None:
        """Deliver freshly decoded tokens to a streaming request's
        ``on_tokens`` callback (no-op for non-streaming requests)."""
        if req.on_tokens is not None:
            req.on_tokens(req, list(toks), done)
            req._streamed += len(toks)
        elif done:
            req._streamed = len(req.generated)

    def _admit(self) -> None:
        self._shed_overdue()
        if self._paged:
            self._admit_paged()
            return
        free = self._free_slots()
        if not free or not self.scheduler.pending:
            return
        reqs = self.scheduler.select(len(free),
                                     equal_length_only=not self._pad_ok)
        if not reqs:
            return
        self._admit_wave(reqs, free[:len(reqs)])
        self._note_residency()

    def _free_slots(self) -> List[int]:
        busy = set(self._slot_req)
        busy.update(j["slot"] for j in self._tail_jobs)
        return [s for s in range(self.slots) if s not in busy]

    def _affinity_key(self, req):
        """Grouping key for prefix-aware scheduling: requests whose
        prompts extend the same cached chain share its block-id tuple, so
        the scheduler pulls them back-to-back and the chain is admitted
        while still hot in the allocator's LRU (a miss returns None — no
        grouping). Ordering is a *hint*, so unlike admission (which needs
        version-exact block ids) a stale key is acceptable: each request
        pays one real lookup on first sight and then reuses its last
        known key until some other path (head check, wave predicate)
        re-looks it up for real — the index version bumps on every wave
        window, and re-hashing the whole queue per engine step would put
        O(queue x prompt) sha256 digests on the admission hot path."""
        ver2 = (id(self), self._alloc_epoch)
        memo = getattr(req, "_prefix_hit", None)
        if memo is not None and memo[0] == ver2 + (
                self.alloc.index_version,):
            ids = memo[1][0]
            return tuple(ids) if ids else None
        hint = getattr(req, "_affinity_memo", None)
        if hint is not None and hint[0] == ver2:
            return hint[1]
        ids = self._lookup(req)[0]
        return tuple(ids) if ids else None

    def _admit_paged(self) -> None:
        """Paged admission loop. Swapped-out (preempted) requests restore
        ahead of new work (head-of-line, so preemption can't starve).
        Each new request is first looked up in the prefix cache: a request
        with a cached prefix maps the hit blocks (refcount++) and admits
        through the tail-prefill path, computing only the uncached tail;
        prompts longer than ``prefill_chunk`` take the same path window by
        window. Up to ``tail_batch`` tail admissions ride concurrently —
        each engine step advances all of them in ONE compiled wave
        (``_advance_tail_jobs``), so simultaneous prefix-hit arrivals no
        longer serialize. With ``prefix_affinity`` the queue is grouped so
        requests sharing a cached chain admit back-to-back. Everything
        else admits as a batched cold wave under the free-block criterion
        with head-of-line blocking."""
        if self._swapped:
            self._try_swap_in()
            if self._swapped:
                return              # restore before admitting new work
        gk = self._affinity_key if self.prefix_affinity else None
        held: set = set()
        while self.scheduler.pending > len(held):
            free = self._free_slots()
            if not free:
                return
            # chains with a tail admission in flight stay "hot": their
            # queued sharers rank ahead so the chain's LRU blocks are
            # mapped again before anything can evict them
            hot = ({j["akey"] for j in self._tail_jobs
                    if j.get("akey") is not None} if gk else ())
            head = self.scheduler.first(group_key=gk, hot=hot, skip=held)
            if head is None:
                return
            plen = len(head.prompt)
            hit_ids, cached, partial = self._lookup(head)
            if self._dedup_hold(head, cached):
                # cross-wave dedup: this head waits a wave for the
                # in-flight sharer to register — but only IT is held;
                # unrelated work behind it still admits this step
                held.add(head)
                continue
            if cached or plen > self.prefill_chunk:
                if len(self._tail_jobs) >= self.tail_batch:
                    return          # wave is full: head waits its turn
                slot = free[0]
                eff = self._paged_admit_slot(slot, head, hit_ids, partial,
                                             cached)
                if eff is None:
                    return              # pool exhausted: head waits
                self.scheduler.take(head)
                self._host["prefix_hit_tokens"] += eff
                self._tail_jobs.append({"req": head, "slot": slot,
                                        "c0": eff,
                                        "akey": tuple(hit_ids) or None})
                self._note_residency()
                continue
            taken: List[int] = []
            batch_reqs: List = []

            def ok(r):
                if len(r.prompt) > self.prefill_chunk:
                    return False        # long prompt: chunked next round
                if r is not head and self._lookup(r)[1]:
                    return False        # cached prefix: tail path next round
                bs = self.block_size
                if self.prefix_cache and len(r.prompt) - 1 >= bs and any(
                        len(q.prompt) >= bs
                        and np.array_equal(np.asarray(r.prompt[:bs]),
                                           q.prompt[:bs])
                        for q in batch_reqs):
                    # cross-wave dedup: r shares >= one full block with a
                    # request already in THIS forming wave; co-admitting
                    # would compute the shared content twice. Held one
                    # wave, it prefix-hits the blocks the wave registers
                    # (only the first block is compared: that is the
                    # whole trigger condition, so cost stays O(bs))
                    return False
                if self._paged_admit_slot(free[len(taken)], r, (),
                                          False, 0) is None:
                    return False
                taken.append(free[len(taken)])
                batch_reqs.append(r)
                return True

            reqs = self.scheduler.select(len(free), admit_ok=ok,
                                         group_key=gk, hot=hot, skip=held)
            if not reqs:
                return
            # lazy prefill allocation: just the prompt's blocks for now
            for s, r in zip(taken, reqs):
                self._ensure(s, len(r.prompt))
            self._admit_wave(reqs, taken, paged=True)
            self._note_residency()

    def _lookup(self, req):
        """Prefix-cache lookup memoized per request against the allocator
        identity + index version, so re-walking the queue every engine
        step doesn't re-hash prompts (or inflate the lookup stats) while
        nothing changed — and a request resubmitted after ``reset()`` (or
        to another engine) can't replay block ids from a dead pool."""
        if not self.prefix_cache:
            return (), 0, False
        ver = (id(self), self._alloc_epoch, self.alloc.index_version)
        memo = getattr(req, "_prefix_hit", None)
        if memo is not None and memo[0] == ver:
            return memo[1]
        hit = self.alloc.lookup(req.prompt)
        req._prefix_hit = (ver, hit)
        # refresh the affinity hint whenever a real lookup runs (see
        # _affinity_key: grouping tolerates staleness, admission doesn't)
        req._affinity_memo = (ver[:2], tuple(hit[0]) or None)
        return hit

    def _dedup_hold(self, req, cached: int) -> bool:
        """Cross-wave dedup (tail path): when ``req`` extends the same
        chain an in-flight tail admission is still prefilling, admitting
        it now would recompute the shared content. Hold it while any
        in-flight job has at least one block of overlap ``req`` hasn't
        prefix-hit yet — a wave later the job's freshly registered
        blocks turn the overlap into a hit. Bounded: jobs leave
        ``_tail_jobs`` in finitely many waves (completion or
        preemption), registration is monotone, and the gap closes once
        the registered extent covers the overlap."""
        if not self.prefix_cache or not self._tail_jobs:
            return False
        # the hold triggers iff >= one whole block of overlap remains
        # unhit, i.e. the first cached + block_size tokens agree — so
        # only that slice is ever compared, keeping the per-step cost
        # O(block_size + cached) per in-flight job instead of O(prompt)
        need = cached + self.block_size
        if len(req.prompt) - 1 < need:
            return False
        head = np.asarray(req.prompt[:need])
        for job in self._tail_jobs:
            jp = job["req"].prompt
            if len(jp) >= need and np.array_equal(head, jp[:need]):
                return True
        return False

    def _paged_admit_slot(self, slot: int, req, hit_ids, partial: bool,
                          cached: int) -> Optional[int]:
        """Admit one request into ``slot``: map its shared prefix blocks
        and commit capacity under the engine's admission discipline.
        ``reserve`` debits the worst-case fresh-block count up front;
        ``optimistic`` physically allocates only the first tail window
        (the whole prompt for a wave row) and relies on preemption for
        later growth. Returns the effective cached-token count (0 when
        the prefix ended up unused), or None — leaving no state behind —
        when the pool can't take the request now."""
        plen = len(req.prompt)
        need = plen + req.max_new_tokens - 1
        if self.admission == "reserve":
            if not self.alloc.reserve(slot, need, shared=hit_ids,
                                      partial=partial):
                # a shared admission transiently needs more obtainable
                # blocks than an exclusive one (resurrecting LRU hits +
                # the split-block COW can exceed the pool on tiny pools);
                # when nothing is resident the pool will never get freer,
                # so fall back to an unshared reservation over deadlock
                idle = (not self._slot_req and not self._tail_jobs
                        and not self._swapped)
                if not (idle and hit_ids and self.alloc.reserve(slot, need)):
                    return None
                hit_ids, cached = (), 0
        else:
            self.alloc.register(slot, shared=hit_ids)
            try:
                self.alloc.ensure(slot, min(cached + self.prefill_chunk,
                                            plen))
            except PoolDry:
                self.alloc.release(slot)
                return None
        if hit_ids or self.admission == "optimistic":
            self._tbl_dirty = True
        self._admit_seq[slot] = self._seq
        self._seq += 1
        return cached

    def _admit_wave(self, reqs, taken, paged: bool = False) -> None:
        """One batched prefill admission (dense or paged)."""
        n = len(reqs)
        # pad the admission batch up to a power of two (dummy rows scatter
        # out of range and drop) so compile variants are O(log slots) per
        # length bucket instead of one per free-slot count
        n_pad = min(_pow2_ceil(n), self.slots)
        lens = np.ones((n_pad,), np.int32)
        lens[:n] = [len(r.prompt) for r in reqs]
        if self._pad_ok:
            L = -(-int(lens.max()) // self.prefill_bucket) \
                * self.prefill_bucket
        else:
            L = int(lens[0])
        toks = np.zeros((n_pad, L), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :lens[i]] = r.prompt[:L]
        slot_idx = np.full((n_pad,), self.slots, np.int32)   # dummy: dropped
        slot_idx[:n] = taken[:n]
        keys = np.zeros((n_pad, 2), np.uint32)
        keys[:n] = np.stack([jax.random.fold_in(jax.random.PRNGKey(r.seed),
                                                r.uid) for r in reqs])

        def col(fn, fill, dtype):
            v = np.full((n_pad,), fill, dtype)
            v[:n] = [fn(r) for r in reqs]
            return jnp.asarray(v)

        greedy_only = all(r.temperature <= 0.0 for r in reqs)
        wave_tokens = int(sum(len(r.prompt) for r in reqs))
        with self.trace.span("prefill_wave", rows=n, tokens=wave_tokens,
                             paged=paged) as sp:
            common = (jnp.asarray(toks), jnp.asarray(lens),
                      jnp.asarray(slot_idx))
            tail = (col(lambda r: r.eos_id, -1, np.int32),
                    col(lambda r: r.max_new_tokens, 1, np.int32),
                    col(lambda r: r.temperature, 0.0, np.float32),
                    col(lambda r: r.top_k, 0, np.int32), jnp.asarray(keys),
                    greedy_only)
            if paged:
                # prefill emits ceil(L / block_size) blocks per row (bucket-
                # padded); rows point their own allocated blocks at the pool
                # and sentinel out both their tail blocks and the dummy rows
                nb = self.alloc.blocks_for_tokens(L)
                ids = np.full((n_pad, nb), self.num_blocks, np.int32)
                for i, (s, r) in enumerate(zip(taken, reqs)):
                    nb_i = self.alloc.blocks_for_tokens(len(r.prompt))
                    ids[i, :nb_i] = self.alloc.tables[s, :nb_i]
                self._push_tables()
                self.state = self._admit_paged_jit(
                    self.params, self.state, *common, jnp.asarray(ids),
                    *tail)
            else:
                self.state = self._admit_jit(self.params, self.state,
                                             *common, *tail)
            with self.trace.span("sync"):
                jax.block_until_ready(self.state["tokens"])
        self._host["prefill_s"] += sp.dt
        self._host["prefill_calls"] += 1
        self._host["prefill_tokens"] += n     # first token of each request
        self._host["prompt_tokens"] += wave_tokens
        self._note_rate("_pred_per_tok", sp.dt / max(wave_tokens, 1))
        self.scheduler.on_admitted(reqs)
        for r in reqs:
            # the admission wave sampled each row's first token, so TTFT
            # lands here (admission-wave granularity)
            tm = getattr(r, "_timing", None)
            if tm is not None:
                self.metrics.observe_ttft(tm.ttft)
            self.trace.event("first_token", uid=r.uid)
        for s, r in zip(taken, reqs):
            self._slot_req[s] = r
            if self._paged:
                self._written[s] = len(r.prompt)
                # content-address the freshly written prompt blocks so
                # later requests sharing the prefix skip their prefill
                self.alloc.register_prefix(s, r.prompt, len(r.prompt))
        if self.spec is not None:
            self._draft_prefill_rows([(s, r.prompt)
                                      for s, r in zip(taken, reqs)])

    def _advance_tail_jobs(self) -> None:
        """Advance EVERY in-progress tail/chunked prefill by one window —
        all jobs batched into a single compiled call (the tail-wave).
        ``c0`` starts at the cached-prefix length (0 for a plain long
        prompt), so a prefix-hit request computes only its uncached tail;
        per-row ``(c0, tail_len)`` offsets let rows at different depths of
        different prompts share the wave. One window per engine step:
        resident slots keep decoding between windows, so long prompts
        can't freeze everyone else's inter-token latency. Rows whose final
        window completes sample their first token and arm their slots
        together, exactly like a batched admission."""
        C = self.prefill_chunk
        with self.trace.span("schedule", kind="tail"):
            ready: List[Dict] = []
            lens: List[int] = []
            for job in list(self._tail_jobs):
                slot, c0 = job["slot"], job["c0"]
                cl = min(C, len(job["req"].prompt) - c0)
                # growth/COW may swap the job itself out on a dry pool
                # (_preempt_for never victimizes tail jobs, so jobs in this
                # loop can't evict each other)
                if not self._ensure(slot, c0 + cl):
                    continue
                if not self._cow_guard(slot, c0, c0 + cl):
                    continue
                ready.append(job)
                lens.append(cl)
        if not ready:
            return
        n = len(ready)
        done: List[Dict] = []
        with self.trace.span("tail_wave", rows=n,
                             tokens=int(sum(lens))) as sp:
            self._push_tables()
            n_pad = min(_pow2_ceil(n), self.slots)
            toks = np.zeros((n_pad, C), np.int32)
            slots_arr = np.full((n_pad,), self.slots, np.int32)  # pad: drop
            c0s = np.zeros((n_pad,), np.int32)
            clens = np.zeros((n_pad,), np.int32)
            hb_need = 1
            for i, (job, cl) in enumerate(zip(ready, lens)):
                c0 = job["c0"]
                toks[i, :cl] = job["req"].prompt[c0:c0 + cl]
                slots_arr[i] = job["slot"]
                c0s[i] = c0
                clens[i] = cl
                # table walk bounded by the tokens the deepest row can
                # touch, bucketed to a power of two to bound variants
                hb_need = max(hb_need, self.alloc.blocks_for_tokens(c0 + C))
            hb = min(_pow2_ceil(hb_need), self.table_len)
            logits, self.state["cache"] = self._tail_jit(
                self.params, self.state["cache"], jnp.asarray(toks),
                jnp.asarray(slots_arr), jnp.asarray(c0s),
                jnp.asarray(clens), hb)
            self._host["prefill_chunks"] += n
            self._host["prompt_tokens"] += int(sum(lens))
            rows: List[int] = []
            for i, (job, cl) in enumerate(zip(ready, lens)):
                job["c0"] += cl
                self.alloc.register_prefix(job["slot"], job["req"].prompt,
                                           job["c0"])
                if job["c0"] >= len(job["req"].prompt):
                    done.append(job)
                    rows.append(i)
            if done:
                reqs = [j["req"] for j in done]
                keys = jnp.asarray(np.stack(
                    [jax.random.fold_in(jax.random.PRNGKey(r.seed), r.uid)
                     for r in reqs]))
                temp = jnp.asarray([r.temperature for r in reqs],
                                   jnp.float32)
                top_k = jnp.asarray([r.top_k for r in reqs], jnp.int32)
                first = sample_tokens(
                    logits[np.asarray(rows)],
                    fold_step(keys, jnp.zeros((len(done),), jnp.int32)),
                    temp, top_k,
                    greedy_only=all(r.temperature <= 0.0 for r in reqs))
                self.state = self._post_prefill_state(
                    self.state, self.state["cache"], first,
                    jnp.asarray([j["slot"] for j in done], jnp.int32),
                    jnp.asarray([r.eos_id for r in reqs], jnp.int32),
                    jnp.asarray([r.max_new_tokens for r in reqs],
                                jnp.int32),
                    temp, top_k, keys)
                with self.trace.span("sync"):
                    jax.block_until_ready(self.state["tokens"])
            else:
                with self.trace.span("sync"):
                    jax.block_until_ready(self.state["cache"]["position"])
        self._host["prefill_s"] += sp.dt
        self._note_rate("_pred_per_tok", sp.dt / max(int(sum(lens)), 1))
        if not done:
            return
        self._host["prefill_calls"] += 1
        self._host["prefill_tokens"] += len(done)
        self.scheduler.on_admitted(reqs)
        for r in reqs:
            tm = getattr(r, "_timing", None)
            if tm is not None:
                self.metrics.observe_ttft(tm.ttft)
            self.trace.event("first_token", uid=r.uid)
        for j in done:
            self._tail_jobs.remove(j)
            self._slot_req[j["slot"]] = j["req"]
            self._written[j["slot"]] = len(j["req"].prompt)
        if self.spec is not None:
            # the tail computed only the uncached suffix, but the draft
            # has no prefix cache: its rows prefill the whole prompt
            self._draft_prefill_rows([(j["slot"], j["req"].prompt)
                                      for j in done])

    def _ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow the slot's block table to cover ``n_tokens``. Under
        optimistic admission a dry pool preempts a victim — or, when no
        other resident can be evicted, swaps out ``slot`` itself. Returns
        False iff ``slot`` was swapped out (the caller must abandon its
        pending work for the slot)."""
        while True:
            try:
                if self.alloc.ensure(slot, n_tokens):
                    self._tbl_dirty = True
                return True
            except PoolDry:
                if not self._preempt_for(slot):
                    self._swap_out(slot)
                    return False

    def _cow_guard(self, slot: int, start_tok: int, end_tok: int) -> bool:
        """Resolve copy-on-write for a pending write of token positions
        ``[start_tok, end_tok)``: shared blocks in the range are replaced
        by fresh blocks and their int8 payload + scales cloned device-side
        *before* the write executes. A dry pool preempts like ``_ensure``
        (cow_range pre-checks its block need, so a raise applies nothing);
        returns False iff ``slot`` itself was swapped out."""
        while True:
            try:
                pairs = self.alloc.cow_range(slot, start_tok, end_tok)
                break
            except PoolDry:
                if not self._preempt_for(slot):
                    self._swap_out(slot)
                    return False
        if pairs:
            self._apply_cow(pairs)
        return True

    def _apply_cow(self, pairs) -> None:
        """Device-side block clones for resolved COW pairs, bucketed to a
        power of two (pad dsts sit on the sentinel and drop)."""
        n_pad = _pow2_ceil(len(pairs))
        src = np.zeros((n_pad,), np.int32)
        dst = np.full((n_pad,), self.num_blocks, np.int32)
        src[:len(pairs)] = [p[0] for p in pairs]
        dst[:len(pairs)] = [p[1] for p in pairs]
        with self.trace.span("cow", blocks=len(pairs)):
            self.state["cache"] = self._cow_jit(
                self.state["cache"], jnp.asarray(src), jnp.asarray(dst))
        self._host["cow_copies"] += len(pairs)
        self._tbl_dirty = True

    def _preempt_for(self, slot: int) -> bool:
        """Swap out one scheduler-chosen victim to free blocks. Candidates
        are the decode residents other than ``slot`` (in-progress tail
        jobs are never in ``_slot_req``, so they are implicitly protected
        — jobs in one wave can't evict each other). False when no other
        resident is preemptible."""
        cands = []
        for s, r in self._slot_req.items():
            if s == slot:
                continue
            remaining = (len(r.prompt) + r.max_new_tokens - 1
                         - self._written[s])
            cands.append((s, self._admit_seq.get(s, 0), remaining))
        victim = self.scheduler.pick_victim(cands, self.preempt)
        if victim is None:
            return False
        self._swap_out(victim)
        return True

    def _push_tables(self) -> None:
        """Push the host block-table mirror to the device iff it changed
        since the last push (block growth or a harvest-time release — the
        release is what retires freed slots' rows to the sentinel so their
        masked commits drop)."""
        if self._tbl_dirty:
            tbl = jnp.asarray(self.alloc.tables)
            if self.mesh is not None:
                # commit replicated: uncommitted single-device arrays
                # would make XLA pick a fresh sharding per program
                tbl = jax.device_put(tbl, NamedSharding(self.mesh, P()))
            self.state["cache"]["block_tbl"] = tbl
            self._tbl_dirty = False

    def _ensure_decode_blocks(self) -> None:
        """Grow resident slots' block tables to cover the upcoming decode
        chunk (lazy allocation at block-boundary crossings) and resolve
        copy-on-write for shared blocks in each slot's write range. Under
        optimistic admission either step may preempt a victim — possibly
        one of the slots this loop has yet to visit."""
        for s in list(self._slot_req):
            if s not in self._slot_req:
                continue            # preempted by an earlier iteration
            r = self._slot_req[s]
            cap = len(r.prompt) + r.max_new_tokens - 1
            w = self._written[s]
            target = min(w + self.decode_block, cap)
            if not self._ensure(s, target):
                continue            # s itself was swapped out
            if s in self._slot_req:
                self._cow_guard(s, w, target)
        self._push_tables()

    # ------------------------------------------------------------------
    # Preemption: swap-out / swap-in of quantized blocks
    # ------------------------------------------------------------------

    def _attn_layer_caches(self):
        """Every attention layer's cache dict, in a stable order (the
        swap payload lists follow this order)."""
        for seg in self.state["cache"]["segments"]:
            for li in sorted(seg, key=int):
                yield seg[li]

    def _gather_blocks(self, ids) -> List[Dict]:
        """Pull the listed pool blocks' int8 payload + scales to host
        buffers, one dict per attention layer — one batched device_get
        for the whole swap, not a sync per (layer, leaf)."""
        idx = jnp.asarray(np.asarray(ids, np.int32))
        gathered = [{k: layer["self"][k][:, idx] for k in _POOL_KEYS}
                    for layer in self._attn_layer_caches()]
        return jax.device_get(gathered)

    def _swap_in_scatter(self, cache, payloads: List[Dict], idx, slot, w):
        """One donated program for the whole swap-in restore: every
        layer's payload scattered into its freshly allocated pool blocks
        (``idx``; sentinel pads drop) plus the slot's per-layer lengths /
        position rebuilt at ``w`` written tokens. Donating ``cache`` lets
        XLA rewrite the pools in place — the per-leaf ``.at[].set`` path
        this replaces materialized a second copy of every pool leaf."""
        li = 0
        segments = []
        for seg in cache["segments"]:
            new_seg = {}
            for lk in sorted(seg, key=int):
                sa = dict(seg[lk]["self"])
                pay = payloads[li]
                li += 1
                for k in _POOL_KEYS:
                    sa[k] = sa[k].at[:, idx].set(pay[k], mode="drop")
                sa["length"] = sa["length"].at[:, slot].set(w)
                new_seg[lk] = {"self": sa}
            segments.append(new_seg)
        return {"segments": segments,
                "position": cache["position"].at[slot].set(w),
                "block_tbl": cache["block_tbl"]}

    def _scatter_blocks(self, slot: int, ids, payload: List[Dict],
                        w: int) -> None:
        """Restore swapped payloads into freshly allocated pool blocks via
        the jitted donated scatter. The pad bucket (power of two) bounds
        compile variants across restores of different block counts."""
        m = len(ids)
        m_pad = _pow2_ceil(max(m, 1))
        idx = np.full((m_pad,), self.num_blocks, np.int32)   # pad: dropped
        idx[:m] = ids
        pad = m_pad - m

        def padded(a):
            if not pad:
                return jnp.asarray(a)
            widths = ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2)
            return jnp.asarray(np.pad(a, widths))

        payloads = [{k: padded(pay[k]) for k in _POOL_KEYS}
                    for pay in payload]
        self.state["cache"] = self._swap_in_jit(
            self.state["cache"], payloads, jnp.asarray(idx),
            jnp.int32(slot), jnp.int32(w))

    def _swap_out(self, slot: int) -> None:
        """Preempt ``slot``: gather its quantized blocks into a host
        buffer (int8 payloads move 4x cheaper than an fp32 cache would),
        release the blocks to the pool, and park the request on the swap
        queue for later restore. Works for decode residents and for the
        in-progress chunk job (which resumes from its last finished
        window)."""
        with self.trace.span("swap_out", slot=slot) as sp:
            job = next((j for j in self._tail_jobs if j["slot"] == slot),
                       None)
            w = job["c0"] if job is not None else self._written[slot]
            # only blocks holding written tokens travel; lazily grown tail
            # blocks past ``w`` hold nothing and are re-allocated on restore
            ids = self.alloc.owned(slot)[:self.alloc.blocks_for_tokens(w)]
            payload = self._gather_blocks(ids)
            nbytes = sum(a.nbytes for layer in payload
                         for a in layer.values())
            if job is not None:
                # the affinity key rides along so a restored tail job keeps
                # its chain "hot" for queued sharers
                rec = {"req": job["req"], "kind": "prefill", "w": w,
                       "akey": job.get("akey")}
                self._tail_jobs.remove(job)
            else:
                req = self._slot_req.pop(slot)
                self._written.pop(slot)
                # the live sampling key travels with the record so restore
                # resumes the slot's PRNG state verbatim. Today the key is
                # constant per slot (steps derive their keys by folding
                # n_gen into it), so rebuilding from
                # fold_in(PRNGKey(seed), uid) happened to match — carrying
                # it makes the invariant explicit instead of leaning on
                # that coincidence, and any future key-advancing sampler
                # keeps resume bit-exact.
                n_gen, out_row, last, key = jax.device_get(
                    (self.state["n_gen"][slot], self.state["out"][slot],
                     self.state["tokens"][slot, 0],
                     self.state["keys"][slot]))
                rec = {"req": req, "kind": "decode", "w": w,
                       "n_gen": int(n_gen), "out": np.asarray(out_row),
                       "last": int(last), "key": np.asarray(key)}
                self.state["active"] = \
                    self.state["active"].at[slot].set(False)
                # tokens decoded before preemption stream out now (the out
                # row is already on the host); the stream resumes at the
                # next harvest after restore — same tokens, same order
                self._emit_stream(req,
                                  rec["out"][req._streamed:rec["n_gen"]],
                                  done=False)
            rec["payload"] = payload
            rec["bytes"] = nbytes
            self.alloc.release(slot)
            self._admit_seq.pop(slot, None)
            self._tbl_dirty = True
            self._swapped.append(rec)
            self._host["preemptions"] += 1
            self._host["swap_out_bytes"] += nbytes
        self._host["swap_s"] += sp.dt
        self.trace.event("preempted", uid=rec["req"].uid,
                         kind=rec["kind"], bytes=nbytes)

    def _try_swap_in(self) -> None:
        """Restore swapped-out requests while slots and blocks allow.

        Policy — strictly FCFS over the swap queue, head-of-line: a
        later, smaller record is never restored ahead of the head even
        when it would fit right now and free a slot sooner. The head was
        already preempted once; letting smaller records jump the queue
        could starve it indefinitely behind a stream of short work, so
        fairness wins over pool utilization here (the cost is idle blocks
        while the head's worst case doesn't fit). The per-record gate is
        the request's full remaining worst case — a restore that could
        immediately become the next victim would thrash swap bandwidth
        for no progress.

        Every stop condition below is terminal for this call, so the free
        list is gathered once up front and popped as restores consume
        slots instead of being rebuilt per iteration."""
        free = self._free_slots()
        while self._swapped:
            rec = self._swapped[0]
            req = rec["req"]
            if rec["kind"] == "prefill" \
                    and len(self._tail_jobs) >= self.tail_batch:
                return
            if not free:
                return
            need = len(req.prompt) + req.max_new_tokens - 1
            if self.alloc.blocks_for_tokens(need) > self.alloc.free_blocks:
                return              # head doesn't fit: nobody jumps it
            self._restore(free.pop(0), rec)
            self._swapped.pop(0)
            self._note_residency()

    def _restore(self, slot: int, rec: Dict) -> None:
        """Swap a preempted request back in: fresh blocks, scattered
        payload, and the slot's sampling/output state rebuilt exactly as
        it was — greedy AND sampled decode resume bit-identically (the
        record carries the slot's PRNG key verbatim; see ``_swap_out``)."""
        with self.trace.span("swap_in", slot=slot,
                             kind=rec["kind"]) as sp:
            self._restore_body(slot, rec)
        self._host["swap_in_bytes"] += rec["bytes"]
        self._host["swap_s"] += sp.dt
        self.trace.event("swap_resumed", uid=rec["req"].uid,
                         kind=rec["kind"], bytes=rec["bytes"])

    def _restore_body(self, slot: int, rec: Dict) -> None:
        req, w = rec["req"], rec["w"]
        need = len(req.prompt) + req.max_new_tokens - 1
        if self.admission == "reserve":
            # preemption only triggers under optimistic admission, but a
            # reserve-mode restore must re-debit to stay accounted
            if not self.alloc.reserve(slot, need):
                raise RuntimeError("swap-in gate admitted an unreservable "
                                   "request — accounting bug")
        else:
            self.alloc.register(slot)
        self.alloc.ensure(slot, w)
        self._tbl_dirty = True
        ids = self.alloc.owned(slot)
        self._scatter_blocks(slot, ids, rec["payload"], w)
        self._admit_seq[slot] = self._seq
        self._seq += 1
        if rec["kind"] == "prefill":
            self._tail_jobs.append({"req": req, "slot": slot, "c0": w,
                                    "akey": rec.get("akey")})
        else:
            st = self.state
            keys = jnp.asarray(rec["key"])
            st["tokens"] = st["tokens"].at[slot, 0].set(rec["last"])
            st["out"] = st["out"].at[slot].set(jnp.asarray(rec["out"]))
            st["n_gen"] = st["n_gen"].at[slot].set(rec["n_gen"])
            st["active"] = st["active"].at[slot].set(True)
            st["eos"] = st["eos"].at[slot].set(req.eos_id)
            st["max_new"] = st["max_new"].at[slot].set(req.max_new_tokens)
            st["temp"] = st["temp"].at[slot].set(req.temperature)
            st["top_k"] = st["top_k"].at[slot].set(req.top_k)
            st["keys"] = st["keys"].at[slot].set(keys)
            self._slot_req[slot] = req
            self._written[slot] = w
            if self.spec is not None:
                # rebuild the draft cache from the consumed stream
                # (prompt + generated-so-far): swap records never carry
                # draft payloads
                consumed = np.concatenate(
                    [np.asarray(req.prompt, np.int32),
                     np.asarray(rec["out"][:rec["n_gen"] - 1], np.int32)])
                self._draft_prefill_rows([(slot, consumed)])

    # ------------------------------------------------------------------
    # Speculative decoding: host driver
    # ------------------------------------------------------------------

    def _draft_prefill_rows(self, rows) -> None:
        """Prefill the draft cache for freshly armed decode residents.

        ``rows``: (slot, consumed-token array) pairs — the prompt at
        admission / tail completion, or prompt + generated-so-far on a
        swap-in restore (the draft cache never travels with a swap
        record; it is rebuilt from tokens, which keeps swap bytes
        unchanged and the draft strictly a performance hint)."""
        if self.spec is None or not rows:
            return
        n = len(rows)
        n_pad = min(_pow2_ceil(n), self.slots)
        lens = np.ones((n_pad,), np.int32)
        lens[:n] = [len(t) for _, t in rows]
        L = -(-int(lens.max()) // self.prefill_bucket) * self.prefill_bucket
        toks = np.zeros((n_pad, L), np.int32)
        slot_idx = np.full((n_pad,), self.slots, np.int32)   # pad: dropped
        for i, (s, t) in enumerate(rows):
            toks[i, :len(t)] = t
            slot_idx[i] = s
        self._draft_cache = self._draft_admit_jit(
            self.draft_params, self._draft_cache, jnp.asarray(toks),
            jnp.asarray(lens), jnp.asarray(slot_idx))
        self._host["spec_draft_prefill_tokens"] += int(
            sum(len(t) for _, t in rows))

    def _spec_step(self) -> None:
        """One speculative wave over every decode resident.

        The draft proposes ``k`` tokens per slot (one compiled scan of
        the cheap model), the target verifies all residents' windows in
        ONE compiled call (``_spec_wave``), the accepted prefix plus one
        target token commit, and the rejected suffix rolls back — the
        wave re-clamps the device counters, this driver releases the
        whole blocks past each survivor's accepted extent
        (``BlockAllocator.trim``). Capacity/COW for the full window is
        secured up front exactly like a decode chunk, so preemption and
        prefix-shared (COW) blocks compose with the wave unchanged.
        """
        C = self.spec.k + 1
        tail = np.zeros((self.slots,), np.int32)
        hb_need = 1
        with self.trace.span("schedule", kind="spec"):
            for s in list(self._slot_req):
                if s not in self._slot_req:
                    continue        # preempted by an earlier iteration
                r = self._slot_req[s]
                w = self._written[s]
                # the window is clamped to the row's remaining max_new
                # budget, so peak occupancy never exceeds the
                # admission-time worst case (prompt + max_new - 1) — no
                # spec headroom
                t = min(C, len(r.prompt) + r.max_new_tokens - 1 - w)
                if not self._ensure(s, w + t):
                    continue        # s itself was swapped out
                if s not in self._slot_req \
                        or not self._cow_guard(s, w, w + t):
                    continue
                tail[s] = t
                hb_need = max(hb_need, self.alloc.blocks_for_tokens(w + t))
            for s in range(self.slots):
                # a slot whose capacity was secured and then swapped out
                # by a LATER iteration's preemption must ride the wave
                # fully masked (its table row is already parked on the
                # sentinel)
                if tail[s] and s not in self._slot_req:
                    tail[s] = 0
        if not self._slot_req:
            return
        if not tail.any():
            # no slot has budget to draft — every resident finished at
            # admission (max_new == 1); they still need harvesting or
            # they would sit in their slots forever
            self._harvest()
            return
        self._push_tables()
        greedy_only = all(r.temperature <= 0.0
                          for r in self._slot_req.values())
        n_gen_before = {s: self._written[s] - len(r.prompt) + 1
                        for s, r in self._slot_req.items()}
        st = self.state
        with self.trace.span("spec_draft", rows=len(self._slot_req)):
            dtoks, dq, self._draft_cache = self._draft_jit(
                self.draft_params, self._draft_cache, st["tokens"],
                st["temp"], st["top_k"], st["keys"], st["n_gen"],
                st["cache"]["position"], greedy_only)
        with self.trace.span("spec_verify"):
            hb = min(_pow2_ceil(hb_need), self.table_len)
            self.state = self._spec_jit(self.params, self.state, dtoks, dq,
                                        jnp.asarray(tail), hb, greedy_only)
            # ONE host sync per wave (like a decode chunk): the harvest's
            # (active, n_gen) fetch also yields each row's committed count
            with self.trace.span("sync"):
                act, n_gen = jax.device_get((self.state["active"],
                                             self.state["n_gen"]))
        drafted = accepted = 0
        for s, n0 in n_gen_before.items():
            m_s = int(n_gen[s]) - n0
            if m_s > 0:
                # rows committing nothing were inactive the whole wave
                # (finished at admission, e.g. EOS on the first token) —
                # their proposals were never in play, so counting them
                # as drafted(-and-rolled-back) or letting their m = 0
                # subtract from the accepted total would corrupt the
                # accept rate the CI gate watches
                drafted += max(int(tail[s]) - 1, 0)
                accepted += m_s - 1
        self._host["spec_waves"] += 1
        self._host["spec_drafted"] += drafted
        self._host["spec_accepted"] += accepted
        self._host["spec_rolled_back"] += drafted - accepted
        self._harvest(act, n_gen)
        # rollback, host side: finished slots were fully released by the
        # harvest; survivors drop the whole blocks past their accepted
        # extent (freshly grown for this wave, so never shared/indexed)
        for s in list(self._slot_req):
            if self.alloc.trim(s, self._written[s]):
                self._tbl_dirty = True

    def _harvest(self, act=None, n_gen=None) -> None:
        """Admission-boundary sync: pull finished slots' token buffers.
        ``act``/``n_gen`` may be passed pre-fetched (the spec step pulls
        them for its acceptance accounting) to keep one sync per step."""
        if not self._slot_req:
            return
        with self.trace.span("harvest"):
            self._harvest_body(act, n_gen)

    def _harvest_body(self, act, n_gen) -> None:
        if act is None:
            with self.trace.span("sync"):
                act, n_gen = jax.device_get((self.state["active"],
                                             self.state["n_gen"]))
        if self._paged:
            # exact per-slot progress from the device counter: each decode
            # step writes the KV of the token it consumes, so a slot holds
            # prompt + (n_gen - 1) written tokens (the newest sampled token
            # is not yet committed). Advancing by a flat ``decode_block``
            # instead over-counts any slot that did not run the full chunk
            # (armed by a tail wave or restored mid-window while others
            # kept the loop alive) — and an over-counted ``_written`` makes
            # a later swap-out gather unwritten tail blocks as payload.
            for s, r in self._slot_req.items():
                if act[s]:
                    self._written[s] = len(r.prompt) + int(n_gen[s]) - 1
        finished = [s for s in self._slot_req if not act[s]]
        # incremental token drain: streaming residents surface the tokens
        # decoded since the last harvest (decode_block / spec-wave
        # granularity) without waiting for finish — their rows ride the
        # same batched device_get as the finished slots' buffers
        streaming = [s for s, r in self._slot_req.items()
                     if act[s] and r.on_tokens is not None
                     and int(n_gen[s]) > r._streamed]
        fetch = finished + streaming
        if not fetch:
            return
        with self.trace.span("sync", rows=len(fetch)):
            all_rows = jax.device_get(
                self.state["out"][np.asarray(fetch)])
        rows = all_rows[:len(finished)]
        for i, s in enumerate(streaming):
            r = self._slot_req[s]
            self._emit_stream(r, all_rows[len(finished) + i,
                                          r._streamed:int(n_gen[s])],
                              done=False)
        for i, s in enumerate(finished):
            req = self._slot_req.pop(s)
            req.generated = rows[i, :n_gen[s]].tolist()
            req.done = True
            self._emit_stream(req, req.generated[req._streamed:], done=True)
            self.scheduler.on_finished(req)
            tm = getattr(req, "_timing", None)
            if tm is not None and tm.admit_t is not None \
                    and tm.finish_t is not None:
                self.metrics.observe_finished(
                    tm.latency, tm.finish_t - tm.admit_t,
                    len(req.generated))
            if self._paged:
                if self.prefix_cache and req.generated:
                    # content-address the decoded stream too (the last
                    # sampled token is never written): a follow-up prompt
                    # extending prompt+completion — a chat turn, say —
                    # reuses these blocks. [0, true_w) is intact even for
                    # an early-EOS slot: its post-EOS masked steps only
                    # rewrote positions >= true_w.
                    true_w = len(req.prompt) + int(n_gen[s]) - 1
                    content = np.concatenate(
                        [np.asarray(req.prompt, np.int32),
                         np.asarray(req.generated[:-1], np.int32)])
                    self.alloc.register_prefix(s, content, true_w)
                self.alloc.release(s)       # blocks return to the pool
                self._written.pop(s, None)
                self._admit_seq.pop(s, None)
                self._tbl_dirty = True      # row parked on the sentinel

    # ------------------------------------------------------------------
    # Drive
    # ------------------------------------------------------------------

    def step(self) -> None:
        """One admission + one batched tail-wave window of the in-progress
        tail/chunked admissions + one decode round (a speculative
        draft+verify wave when spec is enabled, else one on-device decode
        chunk) + harvest."""
        self._step_idx += 1
        self.trace.step = self._step_idx
        with self.trace.span("step"):
            with self.trace.span("admit"):
                self._admit()
            if self._tail_jobs:
                self._advance_tail_jobs()
            if self._slot_req:
                with self.trace.span("decode") as sp:
                    if self.spec is not None:
                        self._spec_step()  # drafts + verify + harvest+trim
                    else:
                        greedy_only = all(r.temperature <= 0.0
                                          for r in self._slot_req.values())
                        if self._paged:
                            with self.trace.span("schedule", kind="decode"):
                                self._ensure_decode_blocks()
                        with self.trace.span("decode_chunk",
                                             rows=len(self._slot_req)):
                            self.state = self._decode_jit(
                                self.params, self.state, greedy_only)
                        # the harvest's device_get doubles as the sync
                        self._harvest()
                self._host["decode_s"] += sp.dt
                self._host["decode_rounds"] += 1
                self._note_rate("_pred_round_s", sp.dt)

    def _flush_partial(self) -> None:
        """Surface still-resident slots' tokens (budget-aborted drain):
        their buffers are on device and already counted in the stats.
        Swapped-out requests surface the tokens captured at preemption."""
        for rec in self._swapped:
            if rec["kind"] == "decode":
                rec["req"].generated = rec["out"][:rec["n_gen"]].tolist()
        if not self._slot_req:
            return
        resident = sorted(self._slot_req)
        n_gen = jax.device_get(self.state["n_gen"])
        rows = jax.device_get(self.state["out"][np.asarray(resident)])
        for i, s in enumerate(resident):
            self._slot_req[s].generated = rows[i, :n_gen[s]].tolist()

    def run_until_drained(self, max_steps: int = 10_000) -> Dict:
        """Serve until queue + slots are empty; ``max_steps`` bounds the
        total decode-step budget (chunk-granular). If the budget aborts the
        drain, in-flight requests keep their partial ``generated`` output
        (``done`` stays False)."""
        chunks = 0
        while ((self.scheduler.pending or self._slot_req
                or self._tail_jobs or self._swapped)
               and chunks * self.decode_block < max_steps):
            self.step()
            chunks += 1
        self._flush_partial()
        return self.stats()

    # ------------------------------------------------------------------
    # decode_block auto-tuning
    # ------------------------------------------------------------------

    def _probe_state(self) -> Dict:
        """Fresh state with every slot armed to run a full decode chunk."""
        st = self._blank_state()
        st["active"] = jnp.ones((self.slots,), bool)
        st["max_new"] = jnp.full((self.slots,), self.max_new_cap, jnp.int32)
        return self._shard_state(st)

    def _probe_decode_block(self, candidates=(4, 8, 16, 32)) -> int:
        """Measured decode-step latency probe (``decode_block="auto"``).

        Times one compiled decode chunk at lengths 1 and 8 to split the
        per-chunk cost into a fixed part (dispatch + the host sync that
        follows every chunk) and a per-step part, then picks the smallest
        candidate whose amortized fixed cost is under 15% of compute —
        bigger chunks waste steps on slots that finish mid-chunk, so we
        want the smallest chunk that the host overhead can afford.
        Passing an int ``decode_block`` to the constructor overrides this.
        """
        def chunk_time(c: int) -> float:
            self.decode_block = c
            # donate each probe state: the probe must not stack extra full
            # cache pytrees on top of the engine's own state (the paged
            # pool can be sized near device HBM)
            fn = self._under_mesh(
                jax.jit(self._decode_chunk, static_argnums=(2,),
                        donate_argnums=(1,)))
            jax.block_until_ready(
                fn(self.params, self._probe_state(), True)["tokens"])
            best = float("inf")
            for _ in range(3):          # min-of-N: shed host scheduler noise
                st = self._probe_state()
                t0 = time.perf_counter()
                jax.block_until_ready(fn(self.params, st, True)["tokens"])
                best = min(best, time.perf_counter() - t0)
            return best

        t1 = chunk_time(1)
        t8 = chunk_time(8)
        per_step = max((t8 - t1) / 7.0, 1e-9)
        overhead = max(t1 - per_step, 0.0)
        for c in candidates:
            if overhead <= 0.15 * c * per_step:
                return c
        return candidates[-1]

    # ------------------------------------------------------------------
    # Stats
    # ------------------------------------------------------------------

    def stats(self) -> Dict:
        """Serving counters and latency stats (one host sync).

        Every key, so bench parsers don't reverse-engineer them:

        ==========================  =========================================
        key                         meaning
        ==========================  =========================================
        tokens_out                  tokens returned to requests (first
                                    prefill token + committed decode tokens)
        decode_steps                device decode steps executed
        decode_s / decode_step_s    wall seconds in decode / per device step
        decode_rounds               engine steps that ran a decode chunk or
                                    spec wave (the shed predictor's divisor)
        prefill_calls               compiled prefill/tail-finish admissions
        prefill_chunks              tail-wave rows advanced (batched chunks)
        prompt_tokens_prefilled     prompt tokens actually computed (excludes
                                    prefix-cache hits)
        prefill_s                   wall seconds in prefill + tail waves
        prefix_hit_tokens           prompt tokens served from the prefix
                                    cache instead of being prefilled
        prefix_lookups/_hit_blocks  prefix-index probes / whole blocks hit
        prefix_cache_blocks         evictable blocks alive only in the index
        prefix_evictions            indexed blocks reclaimed by allocation
        cow_copies                  copy-on-write block clones
        preemptions                 swap-outs (optimistic admission)
        swap_out_bytes/_in_bytes    quantized bytes moved by swaps
        swap_s                      wall seconds in swap gather/restore
        max_residents               peak concurrently resident requests
        pending_requests            requests waiting in the scheduler queue
        resident_requests           requests resident in slots (decode +
                                    in-flight tail prefills)
        swapped_requests            preempted requests awaiting restore
        free_blocks                 free cache blocks in the paged pool
        pool_occupancy              fraction of pool blocks in use
        cache_tokens_capacity       pool/stripe capacity in tokens
        peak_cache_tokens/_bytes    peak occupancy in tokens / bytes
        cache_bytes                 total cache allocation
        decode_block(_mode)         chunk length and how it was chosen
                                    ("fixed" / "auto" / "spec")
        mesh_shape / tp_degree      serving mesh axis sizes (None off-mesh)
                                    and the "model"-axis TP degree
        per_device_pool_bytes       one device's share of the KV cache
                                    (sharded leaves count shard bytes)
        per_device_weight_bytes     one device's share of the served
                                    weights (w4a8: the packed planes)
        weights_layout              serve weight layout ("bf16" / "w4a8")
        packed_weight_bytes         int4-packed weight + scale + bias bytes
                                    the w4a8 forward streams (0 under bf16)
        weight_hbm_saved_bytes      bf16 weight bytes per forward the packed
                                    layout no longer reads (0 under bf16)
        spec_waves/_drafted/        verify-waves run, draft tokens proposed
        _accepted/_rolled_back      / accepted / rolled back (spec only)
        spec_accept_rate            accepted / drafted (spec only)
        spec_k/_draft_layers/       the resolved SpecConfig actually
        _accept_mode                serving (spec only)
        requests_finished           requests fully served
        requests_shed               requests rejected by SLO shed-load
        requests_downgraded         requests demoted to best-effort
        ttft_p50_s/p95_s            submit -> first-token percentiles
        latency_p50_s/p95_s         submit -> finish percentiles
        ==========================  =========================================

        Paged-only keys appear only with ``kv_layout="paged"``; spec-only
        keys only when ``spec`` is configured.

        Every value is a native Python scalar / container — the dict
        round-trips through ``json.dumps`` unchanged, which is what the
        ``/v1/stats`` and ``/v1/metrics`` HTTP surfaces serve.
        """
        steps, committed = jax.device_get((self.state["steps"],
                                           self.state["committed"]))
        d = dict(self._host)
        prefill_tokens = d.pop("prefill_tokens")
        d["prompt_tokens_prefilled"] = d.pop("prompt_tokens")
        d["decode_steps"] = int(steps)
        d["tokens_out"] = int(committed) + prefill_tokens
        d["decode_step_s"] = (d["decode_s"] / max(int(steps), 1))
        d["max_residents"] = self._max_residents
        d["decode_block"] = self.decode_block
        d["decode_block_mode"] = self._decode_block_mode
        d["mesh_shape"] = (dict(self.mesh.shape)
                           if self.mesh is not None else None)
        d["tp_degree"] = self.tp
        d["per_device_pool_bytes"] = _device_local_bytes(
            self.state["cache"]["segments"])
        d["per_device_weight_bytes"] = _device_local_bytes(
            self._served_weight_leaves())
        d["weights_layout"] = self.weights_layout
        d["packed_weight_bytes"] = self._w4a8_bytes["packed"]
        d["weight_hbm_saved_bytes"] = max(
            self._w4a8_bytes["replaced"] - self._w4a8_bytes["packed"], 0)
        if self.spec is not None:
            drafted = d["spec_drafted"]
            d["spec_accept_rate"] = (d["spec_accepted"] / drafted
                                     if drafted else 0.0)
            d["spec_k"] = self.spec.k
            d["spec_draft_layers"] = self.spec.resolved_layers(self.cfg)
            d["spec_accept_mode"] = self.spec.accept_mode
        d["pending_requests"] = self.scheduler.pending
        d["resident_requests"] = (len(self._slot_req)
                                  + len(self._tail_jobs))
        d["swapped_requests"] = len(self._swapped)
        if self._paged:
            d["prefix_lookups"] = self.alloc.prefix_lookups
            d["prefix_hit_blocks"] = self.alloc.prefix_hit_blocks
            d["prefix_cache_blocks"] = self.alloc.cached_blocks
            d["prefix_evictions"] = self.alloc.prefix_evictions
            d["free_blocks"] = self.alloc.free_blocks
            d["pool_occupancy"] = (1.0 - self.alloc.free_blocks
                                   / max(self.num_blocks, 1))
            cap_tokens = self.num_blocks * self.block_size
            d["cache_tokens_capacity"] = cap_tokens
            d["peak_cache_tokens"] = self.alloc.peak_blocks * self.block_size
        else:
            cap_tokens = self.slots * self.cache_len
            d["cache_tokens_capacity"] = cap_tokens
            # a dense stripe is reserved whole for a slot's lifetime:
            # reservation *is* usage, fragmentation included — but only
            # for the stripes that were actually occupied at peak
            d["peak_cache_tokens"] = self._max_residents * self.cache_len
        d["cache_bytes"] = self._cache_bytes
        d["peak_cache_bytes"] = int(
            self._cache_bytes * d["peak_cache_tokens"] / max(cap_tokens, 1))
        d["compile_variants"] = self.compile_variant_counts()
        d.update(self.scheduler.stats())
        return _jsonable(d)

    # ------------------------------------------------------------------
    # Compiled-graph introspection (the `repro.analysis` audit surface)
    # ------------------------------------------------------------------

    def compile_variant_counts(self) -> Dict[str, int]:
        """Live compiled-variant count per wave family — fresh compiles
        observed through the ``_wave`` registry since construction. The
        retrace-budget audit and operators read the same numbers."""
        return {f: len(v) for f, v in self._wave_variants.items()}

    def wave_variant_signatures(self) -> Dict[str, List[str]]:
        """Per-family shape signatures of every call that compiled a new
        variant, in compile order — names the offending shape when a
        family blows its retrace budget."""
        return {f: list(v) for f, v in self._wave_variants.items()}

    def pool_shard_elems(self) -> int:
        """Per-device element count of the largest int8 cache plane —
        the reference size for the dequant-placement audit (a wholesale
        dequant materializes at least one full plane in floats)."""
        best = 0
        for leaf in jax.tree.leaves(self.state["cache"]):
            if leaf.dtype != jnp.int8:
                continue
            sh = getattr(leaf, "sharding", None)
            if sh is not None and hasattr(sh, "shard_shape"):
                n = int(np.prod(sh.shard_shape(leaf.shape)))
            else:
                n = int(leaf.size)
            best = max(best, n)
        return best

    def compiled_waves(self, buckets: int = 1) -> List[Dict]:
        """Enumerate every live wave family as an auditable unit.

        Each entry is a plain dict (no analysis import here — the
        auditor duck-types engines):

          family   — registry name ("decode", "admit_paged", ...)
          label    — family plus the representative statics
          lower    — zero-arg closure returning the ``jax.jit(...).lower``
                     of one representative call, built from
                     ``ShapeDtypeStruct``s that mirror the live arrays
                     (shapes, dtypes, shardings) — nothing materializes
          donated  — leaf inventory of the donated argument(s):
                     [{path, dtype, bytes}] with per-device byte counts,
                     so the donation rule can name a leaked plane

        ``buckets`` enumerates that many power-of-two prefill length
        buckets (L = prefill_bucket * 2**b) for the admission families.
        Fresh jit objects are lowered, so the serving jits' compile
        caches — and ``compile_variant_counts`` — are untouched.
        """
        def sds(tree):
            return jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(
                    a.shape, a.dtype, sharding=getattr(a, "sharding", None)),
                tree)

        def arr(shape, dtype):
            return jax.ShapeDtypeStruct(shape, dtype)

        def inventory(tree) -> List[Dict]:
            flat, _ = jax.tree_util.tree_flatten_with_path(tree)
            out = []
            for path, a in flat:
                sh = getattr(a, "sharding", None)
                if sh is not None and hasattr(sh, "shard_shape"):
                    n = int(np.prod(sh.shard_shape(a.shape)))
                else:
                    n = int(np.prod(a.shape))
                dt = np.dtype(a.dtype)
                out.append({"path": jax.tree_util.keystr(path),
                            "dtype": dt.name, "bytes": n * dt.itemsize})
            return out

        params = sds(self.params)
        state = sds(self.state)
        cache = state["cache"]
        S = self.slots
        mesh = self.mesh
        waves: List[Dict] = []

        def add(family, fn, args, *, static_argnums=(), donate_argnums=(),
                label=None):
            jitted = jax.jit(fn, static_argnums=static_argnums,
                             donate_argnums=donate_argnums)

            def lower(jitted=jitted, args=args):
                if mesh is not None:
                    with mesh:
                        return jitted.lower(*args)
                return jitted.lower(*args)

            donated: List[Dict] = []
            for dn in donate_argnums:
                donated += inventory(args[dn])
            waves.append({"family": family, "label": label or family,
                          "lower": lower, "donated": donated})

        add("decode", self._decode_chunk, (params, state, False),
            static_argnums=(2,), donate_argnums=(1,),
            label="decode[greedy=False]")
        for b in range(max(buckets, 1)):
            L = self.prefill_bucket * (1 << b)
            n_pad = min(_pow2_ceil(S), S)
            common = (arr((n_pad, L), jnp.int32), arr((n_pad,), jnp.int32),
                      arr((n_pad,), jnp.int32))
            tail = (arr((n_pad,), jnp.int32), arr((n_pad,), jnp.int32),
                    arr((n_pad,), jnp.float32), arr((n_pad,), jnp.int32),
                    arr((n_pad, 2), jnp.uint32), False)
            if self._paged:
                nb = self.alloc.blocks_for_tokens(L)
                add("admit_paged", self._admit_batch_paged,
                    (params, state, *common, arr((n_pad, nb), jnp.int32),
                     *tail),
                    static_argnums=(11,), donate_argnums=(1,),
                    label=f"admit_paged[n={n_pad},L={L}]")
            else:
                add("admit_dense", self._admit_batch,
                    (params, state, *common, *tail),
                    static_argnums=(10,), donate_argnums=(1,),
                    label=f"admit_dense[n={n_pad},L={L}]")
        if self._paged:
            C = self.prefill_chunk
            hb = min(_pow2_ceil(self.alloc.blocks_for_tokens(C)),
                     self.table_len)
            add("tail", self._tail_wave,
                (params, cache, arr((1, C), jnp.int32),
                 arr((1,), jnp.int32), arr((1,), jnp.int32),
                 arr((1,), jnp.int32), hb),
                static_argnums=(6,), donate_argnums=(1,),
                label=f"tail[rows=1,C={C},hb={hb}]")
            payloads = []
            for layer in self._attn_layer_caches():
                pay = {}
                for k in _POOL_KEYS:
                    shape = list(layer["self"][k].shape)
                    shape[1] = 1            # m_pad=1 restored blocks
                    pay[k] = arr(tuple(shape), layer["self"][k].dtype)
                payloads.append(pay)
            add("swap_in", self._swap_in_scatter,
                (cache, payloads, arr((1,), jnp.int32),
                 arr((), jnp.int32), arr((), jnp.int32)),
                donate_argnums=(0,), label="swap_in[m=1]")
            add("cow", self._cow_copy,
                (cache, arr((1,), jnp.int32), arr((1,), jnp.int32)),
                donate_argnums=(0,), label="cow[n=1]")
        if self.spec is not None:
            dparams = sds(self.draft_params)
            dcache = sds(self._draft_cache)
            k = self.spec.k
            add("spec_draft", self._spec_draft,
                (dparams, dcache, arr((S, 1), jnp.int32),
                 arr((S,), jnp.float32), arr((S,), jnp.int32),
                 arr((S, 2), jnp.uint32), arr((S,), jnp.int32),
                 arr((S,), jnp.int32), False),
                static_argnums=(8,), donate_argnums=(1,),
                label="spec_draft[greedy=False]")
            dq = (arr((S, k, self.cfg.vocab_size), jnp.float32)
                  if self.spec.accept_mode == "rejection" else None)
            hb = min(_pow2_ceil(self.alloc.blocks_for_tokens(
                self.max_seq_len)), self.table_len)
            add("spec_verify", self._spec_wave,
                (params, state, arr((S, k), jnp.int32), dq,
                 arr((S,), jnp.int32), hb, False),
                static_argnums=(5, 6), donate_argnums=(1,),
                label=f"spec_verify[hb={hb},greedy=False]")
            n_pad = min(_pow2_ceil(S), S)
            L = self.prefill_bucket
            add("admit_draft", self._draft_admit,
                (dparams, dcache, arr((n_pad, L), jnp.int32),
                 arr((n_pad,), jnp.int32), arr((n_pad,), jnp.int32)),
                donate_argnums=(1,), label=f"admit_draft[n={n_pad},L={L}]")
        return waves
