"""Batched serving engine over the quantized cache.

Slot-based continuous batching (vLLM-lite, sized for the framework's serve
path): a fixed number of slots share one decode step; finished sequences
free their slot, queued requests prefill into it. All state (int8 KV /
recurrent caches) lives in one pytree so the decode step stays a single
compiled program.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.qat import make_ctx
from repro.models import decode_step, init_cache, prefill


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                    # -1: never stops early
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, policy: str = "A8d-C8-W4",
                 slots: int = 8, cache_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.ctx = make_ctx(policy)
        self.slots = slots
        self.cache_len = cache_len
        self.cache = init_cache(cfg, self.ctx, slots, cache_len)
        self.active: Dict[int, Request] = {}        # slot -> request
        self.queue: List[Request] = []
        self.last_tokens = jnp.zeros((slots, 1), jnp.int32)
        self._decode = jax.jit(
            lambda p, t, c: decode_step(cfg, p, self.ctx, t, c))
        self._stats = {"tokens_out": 0, "decode_steps": 0, "decode_s": 0.0}

    # ---- request lifecycle ----
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [s for s in range(self.slots) if s not in self.active]

    def _admit(self) -> None:
        """Prefill queued requests into free slots (per-slot prefill)."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
            logits, cache1 = prefill(self.cfg, self.params, self.ctx, batch,
                                     cache_budget=self.cache_len)
            first = int(jnp.argmax(logits[0, -1]))
            req.generated.append(first)
            self._write_slot(slot, cache1)
            self.last_tokens = self.last_tokens.at[slot, 0].set(first)
            self.active[slot] = req

    def _write_slot(self, slot: int, cache1) -> None:
        """Copy a freshly prefilled (batch=1) cache into slot ``slot``."""
        def cp(dst, src):
            if dst.ndim == src.ndim and dst.shape[0] == self.slots:
                return dst.at[slot].set(src[0])
            # scan-stacked leaves: (rep, B, ...) vs (rep, 1, ...)
            return dst.at[:, slot].set(src[:, 0])
        # position vector is (slots,) vs (1,)
        self.cache = jax.tree.map(
            lambda d, s: d.at[slot].set(s[0]) if d.ndim == 1 else cp(d, s),
            self.cache, cache1)

    # ---- decode ----
    def step(self) -> None:
        self._admit()
        if not self.active:
            return
        t0 = time.perf_counter()
        logits, self.cache = self._decode(self.params, self.last_tokens,
                                          self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        self._stats["decode_s"] += time.perf_counter() - t0
        self._stats["decode_steps"] += 1
        for slot, req in list(self.active.items()):
            tok = int(nxt[slot])
            req.generated.append(tok)
            self._stats["tokens_out"] += 1
            if tok == req.eos_id or len(req.generated) >= req.max_new_tokens:
                req.done = True
                del self.active[slot]
            else:
                self.last_tokens = self.last_tokens.at[slot, 0].set(tok)

    def run_until_drained(self, max_steps: int = 10_000) -> Dict:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return dict(self._stats)
