"""Continuous-batching serve engine v2 over the quantized cache.

vLLM-style slot engine, rebuilt so the host only touches the device at
admission boundaries:

* **Batched prefill** — the scheduler hands over up to ``slots`` queued
  requests at once; they are right-padded to a length bucket and prefilled
  in one compiled call (per-row ``lengths`` keep the cache and logits exact;
  see ``models.prefill``). Architectures with recurrent blocks, where
  padding would corrupt the scan state, admit exact-length groups instead.
* **On-device decode loop** — sampling (greedy / temperature / top-k),
  per-slot EOS + max-token tracking, and the generated-token buffers all
  live in the device state pytree; ``lax.while_loop`` runs up to
  ``decode_block`` steps per compiled call and stops early once every slot
  is inactive. No ``int(...)`` / ``np.asarray`` per token — the host syncs
  once per chunk to harvest finished slots and admit new work.
* **Scheduler** (``serve.scheduler``) — pluggable FCFS / shortest-prompt
  policies plus per-request TTFT/latency accounting.

All per-slot cache state (int8 KV / recurrent) stays in one pytree so the
decode chunk is a single compiled program regardless of slot occupancy;
inactive slots ride along masked (their commits are dropped) and are
recycled by the next admission.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTENTION_BLOCKS, BLOCK_ATTN, ModelConfig
from repro.core.qat import make_ctx
from repro.models import decode_step, init_cache, prefill
from repro.serve.sampling import TOP_K_CAP, fold_step, sample_tokens
from repro.serve.scheduler import Scheduler


@dataclass(eq=False)                    # identity equality: the ndarray
class Request:                          # prompt field breaks value __eq__
    uid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                    # -1: never stops early
    temperature: float = 0.0            # <= 0: greedy
    top_k: int = 0                      # 0: no top-k filtering
    seed: int = 0
    generated: List[int] = field(default_factory=list)
    done: bool = False
    _arrival: int = 0                   # set by the scheduler


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, policy: str = "A8d-C8-W4",
                 slots: int = 8, cache_len: int = 512,
                 max_new_cap: int = 256, decode_block: int = 8,
                 sched_policy: str = "fcfs", prefill_bucket: int = 16):
        self.cfg = cfg
        self.params = params
        self.ctx = make_ctx(policy)
        self.slots = slots
        self.cache_len = cache_len
        self.max_new_cap = max_new_cap
        self.decode_block = decode_block
        self.prefill_bucket = prefill_bucket
        self.scheduler = Scheduler(sched_policy)
        # right-padded batched prefill is exact only when every block is
        # attention (causality isolates real tokens from padding); recurrent
        # scans absorb pad steps into their state, so those admit
        # exact-length groups instead.
        self._pad_ok = (all(k in ATTENTION_BLOCKS for k in cfg.block_pattern)
                        and not cfg.is_encdec)
        # full (non-sliding) attention caches are a hard capacity bound;
        # ring-buffered / recurrent state is not
        self._cache_bound = (BLOCK_ATTN in cfg.block_pattern
                             and not cfg.sliding_window)
        # greedy_only is a trace-time constant: two compiled variants at
        # most. The state pytree is donated so the slot caches are updated
        # in place (no 2x cache copy per chunk; a no-op on backends
        # without donation support, e.g. CPU).
        self._decode_jit = jax.jit(self._decode_chunk, static_argnums=(2,),
                                   donate_argnums=(1,))
        self._admit_jit = jax.jit(self._admit_batch, static_argnums=(10,),
                                  donate_argnums=(1,))
        self.reset()

    # ------------------------------------------------------------------
    # Compiled programs
    # ------------------------------------------------------------------

    def _decode_chunk(self, params, state, greedy_only):
        """Up to ``decode_block`` decode steps, entirely on device."""
        slots, cap = self.slots, self.max_new_cap

        def cond(st):
            return (st["i"] < self.decode_block) & jnp.any(st["active"])

        def body(st):
            logits, cache = decode_step(self.cfg, params, self.ctx,
                                        st["tokens"], st["cache"])
            keys_t = fold_step(st["keys"], st["n_gen"])
            toks = sample_tokens(logits[:, -1], keys_t, st["temp"],
                                 st["top_k"], greedy_only=greedy_only)
            act = st["active"]
            # commit only active slots; inactive rows scatter out of range
            row = jnp.where(act, st["n_gen"], cap)
            out = st["out"].at[jnp.arange(slots), row].set(toks, mode="drop")
            n_gen = st["n_gen"] + act.astype(jnp.int32)
            still = act & (toks != st["eos"]) & (n_gen < st["max_new"])
            return {**st, "cache": cache,
                    "tokens": jnp.where(act[:, None], toks[:, None],
                                        st["tokens"]),
                    "out": out, "n_gen": n_gen, "active": still,
                    "steps": st["steps"] + 1,
                    "committed": st["committed"] + jnp.sum(
                        act.astype(jnp.int32)),
                    "i": st["i"] + 1}

        st = {**state, "i": jnp.int32(0)}
        st = jax.lax.while_loop(cond, body, st)
        st.pop("i")
        return st

    def _admit_batch(self, params, state, tokens, lengths, slot_idx, eos,
                     max_new, temp, top_k, keys, greedy_only):
        """One batched prefill + scatter of n fresh rows into their slots.

        Rows may be padding (the host pads the admission batch up to a
        power of two to bound compile variants); their ``slot_idx`` is
        out of range and every scatter drops them.
        """
        batch = {"tokens": tokens}
        if self._pad_ok:
            batch["lengths"] = lengths
        logits, cache_n = prefill(self.cfg, params, self.ctx, batch,
                                  cache_budget=self.cache_len)
        n = tokens.shape[0]
        first = sample_tokens(logits[:, 0],
                              fold_step(keys, jnp.zeros((n,), jnp.int32)),
                              temp, top_k, greedy_only=greedy_only)
        cache = state["cache"]
        # cache leaves are scan-stacked (repeat, slots, ...); position (slots,)
        segments = [jax.tree.map(
            lambda d, s: d.at[:, slot_idx].set(s, mode="drop"), ds, ss)
            for ds, ss in zip(cache["segments"], cache_n["segments"])]
        new_cache = {"segments": segments,
                     "position": cache["position"].at[slot_idx].set(
                         cache_n["position"], mode="drop")}
        out = state["out"].at[slot_idx].set(0, mode="drop")
        return {**state, "cache": new_cache,
                "tokens": state["tokens"].at[slot_idx, 0].set(first,
                                                              mode="drop"),
                "out": out.at[slot_idx, 0].set(first, mode="drop"),
                "n_gen": state["n_gen"].at[slot_idx].set(1, mode="drop"),
                "active": state["active"].at[slot_idx].set(
                    (first != eos) & (max_new > 1), mode="drop"),
                "eos": state["eos"].at[slot_idx].set(eos, mode="drop"),
                "max_new": state["max_new"].at[slot_idx].set(max_new,
                                                             mode="drop"),
                "temp": state["temp"].at[slot_idx].set(temp, mode="drop"),
                "top_k": state["top_k"].at[slot_idx].set(top_k, mode="drop"),
                "keys": state["keys"].at[slot_idx].set(keys, mode="drop")}

    # ------------------------------------------------------------------
    # Request lifecycle (host side)
    # ------------------------------------------------------------------

    def reset(self) -> None:
        """Clear all serving state but keep compiled programs warm."""
        slots = self.slots
        self.state = {
            "cache": init_cache(self.cfg, self.ctx, slots, self.cache_len),
            "tokens": jnp.zeros((slots, 1), jnp.int32),
            "out": jnp.zeros((slots, self.max_new_cap), jnp.int32),
            "n_gen": jnp.zeros((slots,), jnp.int32),
            "active": jnp.zeros((slots,), bool),
            "eos": jnp.full((slots,), -1, jnp.int32),
            "max_new": jnp.ones((slots,), jnp.int32),
            "temp": jnp.zeros((slots,), jnp.float32),
            "top_k": jnp.zeros((slots,), jnp.int32),
            "keys": jnp.zeros((slots, 2), jnp.uint32),
            "steps": jnp.int32(0),
            "committed": jnp.int32(0),
        }
        self._slot_req = {}
        self.scheduler = Scheduler(self.scheduler.policy)
        self._host = {"decode_s": 0.0, "prefill_s": 0.0, "prefill_calls": 0,
                      "prefill_tokens": 0}

    def submit(self, req: Request) -> None:
        if req.max_new_tokens > self.max_new_cap:
            raise ValueError(
                f"max_new_tokens={req.max_new_tokens} exceeds this engine's "
                f"max_new_cap={self.max_new_cap} (the on-device token "
                f"buffer); construct ServeEngine with a larger max_new_cap")
        if req.top_k > TOP_K_CAP:
            raise ValueError(f"top_k={req.top_k} exceeds TOP_K_CAP="
                             f"{TOP_K_CAP} (static sampling bound)")
        # peak cache occupancy is prompt + max_new - 1: the last sampled
        # token is returned but its KV is never written while resident
        if self._cache_bound and \
                len(req.prompt) + req.max_new_tokens - 1 > self.cache_len:
            raise ValueError(
                f"prompt ({len(req.prompt)}) + max_new_tokens "
                f"({req.max_new_tokens}) - 1 exceeds cache_len="
                f"{self.cache_len} on a full-attention model; raise "
                f"cache_len or shorten the request")
        self.scheduler.submit(req)

    def _admit(self) -> None:
        free = [s for s in range(self.slots) if s not in self._slot_req]
        if not free or not self.scheduler.pending:
            return
        reqs = self.scheduler.select(len(free),
                                     equal_length_only=not self._pad_ok)
        if not reqs:
            return
        n = len(reqs)
        # pad the admission batch up to a power of two (dummy rows scatter
        # out of range and drop) so compile variants are O(log slots) per
        # length bucket instead of one per free-slot count
        n_pad = 1
        while n_pad < n:
            n_pad *= 2
        n_pad = min(n_pad, self.slots)
        lens = np.ones((n_pad,), np.int32)
        lens[:n] = [len(r.prompt) for r in reqs]
        if self._pad_ok:
            L = -(-int(lens.max()) // self.prefill_bucket) \
                * self.prefill_bucket
        else:
            L = int(lens[0])
        toks = np.zeros((n_pad, L), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :lens[i]] = r.prompt[:L]
        slot_idx = np.full((n_pad,), self.slots, np.int32)   # dummy: dropped
        slot_idx[:n] = free[:n]
        keys = np.zeros((n_pad, 2), np.uint32)
        keys[:n] = np.stack([jax.random.fold_in(jax.random.PRNGKey(r.seed),
                                                r.uid) for r in reqs])

        def col(fn, fill, dtype):
            v = np.full((n_pad,), fill, dtype)
            v[:n] = [fn(r) for r in reqs]
            return jnp.asarray(v)

        greedy_only = all(r.temperature <= 0.0 for r in reqs)
        t0 = time.perf_counter()
        self.state = self._admit_jit(
            self.params, self.state, jnp.asarray(toks), jnp.asarray(lens),
            jnp.asarray(slot_idx),
            col(lambda r: r.eos_id, -1, np.int32),
            col(lambda r: r.max_new_tokens, 1, np.int32),
            col(lambda r: r.temperature, 0.0, np.float32),
            col(lambda r: r.top_k, 0, np.int32), jnp.asarray(keys),
            greedy_only)
        jax.block_until_ready(self.state["tokens"])
        self._host["prefill_s"] += time.perf_counter() - t0
        self._host["prefill_calls"] += 1
        self._host["prefill_tokens"] += n     # first token of each request
        self.scheduler.on_admitted(reqs)
        for s, r in zip(slot_idx.tolist(), reqs):
            self._slot_req[s] = r

    def _harvest(self) -> None:
        """Admission-boundary sync: pull finished slots' token buffers."""
        if not self._slot_req:
            return
        act, n_gen = jax.device_get((self.state["active"],
                                     self.state["n_gen"]))
        finished = [s for s in self._slot_req if not act[s]]
        if not finished:
            return
        rows = jax.device_get(self.state["out"][np.asarray(finished)])
        for i, s in enumerate(finished):
            req = self._slot_req.pop(s)
            req.generated = rows[i, :n_gen[s]].tolist()
            req.done = True
            self.scheduler.on_finished(req)

    # ------------------------------------------------------------------
    # Drive
    # ------------------------------------------------------------------

    def step(self) -> None:
        """One admission + one on-device decode chunk + harvest."""
        self._admit()
        if self._slot_req:
            greedy_only = all(r.temperature <= 0.0
                              for r in self._slot_req.values())
            t0 = time.perf_counter()
            self.state = self._decode_jit(self.params, self.state,
                                          greedy_only)
            self._harvest()               # device_get doubles as the sync
            self._host["decode_s"] += time.perf_counter() - t0

    def _flush_partial(self) -> None:
        """Surface still-resident slots' tokens (budget-aborted drain):
        their buffers are on device and already counted in the stats."""
        if not self._slot_req:
            return
        resident = sorted(self._slot_req)
        n_gen = jax.device_get(self.state["n_gen"])
        rows = jax.device_get(self.state["out"][np.asarray(resident)])
        for i, s in enumerate(resident):
            self._slot_req[s].generated = rows[i, :n_gen[s]].tolist()

    def run_until_drained(self, max_steps: int = 10_000) -> Dict:
        """Serve until queue + slots are empty; ``max_steps`` bounds the
        total decode-step budget (chunk-granular). If the budget aborts the
        drain, in-flight requests keep their partial ``generated`` output
        (``done`` stays False)."""
        chunks = 0
        while ((self.scheduler.pending or self._slot_req)
               and chunks * self.decode_block < max_steps):
            self.step()
            chunks += 1
        self._flush_partial()
        return self.stats()

    def stats(self) -> Dict:
        steps, committed = jax.device_get((self.state["steps"],
                                           self.state["committed"]))
        d = dict(self._host)
        prefill_tokens = d.pop("prefill_tokens")
        d["decode_steps"] = int(steps)
        d["tokens_out"] = int(committed) + prefill_tokens
        d["decode_step_s"] = (d["decode_s"] / max(int(steps), 1))
        d.update(self.scheduler.stats())
        return d
