"""Speculative decoding: low-bit draft proposals, one verify-wave, rollback.

The serve engine's decode loop samples one token per model call; this
module supplies the pieces that let a cheap *draft* model propose ``k``
tokens per resident slot and the full target model verify all residents'
drafts in ONE compiled wave (``models.spec_verify``), committing up to
``k + 1`` tokens per slot per target call:

* **Draft construction** (:func:`make_draft`) — the draft is a cheap
  variant of the *same* quantized model: a truncated-layer prefix (the
  first ``draft_layers`` layers) and/or a lower-bit deployment policy,
  sharing the embedding / final-norm / head parameters by reference (no
  extra HBM for the shared pieces; only the truncated trunk is "second
  model resident"). ``draft_layers == n_layers`` with the target policy
  is *self-draft*: the draft IS the target (acceptance ~1, the upper
  bound workload the CI gate pins).
* **Acceptance** (:func:`accept_exact`, :func:`accept_rejection`) — how
  many proposals survive against the target's logits:

  - ``exact``: position ``j`` is accepted iff the draft token equals the
    token the target itself would sample there with the plain-decode
    PRNG stream (``fold_in(slot_key, n_gen + j)``). The committed stream
    is *identical to plain decode by construction* — greedy and sampled
    — so token parity holds for ANY draft, across preemption/swap and
    rollback. This is the default.
  - ``rejection``: speculative (Leviathan-style) rejection sampling —
    accept draft token ``d`` with probability ``min(1, p(d) / q(d))``,
    sample the first rejection from the normalized residual
    ``max(p - q, 0)``. The committed-token *distribution* provably
    equals the target's (unit-tested on synthetic distributions); with
    a self-draft the coupled keys accept everything and the stream
    collapses to plain decode exactly.

* **Rollback** is the allocator's job (``BlockAllocator.trim``): the
  verify-wave writes all ``k + 1`` candidate KVs through the block
  table up front, and rejected suffixes are un-written by resetting the
  device ``length``/``position`` counters to the accepted extent and
  releasing the whole blocks past it.

All randomness is derived from the per-slot key and the generated-token
counter only (never from wave packing), so a preempted-and-resumed slot
replays the identical stream.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.serve.sampling import fold_step

# fold_in tags deriving the rejection-sampling streams from the plain-
# decode step key (the step key itself draws the target/bonus/residual
# tokens, so exact-mode and full-acceptance paths reuse it verbatim)
_COIN_TAG = 0x5BEC
_RESID_TAG = 0x5BED


@dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs (engine ``spec=`` argument).

    ``k``: draft tokens proposed per slot per wave (the wave verifies
    ``k + 1`` positions and commits 1..k+1 tokens).
    ``draft_layers``: truncated-layer draft depth; ``None`` = half the
    target's layers (min 1); equal to ``n_layers`` = self-draft.
    ``draft_policy``: deployment policy for the draft (``None`` = the
    target's policy) — e.g. a lower cache-bit variant.
    ``accept_mode``: ``"exact"`` (plain-decode-equivalent, default) or
    ``"rejection"`` (speculative rejection sampling for temperature /
    top-k requests; greedy rows always use exact matching).
    """
    k: int = 4
    draft_layers: Optional[int] = None
    draft_policy: Optional[str] = None
    accept_mode: str = "exact"

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"spec k must be >= 1, got {self.k}")
        if self.accept_mode not in ("exact", "rejection"):
            raise ValueError(f"accept_mode must be 'exact' or 'rejection', "
                             f"got {self.accept_mode!r}")

    def resolved_layers(self, cfg: ModelConfig) -> int:
        d = self.draft_layers
        if d is None:
            d = max(1, cfg.n_layers // 2)
        if not 1 <= d <= cfg.n_layers:
            raise ValueError(f"draft_layers={d} outside [1, {cfg.n_layers}]")
        return d

    def key(self) -> tuple:
        """Hashable identity for probe-cache keys and memoization."""
        return (self.k, self.draft_layers, self.draft_policy,
                self.accept_mode)


def make_draft(cfg: ModelConfig, params: Dict,
               spec: SpecConfig) -> Tuple[ModelConfig, Dict]:
    """Build the draft (config, params) from the target's.

    The draft is the target's first ``draft_layers`` layers; embedding,
    positional tables, final norm, and the (possibly tied) head are the
    *same objects* as the target's — shared HBM, updated in lockstep if
    the caller ever swaps params. Layer slicing respects the scanned
    segment layout: full-pattern repeats slice the stacked leading axis,
    a pattern remainder becomes a repeat-1 segment (mirroring
    ``models.segment_plan`` for the truncated config).
    """
    from repro.models import segment_plan
    L = spec.resolved_layers(cfg)
    if L == cfg.n_layers and spec.draft_policy is None:
        return cfg, params          # self-draft: the target verbatim
    dcfg = cfg.replace(name=f"{cfg.name}-draft{L}", n_layers=L)
    if L == cfg.n_layers:
        return dcfg, params         # same trunk, different policy
    pat = cfg.block_pattern
    n_full0 = segment_plan(cfg)[0][1]
    dfull, rem = divmod(L, len(pat))
    segs = []
    src0 = params["segments"][0]
    if dfull:
        segs.append(jax.tree.map(lambda x: x[:dfull], src0))
    if rem:
        # the partial super-block comes from the next stacked row (or the
        # target's own remainder segment when the trunk is exhausted)
        if dfull < n_full0:
            row = jax.tree.map(lambda x: x[dfull:dfull + 1], src0)
        else:
            row = jax.tree.map(lambda x: x[:1], params["segments"][1])
        segs.append({str(i): row[str(i)] for i in range(rem)})
    dparams = dict(params)          # embed / norms / head shared by ref
    dparams["segments"] = segs
    return dcfg, dparams


# --------------------------------------------------------------------------
# Acceptance
# --------------------------------------------------------------------------

def accept_exact(draft: jnp.ndarray, target: jnp.ndarray,
                 n_draft: jnp.ndarray) -> jnp.ndarray:
    """Leading-match acceptance count.

    draft (S, k): proposed tokens; target (S, k+1): the token the target
    samples at each window position with the plain-decode key stream;
    n_draft (S,): proposals actually in play this wave (rows near their
    ``max_new`` budget draft fewer). Returns n_acc (S,) in [0, n_draft]:
    the length of the leading run where ``draft[:, j] == target[:, j]``.
    """
    k = draft.shape[1]
    live = jnp.arange(k)[None] < n_draft[:, None]
    match = (draft == target[:, :-1]) & live
    return jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)


def accept_rejection(draft: jnp.ndarray, q: jnp.ndarray, p: jnp.ndarray,
                     target: jnp.ndarray, keys: jnp.ndarray,
                     n_gen: jnp.ndarray,
                     n_draft: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Speculative rejection sampling over a wave of drafts.

    draft (S, k) proposals; q (S, k, V) the draft model's sampling
    distribution at each proposal; p (S, k+1, V) the target's; target
    (S, k+1) the target's own samples under the plain-decode key stream
    (used verbatim for the bonus token, so full acceptance reproduces
    plain decode bit-exactly when q == p); keys (S, 2) slot PRNG keys;
    n_gen (S,) generated-token counters; n_draft (S,) live proposals.

    Returns (n_acc (S,), committed (S, k+1)): committed[:, j] is the
    draft token for accepted positions, the residual sample at the first
    rejection, and the target's sample beyond (only position ``n_acc``
    is ever committed there — the bonus token when all drafts survive).
    The committed-token distribution equals sampling from ``p`` directly
    (Leviathan et al. 2023), which the unit test checks empirically.
    """
    S, k = draft.shape
    ctr = (n_gen[:, None] + jnp.arange(k)[None]).reshape(S * k)
    step_keys = fold_step(jnp.repeat(keys, k, axis=0),
                          ctr).reshape(S, k, 2)
    coin_keys = jax.vmap(jax.vmap(lambda kk: jax.random.fold_in(
        kk, _COIN_TAG)))(step_keys)
    resid_keys = jax.vmap(jax.vmap(lambda kk: jax.random.fold_in(
        kk, _RESID_TAG)))(step_keys)
    p_d = jnp.take_along_axis(p[:, :k], draft[..., None], axis=-1)[..., 0]
    q_d = jnp.take_along_axis(q, draft[..., None], axis=-1)[..., 0]
    u = jax.vmap(jax.vmap(lambda kk: jax.random.uniform(kk)))(coin_keys)
    live = jnp.arange(k)[None] < n_draft[:, None]
    # strict <: uniform draws live in [0, 1), so u == 0.0 must not accept
    # a token the target assigns zero probability (outside its top-k /
    # off the greedy one-hot); u < 1 keeps the self-draft (p == q)
    # collapse accepting everything
    ok = (u * q_d < p_d) & live
    n_acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
    # residual distribution at every draft position; only the first
    # rejection's is consumed. A numerically-empty residual (q >= p
    # everywhere it matters) falls back to the target distribution.
    resid = jnp.maximum(p[:, :k] - q, 0.0)
    rsum = jnp.sum(resid, axis=-1, keepdims=True)
    resid = jnp.where(rsum > 1e-9, resid / jnp.maximum(rsum, 1e-20),
                      p[:, :k])
    rtok = jax.vmap(jax.vmap(
        lambda kk, pr: jax.random.categorical(kk, jnp.log(pr + 1e-20))))(
        resid_keys, resid).astype(jnp.int32)
    # committed stream: draft tokens below n_acc; at n_acc the residual
    # sample — but only when a draft was actually rejected there
    # (n_acc < n_draft); when every live draft survived (a full accept,
    # or a window clamped by the max_new budget) the final position is
    # the bonus: the target's own plain-decode sample
    jj = jnp.arange(k + 1)[None]
    dpad = jnp.concatenate([draft, target[:, -1:]], axis=1)
    rpad = jnp.concatenate([rtok, target[:, -1:]], axis=1)
    rejected = (jj == n_acc[:, None]) & (n_acc < n_draft)[:, None]
    committed = jnp.where(jj < n_acc[:, None], dpad,
                          jnp.where(rejected, rpad, target))
    return n_acc, committed
