"""OpenAI-style HTTP endpoint over the asyncio serving frontend.

Stdlib-only (``asyncio.start_server`` + a hand-rolled HTTP/1.1 parser —
no web framework dependency), exposing the :class:`~repro.serve.frontend.
AsyncFrontend` as four routes:

* ``POST /v1/completions`` — submit a completion. The request body is
  JSON; ``prompt`` is a **list of int token ids** (this repo serves
  models, it does not ship a tokenizer). With ``"stream": true`` the
  response is Server-Sent Events: one ``data: {...}`` chunk per drained
  token span (``decode_block`` / spec-wave granularity), a final chunk
  carrying ``finish_reason``, then ``data: [DONE]``. Without ``stream``
  the response is a single OpenAI-shaped JSON completion.
* ``GET /v1/stats`` — engine stats snapshot (the
  ``ServeEngine.stats`` key table) plus a ``metrics`` histogram digest,
  JSON.
* ``GET /v1/metrics`` — the same counters in Prometheus text exposition
  format plus TTFT/TPOT/latency histograms (``repro.obs.metrics``),
  ready for a Prometheus scrape job.
* ``GET /health`` — liveness probe, ``{"status": "ok"}``.

``finish_reason`` is ``"length"`` (hit ``max_tokens``), ``"stop"``
(early EOS), or ``"shed"`` (SLO admission control rejected the request —
the non-streaming path also sets HTTP 503 in that case, streaming has
already sent its 200 so the reason string is the signal).

See ``docs/serving_api.md`` for the full protocol, every knob and its
default, and curl / ``examples/stream_client.py`` walkthroughs.
"""
from __future__ import annotations

import asyncio
import json
from typing import Dict, Optional, Tuple

from repro.obs.metrics import CONTENT_TYPE as METRICS_CONTENT_TYPE
from repro.serve.frontend import AsyncFrontend, RequestStream

MAX_BODY_BYTES = 8 << 20        # refuse absurd request bodies (8 MiB)

# completion-request knobs: JSON key -> (submit kwarg, type, default)
_KNOBS = (
    ("max_tokens", "max_new_tokens", int, 32),
    ("temperature", "temperature", float, 0.0),
    ("top_k", "top_k", int, 0),
    ("seed", "seed", int, 0),
    ("eos_id", "eos_id", int, -1),
    ("deadline_ms", "deadline_ms", float, None),
    ("priority", "priority", int, None),
)


class HTTPError(Exception):
    """Routed straight to an error response (status + JSON message)."""

    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status
        self.message = message


def _parse_completion_body(raw: bytes) -> Tuple[list, Dict, bool]:
    """Validate a ``/v1/completions`` body -> (prompt, submit-kwargs,
    stream?). Raises :class:`HTTPError` (400) on anything malformed.

    >>> _parse_completion_body(b'{"prompt": [1, 2], "stream": true}')
    ([1, 2], {'max_new_tokens': 32, 'temperature': 0.0, 'top_k': 0, 'seed': 0, 'eos_id': -1}, True)
    >>> _parse_completion_body(b'{"prompt": "text"}')
    Traceback (most recent call last):
        ...
    repro.serve.http.HTTPError: 'prompt' must be a non-empty list of int token ids (this server has no tokenizer)
    """
    try:
        body = json.loads(raw)
    except (ValueError, UnicodeDecodeError):
        raise HTTPError(400, "request body is not valid JSON")
    if not isinstance(body, dict):
        raise HTTPError(400, "request body must be a JSON object")
    prompt = body.get("prompt")
    if (not isinstance(prompt, list) or not prompt
            or not all(isinstance(t, int) for t in prompt)):
        raise HTTPError(400, "'prompt' must be a non-empty list of int "
                             "token ids (this server has no tokenizer)")
    kwargs: Dict = {}
    for key, kwarg, typ, default in _KNOBS:
        v = body.get(key, default)
        if v is None:
            continue
        try:
            kwargs[kwarg] = typ(v)
        except (TypeError, ValueError):
            raise HTTPError(400, f"'{key}' must be a {typ.__name__}")
    stream = bool(body.get("stream", False))
    return prompt, kwargs, stream


def _finish_reason(handle: RequestStream) -> str:
    req = handle.request
    if req.shed:
        return "shed"
    if len(req.generated) < req.max_new_tokens:
        return "stop"               # early EOS ended the request
    return "length"


def _completion_json(handle: RequestStream, token_ids: list) -> Dict:
    req = handle.request
    return {
        "id": f"cmpl-{req.uid}",
        "object": "text_completion",
        "choices": [{
            "index": 0,
            "token_ids": token_ids,
            "finish_reason": _finish_reason(handle),
        }],
        "usage": {
            "prompt_tokens": int(len(req.prompt)),
            "completion_tokens": len(token_ids),
            "total_tokens": int(len(req.prompt)) + len(token_ids),
        },
    }


def _chunk_json(uid: int, token_ids: list,
                finish_reason: Optional[str]) -> Dict:
    return {
        "id": f"cmpl-{uid}",
        "object": "text_completion.chunk",
        "choices": [{
            "index": 0,
            "token_ids": token_ids,
            "finish_reason": finish_reason,
        }],
    }


class ServeHTTP:
    """The HTTP server. Owns nothing but sockets — engine stepping and
    SLO admission live in the :class:`AsyncFrontend` it wraps.

    Args:
        frontend: a **started** AsyncFrontend (the server does not
            start/stop it; ``launch/serve.py`` composes their
            lifetimes).
        host / port: bind address. Port 0 picks a free port —
            ``self.port`` reports the bound one after :meth:`start`.
    """

    def __init__(self, frontend: AsyncFrontend, host: str = "127.0.0.1",
                 port: int = 8000):
        self.frontend = frontend
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> "ServeHTTP":
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def aclose(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    async def __aenter__(self) -> "ServeHTTP":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    async def serve_forever(self) -> None:
        await self._server.serve_forever()

    # ---- connection handling ----
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            try:
                method, path, body = await self._read_request(reader)
            except HTTPError as e:
                await self._respond_json(writer, e.status,
                                         {"error": {"message": e.message}})
                return
            try:
                await self._route(method, path, body, writer)
            except HTTPError as e:
                await self._respond_json(writer, e.status,
                                         {"error": {"message": e.message}})
            except ValueError as e:
                # engine-side never-admittable rejection (prompt too long
                # for the configured cache, max_tokens over cap, ...)
                await self._respond_json(writer, 400,
                                         {"error": {"message": str(e)}})
        except (ConnectionError, asyncio.IncompleteReadError):
            pass                      # client went away mid-exchange
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    @staticmethod
    async def _read_request(reader) -> Tuple[str, str, bytes]:
        line = await reader.readline()
        if not line:
            raise HTTPError(400, "empty request")
        parts = line.decode("latin-1").split()
        if len(parts) != 3:
            raise HTTPError(400, "malformed request line")
        method, path, _version = parts
        headers = {}
        while True:
            line = await reader.readline()
            if line in (b"\r\n", b"\n", b""):
                break
            name, _, value = line.decode("latin-1").partition(":")
            headers[name.strip().lower()] = value.strip()
        length = int(headers.get("content-length", "0") or 0)
        if length > MAX_BODY_BYTES:
            raise HTTPError(413, "request body too large")
        body = await reader.readexactly(length) if length else b""
        return method, path.split("?", 1)[0], body

    async def _route(self, method: str, path: str, body: bytes,
                     writer) -> None:
        if path == "/health" and method == "GET":
            await self._respond_json(writer, 200, {"status": "ok"})
        elif path == "/v1/stats" and method == "GET":
            stats = await self.frontend.stats()
            await self._respond_json(writer, 200, stats)
        elif path == "/v1/metrics" and method == "GET":
            # the counters/gauges are a scrape-time projection of the
            # same stats() snapshot /v1/stats serves (see obs.metrics)
            stats = await self.frontend.stats()
            text = self.frontend.engine.metrics.render(stats)
            await self._respond_text(writer, 200, text, METRICS_CONTENT_TYPE)
        elif path == "/v1/completions" and method == "POST":
            prompt, kwargs, stream = _parse_completion_body(body)
            if stream:
                await self._stream_completion(writer, prompt, kwargs)
            else:
                await self._blocking_completion(writer, prompt, kwargs)
        else:
            raise HTTPError(404, f"no route for {method} {path}")

    # ---- the two completion paths ----
    async def _blocking_completion(self, writer, prompt, kwargs) -> None:
        handle = await self.frontend.submit(prompt, **kwargs)
        toks = await handle.tokens()
        status = 503 if handle.shed else 200
        await self._respond_json(writer, status,
                                 _completion_json(handle, toks))

    async def _stream_completion(self, writer, prompt, kwargs) -> None:
        handle = await self.frontend.submit(prompt, **kwargs)
        uid = handle.request.uid
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()
        # forward spans as they drain; RequestStream yields single tokens,
        # so re-batch per queue burst to keep one SSE event per harvest
        pending: list = []
        async for tok in handle:
            pending.append(tok)
            if handle._queue.empty():
                await self._send_event(writer, _chunk_json(uid, pending,
                                                           None))
                pending = []
        final = _chunk_json(uid, pending, _finish_reason(handle))
        await self._send_event(writer, final)
        writer.write(b"data: [DONE]\n\n")
        await writer.drain()

    # ---- response plumbing ----
    @staticmethod
    async def _send_event(writer, obj: Dict) -> None:
        writer.write(b"data: " + json.dumps(obj).encode() + b"\n\n")
        await writer.drain()

    @staticmethod
    async def _respond_json(writer, status: int, obj: Dict) -> None:
        await ServeHTTP._respond_text(writer, status, json.dumps(obj),
                                      "application/json")

    @staticmethod
    async def _respond_text(writer, status: int, text: str,
                            content_type: str) -> None:
        payload = text.encode()
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  413: "Payload Too Large",
                  503: "Service Unavailable"}.get(status, "Error")
        writer.write(f"HTTP/1.1 {status} {reason}\r\n"
                     f"Content-Type: {content_type}\r\n"
                     f"Content-Length: {len(payload)}\r\n"
                     f"Connection: close\r\n\r\n".encode() + payload)
        await writer.drain()
