"""Host-side block allocator for the paged quantized KV cache.

The device holds one global pool of fixed-size cache blocks per attention
layer (``(num_blocks, Hkv, block_size, D)`` int8 + per-token scales); this
allocator owns the free list and decides which pool blocks back which slot.
The engine mirrors the resulting ``(slots, table_len)`` block table on the
host and pushes it to the device at admission/chunk boundaries, so the
compiled decode program only ever *reads* the table.

Accounting is reservation-based: admission reserves a slot's worst-case
block count (``ceil((prompt + max_new - 1) / block_size)``) up front, which
guarantees a resident request can never strand mid-decode on an empty pool,
while physical blocks are still handed out lazily — only once decode (or a
prefill chunk) actually crosses a block boundary. Requests that finish
early (EOS) therefore never touch their tail blocks, and ``peak_blocks``
records true residency, not the reservation.

Entries never allocated stay at the ``num_blocks`` sentinel, which the
device-side scatters drop (``mode="drop"``) and gathers clamp.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


class BlockAllocator:
    """Free-list allocator over ``num_blocks`` cache blocks of
    ``block_size`` tokens, with per-slot reservation accounting."""

    def __init__(self, num_blocks: int, block_size: int, slots: int,
                 table_len: int):
        if num_blocks < 1 or block_size < 1:
            raise ValueError("num_blocks and block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.table_len = table_len
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._owned: Dict[int, List[int]] = {}      # slot -> block ids
        self._reserved: Dict[int, int] = {}         # slot -> blocks not yet
        self.peak_blocks = 0                        #         allocated
        # host mirror of the device block table; sentinel = num_blocks
        self.tables = np.full((slots, table_len), num_blocks, np.int32)

    # ---- accounting ----
    def blocks_for_tokens(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.block_size)

    @property
    def allocated_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def free_blocks(self) -> int:
        """Blocks neither allocated nor promised to a resident slot."""
        return len(self._free) - sum(self._reserved.values())

    # ---- lifecycle ----
    def reserve(self, slot: int, n_tokens: int) -> bool:
        """Reserve the slot's worst-case block count; False if the pool
        can't honor it right now (the request stays queued)."""
        nb = self.blocks_for_tokens(n_tokens)
        if nb > self.free_blocks or slot in self._reserved:
            return False
        self._reserved[slot] = nb
        self._owned[slot] = []
        return True

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow the slot's table to cover ``n_tokens``; returns True if any
        new block was allocated (the device table needs a push)."""
        need = self.blocks_for_tokens(n_tokens)
        owned = self._owned[slot]
        if need > self.table_len:
            raise ValueError(
                f"slot {slot} needs {need} blocks but the block table is "
                f"only {self.table_len} entries wide")
        grew = False
        while len(owned) < need:
            if self._reserved[slot] <= 0 or not self._free:
                raise RuntimeError(
                    f"slot {slot} outgrew its reservation "
                    f"({len(owned)} owned, {self._reserved[slot]} reserved, "
                    f"{len(self._free)} free) — admission accounting bug")
            self._reserved[slot] -= 1
            bid = self._free.pop()
            self.tables[slot, len(owned)] = bid
            owned.append(bid)
            grew = True
        self.peak_blocks = max(self.peak_blocks, self.allocated_blocks)
        return grew

    def release(self, slot: int) -> int:
        """Free the slot's blocks and drop its remaining reservation.
        Returns the number of blocks returned to the pool."""
        owned = self._owned.pop(slot, [])
        self._reserved.pop(slot, None)
        self._free.extend(owned)
        self.tables[slot, :] = self.num_blocks
        return len(owned)
